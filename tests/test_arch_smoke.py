"""Per-architecture smoke tests: reduced same-family configs, one forward +
one train step on CPU, asserting output shapes and no NaNs (assignment (f))."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models.registry import (
    build_model,
    decode_step,
    init_serve_state,
    prefill,
    train_loss,
)
from repro.optim import adamw_init, adamw_update, constant_lr


# multi-minute model/kernel path: runs in the full CI job only
pytestmark = pytest.mark.slow



def _batch(cfg, B=2, L=32, key=0):
    k = jax.random.key(key)
    batch = {
        "tokens": jax.random.randint(k, (B, L), 0, cfg.vocab),
        "labels": jax.random.randint(k, (B, L), 0, cfg.vocab),
    }
    if cfg.encoder_layers:
        batch["frames"] = jax.random.normal(k, (B, cfg.frontend_len, cfg.d_model))
    if cfg.frontend == "vision":
        batch["prefix"] = jax.random.normal(k, (B, cfg.frontend_len, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params, axes = model.init(jax.random.key(0))
    # axes tree mirrors params tree
    assert jax.tree.structure(params) == jax.tree.structure(
        axes, is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x)
    )
    batch = _batch(cfg)

    loss, metrics = jax.jit(lambda p, b: train_loss(model, p, b))(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"

    # one optimizer step: params change, loss stays finite
    def step(params, opt, batch):
        (l, m), g = jax.value_and_grad(
            lambda p: train_loss(model, p, batch), has_aux=True
        )(params)
        p2, opt2, _ = adamw_update(params, g, opt, constant_lr(1e-3)(opt["count"]))
        return l, p2, opt2

    l1, p2, opt2 = jax.jit(step)(params, adamw_init(params), batch)
    l2, _, _ = jax.jit(step)(p2, opt2, batch)
    assert bool(jnp.isfinite(l2)), f"{arch}: non-finite after update"
    changed = jax.tree.map(
        lambda a, b: bool(jnp.any(a != b)), params, p2
    )
    assert any(jax.tree.leaves(changed)), f"{arch}: update was a no-op"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_logits_shape(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params, _ = model.init(jax.random.key(0))
    B, L = 2, 16
    toks = jax.random.randint(jax.random.key(1), (B, L), 0, cfg.vocab)
    x = model.embed(params, toks)
    pos = jnp.broadcast_to(jnp.arange(L)[None], (B, L))
    mem = None
    if cfg.encoder_layers:
        mem = model.encode(
            params, jax.random.normal(jax.random.key(2), (B, cfg.frontend_len, cfg.d_model))
        )
    xt, aux, _ = model.trunk(params, x, pos, memory=mem)
    logits = model.logits(params, xt)
    assert logits.shape == (B, L, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_serve_roundtrip(arch):
    """prefill + a few decode steps produce finite logits of the right shape."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params, _ = model.init(jax.random.key(0))
    B = 2
    toks = jax.random.randint(jax.random.key(1), (B, 8), 0, cfg.vocab)
    frames = (
        jax.random.normal(jax.random.key(2), (B, cfg.frontend_len, cfg.d_model))
        if cfg.encoder_layers
        else None
    )
    state = init_serve_state(model, B, max_len=32)
    logits, state = prefill(model, params, toks, state, frames=frames)
    assert logits.shape == (B, cfg.vocab)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    for _ in range(3):
        logits, state = decode_step(model, params, tok, state)
        assert logits.shape == (B, cfg.vocab)
        assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)


def test_param_counts_are_sane():
    """Full-config analytic parameter counts land near the published sizes."""
    expected = {
        "jamba-v0.1-52b": (45e9, 60e9),
        "mixtral-8x7b": (42e9, 50e9),
        "phi3.5-moe-42b": (38e9, 46e9),
        "internlm2-20b": (17e9, 23e9),
        "qwen2.5-32b": (28e9, 36e9),
        "stablelm-1.6b": (1.3e9, 2.0e9),
        "minicpm3-4b": (3.3e9, 5.0e9),
        "falcon-mamba-7b": (6e9, 8.5e9),
        "internvl2-1b": (0.4e9, 1.2e9),
        "seamless-m4t-medium": (0.8e9, 1.8e9),
    }
    for arch, (lo, hi) in expected.items():
        n = get_config(arch).n_params()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B params outside [{lo/1e9}, {hi/1e9}]"
