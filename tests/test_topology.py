"""Extended-cloud topology (ISSUE 4): zones/placement/ledger model,
data-gravity co-location, hash-only cross-zone transport, ZonedExecutor
determinism against Inline/Concurrent, and the gravity-never-loses
property on reducer fan-ins."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # hermetic containers: seeded-random fallback
    from repro.testing.hypothesis_fallback import given, settings, strategies as st

from repro.topology import (
    DataGravityPlacement,
    PinPlacement,
    Topology,
    TopologyError,
    TransferLedger,
    default_topology,
    make_placement,
)
from repro.workspace import (
    AdaptiveExecutor,
    ConcurrentExecutor,
    InlineExecutor,
    WiringError,
    Workspace,
    ZonedExecutor,
)

EDGE_ZONES = ("edge-a", "edge-b", "edge-c")


# ---------------------------------------------------------------------------
# circuits
# ---------------------------------------------------------------------------


def _iot_topology():
    topo = Topology("iot")
    topo.zone("cloud", tier="cloud")
    for z in EDGE_ZONES:
        topo.zone(z, tier="edge")
        topo.link("cloud", z, bandwidth_mbps=50, latency_ms=20, energy_j_per_mb=0.05)
    return topo


def _iot_ws(placement, executor=None, sensors=2, zones=EDGE_ZONES, coalesce=None):
    """Edge fan-in: per-zone sensors -> per-zone aggregator -> cloud merge
    reducer. Sensors and the reducer are pinned; aggregators float.
    ``coalesce`` opts the aggregators and the reducer into arrival
    coalescing (TaskHandle.coalesce) with the given max batch."""
    ws = Workspace(
        "iot", topology=_iot_topology(), placement=placement,
        executor=executor, cache=False,
    )
    for z in zones:
        for i in range(sensors):
            ws.source(
                lambda i=i: {"reading": np.full(4, float(i), np.float32)},
                name=f"s_{z}_{i}", outputs=["reading"],
            ).place(z)
        agg = ws.task(
            lambda **kw: {"agg": sum(kw.values())},
            name=f"agg_{z}", inputs=[f"r{i}" for i in range(sensors)],
            outputs=["agg"],
        )
        if coalesce is not None:
            agg.coalesce(coalesce)
        for i in range(sensors):
            ws[f"s_{z}_{i}"]["reading"] >> agg[f"r{i}"]
    red = ws.task(
        lambda merged: {"total": [float(np.sum(m)) for m in merged]},
        name="reduce", inputs=[f"a_{z}" for z in zones], outputs=["total"],
        mode="merge",
    ).place("cloud")
    if coalesce is not None:
        red.coalesce(coalesce)
    for z in zones:
        ws[f"agg_{z}"]["agg"] >> red[f"a_{z}"]
    return ws


def _drive(ws, rounds=2, n=64, sensors=2, zones=EDGE_ZONES, seed=0):
    rng = np.random.RandomState(seed)
    for _ in range(rounds):
        for z in zones:
            for i in range(sensors):
                ws.push(f"s_{z}_{i}", reading=rng.randn(n).astype(np.float32))
    return ws


# ---------------------------------------------------------------------------
# topology model
# ---------------------------------------------------------------------------


class TestTopologyModel:
    def test_zones_and_default(self):
        topo = Topology("t")
        topo.zone("cloud")
        topo.zone("edge", tier="edge")
        assert topo.default_zone == "cloud"  # first declared
        assert topo.zone_names() == ["cloud", "edge"]
        assert Topology("t2", default_zone="x")._default_zone == "x"

    def test_duplicate_zone_and_bad_tier_rejected(self):
        topo = Topology("t")
        topo.zone("a")
        with pytest.raises(TopologyError):
            topo.zone("a")
        with pytest.raises(TopologyError):
            topo.zone("b", tier="orbit")

    def test_link_costs_and_tier_defaults(self):
        topo = Topology("t")
        topo.zone("cloud")
        topo.zone("edge", tier="edge")
        topo.link("cloud", "edge", bandwidth_mbps=100, energy_j_per_mb=0.05)
        # declared link, both directions (symmetric default)
        assert topo.cost("cloud", "edge").energy_j_per_mb == 0.05
        assert topo.cost("edge", "cloud").energy_j_per_mb == 0.05
        # undeclared pair falls back to tier defaults
        topo.zone("dev", tier="device")
        assert topo.cost("edge", "dev").bandwidth_mbps > 0
        # self-edge is free
        assert topo.cost("cloud", "cloud").energy_j_per_mb == 0.0
        # energy scales with bytes
        assert topo.transfer_energy_j("cloud", "edge", 2_000_000) == pytest.approx(0.1)

    def test_three_zone_canned(self):
        topo = Topology.three_zone()
        assert topo.zone_names() == ["cloud", "edge", "device"]
        assert topo.default_zone == "cloud"
        assert topo.tier_of("device") == "device"

    def test_default_topology_env(self, monkeypatch):
        monkeypatch.delenv("KOALJA_TOPOLOGY", raising=False)
        assert default_topology() is None
        monkeypatch.setenv("KOALJA_TOPOLOGY", "flat")
        assert default_topology() is None
        monkeypatch.setenv("KOALJA_TOPOLOGY", "3zone")
        assert default_topology().zone_names() == ["cloud", "edge", "device"]
        monkeypatch.setenv("KOALJA_TOPOLOGY", "klingon")
        with pytest.raises(ValueError):
            default_topology()


class TestLedger:
    def test_charge_once_per_zone_then_dedup(self):
        topo = Topology.three_zone()
        led = TransferLedger(topo)
        led.register_resident("h1", "edge")
        assert led.on_materialize("h1", 1000, "edge", "cloud") is True
        # second consumer in cloud: already resident there -> ghost credit
        assert led.on_materialize("h1", 1000, "edge", "cloud") is False
        assert led.bytes_moved_crosszone == 1000
        assert led.bytes_not_moved_crosszone == 1000
        assert led.stats()["by_pair"] == {"edge->cloud": 1000}

    def test_same_zone_is_free_handover(self):
        led = TransferLedger(Topology.three_zone())
        assert led.on_materialize("h1", 1000, "edge", "edge") is False
        assert led.local_handovers == 1
        assert led.bytes_moved_crosszone == 0

    def test_energy_priced_from_pair_totals(self):
        topo = Topology("t")
        topo.zone("cloud")
        topo.zone("edge", tier="edge")
        topo.link("cloud", "edge", energy_j_per_mb=0.05)
        led = TransferLedger(topo)
        led.on_materialize("h1", 1_000_000, "edge", "cloud")
        led.on_materialize("h2", 1_000_000, "edge", "cloud")
        assert led.transfer_energy_j == pytest.approx(0.1)


class TestPlacementPolicies:
    def test_make_placement_resolution(self):
        topo = Topology.three_zone()
        assert isinstance(make_placement("pin", topo), PinPlacement)
        assert isinstance(make_placement("data_gravity", topo), DataGravityPlacement)
        assert isinstance(make_placement(None, topo), DataGravityPlacement)
        pol = PinPlacement(topo)
        assert make_placement(pol, topo) is pol
        with pytest.raises(TopologyError):
            make_placement("teleport", topo)

    def test_policy_bound_to_foreign_topology_rejected(self):
        """A policy built against another topology would place tasks into
        zones this one never declared — fail at construction, not at the
        first stats() read."""
        mine, theirs = _iot_topology(), Topology.three_zone()
        with pytest.raises(TopologyError, match="bound to topology"):
            make_placement(PinPlacement(theirs), mine)
        ws = Workspace("w", topology=mine, placement=PinPlacement(theirs))
        ws.task(lambda x: {"y": x}, name="t", inputs=["x"], outputs=["y"])
        with pytest.raises(TopologyError):
            ws.push("t", x=1)

    def test_place_requires_topology_and_known_zone(self):
        ws = Workspace("flat", topology=False)
        t = ws.task(lambda x: {"y": x}, name="t", inputs=["x"], outputs=["y"])
        with pytest.raises(WiringError):
            t.place("cloud")
        ws2 = Workspace("topo", topology=Topology.three_zone())
        t2 = ws2.task(lambda x: {"y": x}, name="t", inputs=["x"], outputs=["y"])
        with pytest.raises(WiringError):
            t2.place("mars")
        assert t2.place("edge").zone == "edge"


# ---------------------------------------------------------------------------
# placement through the stack
# ---------------------------------------------------------------------------


class TestPinPlacement:
    def test_unpinned_tasks_run_in_default_zone(self):
        ws = _drive(_iot_ws("pin"))
        zones = ws.stats()["topology"]["zones"]
        # aggregators float -> default (cloud); sensors stay pinned at edge
        assert set(zones["cloud"]["tasks"]) >= {f"agg_{z}" for z in EDGE_ZONES}
        for z in EDGE_ZONES:
            assert f"s_{z}_0" in zones[z]["tasks"]

    def test_all_to_cloud_moves_raw_bytes(self):
        ws = _drive(_iot_ws("pin"), rounds=2, n=64, sensors=2)
        led = ws.stats()["topology"]["ledger"]
        # every raw reading crosses edge->cloud: 3 zones x 2 sensors x 2
        # rounds x 256B; aggregates are born in cloud and never cross
        assert led["bytes_moved_crosszone"] == 3 * 2 * 2 * 64 * 4
        assert all(pair.endswith("->cloud") for pair in led["by_pair"])
        assert led["transfer_energy_j"] > 0


class TestDataGravityPlacement:
    def test_aggregators_follow_their_bytes(self):
        ws = _drive(_iot_ws("data_gravity"))
        zones = ws.stats()["topology"]["zones"]
        for z in EDGE_ZONES:
            assert f"agg_{z}" in zones[z]["tasks"]
            assert zones[z]["executions"] >= 2  # sensors + aggregator ran there
        # the pinned reducer stays in cloud regardless of gravity
        assert "reduce" in zones["cloud"]["tasks"]

    def test_gravity_moves_only_aggregates(self):
        ws = _drive(_iot_ws("data_gravity"), rounds=2, n=64, sensors=2)
        led = ws.stats()["topology"]["ledger"]
        # only the 3 per-zone aggregates cross per round (256B each)
        assert led["bytes_moved_crosszone"] == 3 * 2 * 64 * 4
        assert led["bytes_moved_crosszone"] * 2 == 3 * 2 * 2 * 64 * 4

    def test_gravity_vs_pin_byte_reduction(self):
        pin = _drive(_iot_ws("pin")).stats()["topology"]["ledger"]
        grav = _drive(_iot_ws("data_gravity")).stats()["topology"]["ledger"]
        assert grav["bytes_moved_crosszone"] * 2 == pin["bytes_moved_crosszone"]
        assert grav["transfer_energy_j"] < pin["transfer_energy_j"]

    def test_pinned_tasks_resist_gravity(self):
        topo = Topology.three_zone()
        ws = Workspace("pins", topology=topo, placement="data_gravity", cache=False)
        src = ws.source(lambda: None, name="src", outputs=["x"]).place("edge")
        sink = ws.task(lambda x: {"y": float(np.sum(x))}, name="sink",
                       inputs=["x"], outputs=["y"]).place("cloud")
        src["x"] >> sink["x"]
        ws.push("src", x=np.ones(32, np.float32))
        zones = ws.stats()["topology"]["zones"]
        assert "sink" in zones["cloud"]["tasks"]  # pinned beats gravity
        assert ws.stats()["topology"]["ledger"]["bytes_moved_crosszone"] == 128

    def test_byte_shares_dedupe_by_uid(self):
        """An AV pending in more than one buffer of the same task (a window
        consumer holds values in both ``fresh`` and ``window``; a dual-wired
        output lands the same AV in two input buffers) exerts gravity once:
        shares weigh payload bytes resident in a zone, not reference count."""
        from types import SimpleNamespace as NS

        av1 = NS(uid="u1", meta={"zone": "edge", "nbytes": 256})
        av2 = NS(uid="u2", meta={"zone": "edge", "nbytes": 256})
        av3 = NS(uid="u3", meta={"zone": "cloud", "nbytes": 100})
        task = NS(policy=NS(buffers={
            "a": NS(fresh=[av1, av2], window=[av1]),  # av1 in both deques
            "b": NS(fresh=[av2], window=[av3]),  # av2 also wired to input b
        }))
        shares = DataGravityPlacement._byte_shares(task)
        assert shares == {"edge": 512, "cloud": 100}

    def test_byte_shares_pinned_for_window_consumer(self):
        """Regression: the pending byte shares of an ``input[N/k]`` consumer
        are exactly one count per resident AV — 4 window slots + 1 fresh
        arrival x 256B, never double-counted across the two deques."""
        topo = Topology.three_zone()
        ws = Workspace("w", topology=topo, placement="data_gravity", cache=False)
        src = ws.source(lambda x: {"x": x}, name="src", outputs=["x"]).place("edge")
        win = ws.task(lambda x: {"y": float(np.sum(x[-1]))}, name="win",
                      inputs=["x[4/2]"], outputs=["y"])
        src["x"] >> win["x"]
        for i in range(5):
            ws.push("src", x=np.full(64, float(i), np.float32))  # 256 B each
        task = ws.pipeline.tasks["win"]
        buf = task.policy.buffers["x"]
        assert (len(buf.window), len(buf.fresh)) == (4, 1)
        shares = DataGravityPlacement._byte_shares(task)
        assert shares == {"edge": 5 * 256}

    def test_crosszone_refs_counted_on_links(self):
        ws = _drive(_iot_ws("pin"))
        stats = ws.stats()
        # sensor->aggregator links cross edge->cloud carrying refs only
        assert stats["topology"]["crosszone_refs"] > 0
        link = stats["links"]["s_edge-a_0.reading->agg_edge-a.r0"]
        assert link["crosszone_refs"] > 0

    def test_crosszone_refs_judged_after_placement(self):
        """An aggregator that gravity co-locates with its sensors consumes
        in the same zone the AVs were born in: no ref crossing, even though
        its pre-placement zone was the cloud default."""
        ws = _drive(_iot_ws("data_gravity"))
        stats = ws.stats()
        for z in EDGE_ZONES:
            link = stats["links"][f"s_{z}_0.reading->agg_{z}.r0"]
            assert link["crosszone_refs"] == 0
        # while the aggregate->reducer links really do cross edge->cloud
        link = stats["links"]["agg_edge-a.agg->reduce.a_edge-a"]
        assert link["crosszone_refs"] > 0

    def test_memo_hit_replays_birth_zone(self):
        """A memo hit replays references to payloads resident where the
        original run executed — the minted AVs must carry that birth zone,
        not the replaying task's zone, or the ledger underbills."""
        from repro.cache import MemoCache
        from repro.core.store import ArtifactStore

        topo_a, topo_b = _iot_topology(), _iot_topology()
        store, cache = ArtifactStore(), MemoCache()

        def build(topo, pin_zone):
            ws = Workspace("memo-zone", topology=topo, placement="pin",
                           store=store, cache=cache)
            src = ws.source(lambda: None, name="src", outputs=["x"]).place(pin_zone)
            t = ws.task(lambda x: {"y": x * 2}, name="t",
                        inputs=["x"], outputs=["y"]).place(pin_zone)
            src["x"] >> t["x"]
            return ws

        x = np.ones(32, np.float32)
        ws_edge = build(topo_a, "edge-a")
        ws_edge.push("src", x=x)  # cold: executes in edge-a
        ws_cloud = build(topo_b, "cloud")
        ws_cloud.push("src", x=x)  # hit: replays in cloud
        t_cloud = ws_cloud.pipeline.tasks["t"]
        assert t_cloud.cache_hits == 1
        assert t_cloud.last_outputs["y"].zone == "edge-a"  # birth, not replay

    def test_ledger_dedup_on_identical_content(self):
        """Two consumers in one zone materializing the same content: bytes
        cross once; the second transfer is a hash-only ghost credit."""
        topo = Topology.three_zone()
        ws = Workspace("dedup", topology=topo, placement="pin", cache=False)
        src = ws.source(lambda: None, name="src", outputs=["x"]).place("edge")
        for i in range(2):
            t = ws.task(lambda x: {"y": float(np.sum(x))}, name=f"c{i}",
                        inputs=["x"], outputs=["y"]).place("cloud")
            src["x"] >> t["x"]
        ws.push("src", x=np.ones(64, np.float32))
        led = ws.stats()["topology"]["ledger"]
        assert led["bytes_moved_crosszone"] == 256
        assert led["bytes_not_moved_crosszone"] == 256


class TestEnergyAwarePlacement:
    """ISSUE 10: the ``energy`` policy minimizes transfer + compute joules
    as a pure function of (topology, pending bytes, coefficients)."""

    def _wan_topology(self):
        """Cheap radio hop to the edge, metered WAN to the cloud, compute
        priced by tier defaults (cloud 0.02 < edge 0.05 < device 0.12)."""
        t = Topology("wan")
        t.zone("cloud", tier="cloud")
        t.zone("edge", tier="edge")
        t.zone("device", tier="device")
        t.link("device", "edge", latency_ms=1, bandwidth_mbps=1000,
               energy_j_per_mb=0.01)
        t.link("edge", "cloud", latency_ms=20, bandwidth_mbps=100,
               energy_j_per_mb=0.05)
        t.link("device", "cloud", latency_ms=50, bandwidth_mbps=10,
               energy_j_per_mb=0.5)
        return t

    def test_registered_and_env_valid(self):
        from repro.topology import EnergyAwarePlacement

        topo = self._wan_topology()
        pol = make_placement("energy", topo)
        assert isinstance(pol, EnergyAwarePlacement)
        assert isinstance(pol, DataGravityPlacement)  # shares _byte_shares

    def test_minimizes_transfer_plus_compute(self):
        """Device-born bytes: gravity would keep the consumer on the
        battery-powered device (0.12 J/MB compute); energy pays the cheap
        radio hop (0.01) to the edge's 0.05 compute instead."""
        from types import SimpleNamespace as NS

        topo = self._wan_topology()
        pol = make_placement("energy", topo)
        av = NS(uid="u1", meta={"zone": "device", "nbytes": 1_000_000})
        task = NS(pinned_zone=None, zone=None,
                  policy=NS(buffers={"x": NS(fresh=[av], window=[])}))
        assert pol.zone_for(task, None) == "edge"
        # gravity on the same pending bytes stays at the device
        assert make_placement("data_gravity", topo).zone_for(task, None) == "device"

    def test_pin_and_empty_buffers_respected(self):
        from types import SimpleNamespace as NS

        topo = self._wan_topology()
        pol = make_placement("energy", topo)
        pinned = NS(pinned_zone="device", zone=None, policy=NS(buffers={}))
        assert pol.zone_for(pinned, None) == "device"
        idle = NS(pinned_zone=None, zone=None, policy=NS(buffers={}))
        assert pol.zone_for(idle, None) == "cloud"  # default zone

    def test_through_the_stack_lands_on_edge(self):
        ws = Workspace("energy", topology=self._wan_topology(),
                       placement="energy", cache=False)
        src = ws.source(lambda x: {"x": x}, name="src",
                        outputs=["x"]).place("device")
        t = ws.task(lambda x: {"y": float(np.sum(x))}, name="analyze",
                    inputs=["x"], outputs=["y"])
        src["x"] >> t["x"]
        ws.push("src", x=np.ones(65536, np.float32))
        zones = ws.stats()["topology"]["zones"]
        assert "analyze" in zones["edge"]["tasks"]
        led = ws.stats()["topology"]["ledger"]
        assert led["compute_energy_j"] > 0
        assert led["total_energy_j"] == pytest.approx(
            led["transfer_energy_j"] + led["compute_energy_j"]
        )


# ---------------------------------------------------------------------------
# determinism across executors (the ISSUE 4 contract)
# ---------------------------------------------------------------------------


def _fingerprint(ws):
    """Everything that must be identical across executor backends."""
    stats = ws.stats()
    merge_order = ws.value_of(ws.pipeline.tasks["reduce"].last_outputs["total"])
    events = sorted(
        (t, e["event"]) for t in ws.tasks() for e in ws.visitor_log(t)
    )
    return {
        "merge_order": merge_order,
        "events": events,
        "ledger": stats["topology"]["ledger"],
        "placement_by_zone": stats["topology"]["placement"]["by_zone"],
        "zone_executions": {
            z: v["executions"] for z, v in stats["topology"]["zones"].items()
        },
        "sustainability": stats["sustainability"],
    }


class TestExecutorDeterminism:
    @pytest.mark.parametrize("placement", ["pin", "data_gravity", "energy"])
    def test_identical_across_backends(self, placement):
        from repro.runtime import ProcessExecutor, ZonedProcessExecutor

        backends = [
            InlineExecutor(),
            ConcurrentExecutor(max_workers=4),
            ZonedExecutor(),
            ZonedExecutor(inner=ConcurrentExecutor(max_workers=4)),
            ProcessExecutor(max_workers=4),
            ZonedProcessExecutor(max_workers=4),
            AdaptiveExecutor(min_workers=1, max_workers=4),
            ZonedExecutor(inner=AdaptiveExecutor(min_workers=1, max_workers=4)),
        ]
        prints = []
        for ex in backends:
            prints.append(
                _fingerprint(_drive(_iot_ws(placement, executor=ex), rounds=2))
            )
            if hasattr(ex, "shutdown"):
                ex.shutdown()
        for other in prints[1:]:
            assert other == prints[0]

    @pytest.mark.parametrize("placement", ["pin", "data_gravity", "energy"])
    def test_identical_across_backends_with_coalescing(self, placement):
        """Arrival coalescing (PR 8) regroups firings inside one execute
        call; merge-FCFS order, visitor events, ledger bytes, and zone
        executions must stay bit-identical to the uncoalesced schedule on
        every backend."""
        from repro.runtime import ProcessExecutor, ZonedProcessExecutor

        baseline = _fingerprint(_drive(_iot_ws(placement), rounds=2))
        backends = [
            InlineExecutor(),
            ConcurrentExecutor(max_workers=4),
            ZonedExecutor(),
            ZonedExecutor(inner=ConcurrentExecutor(max_workers=4)),
            ProcessExecutor(max_workers=4),
            ZonedProcessExecutor(max_workers=4),
            AdaptiveExecutor(min_workers=1, max_workers=4),
            ZonedExecutor(inner=AdaptiveExecutor(min_workers=1, max_workers=4)),
        ]
        for ex in backends:
            ws = _drive(_iot_ws(placement, executor=ex, coalesce=4), rounds=2)
            print_ = _fingerprint(ws)
            if hasattr(ex, "shutdown"):
                ex.shutdown()
            assert print_ == baseline

    def test_zoned_executor_partitions_by_zone(self):
        ex = ZonedExecutor(inner=ConcurrentExecutor(max_workers=4))
        ws = _drive(_iot_ws("data_gravity", executor=ex))
        topo_stats = ws.stats()["topology"]
        assert set(topo_stats["executor_zones"]) >= set(EDGE_ZONES)
        for z in EDGE_ZONES:
            assert topo_stats["executor_zones"][z]["tasks"] > 0
        assert ex.stats()["inner"]["backend"] == "ConcurrentExecutor"

    def test_zoned_executor_flat_circuit_passthrough(self):
        ws = Workspace("flat", topology=False, executor=ZonedExecutor(), cache=False)
        a = ws.task(lambda x: {"y": x + 1}, name="a", inputs=["x"], outputs=["y"])
        b = ws.task(lambda x: {"y": x + 1}, name="b", inputs=["x"], outputs=["y"])
        a["y"] >> b["x"]
        ws.push("a", x=1)
        assert ws.value_of(ws.pipeline.tasks["b"].last_outputs["y"]) == 3
        assert ws.stats()["topology"] is None

    def test_pull_mode_places_too(self):
        ws = _iot_ws("data_gravity")
        _drive(ws, rounds=1)
        out = ws.pull("reduce")
        assert "total" in out
        zones = ws.stats()["topology"]["zones"]
        assert "reduce" in zones["cloud"]["tasks"]


class TestStatsSurface:
    def test_topology_block_shape(self):
        ws = _drive(_iot_ws("data_gravity"))
        block = ws.stats()["topology"]
        assert block["name"] == "iot"
        assert block["default_zone"] == "cloud"
        assert block["placement"]["policy"] == "data_gravity"
        assert set(block["zones"]) == {"cloud", *EDGE_ZONES}
        for key in ("bytes_moved_crosszone", "transfer_energy_j", "by_pair"):
            assert key in block["ledger"]

    def test_flat_workspace_has_none_block(self):
        ws = Workspace("flat", topology=False, cache=False)
        ws.task(lambda x: {"y": x}, name="t", inputs=["x"], outputs=["y"])
        ws.push("t", x=1)
        assert ws.stats()["topology"] is None

    def test_duplicate_input_wire_rejected(self):
        """Fan-in must use distinct inputs: a second wire into an occupied
        input would shadow the first link and starve the sweep forever."""
        ws = Workspace("dup", topology=False)
        a = ws.task(lambda x: {"y": x}, name="a", inputs=["x"], outputs=["y"])
        b = ws.task(lambda x: {"y": x}, name="b", inputs=["x"], outputs=["y"])
        c = ws.task(lambda x: {"y": x}, name="c", inputs=["x"], outputs=["y"])
        a["y"] >> c["x"]
        b["y"] >> c["x"]
        with pytest.raises(ValueError, match="already wired"):
            ws.push("a", x=1)


# ---------------------------------------------------------------------------
# property: gravity never loses to all-to-cloud on reducer fan-ins
# ---------------------------------------------------------------------------


@settings(max_examples=12, deadline=None)
@given(
    sensors=st.integers(1, 4),
    rounds=st.integers(1, 3),
    n=st.integers(8, 96),
    n_zones=st.integers(1, 3),
)
def test_data_gravity_never_moves_more_bytes(sensors, rounds, n, n_zones):
    """On reducer fan-ins (outputs no larger than any input — the IoT
    regime B10 models), co-locating with the majority share can only cut
    cross-zone bytes: gravity <= all-to-cloud, with identical results."""
    zones = EDGE_ZONES[:n_zones]
    pin = _drive(
        _iot_ws("pin", sensors=sensors, zones=zones),
        rounds=rounds, n=n, sensors=sensors, zones=zones, seed=n,
    )
    grav = _drive(
        _iot_ws("data_gravity", sensors=sensors, zones=zones),
        rounds=rounds, n=n, sensors=sensors, zones=zones, seed=n,
    )
    pin_led = pin.stats()["topology"]["ledger"]
    grav_led = grav.stats()["topology"]["ledger"]
    assert grav_led["bytes_moved_crosszone"] <= pin_led["bytes_moved_crosszone"]
    assert grav_led["transfer_energy_j"] <= pin_led["transfer_energy_j"] + 1e-12
    # placement changes where work runs, never what it computes
    assert pin.value_of(
        pin.pipeline.tasks["reduce"].last_outputs["total"]
    ) == grav.value_of(grav.pipeline.tasks["reduce"].last_outputs["total"])
