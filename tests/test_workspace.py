"""Workspace facade: typed ports, operator wiring, buffers, trigger modes,
watchers, ghost runs, executor backends, and the core deprecation shims."""

import numpy as np
import pytest

from repro.workspace import (
    InlineExecutor,
    MeshExecutor,
    Workspace,
    WorkspaceFrozenError,
    WiringError,
)


def _simple_ws():
    ws = Workspace("t")
    double = ws.task(lambda x: {"y": x * 2}, name="double", inputs=["x"], outputs=["y"])
    double2 = ws.task(lambda y: {"z": y + 1}, name="double2", inputs=["y"], outputs=["z"])
    add = ws.task(
        lambda y, z: {"w": y + z}, name="add", inputs=["y", "z"], outputs=["w"],
        mode="swap_new_for_old",
    )
    double["y"] >> double2["y"]
    double["y"] >> add["y"]
    double2["z"] >> add["z"]
    return ws, double, double2, add


# ---------------------------------------------------------------------------
# typed handles
# ---------------------------------------------------------------------------


def test_unknown_port_fails_at_access_time():
    ws, double, *_ = _simple_ws()
    with pytest.raises(KeyError, match="no port 'nope'"):
        double["nope"]


def test_wire_direction_enforced():
    ws, double, double2, _ = _simple_ws()
    with pytest.raises(WiringError, match="must start at an output"):
        double["x"] >> double2["y"]
    with pytest.raises(WiringError, match="no input 'z'"):
        double2["z"] >> double  # name-matched wiring: double has no input 'z'


def test_duplicate_task_rejected():
    ws = Workspace()
    ws.task(lambda: {"out": 1}, name="a")
    with pytest.raises(WiringError, match="duplicate task 'a'"):
        ws.task(lambda: {"out": 2}, name="a")


def test_name_matched_task_wiring():
    ws = Workspace()
    a = ws.source(lambda: {"doc": 1}, name="a", outputs=["doc"])
    b = ws.task(lambda doc: {"out": doc}, name="b", inputs=["doc"], outputs=["out"])
    a >> b  # single output matches same-named input
    ws.sample(a)
    assert ws.pull(b)["out"] == 1


# ---------------------------------------------------------------------------
# trigger modes on one engine
# ---------------------------------------------------------------------------


def test_push_and_pull_share_engine():
    ws, double, double2, add = _simple_ws()
    run = ws.push(double, x=21)
    assert "add" in run
    assert run["add"]["w"] == 42 + 43
    # pulling again with no new input resolves without re-execution
    execs = ws.pipeline.tasks["double2"].executions
    out = ws.pull(add)
    assert ws.pipeline.tasks["double2"].executions == execs
    assert out["w"] == 42 + 43


def test_push_output_name_emits_as_sensor():
    ws = Workspace()
    cam = ws.source(lambda: {"image": np.zeros(2)}, name="camera", outputs=["image"])
    det = ws.task(
        lambda frame: {"s": float(np.sum(frame))}, name="det", inputs=["frame"],
        outputs=["s"],
    )
    cam["image"] >> det["frame"]
    run = ws.push(cam, image=np.arange(4.0))
    assert run["det"]["s"] == 6.0
    # the emitted AV is attributed to the camera in the provenance story
    lin = ws.lineage(run["det"].av("s"))
    assert lin["parents"][0]["source_task"] == "camera"


def test_push_unknown_payload_name_raises():
    ws, double, *_ = _simple_ws()
    with pytest.raises(KeyError, match="no input or output named 'bogus'"):
        ws.push(double, bogus=1)


def test_push_output_name_on_non_source_rejected():
    """Provenance integrity: only sensors may emit external payloads as
    their own outputs — otherwise forged artifacts would carry
    authentic-looking travel documents."""
    ws, double, *_ = _simple_ws()
    with pytest.raises(ValueError, match="non-source task 'double'"):
        ws.push(double, y=123)


def test_buffer_window_snapshots():
    ws = Workspace()
    s = ws.source(lambda: {"x": 0}, name="s", outputs=["x"])
    agg = ws.task(
        lambda x: {"n": len(x), "vals": list(x)}, name="agg", inputs=["x"],
        outputs=["n", "vals"],
    )
    agg["x"].buffer(4, slide=2)
    s["x"] >> agg["x"]
    seen = []
    ws.watch(agg, lambda r: seen.append(r["vals"]))
    for i in range(8):
        ws.push(s, x=i)
    # windows: [0..3], [2..5], [4..7]
    assert seen == [[0, 1, 2, 3], [2, 3, 4, 5], [4, 5, 6, 7]]


def test_task_buffer_requires_single_input():
    ws, *_ , add = _simple_ws()
    with pytest.raises(WiringError, match="2 inputs"):
        add.buffer(3)


def test_frozen_after_first_run():
    ws, double, *_ = _simple_ws()
    ws.push(double, x=1)
    with pytest.raises(WorkspaceFrozenError):
        ws.task(lambda: {"out": 1}, name="late")
    with pytest.raises(WorkspaceFrozenError):
        double["x"].buffer(3)


def test_watch_callback_and_events():
    ws, double, *_ = _simple_ws()
    w = ws.watch("add")
    ws.push(double, x=1)
    ws.push(double, x=2)
    assert len(w.events) == 2
    assert w.latest()["w"] == (2 * 2) + (2 * 2 + 1)
    w.cancel()
    ws.push(double, x=3)
    assert len(w.events) == 2


def test_ghost_run_routes_without_data():
    import jax
    import jax.numpy as jnp

    ws = Workspace("g")
    f = ws.task(lambda x: {"y": jnp.asarray(x) * 2.0}, name="f", inputs=["x"], outputs=["y"])
    g = ws.task(lambda y: {"z": y + 1}, name="g", inputs=["y"], outputs=["z"])
    f["y"] >> g["y"]
    report = ws.ghost({f["x"]: jax.ShapeDtypeStruct((4, 4), jnp.float32)})
    assert report["tasks"]["f"]["executions"] == 1
    assert report["routes"]["f.y->g.y"]["carried"] == 1


def test_validate_reports_unwired_inputs():
    ws = Workspace()
    ws.task(lambda a, b: {"out": a + b}, name="t", inputs=["a", "b"], outputs=["out"])
    problems = ws.validate()
    assert sorted(problems) == ["t.a unwired", "t.b unwired"]


def test_validate_does_not_freeze_breadboard():
    ws = Workspace()
    t = ws.task(lambda a: {"out": a}, name="t", inputs=["a"], outputs=["out"])
    assert ws.validate() == ["t.a unwired"]
    # the reported problem can still be fixed after validating
    s = ws.source(lambda: {"a": 1}, name="s", outputs=["a"])
    s["a"] >> t["a"]
    assert ws.validate() == []
    ws.sample(s)
    assert ws.pull(t)["out"] == 1


def test_from_wiring_buffer_edit_reaches_engine():
    impls = {"a": lambda **kw: {"x": kw["in"]}, "b": lambda x: {"y": sum(x)}}
    ws = Workspace.from_wiring("(in) a (x)\n(x) b (y)", impls)
    ws["b"]["x"].buffer(3)
    for i in range(6):
        ws.push("a", **{"in": i})
    b = ws.pipeline.tasks["b"]
    assert b.executions == 2  # fires per 3 fresh values, not per value
    assert str(b.input_specs[0]) == "x[3]"
    assert ws.pull("b")["y"] == 3 + 4 + 5


def test_pull_notifies_watchers():
    ws = Workspace()
    src = ws.source(lambda: {"x": 7}, name="src", outputs=["x"])
    f = ws.task(lambda x: {"y": x * 2}, name="f", inputs=["x"], outputs=["y"])
    src["x"] >> f["x"]
    w = ws.watch(f)
    ws.pull(f)  # make-mode firing is an event too
    assert len(w.events) == 1
    assert w.latest()["y"] == 14


# ---------------------------------------------------------------------------
# executor backends
# ---------------------------------------------------------------------------


def test_mesh_executor_runs_circuit_and_builds_steps():
    import jax

    from repro.configs import get_config
    from repro.launch.mesh import make_host_mesh
    from repro.models.registry import build_model
    from repro.optim import adamw_init, constant_lr

    cfg = get_config("stablelm-1.6b").reduced()
    ex = MeshExecutor(make_host_mesh(), cfg=cfg, mode="train", global_batch=2)
    assert ex.rules["embed"] == "data" or ex.rules["embed"] is None

    # circuit runs under the mesh context
    ws = Workspace("m", executor=ex)
    t = ws.task(lambda x: {"y": x + 1}, name="t", inputs=["x"], outputs=["y"])
    assert ws.push(t, x=1)["t"]["y"] == 2

    # dist-layer step builder is routed through the executor
    model = build_model(cfg)
    jitted, state_shapes, state_shard, _ = ex.train_step(model, constant_lr(1e-3))
    params, _ = model.init(jax.random.key(0))
    state = {
        "params": params,
        "opt": adamw_init(params),
        "step": jax.numpy.zeros((), jax.numpy.int32),
    }
    toks = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab)
    state, metrics = jitted(state, {"tokens": toks, "labels": toks})
    assert int(state["step"]) == 1
    assert float(metrics["loss"]) > 0


def test_executor_protocol_shape():
    from repro.workspace import Executor

    assert isinstance(InlineExecutor(), Executor)


# ---------------------------------------------------------------------------
# deprecation shims: old call forms warn, engine still works
# ---------------------------------------------------------------------------


def test_old_pipeline_surface_warns():
    from repro.core import Pipeline, PipelineManager, SmartTask

    pipe = Pipeline("old")
    with pytest.warns(DeprecationWarning, match="Workspace.task"):
        pipe.add_task(SmartTask("f", lambda x: {"y": x}, ["x"], ["y"]))
    with pytest.warns(DeprecationWarning, match="Workspace"):
        pipe.add_task(SmartTask("g", lambda y: {"z": y}, ["y"], ["z"]))
        pipe.connect("f", "y", "g", "y")
    mgr = PipelineManager(pipe)
    with pytest.warns(DeprecationWarning, match="Workspace.push"):
        fired = mgr.push("f", x=5)
    assert "g" in fired
    with pytest.warns(DeprecationWarning, match="Workspace.pull"):
        out = mgr.pull("g")
    assert mgr.value_of(out["z"]) == 5
