"""Hypothesis property tests for system invariants."""

import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # hermetic containers: seeded-random fallback
    from repro.testing.hypothesis_fallback import given, settings, strategies as st

from repro.core import ContentCache, InputSpec, SnapshotPolicy, snapshot_key
from repro.optim import dequantize_int8, quantize_int8


@settings(max_examples=60, deadline=None)
@given(
    n=st.integers(1, 16),
    k=st.integers(1, 16),
    n_arrivals=st.integers(0, 80),
)
def test_sliding_window_invariants(n, k, n_arrivals):
    """Every window snapshot has exactly N values; consecutive snapshots
    overlap in exactly N-k positions; values appear in arrival order."""
    k = min(k, n)
    p = SnapshotPolicy([InputSpec("x", n, k)], mode="all_new")
    snaps = []
    for v in range(n_arrivals):
        p.arrive("x", v)
        while p.ready():
            snaps.append(p.snapshot()["x"])
    for s in snaps:
        assert len(s) == n
        assert s == sorted(s)  # arrival order preserved
    for a, b in zip(snaps, snaps[1:]):
        assert b[: n - k] == a[k:]  # slide by exactly k


@settings(max_examples=60, deadline=None)
@given(
    bufs=st.lists(st.integers(1, 5), min_size=1, max_size=4),
    arrivals=st.lists(st.tuples(st.integers(0, 3), st.integers(0, 99)), max_size=60),
)
def test_all_new_never_reuses(bufs, arrivals):
    """all_new: every arrived value is consumed at most once."""
    names = [f"i{j}" for j in range(len(bufs))]
    p = SnapshotPolicy(
        [InputSpec(nm, b) for nm, b in zip(names, bufs)], mode="all_new"
    )
    consumed = []
    for idx, val in arrivals:
        p.arrive(names[idx % len(names)], (idx % len(names), val))
        while p.ready():
            snap = p.snapshot()
            for nm, v in snap.items():
                consumed.extend(v if isinstance(v, list) else [v])
    assert len(consumed) == len(set(id(c) for c in consumed)) or len(consumed) == len(
        consumed
    )  # structural: no duplicates beyond equal payloads
    # stronger check: count per input never exceeds arrivals per input
    from collections import Counter

    arrived = Counter(idx % len(names) for idx, _ in arrivals)
    used = Counter(c[0] for c in consumed)
    for j, cnt in used.items():
        assert cnt <= arrived[j]


@settings(max_examples=50, deadline=None)
@given(
    ver=st.text(alphabet="abcdef0123456789", min_size=1, max_size=8),
    hashes=st.dictionaries(
        st.text(alphabet="xyz", min_size=1, max_size=3),
        st.text(alphabet="0123456789abcdef", min_size=4, max_size=8),
        max_size=4,
    ),
)
def test_snapshot_key_deterministic_and_sensitive(ver, hashes):
    k1 = snapshot_key(ver, hashes)
    k2 = snapshot_key(ver, dict(reversed(list(hashes.items()))))
    assert k1 == k2  # order-insensitive
    assert snapshot_key(ver + "x", hashes) != k1  # version-sensitive
    if hashes:
        name = next(iter(hashes))
        mutated = dict(hashes)
        mutated[name] = mutated[name] + "0"
        assert snapshot_key(ver, mutated) != k1  # content-sensitive


@settings(max_examples=60, deadline=None)
@given(
    arr=st.lists(
        st.floats(-1e6, 1e6, allow_nan=False, allow_infinity=False, width=32),
        min_size=1,
        max_size=256,
    )
)
def test_int8_quantization_error_bound(arr):
    """|x - deq(q(x))| <= scale/2 elementwise (symmetric rounding)."""
    x = np.asarray(arr, np.float32)
    q, scale = quantize_int8(x)
    err = np.abs(x - np.asarray(dequantize_int8(q, scale)))
    assert float(err.max()) <= float(scale) / 2 + 1e-6


@settings(max_examples=40, deadline=None)
@given(st.data())
def test_cache_hit_iff_same_key(data):
    cache = ContentCache()
    keys = data.draw(
        st.lists(st.text(alphabet="ab", min_size=1, max_size=4), min_size=1, max_size=10)
    )
    for i, k in enumerate(keys):
        cache.insert(k, {"i": i})
    for k in keys:
        assert cache.lookup(k) is not None
    assert cache.lookup("definitely-not-present") is None
