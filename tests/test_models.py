"""Model-level behaviour: decode==teacher-forced, SWA ring wraparound,
MoE dispatch invariants, Mamba prefill continuation, MLA cache compression."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.common import ArchConfig, LayerSpec
from repro.models.registry import (
    build_model,
    decode_step,
    greedy_generate,
    init_serve_state,
    prefill,
)


# multi-minute model/kernel path: runs in the full CI job only
pytestmark = pytest.mark.slow


DECODE_ARCHS = [
    "internlm2-20b",
    "qwen2.5-32b",
    "mixtral-8x7b",
    "minicpm3-4b",
    "falcon-mamba-7b",
    "jamba-v0.1-52b",
    "seamless-m4t-medium",
    "internvl2-1b",
]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_decode_matches_teacher_forced(arch):
    cfg = get_config(arch).reduced()
    m = build_model(cfg)
    params, _ = m.init(jax.random.key(0))
    B, L = 2, 24
    toks = jax.random.randint(jax.random.key(1), (B, L), 0, cfg.vocab)
    frames = (
        jax.random.normal(jax.random.key(2), (B, cfg.frontend_len, cfg.d_model))
        if cfg.encoder_layers
        else None
    )
    x = m.embed(params, toks)
    pos = jnp.broadcast_to(jnp.arange(L)[None], (B, L))
    mem = m.encode(params, frames) if cfg.encoder_layers else None
    xt, _, _ = m.trunk(params, x, pos, memory=mem)
    full = m.logits(params, xt)

    state = init_serve_state(m, B, max_len=64)
    lg, state = prefill(m, params, toks[:, :16], state, frames=frames)
    errs = [float(jnp.abs(lg - full[:, 15]).max())]
    for t in range(16, L):
        lg, state = decode_step(m, params, toks[:, t : t + 1], state)
        errs.append(float(jnp.abs(lg - full[:, t]).max()))
    assert max(errs) < 5e-3, f"{arch}: decode diverges from teacher forcing"


def test_swa_ring_buffer_wraparound():
    """Generating past the window: ring cache must equal a full-cache run."""
    cfg = get_config("mixtral-8x7b").reduced()
    cfg = dataclasses.replace(cfg, window=16)  # small window, forces wrap
    m = build_model(cfg)
    params, _ = m.init(jax.random.key(0))
    B, L = 1, 40  # generate well past window=16
    toks = jax.random.randint(jax.random.key(1), (B, L), 0, cfg.vocab)

    # teacher-forced reference (full attention with SWA masking)
    x = m.embed(params, toks)
    pos = jnp.broadcast_to(jnp.arange(L)[None], (B, L))
    xt, _, _ = m.trunk(params, x, pos)
    full = m.logits(params, xt)

    # ring-cache decode (cache size == window == 16 < L)
    state = init_serve_state(m, B, max_len=64)
    assert state["caches"][0]["k"].shape[2] == 16  # ring allocated at window
    lg, state = prefill(m, params, toks[:, :8], state)
    errs = [float(jnp.abs(lg - full[:, 7]).max())]
    for t in range(8, L):
        lg, state = decode_step(m, params, toks[:, t : t + 1], state)
        errs.append(float(jnp.abs(lg - full[:, t]).max()))
    assert max(errs) < 5e-3, f"ring cache diverges after wraparound: {max(errs)}"


def test_moe_dispatch_invariants():
    from repro.models.moe import expert_capacity, init_moe, moe_ffn
    from repro.models.common import ParamBuilder

    cfg = get_config("mixtral-8x7b").reduced()
    pb = ParamBuilder(jax.random.key(0), jnp.float32)
    p = jax.tree.map(
        lambda x: x[0],
        init_moe(pb, cfg),
        is_leaf=lambda x: isinstance(x, tuple) and hasattr(x[0], "dtype"),
    )
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model))
    y, aux = moe_ffn(p, cfg, x)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(aux["aux_loss"]))
    assert 0.0 <= float(aux["dropped_frac"]) <= 1.0
    # generous capacity => zero drops
    cfg2 = dataclasses.replace(cfg, capacity_factor=float(cfg.n_experts))
    y2, aux2 = moe_ffn(p, cfg2, x)
    assert float(aux2["dropped_frac"]) == 0.0
    # with zero drops the MoE output must match the dense per-token expert mix
    logits = jnp.einsum("td,de->te", x.reshape(-1, cfg.d_model), p["router"])
    probs = jax.nn.softmax(logits.astype(jnp.float32), -1)
    w, idx = jax.lax.top_k(probs, cfg.top_k)
    w = w / w.sum(-1, keepdims=True)
    xt = x.reshape(-1, cfg.d_model)
    ref = jnp.zeros_like(xt)
    for e in range(cfg.n_experts):
        g = jnp.einsum("td,df->tf", xt, p["w_gate"][e])
        u = jnp.einsum("td,df->tf", xt, p["w_up"][e])
        h = jnp.einsum("tf,fd->td", jax.nn.silu(g) * u, p["w_down"][e])
        wt = ((idx == e) * w).sum(-1)
        ref = ref + h * wt[:, None]
    np.testing.assert_allclose(
        np.asarray(y2.reshape(-1, cfg.d_model)), np.asarray(ref), rtol=2e-4, atol=2e-4
    )


def test_mamba_prefill_continuation():
    """Two-stage prefill (8 then 8 tokens) == one 16-token prefill."""
    cfg = get_config("falcon-mamba-7b").reduced()
    m = build_model(cfg)
    params, _ = m.init(jax.random.key(0))
    B = 2
    toks = jax.random.randint(jax.random.key(1), (B, 16), 0, cfg.vocab)
    s1 = init_serve_state(m, B, max_len=32)
    lg_a, s1 = prefill(m, params, toks, s1)
    s2 = init_serve_state(m, B, max_len=32)
    _, s2 = prefill(m, params, toks[:, :8], s2)
    lg_b, s2 = prefill(m, params, toks[:, 8:], s2)
    np.testing.assert_allclose(np.asarray(lg_a), np.asarray(lg_b), rtol=2e-4, atol=2e-4)


def test_mla_cache_is_latent_compressed():
    cfg = get_config("minicpm3-4b").reduced()
    m = build_model(cfg)
    state = init_serve_state(m, batch=1, max_len=64)
    c = state["caches"][0]
    latent_bytes = c["c_kv"].nbytes + c["k_rope"].nbytes
    full_kv_bytes = 2 * 1 * 64 * cfg.n_heads * 16 * c["c_kv"].dtype.itemsize * cfg.n_groups
    # latent cache strictly smaller than per-head KV would be
    assert latent_bytes < full_kv_bytes


def test_greedy_generate_deterministic():
    cfg = get_config("stablelm-1.6b").reduced()
    m = build_model(cfg)
    params, _ = m.init(jax.random.key(0))
    prompt = jax.random.randint(jax.random.key(1), (2, 8), 0, cfg.vocab)
    g1 = greedy_generate(m, params, prompt, n_steps=8, max_len=32)
    g2 = greedy_generate(m, params, prompt, n_steps=8, max_len=32)
    np.testing.assert_array_equal(np.asarray(g1), np.asarray(g2))
    assert g1.shape == (2, 8)
