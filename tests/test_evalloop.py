"""Make-mode eval: recompute iff the model (or eval code) changed."""

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.evalloop import EvalLoop, build_eval_circuit
from repro.models.registry import build_model, train_loss


def test_eval_cache_hits_on_unchanged_model():
    cfg = get_config("stablelm-1.6b").reduced()
    model = build_model(cfg)
    params, _ = model.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 32), 0, cfg.vocab)
    eval_batch = {"tokens": toks, "labels": toks}

    calls = []

    def eval_fn(p, batch):
        calls.append(1)
        loss, _ = train_loss(model, p, batch)
        return {"ppl": float(jnp.exp(loss))}

    mgr = build_eval_circuit(eval_fn, eval_batch)
    loop = EvalLoop(mgr)

    loop.publish(params, step=1)
    r1 = loop.report()
    assert r1 is not None and r1["ppl"] > 0
    assert len(calls) == 1

    # same params re-published (e.g. a restart): cache hit, no forward pass
    loop.publish(params, step=1)
    r2 = loop.report()
    assert len(calls) == 1
    assert loop.cache_hits >= 1
    assert r2["ppl"] == r1["ppl"]

    # changed params: recompute
    params2 = jax.tree.map(lambda x: x * 1.01, params)
    loop.publish(params2, step=2)
    r3 = loop.report()
    assert len(calls) == 2
    assert r3["ppl"] != r1["ppl"]

    # pulling with nothing new resolves from prior outputs
    r4 = loop.report()
    assert len(calls) == 2
    assert r4["ppl"] == r3["ppl"]
