"""Batched content hashing (ISSUE 8): batch==scalar across payload tiers,
cross-process digest stability (the repr-fallback fix), the >4 MiB tree
digest vs its numpy/jnp/pallas references, and unstable-hash anomalies."""

import dataclasses
import os
import pickle

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # hermetic containers: seeded-random fallback
    from repro.testing.hypothesis_fallback import given, settings, strategies as st

from repro.core.av import content_hash as content_hash_av
from repro.core.hashing import (
    LARGE_ARRAY_BYTES,
    TREE_BLOCK_WORDS,
    content_hash,
    content_hash_batch,
    hashing_stats,
    tree_digest,
    tree_state_np,
)


@dataclasses.dataclass
class Reading:
    sensor: str
    values: tuple
    ok: bool = True


def _payload_zoo():
    rng = np.random.RandomState(0)
    return [
        rng.randn(64).astype(np.float32),
        np.asfortranarray(rng.randn(8, 8)),
        np.arange(100)[::3],  # non-contiguous
        np.float64(3.25),  # 0-d
        np.array([], dtype=np.int32),
        {"a": 1, "b": [1.5, "x", None, True]},
        [1, 2, {"k": "v"}],
        (4, 5),
        "plain string",
        b"raw bytes",
        12345,
        2.5,
        None,
        True,
        Reading("s0", (1.0, 2.0)),  # dataclass -> pickle tier
        {3, 1, 2},  # set -> canonicalized pickle tier
    ]


class TestBatchEqualsScalar:
    def test_batch_matches_scalar_over_zoo(self):
        zoo = _payload_zoo()
        batch = content_hash_batch(zoo)
        assert batch == [content_hash(p) for p in zoo]
        # av re-export is the same function (historical import site)
        assert content_hash_av is content_hash

    def test_digests_stable_across_calls(self):
        zoo = _payload_zoo()
        assert content_hash_batch(zoo) == content_hash_batch(list(zoo))

    def test_empty_batch(self):
        assert content_hash_batch([]) == []


class TestCrossProcess:
    def test_digests_identical_parent_vs_forked_child(self):
        """The old repr fallback embedded `object at 0x...` addresses, so a
        forked worker could disagree with its parent on the same payload.
        Every tier must now digest identically across the fork boundary."""
        zoo = _payload_zoo() + [np.zeros(LARGE_ARRAY_BYTES // 8 + 7)]
        parent = content_hash_batch(zoo)
        r, w = os.pipe()
        pid = os.fork()
        if pid == 0:  # child
            os.close(r)
            try:
                blob = pickle.dumps(content_hash_batch(zoo))
                os.write(w, blob)
            finally:
                os.close(w)
                os._exit(0)
        os.close(w)
        chunks = []
        while True:
            c = os.read(r, 65536)
            if not c:
                break
            chunks.append(c)
        os.close(r)
        os.waitpid(pid, 0)
        child = pickle.loads(b"".join(chunks))
        assert child == parent


class TestTreeTier:
    def test_large_array_uses_tree_digest(self):
        arr = np.random.RandomState(1).randint(
            0, 255, size=LARGE_ARRAY_BYTES + 13, dtype=np.uint8
        )
        assert content_hash(arr) == tree_digest(arr)

    def test_tree_digest_detects_single_element_change(self):
        arr = np.zeros(LARGE_ARRAY_BYTES * 2, dtype=np.uint8)
        h0 = content_hash(arr)
        arr[LARGE_ARRAY_BYTES] = 1
        assert content_hash(arr) != h0

    def test_numpy_state_matches_kernel_reference(self):
        from repro.kernels.ref import reference_hash_tree

        rng = np.random.RandomState(2)
        for n_words in (TREE_BLOCK_WORDS, 8192, 3 * 8192):
            w = rng.randint(0, 2**32, size=n_words, dtype=np.uint64).astype(
                np.uint32
            )
            got = tree_state_np(w.view(np.uint8))
            want = np.asarray(reference_hash_tree(w))
            assert got == tuple(int(x) for x in want)

    @pytest.mark.parametrize("backend", ["jnp", "pallas"])
    def test_accelerator_backends_agree_with_numpy(self, backend, monkeypatch):
        pytest.importorskip("jax")
        rng = np.random.RandomState(3)
        arrs = [
            rng.randn(1_300_001),  # ragged: kernel bulk + numpy remainder
            rng.randint(0, 255, size=LARGE_ARRAY_BYTES + 13, dtype=np.uint8),
        ]
        want = [tree_digest(a) for a in arrs]
        monkeypatch.setenv("KOALJA_HASH_BACKEND", backend)
        assert [tree_digest(a) for a in arrs] == want


class TestBackendSelection:
    def test_unknown_backend_fails_loudly(self, monkeypatch):
        monkeypatch.setenv("KOALJA_HASH_BACKEND", "palas")  # typo'd
        with pytest.raises(ValueError, match="KOALJA_HASH_BACKEND"):
            content_hash_batch([np.arange(8)])

    def test_kernel_failure_counts_and_reports(self, monkeypatch):
        """A broken accelerator kernel degrades to numpy with a counted,
        reported fallback — never a silent ``except: pass``. The digest is
        bit-identical either way."""
        import sys

        from repro.core.hashing import bind_fallback_anomalies

        big = np.arange(2_000_000, dtype=np.uint32)  # > 4 MiB: tree tier
        want = tree_digest(big)  # numpy reference, no backend in play

        notes = []
        monkeypatch.setitem(sys.modules, "repro.kernels.hash_tree", None)
        monkeypatch.setenv("KOALJA_HASH_BACKEND", "pallas")
        before = hashing_stats()["backend_fallbacks"]
        bind_fallback_anomalies(notes.append)
        try:
            got = tree_digest(big)
        finally:
            bind_fallback_anomalies(None)
        assert got == want
        assert hashing_stats()["backend_fallbacks"] == before + 1
        assert notes and "hash_backend_fallback" in notes[0]
        assert "pallas" in notes[0]

    def test_workspace_routes_fallback_to_anomaly_log(self, monkeypatch):
        """Through the stack: a workspace push that trips the kernel
        fallback lands a ``hashing`` anomaly in the provenance registry."""
        import sys

        from repro.workspace import Workspace

        monkeypatch.setitem(sys.modules, "repro.kernels.hash_tree", None)
        monkeypatch.setenv("KOALJA_HASH_BACKEND", "jnp")
        ws = Workspace("fallback", topology=False, cache=False)
        t = ws.task(lambda x: {"y": x + 1}, name="big",
                    inputs=["x"], outputs=["y"])
        try:
            ws.push(t, x=np.arange(2_000_000, dtype=np.uint32))
        finally:
            from repro.core.hashing import bind_fallback_anomalies

            bind_fallback_anomalies(None)
        anomalies = [
            e for e in ws.visitor_log("hashing") if e["event"] == "anomaly"
        ]
        assert anomalies
        assert "hash_backend_fallback" in (anomalies[0]["note"] or "")


class TestUnstableFallback:
    def test_unpicklable_payload_reports_anomaly(self):
        notes = []
        h = content_hash(lambda x: x, on_unstable=notes.append)
        assert len(h) == 16
        assert notes and "unstable_hash" in notes[0]

    def test_workspace_journals_unstable_hash_anomaly(self, tmp_path):
        from repro.workspace import Workspace

        ws = Workspace(
            "unstable", topology=False, cache=False,
            journal_path=str(tmp_path / "j.jsonl"),
        )
        t = ws.task(
            lambda x: {"y": lambda: x},  # unpicklable output
            name="emit_fn", inputs=["x"], outputs=["y"],
        )
        ws.push(t, x=1)
        assert ws.store.stats()["unstable_hashes"] >= 1
        anomalies = [
            e for e in ws.visitor_log("store") if e["event"] == "anomaly"
        ]
        assert anomalies and "unstable_hash" in (anomalies[0]["note"] or "")

    def test_stats_counters_move(self):
        before = dict(hashing_stats())
        content_hash_batch(_payload_zoo())
        after = hashing_stats()
        assert after["calls"] > before["calls"]
        assert after["payloads"] >= before["payloads"] + len(_payload_zoo())


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(0, 600),
    scale=st.floats(0.1, 1e6, allow_nan=False, allow_infinity=False),
    split=st.integers(1, 7),
)
def test_property_batch_equals_scalar(n, scale, split):
    """Random mixed batches: batch digests equal scalar digests, and any
    partition of the batch yields the same digests (associativity of the
    batch boundary)."""
    rng = np.random.RandomState(n)
    payloads = []
    for i in range(1 + n % 5):
        kind = (n + i) % 4
        if kind == 0:
            payloads.append((rng.randn(max(1, n % 97)) * scale).astype(np.float32))
        elif kind == 1:
            payloads.append({"i": i, "vals": [float(scale), None, "s"]})
        elif kind == 2:
            payloads.append(Reading(f"s{i}", (float(i), scale)))
        else:
            payloads.append(i * int(scale) % (1 << 63))
    whole = content_hash_batch(payloads)
    assert whole == [content_hash(p) for p in payloads]
    cut = split % (len(payloads) + 1)
    assert whole == content_hash_batch(payloads[:cut]) + content_hash_batch(
        payloads[cut:]
    )
