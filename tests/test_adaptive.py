"""Adaptive runtime (ISSUE 10): feedback-driven autoscaling, energy-aware
placement wiring, zone-local cache tiers, and the journaled ``scale``
decision history.

The determinism contract under test: pool size never affects merge order,
provenance, or ledgers; resize decisions derive from deterministic wave
widths (not wall clocks), so the journaled scale history reproduces; and a
memo hit served from a same-zone replica credits — never charges — the
transfer ledger.
"""

import os
import tempfile

import numpy as np
import pytest

from repro.topology import Topology
from repro.workspace import (
    AdaptiveExecutor,
    ConcurrentExecutor,
    InlineExecutor,
    Workspace,
    default_executor,
)


def _wan_topology():
    t = Topology("wan")
    t.zone("cloud", tier="cloud")
    t.zone("edge", tier="edge")
    t.zone("device", tier="device")
    t.link("device", "edge", latency_ms=1, bandwidth_mbps=1000,
           energy_j_per_mb=0.01)
    t.link("edge", "cloud", latency_ms=20, bandwidth_mbps=100,
           energy_j_per_mb=0.05)
    t.link("device", "cloud", latency_ms=50, bandwidth_mbps=10,
           energy_j_per_mb=0.5)
    return t


def _fan_ws(widths, executor=None, placement="energy", journal_path=None,
            cache=False):
    """One fan per load level: src_w (device) -> w squarers -> red_w (cloud).
    Pushing ``src_w`` fires exactly one wave of width ``w``."""
    ws = Workspace("adaptive", topology=_wan_topology(), placement=placement,
                   executor=executor, journal_path=journal_path, cache=cache)
    for w in widths:
        src = ws.task(lambda x: {"out": x}, name=f"src{w}",
                      inputs=["x"], outputs=["out"]).place("device")
        red = ws.task(lambda **kw: {"total": sum(kw.values())},
                      name=f"red{w}", inputs=[f"v{i}" for i in range(w)],
                      outputs=["total"]).place("cloud")
        for i in range(w):
            sq = ws.task(lambda y, i=i: {"s": float(np.sum(y)) + i},
                         name=f"sq{w}_{i}", inputs=["y"], outputs=["s"])
            src["out"] >> sq["y"]
            sq["s"] >> red[f"v{i}"]
    return ws


def _drive(ws, schedule, n=256, seed=0):
    rng = np.random.RandomState(seed)
    for w in schedule:
        ws.push(f"src{w}", x=rng.randn(n).astype(np.float32))
    return ws


# ---------------------------------------------------------------------------
# load signals (tentpole layer 1)
# ---------------------------------------------------------------------------


class TestLoadSignals:
    def test_snapshot_shape_and_percentiles(self):
        ws = _drive(_fan_ws([1, 4]), [1, 4, 4, 4, 4, 4, 4, 4, 4, 4])
        load = ws.stats()["scheduler"]["load"]
        assert load["waves_observed"] > 0
        # each push brackets its wide wave with width-1 src/reduce waves,
        # so the median stays 1 while p95 captures the fan width
        assert load["wave_width_p50"] == 1
        assert load["wave_width_p95"] == 4
        assert load["recommended_workers"] == 4
        assert load["queue_depth_high_water_last_drain"] >= 1
        # service EWMAs observed for every task that executed
        assert "red4" in load["service_ewma_s"]
        assert load["service_ewma_max_s"] >= max(load["service_ewma_s"].values())

    def test_percentiles_are_nearest_rank(self):
        from repro.core.scheduler import LoadSignals

        sig = LoadSignals(window=8)
        for w in (1, 1, 1, 1, 1, 1, 1, 8):
            sig.observe_wave(w)
        assert sig.wave_width_p50 == 1
        assert sig.wave_width_p95 == 8  # nearest-rank: the 8th of 8
        assert sig.recommended_workers == 8

    def test_window_slides(self):
        from repro.core.scheduler import LoadSignals

        sig = LoadSignals(window=4)
        for w in (8, 8, 8, 8, 1, 1, 1, 1):
            sig.observe_wave(w)
        assert sig.wave_width_p95 == 1  # the 8s slid out of the window


# ---------------------------------------------------------------------------
# adaptive executor (tentpole layer 3)
# ---------------------------------------------------------------------------


class TestAdaptiveExecutor:
    def test_constructor_validation(self):
        with pytest.raises(ValueError):
            AdaptiveExecutor(min_workers=0)
        with pytest.raises(ValueError):
            AdaptiveExecutor(min_workers=4, max_workers=2)
        with pytest.raises(ValueError):
            AdaptiveExecutor(scale_down_patience=0)
        with pytest.raises(TypeError):
            AdaptiveExecutor(inner=InlineExecutor())  # no resize seam

    def test_scales_up_with_load(self):
        ex = AdaptiveExecutor(min_workers=1, max_workers=8)
        assert ex.current_workers == 1
        _drive(_fan_ws([1, 6], executor=ex), [1, 6, 6])
        assert ex.current_workers == 6
        assert ex.scale_ups >= 1
        ups = [e for e in ex.scale_history if e["direction"] == "up"]
        assert ups and ups[-1]["to"] == 6
        ex.shutdown()

    def test_scales_down_with_hysteresis(self):
        ex = AdaptiveExecutor(min_workers=1, max_workers=8,
                              scale_down_patience=3)
        # ramp up, then a long quiet tail: the pool must not thrash down on
        # the first narrow wave, only after patience expires AND the wide
        # waves leave the percentile window
        schedule = [6] * 4 + [1] * 80
        _drive(_fan_ws([1, 6], executor=ex), schedule)
        assert ex.current_workers == 1
        assert ex.scale_downs >= 1
        ex.shutdown()

    def test_band_is_clamped(self):
        ex = AdaptiveExecutor(min_workers=2, max_workers=4)
        _drive(_fan_ws([1, 6], executor=ex), [1, 6, 6, 6])
        assert 2 <= ex.current_workers <= 4
        ex.shutdown()

    def test_stats_surface(self):
        ex = AdaptiveExecutor(min_workers=1, max_workers=8)
        ws = _drive(_fan_ws([1, 4], executor=ex), [1, 4, 4])
        st = ws.stats()["executor"]
        for key in ("current_workers", "min_workers", "max_workers",
                    "resizes", "scale_ups", "scale_downs", "inner"):
            assert key in st
        assert st["last_scale"] == ex.scale_history[-1]
        ex.shutdown()

    def test_env_knob_resolution(self, monkeypatch):
        from repro.workspace.executors import ZonedExecutor

        monkeypatch.setenv("KOALJA_EXECUTOR", "adaptive")
        monkeypatch.setenv("KOALJA_MAX_WORKERS", "5")
        ex = default_executor()
        assert isinstance(ex, AdaptiveExecutor)
        assert ex.max_workers == 5
        monkeypatch.setenv("KOALJA_EXECUTOR", "zoned-adaptive")
        zex = default_executor()
        assert isinstance(zex, ZonedExecutor)
        assert isinstance(zex.inner, AdaptiveExecutor)

    def test_pool_size_never_affects_results_or_provenance(self):
        """The acceptance clause: same circuit, pool bands 1..1 vs 8..8 —
        identical merge totals, ledger, and visitor events."""
        def run(lo, hi):
            ex = AdaptiveExecutor(min_workers=lo, max_workers=hi)
            ws = _drive(_fan_ws([1, 6], executor=ex), [1, 6, 6, 1])
            stats = ws.stats()
            out = {
                "total": ws.value_of(
                    ws.manager.pipeline.tasks["red6"].last_outputs["total"]),
                "ledger": stats["topology"]["ledger"],
                "events": sorted((t, e["event"]) for t in ws.tasks()
                                 for e in ws.visitor_log(t)),
            }
            ex.shutdown()
            return out

        assert run(1, 1) == run(8, 8)


class TestPoolResize:
    def test_concurrent_resize(self):
        ex = ConcurrentExecutor(max_workers=2)
        ex.resize(6)
        assert ex.max_workers == 6
        with pytest.raises(ValueError):
            ex.resize(0)
        ex.shutdown()

    def test_process_resize_grow_and_shrink(self):
        from repro.runtime import ProcessExecutor

        ex = ProcessExecutor(max_workers=2)
        ex.resize(4)
        assert ex.max_workers == 4 and len(ex._workers) == 4
        ex.resize(1)
        assert ex.max_workers == 1 and len(ex._workers) == 1
        with pytest.raises(ValueError):
            ex.resize(0)
        ex.shutdown()

    def test_adaptive_over_process_pool(self):
        """AdaptiveExecutor composes with the forked pool: same results,
        resizes journal-free here (no journal), pool ends wide."""
        from repro.runtime import ProcessExecutor
        from repro.runtime.worker import fork_context

        if fork_context() is None:
            pytest.skip("platform without fork")
        ex = AdaptiveExecutor(inner=ProcessExecutor(max_workers=1),
                              min_workers=1, max_workers=4)
        ws = _drive(_fan_ws([1, 4], executor=ex), [1, 4, 4])
        assert ex.current_workers == 4
        total = ws.value_of(ws.manager.pipeline.tasks["red4"].last_outputs["total"])
        assert isinstance(total, float)
        ex.shutdown()


# ---------------------------------------------------------------------------
# journaled scale records (tentpole layer 3, replay half)
# ---------------------------------------------------------------------------


class TestScaleRecordReplay:
    def test_scale_records_roundtrip_from_journal(self):
        tmp = tempfile.mkdtemp(prefix="koalja-adaptive-")
        jp = os.path.join(tmp, "journal.jsonl")
        ex = AdaptiveExecutor(min_workers=1, max_workers=8)
        ws = _drive(_fan_ws([1, 6], executor=ex, journal_path=jp),
                    [1, 6, 6, 1, 6])
        live_history = list(ex.scale_history)
        live_ledger = ws.stats()["topology"]["ledger"]
        assert live_history, "schedule must provoke at least one resize"
        ws.journal.close()
        ex.shutdown()

        replayed = Workspace.from_journal(jp)
        jstats = replayed.stats()["journal"]
        assert jstats["scale_events"] == live_history
        assert jstats["replayed_counts"]["scale"] == len(live_history)
        # the replayed ledger agrees on every account, compute included
        rledger = replayed.stats()["topology"]["ledger"]
        assert rledger == live_ledger

    def test_scale_record_fields(self):
        tmp = tempfile.mkdtemp(prefix="koalja-adaptive-")
        jp = os.path.join(tmp, "journal.jsonl")
        ex = AdaptiveExecutor(min_workers=1, max_workers=8)
        _drive(_fan_ws([1, 6], executor=ex, journal_path=jp), [1, 6, 6])
        event = ex.scale_history[-1]
        for key in ("executor", "wave", "from", "to", "direction",
                    "width_p95", "queue_high_water"):
            assert key in event
        assert event["direction"] in ("up", "down")
        assert event["from"] != event["to"]
        ex.shutdown()


# ---------------------------------------------------------------------------
# zone-local memo/store tiers (tentpole layer 4)
# ---------------------------------------------------------------------------


class TestZoneLocalTier:
    def _memo_pair(self, hit_zone):
        """Two workspaces sharing one store + memo table (the B15 pattern):
        the first executes in edge-a; the second replays the hit in
        ``hit_zone``."""
        from repro.cache import MemoCache
        from repro.core.store import ArtifactStore

        store, cache = ArtifactStore(), MemoCache()

        def build(pin_zone):
            ws = Workspace("zl", topology=Topology.three_zone(),
                           placement="pin", store=store, cache=cache)
            src = ws.source(lambda: None, name="src",
                            outputs=["x"]).place(pin_zone)
            t = ws.task(lambda x: {"y": x * 2}, name="t",
                        inputs=["x"], outputs=["y"]).place(pin_zone)
            src["x"] >> t["x"]
            return ws

        x = np.ones(64, np.float32)
        cold = build("edge")
        cold.push("src", x=x)
        warm = build(hit_zone)
        return store, cache, cold, warm, x

    def test_hit_without_local_replica_keeps_birth_zone(self):
        store, cache, cold, warm, x = self._memo_pair("cloud")
        warm.push("src", x=x)
        t = warm.manager.pipeline.tasks["t"]
        assert t.cache_hits == 1
        # no cloud replica of the output exists: the AV still points at the
        # birth zone and no zone-local credit is taken
        assert t.last_outputs["y"].zone == "edge"
        assert warm.stats()["topology"]["ledger"]["zone_local_hits"] == 0

    def test_hit_with_local_replica_credits_ledger(self):
        store, cache, cold, warm, x = self._memo_pair("cloud")
        # materialize the output into cloud first (a cloud consumer read it)
        out = cold.manager.pipeline.tasks["t"].last_outputs["y"]
        store.note_zone_resident(out.chash, "cloud")
        warm.push("src", x=x)
        t = warm.manager.pipeline.tasks["t"]
        assert t.cache_hits == 1
        # served from the cloud-local replica: AV rebinds to the replay zone
        assert t.last_outputs["y"].zone == "cloud"
        led = warm.stats()["topology"]["ledger"]
        assert led["zone_local_hits"] == 1
        assert led["bytes_served_zone_local"] == out.meta["nbytes"]
        assert cache.stats()["zone_local_hits"] == 1
        assert store.stats()["zone_local_serves"] >= 1

    def test_store_zone_residency_index(self):
        from repro.core.store import ArtifactStore

        store = ArtifactStore()
        store.note_zone_resident("h1", "edge")
        store.note_zone_resident("h1", "edge")  # idempotent
        store.note_zone_resident("h1", "cloud")
        store.note_zone_resident("h2", None)  # flat circuits: no-op
        assert store.zone_resident("h1", "edge")
        assert store.zone_resident("h1", "cloud")
        assert not store.zone_resident("h2", "edge")
        assert not store.zone_resident("h1", None)
        assert store.zone_resident_counts() == {"cloud": 1, "edge": 1}

    def test_same_zone_executions_index_the_store(self):
        """Every minted output registers residency in its execution zone."""
        ws = _drive(_fan_ws([2]), [2, 2])
        counts = ws.stats()["store"]["zone_residents"]
        assert counts.get("device", 0) > 0  # src outputs
        assert counts.get("cloud", 0) > 0  # reducer outputs + materialized inputs


# ---------------------------------------------------------------------------
# compute-energy account (tentpole layer 2, ledger half)
# ---------------------------------------------------------------------------


class TestComputeEnergyAccount:
    def test_zone_coefficients_and_pricing(self):
        topo = _wan_topology()
        assert topo.compute_j_per_mb("cloud") == pytest.approx(0.02)
        assert topo.compute_j_per_mb("edge") == pytest.approx(0.05)
        assert topo.compute_j_per_mb("device") == pytest.approx(0.12)
        assert topo.compute_energy_j("edge", 2_000_000) == pytest.approx(0.1)
        from repro.topology import TopologyError

        with pytest.raises(TopologyError):
            topo.compute_j_per_mb("mars")
        with pytest.raises(TopologyError):
            Topology("t").zone("z", compute_j_per_mb=-1.0)

    def test_describe_roundtrips_compute(self):
        topo = _wan_topology()
        spec = topo.describe()
        assert spec["compute"]["device"] == pytest.approx(0.12)
        clone = Topology.from_spec(spec)
        assert clone.describe() == spec

    def test_ledger_charges_executions(self):
        ws = _drive(_fan_ws([2]), [2])
        led = ws.stats()["topology"]["ledger"]
        assert led["executions_charged"] > 0
        assert led["compute_energy_j"] > 0
        assert set(led["zone_compute_bytes"]) <= {"cloud", "edge", "device"}
        assert led["total_energy_j"] == pytest.approx(
            led["transfer_energy_j"] + led["compute_energy_j"]
        )
