"""The docs gate, as a test: links in README/docs must resolve and the
provenance walkthrough must execute (same checks CI's docs job runs via
``tools/check_docs.py``)."""

import importlib.util
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]


def _load_check_docs():
    spec = importlib.util.spec_from_file_location(
        "check_docs", REPO / "tools" / "check_docs.py"
    )
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_markdown_links_resolve():
    mod = _load_check_docs()
    assert mod.check_links() == []


def test_docs_exist_and_are_linked_from_readme():
    readme = (REPO / "README.md").read_text()
    assert (REPO / "docs" / "ARCHITECTURE.md").exists()
    assert (REPO / "docs" / "provenance.md").exists()
    assert (REPO / "docs" / "scheduler.md").exists()
    assert "docs/ARCHITECTURE.md" in readme
    assert "docs/provenance.md" in readme
    assert "docs/scheduler.md" in readme
    assert "Caching & sustainability" in readme
    assert "Scheduler & concurrency" in readme


def test_provenance_walkthrough_executes():
    mod = _load_check_docs()
    n = mod.run_walkthrough()
    assert n >= 4, "walkthrough lost its code blocks"


def test_scheduler_walkthrough_registered_and_executes():
    mod = _load_check_docs()
    assert "docs/scheduler.md" in mod.WALKTHROUGHS
    n = mod.run_walkthrough("docs/scheduler.md")
    assert n >= 5, "scheduler walkthrough lost its code blocks"


def test_journal_walkthrough_registered_and_executes():
    mod = _load_check_docs()
    assert "docs/journal.md" in mod.WALKTHROUGHS
    assert "docs/journal.md" in (REPO / "README.md").read_text()
    n = mod.run_walkthrough("docs/journal.md")
    assert n >= 4, "journal walkthrough lost its code blocks"


def test_runtime_walkthrough_registered_and_executes():
    mod = _load_check_docs()
    assert "docs/runtime.md" in mod.WALKTHROUGHS
    assert "docs/runtime.md" in (REPO / "README.md").read_text()
    n = mod.run_walkthrough("docs/runtime.md")
    assert n >= 5, "runtime walkthrough lost its code blocks"
