"""Journal at production scale (ISSUE 7): segment rotation, checkpoint
compaction, chain-aware resume/merge — proven by a property-based
crash-fuzzer and a mid-compaction chaos matrix.

The contract under test: however the journal is sliced (rotated segments,
checkpoints, zone-runner segment files) and wherever the process dies (torn
tail in any file, kill at any compaction stage), three views of history
agree bit-for-bit — the live registry, the chain replay
(``Workspace.from_journal`` = best checkpoint + tail), and the uncompacted
oracle (``replay_files`` over every archived segment + live tail).
"""

import json
import os
import tempfile

import numpy as np
import pytest

try:  # real hypothesis if installed; seeded-random fallback otherwise
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - depends on environment
    from repro.testing.hypothesis_fallback import given, settings, strategies as st

from repro.core.provenance import ProvenanceRegistry
from repro.provenance import (
    Journal,
    discover_chain,
    merge_segments,
    read_chain,
    read_records,
    replay_files,
    replay_journal,
    replay_segments,
)
from repro.runtime import ZonedProcessExecutor, fork_context
from repro.topology import Topology
from repro.workspace import Workspace

needs_fork = pytest.mark.skipif(
    fork_context() is None, reason="fork start method unavailable"
)

# scheduled CI runs raise this for a deeper fuzz (see .github/workflows)
FUZZ_EXAMPLES = int(os.environ.get("KOALJA_FUZZ_EXAMPLES", "20"))

STAGES = ("fold", "pre-rename", "post-rename", "mid-gc", "post-gc")


class _Kill(RuntimeError):
    """Simulated process death inside Journal.compact."""


def _kill_at(stage):
    def fault(s):
        if s == stage:
            raise _Kill(stage)

    return fault


# ---------------------------------------------------------------------------
# circuits + fingerprints
# ---------------------------------------------------------------------------


def _chain_ws(journal_path, topology=False, cache=False, **kw):
    """source -> normalize -> score, journaling (with rotation) to path."""
    ws = Workspace(
        "compacted",
        journal_path=str(journal_path),
        topology=topology,
        cache=cache,
        **kw,
    )
    norm = ws.task(
        lambda x: {"y": x / (np.linalg.norm(x) + 1e-9)},
        name="normalize", inputs=["x"], outputs=["y"],
    )
    score = ws.task(
        lambda y: {"s": float(y.sum())},
        name="score", inputs=["y"], outputs=["s"],
    )
    norm["y"] >> score["y"]
    return ws, norm, score


def _fp(registry, ledger=None, cache=None, docs=True):
    """Byte-identical equality oracle over the forensic stories: the full
    registry snapshot (AVs canonicalized by uid; visits already seq-sorted),
    optionally ledger totals and the memo table. ``next_seq`` is excluded —
    it is a counter watermark, not a story, and retirement legitimately
    leaves the live counter above a replayed one. ``docs=False`` strips
    travel documents: the journal restores them as of registration time
    (stamps added later are link-side mutations it does not track), so
    live-vs-replay comparisons must not require them; replay-vs-oracle
    comparisons keep them (both views are journal-derived)."""
    state = registry.snapshot_state()
    state.pop("next_seq", None)
    state["avs"] = sorted(state["avs"], key=lambda a: a["av"]["uid"])
    if not docs:
        for item in state["avs"]:
            item["av"] = {
                k: v for k, v in item["av"].items() if k != "travel_document"
            }
    blob = {"registry": state}
    if ledger is not None:
        blob["ledger"] = ledger.snapshot_state()
    if cache is not None:
        snap = cache.snapshot_state()
        snap["entries"] = sorted(snap["entries"], key=lambda e: e["key"])
        blob["cache"] = snap
    return json.dumps(blob, sort_keys=True, default=repr)


def _oracle_files(base, archive_dir):
    """The uncompacted oracle's inputs: every segment compaction archived,
    plus whatever is still on disk in the chain (rotated segments + live
    tail) — full history, no checkpoint."""
    files = []
    if os.path.isdir(archive_dir):
        files += sorted(
            os.path.join(archive_dir, n) for n in os.listdir(archive_dir)
        )
    chain = discover_chain(base)
    files += chain["segments"]
    if chain["live"]:
        files.append(chain["live"])
    return files


# ---------------------------------------------------------------------------
# rotation
# ---------------------------------------------------------------------------


class TestRotation:
    def test_rotates_to_numbered_segments_preserving_seq(self, tmp_path):
        j = Journal(tmp_path / "j.jsonl", flush_every_n=1, rotate_records=4)
        seqs = [j.append("anomaly", {"task": "t", "note": str(i)}) for i in range(14)]
        j.close()
        chain = discover_chain(str(tmp_path / "j.jsonl"))
        assert len(chain["segments"]) >= 2
        assert chain["live"] is not None
        for p in chain["segments"]:
            assert p.endswith(tuple(f".{i:04d}" for i in chain["segment_indices"]))
        # the chain read restores one gapless, sorted stream
        records, truncated, info = read_chain(str(tmp_path / "j.jsonl"))
        assert truncated == 0
        got = [r["seq"] for r in records]
        assert got == sorted(got) and len(set(got)) == len(got)
        notes = [r["data"]["note"] for r in records if r["kind"] == "anomaly"]
        assert notes == [str(i) for i in range(14)]
        assert seqs == sorted(seqs)

    def test_rotate_by_bytes(self, tmp_path):
        j = Journal(tmp_path / "j.jsonl", flush_every_n=1, rotate_bytes=400)
        for i in range(30):
            j.append("anomaly", {"task": "t", "note": f"pad-{i:03d}" * 4})
        j.close()
        chain = discover_chain(j.path)
        assert len(chain["segments"]) >= 2
        # every sealed segment respects the threshold order-of-magnitude
        for p in chain["segments"]:
            assert os.path.getsize(p) >= 400

    def test_rotation_never_spins_empty_segments(self, tmp_path):
        # a threshold smaller than one record must still make progress:
        # each sealed segment carries at least one non-header record
        j = Journal(tmp_path / "j.jsonl", flush_every_n=1, rotate_bytes=1)
        for i in range(6):
            j.append("anomaly", {"task": "t", "note": str(i)})
        j.close()
        for p in discover_chain(j.path)["segments"]:
            rs, _ = read_records(p)
            assert any(r["kind"] != "meta" for r in rs)

    def test_env_knob_enables_rotation(self, tmp_path, monkeypatch):
        monkeypatch.setenv("KOALJA_JOURNAL_ROTATE", "256")
        j = Journal(tmp_path / "j.jsonl", flush_every_n=1)
        assert j.rotate_bytes == 256
        for i in range(20):
            j.append("anomaly", {"task": "t", "note": f"row-{i}" * 4})
        j.close()
        assert len(discover_chain(j.path)["segments"]) >= 1

    def test_env_knob_rejects_garbage(self, tmp_path, monkeypatch):
        monkeypatch.setenv("KOALJA_JOURNAL_ROTATE", "plenty")
        with pytest.raises(ValueError, match="KOALJA_JOURNAL_ROTATE"):
            Journal(tmp_path / "j.jsonl")

    def test_from_journal_discovers_rotated_chain(self, tmp_path):
        base = tmp_path / "ws.jsonl"
        ws, norm, _ = _chain_ws(base, journal_rotate_records=6,
                                journal_flush_every_n=1)
        for i in range(4):
            ws.push(norm, x=np.arange(5.0) + i)
        ws.journal.flush()
        assert discover_chain(str(base))["segments"], "expected a rotation"
        ws2 = Workspace.from_journal(str(base))
        assert _fp(ws2.registry, docs=False) == _fp(ws.registry, docs=False)
        js = ws2.stats()["journal"]
        assert js["rehydrated"] and js["segments"] >= 2
        assert js["checkpoints"] == 0 and js["records_compacted"] == 0


# ---------------------------------------------------------------------------
# satellite 1: resume scans the whole chain
# ---------------------------------------------------------------------------


class TestResumeAfterRotation:
    def test_reopen_seeds_seq_from_rotated_segments(self, tmp_path):
        """Regression: the highest seq lives in a rotated segment when the
        live tail is young; resume must scan the chain, not just the tail."""
        j = Journal(tmp_path / "j.jsonl", flush_every_n=1, rotate_records=3)
        last = 0
        for i in range(7):
            last = j.append("anomaly", {"task": "t", "note": str(i)})
        j.rotate()  # live tail now holds only the continuation header
        header_seq = last + 1
        j.close()
        j2 = Journal(tmp_path / "j.jsonl", flush_every_n=1)
        assert j2.append("anomaly", {"task": "t", "note": "post"}) == header_seq + 1
        j2.close()
        records, truncated, _ = read_chain(j2.path)
        seqs = [r["seq"] for r in records]
        assert truncated == 0 and seqs == sorted(seqs) == list(range(header_seq + 2))

    def test_reopen_seeds_visit_seq_from_rotated_segments(self, tmp_path):
        j = Journal(tmp_path / "j.jsonl", flush_every_n=1, rotate_records=3)
        for i in range(5):
            j.append("visit", {"task": "t", "av_uid": f"a{i}", "event": "executed",
                               "timestamp": 1.0, "software_version": "v",
                               "note": "", "seq": 40 + i})
        j.rotate()
        j.close()
        j2 = Journal(tmp_path / "j.jsonl")
        assert j2.resumed_visit_seq == 44
        reg = ProvenanceRegistry()
        reg.bind_journal(j2)
        reg.log_visit("t", "a9", "executed", "v")
        assert reg.visitor_log("t")[-1]["seq"] == 45
        j2.close()

    def test_reopen_seeds_visit_seq_from_checkpoint(self, tmp_path):
        """After compaction the folded visits exist only inside the
        checkpoint; the restored registry counter is the high-water mark."""
        base = tmp_path / "ws.jsonl"
        ws, norm, _ = _chain_ws(base, journal_flush_every_n=1)
        ws.push(norm, x=np.arange(3.0))
        high = max(e["seq"] for t in ws.tasks() for e in ws.visitor_log(t))
        ws.compact_journal()
        ws.journal.close()
        j2 = Journal(str(base))
        assert j2.resumed_visit_seq >= high
        j2.close()

    def test_workspace_resume_after_rotation_keeps_orders(self, tmp_path):
        base = tmp_path / "ws.jsonl"
        ws, norm, _ = _chain_ws(base, journal_rotate_records=5,
                                journal_flush_every_n=1)
        ws.push(norm, x=np.arange(4.0))
        ws.journal.close()
        ws2, norm2, _ = _chain_ws(base, journal_rotate_records=5,
                                  journal_flush_every_n=1)
        ws2.push(norm2, x=np.arange(4.0) + 1)
        ws2.journal.flush()
        replayed = replay_journal(str(base))
        # both processes' visits replay with a gapless total order per task
        for t in ("normalize", "score"):
            seqs = [e["seq"] for e in replayed.registry.visitor_log(t)]
            assert seqs == sorted(seqs) and len(set(seqs)) == len(seqs)
        # both identical runs journaled their visits; the second process's
        # live log holds only its own half
        own = sum(len(ws2.visitor_log(t)) for t in ("normalize", "score"))
        assert replayed.counts["visit"] == 2 * own


# ---------------------------------------------------------------------------
# satellite 2: stats over the whole chain
# ---------------------------------------------------------------------------


class TestJournalStats:
    def test_bytes_on_disk_sums_all_live_segments(self, tmp_path):
        j = Journal(tmp_path / "j.jsonl", flush_every_n=1, rotate_records=4)
        for i in range(12):
            j.append("anomaly", {"task": "t", "note": str(i)})
        s = j.stats()
        chain = discover_chain(j.path)
        expect = sum(
            os.path.getsize(p)
            for p in chain["segments"] + [chain["live"]]
        )
        assert s["bytes_on_disk"] == expect
        assert s["segments"] == len(chain["segments"]) + 1
        assert s["rotations"] == len(chain["segments"])
        assert s["bytes_reclaimed"] == 0 and s["checkpoints"] == 0
        j.close()

    def test_compaction_reports_reclaimed_bytes(self, tmp_path):
        base = tmp_path / "ws.jsonl"
        ws, norm, _ = _chain_ws(base, journal_rotate_records=6,
                                journal_flush_every_n=1)
        for i in range(5):
            ws.push(norm, x=np.arange(4.0) + i)
        before = ws.journal.stats()["bytes_on_disk"]
        report = ws.compact_journal()
        s = ws.journal.stats()
        assert report["bytes_reclaimed"] > 0
        assert s["bytes_reclaimed"] == report["bytes_reclaimed"]
        assert s["checkpoints"] == 1 and s["compactions"] == 1
        assert s["records_compacted"] == report["records_folded"]
        assert s["segments"] == 1  # only the live tail survives
        # workspace stats surface the same numbers
        js = ws.stats()["journal"]
        assert js["checkpoints"] == 1 and js["records_compacted"] > 0
        assert js["bytes_on_disk"] < before + s["bytes_reclaimed"]
        ws.journal.close()


# ---------------------------------------------------------------------------
# compaction semantics
# ---------------------------------------------------------------------------


class TestCompaction:
    def test_checkpoint_plus_tail_equals_history(self, tmp_path):
        base = tmp_path / "ws.jsonl"
        archive = str(tmp_path / "archive")
        ws, norm, _ = _chain_ws(base, journal_rotate_records=8,
                                journal_flush_every_n=1)
        for i in range(3):
            ws.push(norm, x=np.arange(4.0) + i)
        ws.compact_journal(archive_dir=archive)
        ws.push(norm, x=np.arange(4.0) + 99)  # tail records after the fold
        ws.journal.flush()
        live = _fp(ws.registry, docs=False)
        replayed = replay_journal(str(base))
        assert _fp(replayed.registry, docs=False) == live
        assert replayed.checkpoints == 1 and replayed.records_compacted > 0
        # the uncompacted oracle and the checkpointed replay agree on the
        # FULL state, travel documents included — byte-identical
        oracle = replay_files(_oracle_files(str(base), archive))
        assert _fp(oracle.registry) == _fp(replayed.registry)
        assert _fp(oracle.registry, docs=False) == live

    def test_ledger_and_topology_fold_into_checkpoint(self, tmp_path):
        base = tmp_path / "ws.jsonl"
        ws, norm, _ = _chain_ws(base, topology=Topology.three_zone(),
                                journal_flush_every_n=1)
        for i in range(3):
            ws.push(norm, x=np.arange(6.0) + i, region="edge")
        ws.compact_journal()
        ws.push(norm, x=np.arange(6.0) + 50, region="edge")
        ws.journal.flush()
        replayed = replay_journal(str(base))
        assert replayed.ledger is not None
        assert _fp(replayed.registry, replayed.ledger, docs=False) == _fp(
            ws.registry, ws.ledger, docs=False
        )
        assert replayed.ledger.stats() == ws.ledger.stats()

    def test_memo_table_folds_with_overwrites_deduped(self, tmp_path):
        j = Journal(tmp_path / "j.jsonl", flush_every_n=1)
        from repro.cache import MemoCache

        cache = MemoCache()
        cache.bind_journal(j)
        cache.insert("k1", {"software_version": "v1", "out_nbytes": {}})
        cache.insert("k1", {"software_version": "v2", "out_nbytes": {}})  # overwrite
        cache.insert("k2", {"software_version": "v1", "out_nbytes": {}})
        j.compact()
        ck = read_chain(j.path)[2]["checkpoint_data"]
        # superseded k1 record folded away: one entry per key survives
        assert sorted(e["key"] for e in ck["cache"]["entries"]) == ["k1", "k2"]
        replayed = replay_journal(j.path)
        assert replayed.cache is not None
        assert replayed.cache.lookup("k1")["software_version"] == "v2"
        assert _fp(ProvenanceRegistry(), cache=replayed.cache) == _fp(
            ProvenanceRegistry(), cache=cache
        )
        j.close()

    def test_memo_hits_survive_compaction_end_to_end(self, tmp_path):
        base = tmp_path / "ws.jsonl"
        ws, norm, _ = _chain_ws(base, cache=None, journal_flush_every_n=1)
        x = np.arange(5.0)
        ws.push(norm, x=x)
        ws.push(norm, x=x)  # memo hit
        assert ws.stats()["sustainability"]["executions_avoided"] > 0
        ws.compact_journal()
        ws.journal.flush()
        replayed = replay_journal(str(base))
        assert _fp(replayed.registry, docs=False) == _fp(ws.registry, docs=False)
        assert replayed.cache is not None and len(
            replayed.cache.snapshot_state()["entries"]
        ) == len(ws.manager.cache.snapshot_state()["entries"])

    def test_retirement_bounds_state_and_all_views_agree(self, tmp_path):
        base = tmp_path / "ws.jsonl"
        archive = str(tmp_path / "archive")
        ws, norm, _ = _chain_ws(base, journal_rotate_records=10,
                                journal_flush_every_n=1)
        for i in range(4):
            ws.push(norm, x=np.arange(4.0) + i)
        # evict the oldest normalize output: its payload is gone for good
        victim = ws.registry.all_avs()[0]
        ws.store.evict_local(ws.registry.get_av(victim).uri)
        report = ws.compact_journal(retire_evicted=True, archive_dir=archive)
        assert victim not in ws.registry.all_avs()
        assert victim not in [
            a["av"]["uid"]
            for a in read_chain(str(base))[2]["checkpoint_data"]["registry"]["avs"]
        ]
        live = _fp(ws.registry, docs=False)
        replayed = replay_journal(str(base))
        assert _fp(replayed.registry, docs=False) == live
        # the full-history oracle applies the journaled `retired` marker and
        # lands on the same story — deliberate forgetting, not divergence
        oracle = replay_files(_oracle_files(str(base), archive))
        assert _fp(oracle.registry) == _fp(replayed.registry)
        assert report["avs_live"] == len(ws.registry.all_avs())

    def test_repeated_rounds_keep_disk_bounded(self, tmp_path):
        """The production-scale claim in miniature: steady push+evict+compact
        rounds must not grow the on-disk chain monotonically."""
        base = tmp_path / "ws.jsonl"
        ws, norm, _ = _chain_ws(base, journal_rotate_records=16,
                                journal_flush_every_n=1)
        sizes = []
        for r in range(6):
            for i in range(4):
                ws.push(norm, x=np.arange(4.0) + 10 * r + i)
            for uid in ws.registry.all_avs()[:-4]:
                av = ws.registry.get_av(uid)
                if not av.uri.startswith("ghost://"):
                    ws.store.evict_local(av.uri)
            ws.compact_journal(retire_evicted=True)
            sizes.append(ws.journal.stats()["bytes_on_disk"])
        assert max(sizes[2:]) <= 2 * sizes[1], f"journal grew unbounded: {sizes}"
        assert _fp(replay_journal(str(base)).registry, docs=False) == _fp(
            ws.registry, docs=False
        )

    def test_zone_segment_journal_refuses_compact(self, tmp_path):
        seg = Journal(tmp_path / "m.jsonl.seg-a", segment="a", flush_every_n=1)
        seg.append("anomaly", {"task": "t", "note": "x"}, seq=5)
        with pytest.raises(ValueError, match="segment"):
            seg.compact()
        seg.close()


# ---------------------------------------------------------------------------
# satellite 4: chaos matrix — die at every compaction stage
# ---------------------------------------------------------------------------


class TestMidCompactionChaos:
    def _grown(self, tmp_path):
        base = tmp_path / "ws.jsonl"
        ws, norm, _ = _chain_ws(base, journal_rotate_records=6,
                                journal_flush_every_n=1)
        for i in range(4):
            ws.push(norm, x=np.arange(4.0) + i)
        ws.journal.flush()
        return ws, str(base)

    @pytest.mark.parametrize("stage", STAGES)
    def test_kill_at_stage_leaves_replayable_chain(self, tmp_path, stage):
        ws, base = self._grown(tmp_path)
        live = _fp(ws.registry, docs=False)
        with pytest.raises(_Kill):
            ws.journal.compact(fault=_kill_at(stage))
        # whatever mix of old segments / tmp file / fresh checkpoint the
        # kill stranded on disk, the chain replays to the same story
        replayed = replay_journal(base)
        assert _fp(replayed.registry, docs=False) == live, \
            f"divergence after {stage} kill"
        # and a restarted journal can resume on top of the debris
        ws.journal.close()
        j2 = Journal(base, flush_every_n=1)
        nxt = j2.append("anomaly", {"task": "t", "note": "post-crash"})
        j2.close()
        records, _, _ = read_chain(base)
        seqs = [r["seq"] for r in records]
        assert nxt == max(seqs) and seqs == sorted(seqs)

    @pytest.mark.parametrize("stage", STAGES)
    def test_compact_retry_after_kill_converges(self, tmp_path, stage):
        ws, base = self._grown(tmp_path)
        live = _fp(ws.registry, docs=False)
        with pytest.raises(_Kill):
            ws.journal.compact(fault=_kill_at(stage))
        report = ws.journal.compact()  # the restarted process tries again
        assert report.get("noop") or report["checkpoint"]
        chain = discover_chain(base)
        assert len(chain["checkpoints"]) <= 1  # older/partial ones GC'd
        assert not chain["segments"]
        assert _fp(replay_journal(base).registry, docs=False) == live
        ws.journal.close()

    def test_abandoned_tmp_checkpoint_is_ignored(self, tmp_path):
        ws, base = self._grown(tmp_path)
        with open(base + ".ckpt-999999.tmp", "w") as fh:
            fh.write('{"seq": 999999, "kind": "checkpoint", "data": {')
        assert _fp(replay_journal(base).registry, docs=False) == _fp(
            ws.registry, docs=False
        )
        ws.journal.close()

    def test_torn_checkpoint_file_falls_back(self, tmp_path):
        """A damaged published checkpoint must not poison the replay: the
        reader skips it and falls back to older checkpoints / raw history."""
        ws, base = self._grown(tmp_path)
        live = _fp(ws.registry, docs=False)
        ws.journal.compact(archive_dir=str(tmp_path / "arch"))
        ck = discover_chain(base)["checkpoints"][0]
        with open(ck, "w") as fh:
            fh.write('{"seq": 1, "kind": "checkpoint", "da')
        replayed = replay_journal(base)
        # the good history was archived, so the fallback view is tail-only —
        # but it must not raise, and a full-file oracle still reconstructs
        oracle = replay_files(
            _oracle_files(base, str(tmp_path / "arch"))
        )
        assert _fp(oracle.registry, docs=False) == live
        assert replayed.truncated >= 0  # replay completed without raising
        ws.journal.close()


# ---------------------------------------------------------------------------
# satellite 3: merge/replay over rotated mains + zone segments
# ---------------------------------------------------------------------------


class TestZonedChainMerge:
    def test_revoked_window_spanning_segment_rotation_boundary(self, tmp_path):
        """A dead runner's reserved window whose records straddle the zone
        segment's own rotation boundary must vanish from the merge whole —
        both the part in the sealed segment and the part in its live tail."""
        base = str(tmp_path / "m.jsonl")
        main = Journal(base, workspace="w", flush_every_n=1, rotate_records=3)
        main.append("task", {"task": "t", "inputs": [], "outputs": [],
                             "version": "v"})
        main.append("edge", {"src": "t", "relation": "precedes", "dst": "u"})
        # main has rotated at least once by now (3-record threshold)
        dead = main.reserve(4)
        good = main.reserve(2)
        seg = Journal(base + ".seg-z", workspace="w", segment="z",
                      flush_every_n=1, rotate_records=3)
        for i in range(4):  # rotates after the 3rd record: window straddles
            seg.append("anomaly", {"task": "t", "note": f"orphan-{i}"},
                       seq=dead + i)
        assert discover_chain(seg.path)["segments"], "expected seg rotation"
        for i in range(2):
            seg.append("anomaly", {"task": "t", "note": f"kept-{i}"},
                       seq=good + i)
        seg.close()
        main.append("revoked", {"task": "t", "start": dead, "count": 4})
        main.close()
        assert discover_chain(base)["segments"], "expected main rotation"
        records, truncated = merge_segments(base, [base + ".seg-z"])
        assert truncated == 0
        notes = [r["data"]["note"] for r in records if r["kind"] == "anomaly"]
        assert notes == ["kept-0", "kept-1"]
        seqs = [r["seq"] for r in records]
        assert seqs == sorted(seqs)

    def test_merge_over_compacted_main_drops_folded_zone_records(self, tmp_path):
        base = str(tmp_path / "m.jsonl")
        main = Journal(base, workspace="w", flush_every_n=1)
        main.append("task", {"task": "t", "inputs": [], "outputs": [],
                             "version": "v"})
        w = main.reserve(3)
        seg = Journal(base + ".seg-a", workspace="w", segment="a",
                      flush_every_n=1)
        for i in range(3):
            seg.append(
                "visit",
                {"task": "t", "av_uid": f"a{i}", "event": "executed",
                 "timestamp": float(i), "software_version": "v", "note": "",
                 "seq": w + i},
                seq=w + i,
            )
        seg.close()
        before = replay_segments(base, [base + ".seg-a"])
        main.compact(segment_paths=[base + ".seg-a"])
        after = replay_segments(base, [base + ".seg-a"])
        assert _fp(after.registry) == _fp(before.registry)
        # the folded zone visits live in the checkpoint now, counted once
        assert after.counts.get("visit") == before.counts.get("visit") == 3
        main.close()

    @needs_fork
    def test_zoned_run_with_rotation_merges_to_live_registry(self, tmp_path):
        """Integration: a real multi-process zoned run with rotation enabled
        on every journal (main + zone segments), including a killed runner's
        revoked window, still merges bit-identically to the live registry."""
        jpath = str(tmp_path / "zp.jsonl")
        topo = Topology.three_zone()
        ex = ZonedProcessExecutor(max_workers=2, retry_budget=2)
        ws = Workspace(
            "zones", executor=ex, cache=False, topology=topo, placement="pin",
            journal_path=jpath, journal_flush_every_n=1,
            journal_rotate_records=8,
        )
        zones = ("edge", "device")
        src = ws.task(lambda x: {"out": x}, name="src", inputs=["x"],
                      outputs=["out"]).place("cloud")
        red = ws.task(
            lambda **kw: {"total": float(sum(np.sum(v) for v in kw.values()))},
            name="reduce", inputs=[f"a_{z}" for z in zones], outputs=["total"],
        ).place("cloud")
        for z in zones:
            t = ws.task(lambda x, z=z: {"out": x * 2.0}, name=f"prod_{z}",
                        inputs=["x"], outputs=["out"]).place(z)
            src["out"] >> t["x"]
            t["out"] >> red[f"a_{z}"]
        rng = np.random.RandomState(3)
        try:
            for _ in range(2):
                ws.push("src", x=rng.randn(16).astype(np.float32))
            ex.kill_runner("edge")
            for _ in range(3):
                ws.push("src", x=rng.randn(16).astype(np.float32))
            ws.journal.flush()
            assert discover_chain(jpath)["segments"], "main never rotated"
            replayed = replay_segments(jpath, ex.segment_paths())
            assert _fp(replayed.registry, replayed.ledger, docs=False) == _fp(
                ws.registry, ws.ledger, docs=False
            )
            # from_journal takes the same [main, *segments] shape
            ws2 = Workspace.from_journal([jpath, *ex.segment_paths()])
            assert _fp(ws2.registry, docs=False) == _fp(ws.registry, docs=False)
        finally:
            ex.shutdown()


# ---------------------------------------------------------------------------
# the headline: property-based crash fuzzer
# ---------------------------------------------------------------------------


class TestCrashFuzzer:
    @settings(max_examples=FUZZ_EXAMPLES, deadline=None)
    @given(st.data())
    def test_any_schedule_any_kill_point_replays_identically(self, data):
        """Random pipeline activity, random rotation thresholds, random
        compaction/retirement schedules, random kill points (a fault at any
        compaction stage, then a torn tail in any chain file): the live
        registry, the chain replay, and the uncompacted oracle must agree
        byte-for-byte."""
        with tempfile.TemporaryDirectory() as tmp:
            base = os.path.join(tmp, "fuzz.jsonl")
            archive = os.path.join(tmp, "archive")
            rotate = data.draw(st.integers(min_value=3, max_value=12))
            ws, norm, _ = _chain_ws(base, journal_rotate_records=rotate,
                                    journal_flush_every_n=1)
            killed = False
            for r in range(data.draw(st.integers(min_value=1, max_value=3))):
                for p in range(data.draw(st.integers(min_value=1, max_value=3))):
                    ws.push(norm, x=np.arange(4.0) + 10 * r + p)
                action = data.draw(st.integers(min_value=0, max_value=3))
                if action == 1:
                    ws.compact_journal(archive_dir=archive)
                elif action == 2:
                    uids = ws.registry.all_avs()
                    victim = uids[
                        data.draw(st.integers(min_value=0, max_value=len(uids) - 1))
                    ]
                    av = ws.registry.get_av(victim)
                    if not av.uri.startswith("ghost://"):
                        ws.store.evict_local(av.uri)
                    ws.compact_journal(retire_evicted=True, archive_dir=archive)
                elif action == 3 and not killed:
                    stage = STAGES[
                        data.draw(st.integers(min_value=0, max_value=len(STAGES) - 1))
                    ]
                    with pytest.raises(_Kill):
                        ws.journal.compact(
                            archive_dir=archive, fault=_kill_at(stage)
                        )
                    killed = True  # the process "died"; later rounds are the restart
            ws.journal.flush()
            # the final kill: a torn tail at a random point in the chain
            chain = discover_chain(base)
            targets = ([chain["live"]] if chain["live"] else []) + chain["segments"]
            if data.draw(st.integers(min_value=0, max_value=2)) and targets:
                idx = data.draw(
                    st.integers(min_value=0, max_value=len(targets) - 1)
                )
                with open(targets[idx], "a", encoding="utf-8") as fh:
                    fh.write('{"seq": 999999, "kind": "vis')
            live = _fp(ws.registry, docs=False)
            replayed = replay_journal(base)
            assert _fp(replayed.registry, docs=False) == live, \
                "chain replay diverged from the live registry"
            oracle = replay_files(_oracle_files(base, archive))
            assert _fp(oracle.registry) == _fp(replayed.registry), \
                "uncompacted oracle diverged from the checkpointed replay"
            assert _fp(oracle.registry, docs=False) == live
            # a restart over the debris must resume, not corrupt: reopening
            # changes nothing about the story
            ws.journal.close()
            j2 = Journal(base, flush_every_n=1)
            j2.close()
            assert _fp(replay_journal(base).registry, docs=False) == live
