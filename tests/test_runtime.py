"""repro.runtime (ISSUE 6): multi-process worker pools and zone runners.

Covers the refs-only pipe protocol (zero payload bytes cross a pipe),
worker-crash robustness (``worker_died`` anomaly, bounded retries, inline
fallback, no lost or duplicated AVs), journal-segment merge back into a
registry identical to the single-process oracle — including torn tails
and revoked seq windows — and construction-time validation of the
``KOALJA_EXECUTOR`` / ``KOALJA_MAX_WORKERS`` / ``KOALJA_PLACEMENT`` knobs.
"""

import os

import numpy as np
import pytest

from repro.core.store import ArtifactStore
from repro.provenance import (
    Journal,
    merge_segments,
    read_records,
    replay_segments,
)
from repro.runtime import ProcessExecutor, ZonedProcessExecutor, fork_context
from repro.topology import Topology
from repro.workspace import (
    ConcurrentExecutor,
    InlineExecutor,
    Workspace,
    default_executor,
)

needs_fork = pytest.mark.skipif(
    fork_context() is None, reason="fork start method unavailable"
)


# ---------------------------------------------------------------------------
# circuits
# ---------------------------------------------------------------------------


def _fan_ws(executor, width=4, topology=False, placement=None, **ws_kwargs):
    """src -> width parallel squarers -> merge reducer. Every push fires one
    multi-task wave (the squarers), which is what exercises the pool."""
    ws = Workspace(
        "fan", executor=executor, cache=False,
        topology=topology, placement=placement, **ws_kwargs,
    )
    src = ws.task(
        lambda x: {"out": x}, name="src", inputs=["x"], outputs=["out"]
    )
    red = ws.task(
        lambda **kw: {"total": [float(np.sum(kw[k])) for k in sorted(kw)]},
        name="reduce", inputs=[f"v{i}" for i in range(width)],
        outputs=["total"],
    )
    for i in range(width):
        sq = ws.task(
            lambda y, i=i: {"sq": y * y + i},
            name=f"sq{i}", inputs=["y"], outputs=["sq"],
        )
        src["out"] >> sq["y"]
        sq["sq"] >> red[f"v{i}"]
    return ws


def _drive_fan(ws, rounds=2, n=32, seed=0):
    rng = np.random.RandomState(seed)
    for _ in range(rounds):
        ws.push("src", x=rng.randn(n).astype(np.float32))
    return ws


def _zone_ws(executor, **ws_kwargs):
    """Three-zone circuit for the zoned runners: one producer pinned per
    non-cloud zone, fanned into a cloud reducer."""
    topo = Topology.three_zone()
    ws = Workspace(
        "zones", executor=executor, cache=False, topology=topo,
        placement="pin", **ws_kwargs,
    )
    zones = ("edge", "device")
    src = ws.task(
        lambda x: {"out": x}, name="src", inputs=["x"], outputs=["out"]
    ).place("cloud")
    red = ws.task(
        lambda **kw: {"total": float(sum(np.sum(v) for v in kw.values()))},
        name="reduce", inputs=[f"a_{z}" for z in zones], outputs=["total"],
    ).place("cloud")
    for z in zones:
        # one push -> one wave holding both zone tasks (forks the runners)
        t = ws.task(
            lambda x, z=z: {"out": x * 2.0},
            name=f"prod_{z}", inputs=["x"], outputs=["out"],
        ).place(z)
        src["out"] >> t["x"]
        t["out"] >> red[f"a_{z}"]
    return ws, zones


def _drive_zones(ws, zones, rounds=2, n=16, seed=3):
    rng = np.random.RandomState(seed)
    for _ in range(rounds):
        ws.push("src", x=rng.randn(n).astype(np.float32))
    return ws


def _registry_story(registry):
    """The provenance projection that must survive any process topology:
    per-AV lineage parents, per-AV visit events, anomaly notes."""
    uids = registry.all_avs()
    # the uid counter is process-global: canonicalize to registration order
    # so stories from two workspaces (or a replay) compare by *shape*
    order = {uid: i for i, uid in enumerate(uids)}
    story = {}
    for uid in uids:
        lin = registry.lineage(uid, depth=1)
        story[order[uid]] = {
            "task": lin["source_task"],
            "parents": sorted(order.get(p["uid"], -1) for p in lin["parents"]),
            "visits": [
                (v["task"], v["event"]) for v in registry.visits_of(uid)
            ],
        }
    return story


# ---------------------------------------------------------------------------
# store: the reference-handover primitives
# ---------------------------------------------------------------------------


class TestStoreHandover:
    def test_publish_promotes_local_to_object_tier(self, tmp_path):
        store = ArtifactStore(object_dir=str(tmp_path / "obj"))
        uri, chash = store.put(np.arange(8, dtype=np.float32))
        moved = store.publish(chash)
        assert moved == store.nbytes_of(chash) > 0
        assert store.publish(chash) == 0  # idempotent: already shared
        with pytest.raises(KeyError):
            store.publish("sha256:absent")

    def test_export_then_adopt_round_trip(self, tmp_path):
        giver = ArtifactStore(object_dir=str(tmp_path / "obj"))
        taker = ArtifactStore(object_dir=str(tmp_path / "obj"))
        payload = np.arange(16, dtype=np.float32)
        _, chash, nbytes, existed = giver.export(payload)
        assert not existed
        uri = taker.adopt(chash, nbytes)
        np.testing.assert_array_equal(taker.get(uri), payload)
        # second export of identical content reports existed=True (dedup)
        _, chash2, _, existed2 = giver.export(payload.copy())
        assert chash2 == chash and existed2


# ---------------------------------------------------------------------------
# knob validation (satellite 1)
# ---------------------------------------------------------------------------


class TestKnobValidation:
    def test_executor_env_values(self, monkeypatch):
        monkeypatch.setenv("KOALJA_EXECUTOR", "process")
        assert isinstance(default_executor(), ProcessExecutor)
        monkeypatch.setenv("KOALJA_EXECUTOR", "zoned-process")
        assert isinstance(default_executor(), ZonedProcessExecutor)

    def test_bad_executor_names_choices(self, monkeypatch):
        monkeypatch.setenv("KOALJA_EXECUTOR", "quantum")
        with pytest.raises(ValueError, match="KOALJA_EXECUTOR"):
            Workspace("w", topology=False)
        try:
            default_executor()
        except ValueError as e:
            msg = str(e)
        for choice in ("inline", "concurrent", "process", "zoned-process"):
            assert choice in msg

    def test_bad_max_workers(self, monkeypatch):
        monkeypatch.setenv("KOALJA_EXECUTOR", "process")
        monkeypatch.setenv("KOALJA_MAX_WORKERS", "many")
        with pytest.raises(ValueError, match="KOALJA_MAX_WORKERS"):
            default_executor()
        monkeypatch.setenv("KOALJA_MAX_WORKERS", "0")
        with pytest.raises(ValueError, match=">= 1"):
            default_executor()
        monkeypatch.setenv("KOALJA_MAX_WORKERS", "3")
        ex = default_executor()
        assert ex.max_workers == 3

    def test_bad_placement_fails_at_construction(self, monkeypatch):
        # even on a flat circuit, where placement would never be exercised
        monkeypatch.setenv("KOALJA_PLACEMENT", "gravity_assist")
        with pytest.raises(ValueError, match="KOALJA_PLACEMENT"):
            Workspace("w", topology=False)
        monkeypatch.delenv("KOALJA_PLACEMENT")
        with pytest.raises(ValueError, match="placement="):
            Workspace("w", topology=False, placement="nope")

    def test_bad_topology_env(self, monkeypatch):
        monkeypatch.setenv("KOALJA_TOPOLOGY", "moonbase")
        with pytest.raises(ValueError, match="KOALJA_TOPOLOGY"):
            Workspace("w")


# ---------------------------------------------------------------------------
# ProcessExecutor: the flat pool
# ---------------------------------------------------------------------------


@needs_fork
class TestProcessPool:
    def test_matches_inline_and_moves_no_payload_bytes(self):
        base = _drive_fan(_fan_ws(InlineExecutor()))
        ex = ProcessExecutor(max_workers=4)
        ws = _drive_fan(_fan_ws(ex))
        try:
            assert (
                ws.value_of(ws.pipeline.tasks["reduce"].last_outputs["total"])
                == base.value_of(
                    base.pipeline.tasks["reduce"].last_outputs["total"]
                )
            )
            st = ex.stats()
            assert st["tasks_remote"] > 0
            assert st["payload_bytes_over_pipe"] == 0
            assert st["control_bytes_sent"] > 0
            assert st["control_bytes_received"] > 0
            assert _registry_story(ws.registry) == _registry_story(
                base.registry
            )
        finally:
            ex.shutdown()

    def test_single_task_waves_stay_inline(self):
        ex = ProcessExecutor(max_workers=4)
        ws = Workspace("solo", executor=ex, cache=False, topology=False)
        ws.task(lambda x: {"y": x + 1}, name="t", inputs=["x"], outputs=["y"])
        ws.push("t", x=1)
        try:
            st = ex.stats()
            assert st["tasks_remote"] == 0
            assert st["workers_alive"] == 0  # pool never forked
        finally:
            ex.shutdown()

    def test_scheduler_reports_wave_width(self):
        ex = ProcessExecutor(max_workers=4)
        ws = _drive_fan(_fan_ws(ex, width=4), rounds=1)
        try:
            assert ws.stats()["scheduler"]["max_wave_width"] == 4
        finally:
            ex.shutdown()


@needs_fork
class TestWorkerCrash:
    """Satellite 2: kill a pool worker mid-wave; the wave must retry on a
    fresh worker, journal a ``worker_died`` anomaly, and lose nothing."""

    def _crash_ws(self, ex, parent_pid, crash_flag):
        """4-wide fan-out where sq0 hard-exits the hosting process — but
        only in a *worker* (the parent-pid guard keeps the retry/fallback
        path computing real values)."""
        ws = Workspace("crash", executor=ex, cache=False, topology=False)
        src = ws.task(
            lambda x: {"out": x}, name="src", inputs=["x"], outputs=["out"]
        )
        red = ws.task(
            lambda **kw: {"total": float(sum(np.sum(v) for v in kw.values()))},
            name="reduce", inputs=[f"v{i}" for i in range(4)],
            outputs=["total"],
        )
        def sq0(y):
            if os.getpid() != parent_pid and os.path.exists(crash_flag):
                os.remove(crash_flag)  # one crash, then behave
                os._exit(1)
            return {"sq": y * y}
        tasks = [sq0] + [
            (lambda y, i=i: {"sq": y * y + i}) for i in range(1, 4)
        ]
        for i, fn in enumerate(tasks):
            t = ws.task(fn, name=f"sq{i}", inputs=["y"], outputs=["sq"])
            src["out"] >> t["y"]
            t["sq"] >> red[f"v{i}"]
        return ws

    def test_killed_worker_retries_with_anomaly(self, tmp_path):
        flag = str(tmp_path / "crash-once")
        ex = ProcessExecutor(max_workers=2, retry_budget=2)
        ws = self._crash_ws(ex, os.getpid(), flag)
        open(flag, "w").close()
        ws.push("src", x=np.ones(8, np.float32))
        try:
            st = ex.stats()
            assert st["worker_restarts"] >= 1
            assert st["retries"] >= 1
            notes = [a["note"] for a in ws.registry.anomalies]
            assert any("worker_died" in n for n in notes)
            # the wave completed: reducer saw all four squares exactly once
            total = ws.value_of(
                ws.pipeline.tasks["reduce"].last_outputs["total"]
            )
            assert total == pytest.approx(8 * (1 + 2 + 3) + 4 * 8)
            for i in range(4):
                emits = [
                    v for v in ws.visitor_log(f"sq{i}")
                    if v["event"] == "emitted"
                ]
                assert len(emits) == 1, f"sq{i} emitted {len(emits)} times"
        finally:
            ex.shutdown()

    def test_exhausted_retry_budget_falls_back_inline(self, tmp_path):
        # crash on *every* worker attempt -> the parent runs the task itself
        ex = ProcessExecutor(max_workers=2, retry_budget=1)
        ws = Workspace("fb", executor=ex, cache=False, topology=False)
        parent = os.getpid()
        src = ws.task(
            lambda x: {"out": x}, name="src", inputs=["x"], outputs=["out"]
        )
        def die(y):
            if os.getpid() != parent:
                os._exit(1)
            return {"sq": y * y}
        t0 = ws.task(die, name="sq0", inputs=["y"], outputs=["sq"])
        t1 = ws.task(
            lambda y: {"sq": y + 1}, name="sq1", inputs=["y"], outputs=["sq"]
        )
        src["out"] >> t0["y"]
        src["out"] >> t1["y"]
        ws.push("src", x=np.full(4, 3.0, np.float32))
        try:
            st = ex.stats()
            assert st["inline_fallbacks"] >= 1
            np.testing.assert_array_equal(
                ws.value_of(ws.pipeline.tasks["sq0"].last_outputs["sq"]),
                np.full(4, 9.0, np.float32),
            )
        finally:
            ex.shutdown()

    def test_crash_run_fingerprint_matches_clean_run(self, tmp_path):
        """Modulo the anomaly entries, a run that lost a worker mid-wave
        tells the same provenance story as a crash-free one."""
        flag = str(tmp_path / "crash-once")

        def run(crash):
            ex = ProcessExecutor(max_workers=2, retry_budget=2)
            ws = self._crash_ws(ex, os.getpid(), flag)
            if crash:
                open(flag, "w").close()
            ws.push("src", x=np.full(8, 2.0, np.float32))
            try:
                story = _registry_story(ws.registry)
                total = ws.value_of(
                    ws.pipeline.tasks["reduce"].last_outputs["total"]
                )
            finally:
                ex.shutdown()
            # anomaly visits ride on the task, not the AVs; strip the
            # anomaly *events* from each AV's visit list for comparison
            for s in story.values():
                s["visits"] = [v for v in s["visits"] if v[1] != "anomaly"]
            return story, total

        clean_story, clean_total = run(crash=False)
        crash_story, crash_total = run(crash=True)
        assert crash_total == clean_total
        # uid *values* may differ; compare the per-task story shapes
        def by_task(story):
            out = {}
            for s in story.values():
                out.setdefault(s["task"], []).append(
                    (sorted(v for v in s["visits"]), len(s["parents"]))
                )
            return {k: sorted(v) for k, v in out.items()}
        assert by_task(crash_story) == by_task(clean_story)


# ---------------------------------------------------------------------------
# ZonedProcessExecutor: runners + journal-segment merge (satellite 3)
# ---------------------------------------------------------------------------


@needs_fork
class TestZoneRunnerMerge:
    def _run_zoned(self, tmp_path, name="zp"):
        jpath = str(tmp_path / f"{name}.jsonl")
        ex = ZonedProcessExecutor(max_workers=2)
        ws, zones = _zone_ws(ex, journal_path=jpath)
        _drive_zones(ws, zones)
        return ws, ex, zones, jpath

    def test_segments_merge_to_live_registry(self, tmp_path):
        ws, ex, zones, jpath = self._run_zoned(tmp_path)
        try:
            segs = ex.segment_paths()
            assert len(segs) >= 2, "expected >=2 active zone segments"
            ws.journal.flush()
            replayed = replay_segments(jpath, segs)
            assert _registry_story(replayed.registry) == _registry_story(
                ws.registry
            )
            assert replayed.truncated == 0
        finally:
            ex.shutdown()

    def test_merge_matches_single_process_oracle(self, tmp_path):
        ws, ex, zones, jpath = self._run_zoned(tmp_path)
        oracle, _ = _zone_ws(InlineExecutor())
        _drive_zones(oracle, zones)
        try:
            ws.journal.flush()
            replayed = replay_segments(jpath, ex.segment_paths())

            def project(reg):
                # uid values differ across runs; compare per-task shapes
                out = {}
                for s in _registry_story(reg).values():
                    out.setdefault(s["task"], []).append(
                        (sorted(s["visits"]), len(s["parents"]))
                    )
                return {k: sorted(v) for k, v in out.items()}

            assert project(replayed.registry) == project(oracle.registry)
            # ledger story survives the merge byte-for-byte
            live = ws.ledger.stats()
            assert replayed.ledger is not None
            assert replayed.ledger.stats() == live
        finally:
            ex.shutdown()

    def test_from_journal_accepts_segment_list(self, tmp_path):
        ws, ex, zones, jpath = self._run_zoned(tmp_path)
        try:
            ws.journal.flush()
            ws2 = Workspace.from_journal([jpath, *ex.segment_paths()])
            for t in ws.tasks():
                assert [e["event"] for e in ws2.visitor_log(t)] == [
                    e["event"] for e in ws.visitor_log(t)
                ]
        finally:
            ex.shutdown()

    def test_torn_segment_tail_is_tolerated(self, tmp_path):
        ws, ex, zones, jpath = self._run_zoned(tmp_path)
        try:
            ws.journal.flush()
            segs = ex.segment_paths()
            ex.shutdown()
            intact = replay_segments(jpath, segs)
            # simulate a runner dying mid-append: torn trailing line
            with open(segs[0], "a", encoding="utf-8") as fh:
                fh.write('{"seq": 99999, "kind": "vis')
            torn = replay_segments(jpath, segs)
            assert torn.truncated == 1
            assert _registry_story(torn.registry) == _registry_story(
                intact.registry
            )
        finally:
            ex.shutdown()

    def test_interleaved_seqs_restore_total_order(self, tmp_path):
        """Two hand-built segments with interleaved seq windows merge into
        one stream sorted by the global seq protocol."""
        main = Journal(str(tmp_path / "m.jsonl"), workspace="w")
        main.append("task", {"task": "t", "inputs": [], "outputs": [],
                             "version": "v"})
        s1 = main.reserve(2)
        s2 = main.reserve(2)
        main.append("anomaly", {"task": "t", "note": "tail", "seq": 0,
                                "clock": 0})
        main.close()
        seg_a = Journal(str(tmp_path / "m.jsonl.seg-a"), workspace="w",
                        segment="a", flush_every_n=1)
        # a holds the *second* window: later seqs written first on disk
        seg_a.append("anomaly", {"task": "t", "note": "w2-first",
                                 "seq": 0, "clock": 0}, seq=s2)
        seg_a.append("anomaly", {"task": "t", "note": "w2-second",
                                 "seq": 0, "clock": 0}, seq=s2 + 1)
        seg_a.close()
        seg_b = Journal(str(tmp_path / "m.jsonl.seg-b"), workspace="w",
                        segment="b", flush_every_n=1)
        seg_b.append("anomaly", {"task": "t", "note": "w1-first",
                                 "seq": 0, "clock": 0}, seq=s1)
        seg_b.append("anomaly", {"task": "t", "note": "w1-second",
                                 "seq": 0, "clock": 0}, seq=s1 + 1)
        seg_b.close()
        records, truncated = merge_segments(
            str(tmp_path / "m.jsonl"),
            [str(tmp_path / "m.jsonl.seg-a"), str(tmp_path / "m.jsonl.seg-b")],
        )
        assert truncated == 0
        seqs = [r["seq"] for r in records]
        assert seqs == sorted(seqs)
        notes = [r["data"]["note"] for r in records if r["kind"] == "anomaly"]
        assert notes == ["w1-first", "w1-second", "w2-first", "w2-second",
                         "tail"]

    def test_revoked_window_drops_segment_records(self, tmp_path):
        main = Journal(str(tmp_path / "m.jsonl"), workspace="w")
        start = main.reserve(2)
        main.append("revoked", {"task": "t", "start": start, "count": 2})
        main.close()
        seg = Journal(str(tmp_path / "m.jsonl.seg-z"), workspace="w",
                      segment="z", flush_every_n=1)
        seg.append("anomaly", {"task": "t", "note": "dead-runner-orphan",
                               "seq": 0, "clock": 0}, seq=start)
        seg.close()
        records, _ = merge_segments(
            str(tmp_path / "m.jsonl"), [str(tmp_path / "m.jsonl.seg-z")]
        )
        assert not any(
            r["kind"] == "anomaly"
            and r["data"]["note"] == "dead-runner-orphan"
            for r in records
        )

    def test_killed_runner_revokes_and_merge_still_matches(self, tmp_path):
        """Chaos: kill one zone runner mid-run. The retried firing must not
        duplicate AVs in the merged replay, and the merged registry must
        still match the live one."""
        jpath = str(tmp_path / "chaos.jsonl")
        ex = ZonedProcessExecutor(max_workers=2, retry_budget=2)
        ws, zones = _zone_ws(ex, journal_path=jpath)
        _drive_zones(ws, zones, rounds=1)  # forks the runners
        assert ex.kill_runner("edge")
        _drive_zones(ws, zones, rounds=2, seed=7)
        try:
            st = ex.stats()
            ws.journal.flush()
            replayed = replay_segments(jpath, ex.segment_paths())
            assert _registry_story(replayed.registry) == _registry_story(
                ws.registry
            )
            # every firing emitted exactly once in the merged story too
            for t in ws.tasks():
                live = [e["event"] for e in ws.visitor_log(t)]
                assert [
                    e["event"] for e in replayed.registry.visitor_log(t)
                ] == live
        finally:
            ex.shutdown()

    def test_zoned_stats_surface(self, tmp_path):
        ws, ex, zones, jpath = self._run_zoned(tmp_path)
        try:
            st = ex.stats()
            assert st["payload_bytes_over_pipe"] == 0
            assert st["control_bytes_sent"] > 0
            assert set(st["runners"]) <= set(
                Topology.three_zone().zone_names()
            )
            assert len(st["zones"]) >= 2
            assert st["tasks_remote"] > 0
        finally:
            ex.shutdown()
