"""flash_decode Pallas kernel vs reference, including ring-buffer layouts."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_decode import flash_decode
from repro.kernels.ref import reference_decode


# multi-minute model/kernel path: runs in the full CI job only
pytestmark = pytest.mark.slow


RNG = np.random.RandomState(0)


@pytest.mark.parametrize(
    "B,S,H,KVH,Dh,window,bkv,nv,qp",
    [
        (2, 256, 8, 2, 64, 0, 64, 200, 199),
        (1, 300, 4, 4, 32, 0, 128, 300, 299),  # ragged S, MHA
        (2, 128, 4, 1, 64, 48, 32, 100, 99),  # SWA window
        (1, 64, 8, 2, 64, 0, 32, 10, 9),  # mostly-empty cache
    ],
)
def test_flash_decode_sweep(B, S, H, KVH, Dh, window, bkv, nv, qp):
    q = jnp.asarray(RNG.randn(B, 1, H, Dh), jnp.float32)
    k = jnp.asarray(RNG.randn(B, S, KVH, Dh), jnp.float32)
    v = jnp.asarray(RNG.randn(B, S, KVH, Dh), jnp.float32)
    kpos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    qpos = jnp.full((B,), qp, jnp.int32)
    nval = jnp.full((B,), nv, jnp.int32)
    out = flash_decode(q, k, v, kpos, qpos, nval, window=window, block_kv=bkv)
    ref = reference_decode(q, k, v, kpos, qpos, nval, window=window)
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


def test_flash_decode_ring_positions():
    """SWA ring buffer: slot order is rotated, positions are explicit."""
    B, S, H, KVH, Dh, W = 1, 64, 4, 2, 32, 64
    q = jnp.asarray(RNG.randn(B, 1, H, Dh), jnp.float32)
    k = jnp.asarray(RNG.randn(B, S, KVH, Dh), jnp.float32)
    v = jnp.asarray(RNG.randn(B, S, KVH, Dh), jnp.float32)
    # a ring at absolute time 100: slot i holds position (100 - W + 1 + i)
    # rotated by 13
    base = jnp.arange(S) + (100 - W + 1)
    kpos = jnp.roll(base, 13)[None]
    qpos = jnp.asarray([100], jnp.int32)
    nval = jnp.asarray([S], jnp.int32)
    out = flash_decode(q, k, v, kpos, qpos, nval, window=W, block_kv=16)
    ref = reference_decode(q, k, v, kpos, qpos, nval, window=W)
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


def test_flash_decode_bf16():
    B, S, H, KVH, Dh = 1, 128, 4, 2, 64
    q = jnp.asarray(RNG.randn(B, 1, H, Dh)).astype(jnp.bfloat16)
    k = jnp.asarray(RNG.randn(B, S, KVH, Dh)).astype(jnp.bfloat16)
    v = jnp.asarray(RNG.randn(B, S, KVH, Dh)).astype(jnp.bfloat16)
    kpos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    out = flash_decode(q, k, v, kpos, jnp.asarray([127]), jnp.asarray([128]))
    ref = reference_decode(q, k, v, kpos, jnp.asarray([127]), jnp.asarray([128]))
    np.testing.assert_allclose(
        out.astype(jnp.float32), ref.astype(jnp.float32), rtol=2e-2, atol=2e-2
    )
