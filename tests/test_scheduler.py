"""Event-driven scheduler core (ISSUE 3): notification-driven ready queue,
concurrent executor waves, bounded links/backpressure, notify-threshold
poll-mode fast path, and pull-mode edge cases."""

import threading
import time

import numpy as np
import pytest

from repro.core import (
    AnnotatedValue,
    ArtifactStore,
    LinkBackpressureError,
    Pipeline,
    PipelineManager,
    SmartLink,
    SmartTask,
)
from repro.workspace import ConcurrentExecutor, InlineExecutor, Workspace


# ---------------------------------------------------------------------------
# circuits
# ---------------------------------------------------------------------------


def _chain_ws(n=3, executor=None, cache=False):
    """t0 -> t1 -> ... -> t{n-1}, each incrementing."""
    ws = Workspace("chain", executor=executor, cache=cache)
    prev = ws.task(lambda x: {"y": x + 1}, name="t0", inputs=["x"], outputs=["y"])
    for i in range(1, n):
        cur = ws.task(
            lambda x: {"y": x + 1}, name=f"t{i}", inputs=["x"], outputs=["y"]
        )
        prev["y"] >> cur["x"]
        prev = cur
    return ws


def _fanout_ws(width=4, heavy_ms=0.0, executor=None):
    """src fans out to `width` workers; workers merge-FCFS into a sink."""
    ws = Workspace("fanout", executor=executor)
    outs = [f"o{i}" for i in range(width)]

    def src(x):
        return {f"o{i}": x + i for i in range(width)}

    s = ws.task(src, name="src", inputs=["x"], outputs=outs)

    def work(v):
        if heavy_ms:
            time.sleep(heavy_ms / 1e3)
        return {"w": v * 10}

    sink_inputs = [f"i{i}" for i in range(width)]
    sink = ws.task(
        lambda merged: {"total": list(merged)},
        name="sink",
        inputs=sink_inputs,
        outputs=["total"],
        mode="merge",
    )
    for i in range(width):
        w = ws.task(work, name=f"w{i}", inputs=["v"], outputs=["w"])
        s[f"o{i}"] >> w["v"]
        w["w"] >> sink[f"i{i}"]
    return ws


def _diamond_ws(executor=None, cache=False):
    """     top
           /    \\
        left    right
           \\    /
            join          (swap_new_for_old)
    """
    ws = Workspace("diamond", executor=executor, cache=cache)
    top = ws.task(lambda x: {"y": x * 2}, name="top", inputs=["x"], outputs=["y"])
    left = ws.task(lambda y: {"l": y + 1}, name="left", inputs=["y"], outputs=["l"])
    right = ws.task(lambda y: {"r": y + 2}, name="right", inputs=["y"], outputs=["r"])
    join = ws.task(
        lambda l, r: {"s": l + r},
        name="join",
        inputs=["l", "r"],
        outputs=["s"],
        mode="swap_new_for_old",
    )
    top["y"] >> left["y"]
    top["y"] >> right["y"]
    left["l"] >> join["l"]
    right["r"] >> join["r"]
    return ws


# ---------------------------------------------------------------------------
# event-driven propagation (no polling scans)
# ---------------------------------------------------------------------------


def test_push_results_unchanged_and_no_polling():
    ws = _chain_ws(n=4)
    run = ws.push("t0", x=0)
    assert run["t3"]["y"] == 4
    sched = ws.stats()["scheduler"]
    # 4 tasks enqueued (one per chain stage), while a polling engine would
    # have scanned 4 tasks x (4 waves + quiescence round)
    assert sched["tasks_enqueued"] == 4
    assert sched["tasks_executed"] == 4
    assert sched["polling_scan_equivalent"] > 3 * sched["tasks_enqueued"]
    assert sched["waves"] == 4


def test_enqueued_scales_with_events_not_circuit_size():
    """The acceptance claim: tasks-enqueued << tasks-scanned-equivalent.
    A hot 2-task chain inside a 16-task circuit only ever enqueues the hot
    pair; polling would rescan all 16 every round."""
    ws = Workspace("sparse", cache=False)
    a = ws.task(lambda x: {"y": x}, name="hot_a", inputs=["x"], outputs=["y"])
    b = ws.task(lambda y: {"z": y}, name="hot_b", inputs=["y"], outputs=["z"])
    a["y"] >> b["y"]
    for i in range(14):
        ws.task(lambda q: {"r": q}, name=f"cold{i}", inputs=["q"], outputs=["r"])
    for i in range(10):
        ws.push("hot_a", x=i)
    sched = ws.stats()["scheduler"]
    assert sched["tasks_enqueued"] == 20  # 2 per push
    assert sched["polling_scan_equivalent"] >= 16 * 3 * 10
    assert sched["scan_reduction_x"] > 10


def test_cycle_bounded_by_per_task_fire_budget():
    pipe = Pipeline("cyc")
    pipe._add_task(SmartTask("a", lambda x: {"y": x + 1}, ["x"], ["y"]))
    pipe._add_task(SmartTask("b", lambda y: {"x": y}, ["y"], ["x"]))
    pipe._connect("a", "y", "b", "y")
    pipe._connect("b", "x", "a", "x")
    mgr = PipelineManager(pipe, max_rounds=5, cache=False)
    fired = mgr._push("a", x=0)
    assert len(fired["a"]) <= 5  # per-task budget, not global rounds
    assert mgr.scheduler.stats()["budget_exhausted"] >= 1


def test_diamond_fires_once_per_push_no_glitch():
    """swap_new_for_old join must not fire early on the short diamond leg
    with a stale value (wave deferral = the old topological round order)."""
    ws = _diamond_ws()
    ws.push("top", x=1)
    ws.push("top", x=2)
    t = ws.pipeline.tasks["join"]
    assert t.executions + t.cache_hits == 2
    # l = 2x+1, r = 2x+2 -> s = 4x+3
    assert ws.value_of(t.last_outputs["s"]) == 11


def test_scheduler_stats_surface_in_workspace():
    ws = _chain_ws(n=2)
    ws.push("t0", x=1)
    sched = ws.stats()["scheduler"]
    for key in (
        "waves",
        "tasks_enqueued",
        "tasks_executed",
        "queue_depth_high_water",
        "polling_scan_equivalent",
        "notifications_received",
        "backend",
    ):
        assert key in sched
    assert sched["queue_depth_high_water"] >= 1
    assert ws.stats()["executor"]["waves_run"] == sched["waves"]


# ---------------------------------------------------------------------------
# concurrent executor waves
# ---------------------------------------------------------------------------


def test_concurrent_results_match_inline():
    runs = {}
    for name, ex in (("inline", InlineExecutor()), ("conc", ConcurrentExecutor(4))):
        ws = _fanout_ws(width=4, executor=ex)
        ws.push("src", x=100)
        sink = ws.pipeline.tasks["sink"]
        runs[name] = {
            "total": ws.value_of(sink.last_outputs["total"]),
            "sustainability": ws.stats()["sustainability"],
            "events": sorted(
                (t, e["event"])
                for t in ws.tasks()
                for e in ws.visitor_log(t)
            ),
        }
    # merge-FCFS order, sustainability counters, and provenance event
    # multiset are identical across backends (deferred serial emission)
    assert runs["inline"]["total"] == runs["conc"]["total"]
    assert runs["inline"]["sustainability"] == runs["conc"]["sustainability"]
    assert runs["inline"]["events"] == runs["conc"]["events"]


def test_concurrent_wave_actually_parallel():
    ws = _fanout_ws(width=4, heavy_ms=30.0, executor=ConcurrentExecutor(max_workers=4))
    t0 = time.perf_counter()
    ws.push("src", x=0)
    wall = time.perf_counter() - t0
    # 4 x 30ms serially would be >= 120ms; parallel should be well under
    assert wall < 0.100, f"fanout wave did not parallelize (wall={wall:.3f}s)"
    ex = ws.stats()["executor"]
    assert ex["parallel_waves"] >= 1
    assert ex["tasks_parallel"] >= 4


def test_concurrent_merge_order_deterministic_across_runs():
    def run_once():
        ws = _fanout_ws(width=6, heavy_ms=2.0, executor=ConcurrentExecutor(6))
        ws.push("src", x=0)
        sink = ws.pipeline.tasks["sink"]
        return ws.value_of(sink.last_outputs["total"])

    first = run_once()
    assert first == [i * 10 for i in range(6)]  # wave (emission) order
    for _ in range(3):
        assert run_once() == first


def test_mesh_executor_composes_with_concurrent_inner():
    from repro.workspace import MeshExecutor

    inner = ConcurrentExecutor(max_workers=2)
    ex = MeshExecutor(inner=inner)
    ws = _fanout_ws(width=3, executor=ex)
    ws.push("src", x=1)
    sink = ws.pipeline.tasks["sink"]
    assert ws.value_of(sink.last_outputs["total"]) == [10, 20, 30]
    assert ex.stats()["inner"]["waves_run"] >= 1


def test_default_executor_env_selection(monkeypatch):
    from repro.workspace import default_executor

    monkeypatch.delenv("KOALJA_EXECUTOR", raising=False)
    assert type(default_executor()).__name__ == "InlineExecutor"
    monkeypatch.setenv("KOALJA_EXECUTOR", "concurrent")
    monkeypatch.setenv("KOALJA_MAX_WORKERS", "3")
    ex = default_executor()
    assert type(ex).__name__ == "ConcurrentExecutor"
    assert ex.max_workers == 3
    monkeypatch.setenv("KOALJA_EXECUTOR", "bogus")
    with pytest.raises(ValueError):
        default_executor()


# ---------------------------------------------------------------------------
# bounded links / backpressure
# ---------------------------------------------------------------------------


def _offer(link, store, payload=1):
    uri, h = store.put(payload)
    av = AnnotatedValue.produce(h, uri, "a", "v")
    link.offer(av)
    return av


def test_bounded_link_drop_oldest():
    store = ArtifactStore()
    link = SmartLink("l", "a", "b", "x", capacity=2, overflow="drop_oldest")
    avs = [_offer(link, store, i) for i in range(4)]
    assert link.peek_count() == 2
    assert link.stats()["dropped"] == 2
    # ring semantics: the two newest survive
    assert link.poll().uid == avs[2].uid
    assert link.poll().uid == avs[3].uid


def test_bounded_link_error_policy():
    store = ArtifactStore()
    link = SmartLink("l", "a", "b", "x", capacity=1, overflow="error")
    _offer(link, store)
    with pytest.raises(LinkBackpressureError):
        _offer(link, store, 2)


def test_bounded_link_block_times_out_then_unblocks():
    store = ArtifactStore()
    link = SmartLink(
        "l", "a", "b", "x", capacity=1, overflow="block", block_timeout_s=0.05
    )
    _offer(link, store)
    t0 = time.perf_counter()
    with pytest.raises(LinkBackpressureError):
        _offer(link, store, 2)
    assert time.perf_counter() - t0 >= 0.04
    # a consumer draining from another thread releases the producer
    def drain_soon():
        time.sleep(0.02)
        link.poll()

    threading.Thread(target=drain_soon).start()
    link2 = link  # same bounded link; offer blocks briefly then succeeds
    _offer(link2, store, 3)
    assert link.stats()["blocked_waits"] >= 2


def test_block_link_inside_engine_never_stalls_or_loses():
    """The drain thread is both producer and consumer: a full block-policy
    link is relieved by the scheduler (drained into the consumer's policy
    buffer), not blocked against itself until timeout. Suppressed
    notifications keep the consumer from ingesting, so the producer's
    2nd..5th emissions in this drain genuinely hit a full link."""
    ws = Workspace("blockrelief", cache=False)
    a = ws.task(lambda x: {"y": x}, name="a", inputs=["x"], outputs=["y"])
    got = []
    b = ws.task(
        lambda y: got.append(y) or {"z": y}, name="b", inputs=["y"], outputs=["z"]
    )
    wire = a["y"] >> b["y"]
    wire.capacity(1, overflow="block", block_timeout_s=0.2)
    wire.notify_threshold(10.0)
    for i in range(5):
        ws.inject("a", "x", i)  # buffer 5 firings for one drain
    t0 = time.perf_counter()
    ws.manager.propagate()
    wall = time.perf_counter() - t0
    assert wall < 0.2, f"engine stalled on its own bounded link ({wall:.2f}s)"
    assert got == [0, 1, 2, 3, 4], "relief valve must not lose arrivals"
    assert ws.stats()["links"]["a.y->b.y"]["blocked_waits"] == 0


def test_fire_budget_does_not_strand_buffered_acyclic_work():
    """Seed parity: 150 pre-buffered arrivals drain fully in ONE propagate
    even though the per-task fire budget is 100 — self-requeues (draining
    one's own buffers) are exempt; only arrival-driven refires (cycles)
    are budgeted."""
    pipe = Pipeline("buffered")
    pipe._add_task(SmartTask("t", lambda x: {"y": x}, ["x"], ["y"]))
    mgr = PipelineManager(pipe, max_rounds=100, cache=False)
    for i in range(150):
        mgr._inject("t", "x", i)
    fired = mgr.propagate()
    assert len(fired["t"]) == 150
    assert mgr.pipeline.tasks["t"].policy.stats()["pending"]["x"] == 0


def test_throttled_cycle_resumes_on_next_propagate():
    """Seed parity: a budget-capped cycle picks up again when propagate()
    is called a second time (fresh per-drain budgets)."""
    pipe = Pipeline("cyc")
    pipe._add_task(SmartTask("a", lambda x: {"y": x + 1}, ["x"], ["y"]))
    pipe._add_task(SmartTask("b", lambda y: {"x": y}, ["y"], ["x"]))
    pipe._connect("a", "y", "b", "y")
    pipe._connect("b", "x", "a", "x")
    mgr = PipelineManager(pipe, max_rounds=3, cache=False)
    first = mgr._push("a", x=0)
    n1 = len(first.get("a", []))
    assert n1 <= 3
    second = mgr.propagate()
    assert len(second.get("a", [])) >= 1, "cycle resumes with a fresh budget"


def test_workspace_wire_capacity_fluent():
    ws = Workspace("bounded", cache=False)
    a = ws.task(lambda x: {"y": x}, name="a", inputs=["x"], outputs=["y"])
    b = ws.task(
        lambda y: {"z": y}, name="b", inputs=["y[2]"], outputs=["z"]
    )
    (a["y"] >> b["y"]).capacity(1, overflow="drop_oldest")
    ws.push("a", x=1)  # b needs 2 values; 1 sits on the bounded link
    link = ws.pipeline.links[0]
    assert link.capacity == 1 and link.overflow == "drop_oldest"


# ---------------------------------------------------------------------------
# notify_threshold_s: the poll-mode fast path (§III.J)
# ---------------------------------------------------------------------------


def test_notify_threshold_suppresses_but_loses_nothing():
    ws = Workspace("thresh", cache=False)
    a = ws.task(lambda x: {"y": x}, name="a", inputs=["x"], outputs=["y"])
    got = []
    b = ws.task(
        lambda y: got.append(y) or {"z": y}, name="b", inputs=["y"], outputs=["z"]
    )
    # arrivals far faster than 10s -> every offer after the first suppresses
    (a["y"] >> b["y"]).notify_threshold(10.0)
    for i in range(5):
        ws.push("a", x=i)
    assert got == [0, 1, 2, 3, 4], "suppressed arrivals still processed"
    link_stats = ws.stats()["links"]["a.y->b.y"]
    assert link_stats["notified"] == 1  # only the first arrival interrupted
    assert link_stats["suppressed"] == 4
    assert ws.stats()["scheduler"]["sweeps"] >= 1  # coalesced batch polls


def test_notify_threshold_zero_always_notifies():
    store = ArtifactStore()
    link = SmartLink("l", "a", "b", "x", notify_threshold_s=0.0)
    for i in range(3):
        _offer(link, store, i)
    assert link.stats()["notified"] == 3
    assert link.stats()["suppressed"] == 0


def test_notifications_counted_per_event_not_per_subscriber():
    store = ArtifactStore()
    link = SmartLink("l", "a", "b", "x")
    seen1, seen2 = [], []
    link.subscribe(lambda l, av: seen1.append(av.uid))
    link.subscribe(lambda l, av: seen2.append(av.uid))
    _offer(link, store)
    assert len(seen1) == len(seen2) == 1
    assert link.notifications_sent == 1  # one event, not two callbacks


def test_link_concurrent_offers_thread_safety():
    store = ArtifactStore()
    link = SmartLink("l", "a", "b", "x")
    seen = []
    link.subscribe(lambda l, av: seen.append(av.uid))
    uri, h = store.put(0)

    def spam(n):
        for _ in range(n):
            link.offer(AnnotatedValue.produce(h, uri, "a", "v"))

    threads = [threading.Thread(target=spam, args=(50,)) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert link.peek_count() == 200
    assert link.avs_carried == 200
    assert link.notifications_sent == 200
    assert len(seen) == 200


# ---------------------------------------------------------------------------
# pull-mode edge cases (satellite)
# ---------------------------------------------------------------------------


def _reference_recursive_pull(mgr, target, _visiting=None):
    """The seed's recursive pull, verbatim, as a behavioural oracle."""
    _visiting = _visiting if _visiting is not None else set()
    if target in _visiting:
        return mgr.pipeline.tasks[target].last_outputs
    _visiting.add(target)
    t = mgr.pipeline.tasks[target]
    for link in t.in_links.values():
        _reference_recursive_pull(mgr, link.src_task, _visiting)
    t.ingest()
    if t.ready():
        return t.execute(mgr.store, mgr.registry, mgr.cache)
    if t.source and not t.input_specs:
        return t.execute(mgr.store, mgr.registry, mgr.cache)
    if t.last_outputs:
        return t.last_outputs
    raise RuntimeError(f"pull({target}): no data")


def _pull_circuit():
    pipe = Pipeline("p")
    pipe._add_task(SmartTask("double", lambda x: {"y": x * 2}, ["x"], ["y"]))
    pipe._add_task(SmartTask("inc", lambda y: {"z": y + 1}, ["y"], ["z"]))
    pipe._add_task(
        SmartTask("add", lambda y, z: {"w": y + z}, ["y", "z"], ["w"],
                  mode="swap_new_for_old")
    )
    pipe._connect("double", "y", "inc", "y")
    pipe._connect("double", "y", "add", "y")
    pipe._connect("inc", "z", "add", "z")
    return pipe


def test_scheduler_pull_matches_recursive_oracle():
    mgr_new = PipelineManager(_pull_circuit())
    mgr_old = PipelineManager(_pull_circuit())
    mgr_new._push("double", x=21)
    mgr_old._push("double", x=21)
    out_new = mgr_new._pull("add")
    out_old = _reference_recursive_pull(mgr_old, "add")
    assert out_new.keys() == out_old.keys() == {"w"}
    assert mgr_new.value_of(out_new["w"]) == mgr_old.value_of(out_old["w"])
    # identical (re-)execution behaviour, not just identical values
    for name in ("double", "inc", "add"):
        assert (
            mgr_new.pipeline.tasks[name].executions
            == mgr_old.pipeline.tasks[name].executions
        )


def test_pull_cycle_guard_empty_last_outputs_raises():
    """A pure cycle with no data anywhere: the back-edge contributes empty
    last_outputs, so pull must fail loudly (matches the seed recursion)."""
    pipe = Pipeline("cyc")
    pipe._add_task(SmartTask("a", lambda x: {"y": x + 1}, ["x"], ["y"]))
    pipe._add_task(SmartTask("b", lambda y: {"x": y}, ["y"], ["x"]))
    pipe._connect("a", "y", "b", "y")
    pipe._connect("b", "x", "a", "x")
    mgr = PipelineManager(pipe, cache=False)
    with pytest.raises(RuntimeError, match="no prior"):
        mgr._pull("a")


def test_pull_cycle_with_prior_outputs_reuses_them():
    pipe = Pipeline("cyc2")
    pipe._add_task(SmartTask("a", lambda x: {"y": x + 1}, ["x"], ["y"]))
    pipe._add_task(SmartTask("b", lambda y: {"x": y}, ["y"], ["x"]))
    pipe._connect("a", "y", "b", "y")
    pipe._connect("b", "x", "a", "x")
    mgr = PipelineManager(pipe, max_rounds=3, cache=False)
    mgr._push("a", x=0)  # cycle spins up to the fire budget, leaves outputs
    out = mgr._pull("a")
    assert "y" in out


def test_repeated_pull_diamond_shared_ancestor_executes_once():
    ws = _diamond_ws(cache=False)
    ws.push("top", x=3)
    execs_after_push = {n: ws.pipeline.tasks[n].executions for n in ws.tasks()}
    assert execs_after_push["top"] == 1
    first = ws.pull("join")
    second = ws.pull("join")
    # nothing new arrived: both pulls resolve from prior outputs; the shared
    # ancestor (and everything else) never re-executes
    for name in ("top", "left", "right", "join"):
        assert ws.pipeline.tasks[name].executions == execs_after_push[name]
    assert first["s"] == second["s"] == (2 * 3 + 1) + (2 * 3 + 2)


def test_pull_unknown_task_raises_keyerror():
    ws = _chain_ws(n=2)
    with pytest.raises(KeyError):
        ws.manager._pull("nope")
