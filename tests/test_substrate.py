"""Optimizer, schedules, compression, checkpointing, data pipeline, FT."""

import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, restore_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.data.pipeline import build_data_pipeline, next_batch, synthetic_batch
from repro.dist.ft import FaultToleranceManager, SimulatedFailure
from repro.optim import (
    adamw_init,
    adamw_update,
    cosine_warmup,
    dequantize_int8,
    ef_compress,
    global_norm,
    linear_warmup,
    quantize_int8,
)


# ---------------------------------------------------------------------------
# Optimizer
# ---------------------------------------------------------------------------


def test_adamw_converges_on_quadratic():
    params = {"w": jnp.array([5.0, -3.0, 2.0])}
    opt = adamw_init(params)
    target = jnp.array([1.0, 1.0, 1.0])

    def loss_fn(p):
        return jnp.sum((p["w"] - target) ** 2)

    for _ in range(400):
        g = jax.grad(loss_fn)(params)
        params, opt, m = adamw_update(params, g, opt, jnp.float32(0.05), weight_decay=0.0)
    assert float(loss_fn(params)) < 1e-3
    assert int(opt["count"]) == 400


def test_grad_clip():
    params = {"w": jnp.zeros(3)}
    opt = adamw_init(params)
    g = {"w": jnp.array([1e6, 0.0, 0.0])}
    _, _, m = adamw_update(params, g, opt, jnp.float32(0.1), clip_norm=1.0)
    assert float(m["grad_norm"]) > 1e5
    assert float(m["clip_scale"]) < 1e-4


def test_schedules():
    cos = cosine_warmup(1.0, 10, 100)
    lin = linear_warmup(1.0, 10, 100)
    assert float(cos(jnp.int32(0))) == 0.0
    assert abs(float(cos(jnp.int32(10))) - 1.0) < 1e-6
    assert float(cos(jnp.int32(100))) < 0.2
    assert float(lin(jnp.int32(5))) == pytest.approx(0.5)
    assert float(lin(jnp.int32(100))) == pytest.approx(0.0, abs=1e-6)


def test_quantize_roundtrip_error():
    x = jnp.asarray(np.random.RandomState(0).randn(1000), jnp.float32)
    q, s = quantize_int8(x)
    assert q.dtype == jnp.int8
    err = jnp.abs(x - dequantize_int8(q, s))
    assert float(err.max()) <= float(s) / 2 + 1e-6


def test_error_feedback_accumulates():
    """With error feedback, the *sum* of dequantized grads over steps tracks
    the sum of true grads far better than independent quantization."""
    rng = np.random.RandomState(0)
    true = [jnp.asarray(rng.randn(256) * (10.0 ** rng.uniform(-3, 0)), jnp.float32) for _ in range(50)]
    # simulate single-pod psum (n=1) so we isolate the EF mechanics
    residual = jnp.zeros(256)
    ef_sum = jnp.zeros(256)
    naive_sum = jnp.zeros(256)
    for g in true:
        q, s = quantize_int8(g + residual)
        deq = dequantize_int8(q, s)
        residual = (g + residual) - deq
        ef_sum = ef_sum + deq
        qn, sn = quantize_int8(g)
        naive_sum = naive_sum + dequantize_int8(qn, sn)
    true_sum = sum(true)
    ef_err = float(jnp.abs(ef_sum - true_sum).max())
    naive_err = float(jnp.abs(naive_sum - true_sum).max())
    assert ef_err <= naive_err  # EF at least as good
    assert ef_err < 0.1 * float(jnp.abs(true_sum).max() + 1.0)


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------


def _tiny_state():
    return {
        "params": {"w": jnp.arange(12.0).reshape(3, 4), "b": jnp.ones(4)},
        "opt": {"m": {"w": jnp.zeros((3, 4)), "b": jnp.zeros(4)}, "count": jnp.int32(7)},
        "step": jnp.int32(7),
    }


def test_checkpoint_roundtrip(tmp_path):
    state = _tiny_state()
    av = save_checkpoint(str(tmp_path), state, 7, software_version="v-x")
    assert av.meta["step"] == 7
    like = jax.tree.map(jnp.zeros_like, state)
    restored, manifest = restore_checkpoint(str(tmp_path), like)
    assert manifest["software_version"] == "v-x"
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_manager_retention_and_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, software_version="v-y")
    state = _tiny_state()
    for s in (1, 2, 3, 4):
        mgr.save_async(state, s)
    mgr.wait()
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert steps == ["step_00000003", "step_00000004"]
    assert mgr.latest_step() == 4
    assert len(mgr.saved) == 4  # all AVs carry travel documents
    assert all(a.travel_document for a in mgr.saved)


def test_checkpoint_shape_mismatch_raises(tmp_path):
    save_checkpoint(str(tmp_path), {"w": jnp.zeros((2, 2))}, 1)
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path), {"w": jnp.zeros((3, 3))})


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------


def test_synthetic_batch_deterministic():
    cfg = get_config("stablelm-1.6b").reduced()
    b1 = synthetic_batch(cfg, 4, 32, step=3)
    b2 = synthetic_batch(cfg, 4, 32, step=3)
    b3 = synthetic_batch(cfg, 4, 32, step=4)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    assert b1["tokens"].max() < cfg.vocab
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])


def test_data_pipeline_fresh_batches_with_provenance():
    cfg = get_config("stablelm-1.6b").reduced()
    mgr = build_data_pipeline(cfg, global_batch=4, seq_len=16)
    b1 = next_batch(mgr, cfg)
    b2 = next_batch(mgr, cfg)
    assert b1["tokens"].shape == (4, 16)
    assert not np.array_equal(b1["tokens"], b2["tokens"])  # sensors not cached
    # every batch AV has a lineage reaching back to sample emissions
    av = mgr.pipeline.tasks["batch"].last_outputs["batch"]
    lin = mgr.registry.lineage(av.uid)
    def tasks_in(node, acc):
        acc.add(node["source_task"])
        for p in node["parents"]:
            tasks_in(p, acc)
        return acc
    assert "sample" in tasks_in(lin, set())


# ---------------------------------------------------------------------------
# Fault tolerance
# ---------------------------------------------------------------------------


def test_straggler_detection():
    ft = FaultToleranceManager(n_hosts=8, straggler_zscore=3.0)
    for step in range(16):
        for h in range(8):
            ft.heartbeat(h, 1.0 + (0.5 if h == 5 else 0.01) * np.random.RandomState(step * 8 + h).rand())
    out = ft.stragglers()
    assert [h for h, _ in out] == [5]


def test_dead_host_detection():
    ft = FaultToleranceManager(n_hosts=2, heartbeat_timeout_s=0.01)
    ft.heartbeat(0, 1.0)
    ft.heartbeat(1, 1.0)
    time.sleep(0.05)
    ft.heartbeat(0, 1.0)
    assert ft.dead_hosts() == [1]


def test_run_with_recovery():
    ft = FaultToleranceManager(n_hosts=1)
    calls = {"restores": 0, "fails_left": 2}

    def restore():
        calls["restores"] += 1
        return calls["restores"] - 1  # pretend each restore advances a step

    def run(start):
        if calls["fails_left"] > 0:
            calls["fails_left"] -= 1
            raise SimulatedFailure(0)
        return f"done-from-{start}"

    out = ft.run_with_recovery(run, restore)
    assert out == "done-from-2"
    assert ft.restarts == 2
