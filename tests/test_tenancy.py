"""Multi-tenant workspace control plane (ISSUE 9): hub-hosted workspaces
with memberships/roles, per-tenant journal segments in one hub seq space,
per-tenant transfer quotas, and cross-tenant memo dedup over the shared
content-addressed store.

The load-bearing property: **interleaving is invisible**. Any interleaving
of N tenants' pushes leaves each tenant with lineage / visitor-log /
ledger fingerprints byte-identical to the same session script run on a
private solo workspace — except the sustainability counters
(``bytes_saved`` / ``executions_avoided``), which may only improve.
"""

import os
import threading

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # hermetic containers: seeded-random fallback
    from repro.testing.hypothesis_fallback import given, settings, strategies as st

from repro.tenancy import (
    PermissionDeniedError,
    QuotaExceededError,
    TenancyError,
    TenantQuota,
    WorkspaceHub,
    tenant_fingerprint,
)
from repro.topology import Topology
from repro.workspace import (
    ConcurrentExecutor,
    InlineExecutor,
    Workspace,
    ZonedExecutor,
)

FUZZ_EXAMPLES = int(os.environ.get("KOALJA_FUZZ_EXAMPLES", "20"))


# ---------------------------------------------------------------------------
# shared circuit (module-level fns => identical software versions across
# tenants and solo oracles — the content-dedup precondition)
# ---------------------------------------------------------------------------


def _fx_src(x):
    return {"out": [int(v) * 2 for v in x]}


def _fx_left(v):
    return {"y": [int(i) + 1 for i in v]}


def _fx_right(v):
    return {"y": [int(i) - 1 for i in v]}


def _fx_join(a, b):
    return {"out": sum(a) + sum(b)}


def _wire(api, zoned=False):
    """src -> (left, right) -> join. The fan-out makes wave 2 a two-task
    wave, so process/zoned backends actually dispatch remotely."""
    src = api.task(_fx_src, name="src", inputs=["x"], outputs=["out"])
    left = api.task(_fx_left, name="left", inputs=["v"], outputs=["y"])
    right = api.task(_fx_right, name="right", inputs=["v"], outputs=["y"])
    join = api.task(_fx_join, name="join", inputs=["a", "b"], outputs=["out"])
    if zoned:
        src.place("edge")
        left.place("edge")
        right.place("cloud")
        join.place("cloud")
    api.wire(src["out"], left["v"])
    api.wire(src["out"], right["v"])
    api.wire(left["y"], join["a"])
    api.wire(right["y"], join["b"])


def _topo():
    t = Topology("duo")
    t.zone("cloud", tier="cloud")
    t.zone("edge", tier="edge")
    t.link("cloud", "edge", bandwidth_mbps=50, latency_ms=10, energy_j_per_mb=0.05)
    return t


# the shared working set: payloads tenants have in common dedup hub-wide
def _payload(i):
    return [i, i + 1, i + 2]


def _solo(payloads, *, executor=None, topology=False, journal_path=False,
          zoned=False):
    """The oracle: the same session script on a private workspace."""
    ws = Workspace(
        "solo", executor=executor, topology=topology, journal_path=journal_path,
    )
    _wire(ws, zoned=zoned)
    for p in payloads:
        ws.push("src", x=_payload(p))
    return ws


def _stop(ws):
    stop = getattr(ws.executor, "shutdown", None)
    if stop:
        stop()


def _solo_fp(payloads, **kw):
    ws = _solo(payloads, **kw)
    fp = tenant_fingerprint(ws)
    _stop(ws)
    return fp


# ---------------------------------------------------------------------------
# the isolation property
# ---------------------------------------------------------------------------


class TestIsolationProperty:
    @settings(max_examples=FUZZ_EXAMPLES, deadline=None)
    @given(st.data())
    def test_any_interleaving_matches_solo(self, data):
        n_tenants = data.draw(st.integers(min_value=2, max_value=4))
        scripts = [
            data.draw(
                st.lists(
                    st.integers(min_value=0, max_value=3), min_size=1, max_size=4
                )
            )
            for _ in range(n_tenants)
        ]
        hub = WorkspaceHub("hub", journal_path=False,
                           executor_factory=InlineExecutor,
                           workspace_defaults={"topology": False})
        sessions = [hub.create(f"t{i}", owner=f"u{i}") for i in range(n_tenants)]
        for s in sessions:
            _wire(s)
        # interleave: draw which tenant advances next until scripts drain
        cursors = [0] * n_tenants
        while any(c < len(s) for c, s in zip(cursors, scripts)):
            live = [i for i in range(n_tenants) if cursors[i] < len(scripts[i])]
            pick = live[data.draw(st.integers(min_value=0, max_value=len(live) - 1))]
            sessions[pick].push("src", x=_payload(scripts[pick][cursors[pick]]))
            cursors[pick] += 1
        for i, s in enumerate(sessions):
            assert s.fingerprint() == _solo_fp(scripts[i], executor=InlineExecutor())
            # savings may only improve: tenant-local cache behavior is
            # byte-identical to solo; hub-level dedup only adds on top
            solo = _solo(scripts[i], executor=InlineExecutor())
            assert s.ws._cache.stats() == solo._cache.stats()
        assert hub.memo.stats()["executions_avoided"] >= 0

    def test_cross_tenant_dedup_and_scoping(self):
        hub = WorkspaceHub("hub", journal_path=False,
                           workspace_defaults={"topology": False})
        a = hub.create("team-a", owner="alice")
        b = hub.create("team-b", owner="bev")
        _wire(a)
        _wire(b)
        assert a.ws.store is b.ws.store  # one content-addressed store
        a.push("src", x=_payload(7))
        before = hub.memo.stats()
        b.push("src", x=_payload(7))  # same bytes: B's tasks never run
        after = hub.memo.stats()
        assert after["executions_avoided"] - before["executions_avoided"] == 4
        assert after["bytes_saved"] > before["bytes_saved"]
        assert after["by_tenant"]["team-b"]["hits"] == 4
        # the hub-level credit names both tenants; the tenants' own
        # provenance names neither
        fa, fb = a.fingerprint(), b.fingerprint()
        assert fa == _solo_fp([7])
        assert fb == _solo_fp([7])
        assert "team-a" not in fb and "team-b" not in fa
        # lineage reads stay tenant-scoped: B's registry holds only B's AVs
        assert not set(a.ws.registry.all_avs()) & set(b.ws.registry.all_avs())

    def test_dedup_falls_through_on_evicted_origin(self):
        # an unresolvable origin output must fall back to a real run, not
        # crash and not leak a bogus credit
        hub = WorkspaceHub("hub", journal_path=False,
                           workspace_defaults={"topology": False})
        a = hub.create("a", owner="u")
        b = hub.create("b", owner="u")
        _wire(a)
        _wire(b)
        a.push("src", x=_payload(1))
        # evict everything A produced from the shared store
        for uid in a.ws.registry.all_avs():
            av = a.ws.registry.get_av(uid)
            try:
                hub.store.evict_local(av.uri)
            except Exception:
                pass
        hits_before = hub.memo.stats()["dedup_hits"]
        b.push("src", x=_payload(1))  # recomputes instead of replaying
        assert b.ws.pipeline.tasks["join"].executions >= 1
        assert hub.memo.stats()["dedup_hits"] >= hits_before


# ---------------------------------------------------------------------------
# all six executor backends
# ---------------------------------------------------------------------------


def _backend_factories():
    from repro.runtime import ProcessExecutor, ZonedProcessExecutor

    return [
        ("inline", InlineExecutor),
        ("concurrent", lambda: ConcurrentExecutor(max_workers=4)),
        ("zoned", ZonedExecutor),
        ("zoned-concurrent", lambda: ZonedExecutor(inner=ConcurrentExecutor(max_workers=4))),
        ("process", lambda: ProcessExecutor(max_workers=2)),
        ("zoned-process", lambda: ZonedProcessExecutor(max_workers=2)),
    ]


class TestBackendDeterminism:
    def test_tenant_fingerprints_identical_across_backends(self, tmp_path):
        """The isolation property holds on every backend: each hub tenant's
        fingerprint is bit-identical to the same script on a private solo
        workspace driven by the *same* executor type. Across backend types
        the produced content (AV task/chash graph) must also agree — URIs
        and storage tiers legitimately differ (process backends hand over
        via the object tier), which is the engine's documented contract
        (cf. tests/test_topology determinism)."""
        import json as _json

        scripts = {"t0": [0, 1, 0], "t1": [0, 2], "t2": [2, 1]}
        content = {name: [] for name in scripts}  # (label, av-set) per tenant
        for label, factory in _backend_factories():
            hub = WorkspaceHub(
                f"hub-{label}",
                journal_path=str(tmp_path / f"hub-{label}.jsonl"),
                executor_factory=factory,
            )
            sessions = {
                name: hub.create(name, owner="op", topology=_topo())
                for name in scripts
            }
            for s in sessions.values():
                _wire(s, zoned=True)
            # round-robin interleave across tenants
            step = 0
            while True:
                advanced = False
                for name, script in scripts.items():
                    if step < len(script):
                        sessions[name].push("src", x=_payload(script[step]))
                        advanced = True
                if not advanced:
                    break
                step += 1
            for name, script in scripts.items():
                fp = sessions[name].fingerprint()
                assert fp == _solo_fp(
                    script, executor=factory(), topology=_topo(), zoned=True
                ), f"tenant {name} diverged from solo under {label}"
                avset = sorted(
                    (row["task"], row["chash"])
                    for row in _json.loads(fp)["avs"]
                )
                content[name].append((label, avset))
            hub.shutdown()
        # cross-backend: identical produced content per tenant
        for name, sets in content.items():
            first_label, first = sets[0]
            for label, avset in sets[1:]:
                assert avset == first, (
                    f"tenant {name}: {label} produced different content "
                    f"than {first_label}"
                )


# ---------------------------------------------------------------------------
# quotas
# ---------------------------------------------------------------------------


class TestQuotas:
    def _hub_one(self, quota, **hub_kw):
        hub = WorkspaceHub("hub", journal_path=hub_kw.pop("journal_path", False),
                           workspace_defaults={"topology": False}, **hub_kw)
        s = hub.create("t", owner="u", quota=quota)
        _wire(s)
        return hub, s

    def test_soft_warning_journaled_exactly_once_per_crossing(self):
        hub, s = self._hub_one(TenantQuota(soft_bytes=1))
        for i in range(4):
            s.push("src", x=_payload(i))
        warnings = [
            a for a in s.ws.registry.anomalies
            if a["note"].startswith("quota_warning axis=bytes")
        ]
        assert len(warnings) == 1

    def test_hard_rejection_is_deterministic_and_charges_zero(self):
        hub, s = self._hub_one(TenantQuota(hard_bytes=120))
        s.push("src", x=_payload(0))
        used = s.quota_stats()["ingress_bytes"]
        avs = len(s.ws.registry.all_avs())
        with pytest.raises(QuotaExceededError):
            s.push("src", x=bytes(500))
        assert s.quota_stats()["ingress_bytes"] == used  # zero charged
        assert s.quota_stats()["rejections"] == 1
        assert len(s.ws.registry.all_avs()) == avs  # nothing entered
        rejected = [
            a for a in s.ws.registry.anomalies
            if a["note"].startswith("quota_rejected")
        ]
        assert len(rejected) == 1

    def test_hard_rejection_identical_across_backends(self):
        def run(factory):
            hub = WorkspaceHub("hub", journal_path=False,
                               executor_factory=factory,
                               workspace_defaults={"topology": False})
            s = hub.create("t", owner="u", quota=TenantQuota(hard_bytes=120))
            _wire(s)
            s.push("src", x=_payload(0))
            with pytest.raises(QuotaExceededError):
                s.push("src", x=bytes(500))
            s.push("src", x=_payload(1))  # life goes on after a rejection
            fp, stats = s.fingerprint(), s.quota_stats()
            hub.shutdown()
            return fp, stats

        meters = []
        for label, factory in _backend_factories():
            fp1, stats1 = run(factory)
            fp2, stats2 = run(factory)
            # the rejection story is deterministic: same backend, same run
            assert fp1 == fp2, f"{label} is nondeterministic"
            assert stats1 == stats2
            meters.append((label, stats1))
        # metering happens at the facade and is backend-independent
        for label, stats in meters[1:]:
            assert stats == meters[0][1], f"{label} metered differently"

    def test_quota_story_replays_from_journal(self, tmp_path):
        hub, s = self._hub_one(
            TenantQuota(hard_bytes=120, soft_bytes=1),
            journal_path=str(tmp_path / "hub.jsonl"),
        )
        s.push("src", x=_payload(0))
        with pytest.raises(QuotaExceededError):
            s.push("src", x=bytes(500))
        hub.flush()
        re = WorkspaceHub.from_journal(str(tmp_path / "hub.jsonl"))
        replayed = re.workspace("t")
        notes = [a["note"] for a in replayed.registry.anomalies]
        assert any(n.startswith("quota_warning axis=bytes") for n in notes)
        assert any(n.startswith("quota_rejected axis=bytes") for n in notes)
        assert re.quotas["t"].hard_bytes == 120

    def test_joule_quota_on_zoned_circuit(self):
        hub = WorkspaceHub("hub", journal_path=False)
        s = hub.create("t", owner="u", quota=TenantQuota(hard_joules=1e-9),
                       topology=_topo())
        _wire(s, zoned=True)
        s.push("src", x=_payload(0))  # crosses a zone link -> spends joules
        assert s.quota_stats()["joules_used"] > 0
        with pytest.raises(QuotaExceededError):
            s.push("src", x=_payload(1))


# ---------------------------------------------------------------------------
# memberships / roles / sessions
# ---------------------------------------------------------------------------


class TestMembership:
    def _hub(self):
        hub = WorkspaceHub("hub", journal_path=False,
                           workspace_defaults={"topology": False})
        owner = hub.create("team", owner="alice")
        _wire(owner)
        return hub, owner

    def test_roles_enforced(self):
        hub, owner = self._hub()
        hub.grant("team", "bob", "writer", by="alice")
        hub.grant("team", "carol", "reader", by="alice")
        owner.push("src", x=_payload(0))
        hub.workspace("team", user="bob").push("src", x=_payload(1))
        carol = hub.workspace("team", user="carol")
        assert carol.visitor_log("join")  # readers see tenant forensics
        with pytest.raises(PermissionDeniedError):
            carol.push("src", x=_payload(2))
        with pytest.raises(PermissionDeniedError):
            carol.compact_journal()
        with pytest.raises(PermissionDeniedError):
            hub.grant("team", "dave", "writer", by="bob")  # writers can't grant
        with pytest.raises(PermissionDeniedError):
            hub.workspace("team", user="mallory")  # non-member: no session

    def test_last_owner_is_protected(self):
        hub, _ = self._hub()
        with pytest.raises(TenancyError):
            hub.revoke("team", "alice", by="alice")
        with pytest.raises(TenancyError):
            hub.grant("team", "alice", "reader", by="alice")
        hub.grant("team", "bob", "owner", by="alice")
        hub.revoke("team", "alice", by="bob")  # now fine: bob owns it
        assert hub.role_of("team", "alice") is None

    def test_koalja_tenant_env_selects_workspace(self, monkeypatch):
        hub, _ = self._hub()
        monkeypatch.setenv("KOALJA_TENANT", "team")
        s = hub.workspace()
        assert s.tenant == "team" and s.user == "alice"
        monkeypatch.delenv("KOALJA_TENANT")
        with pytest.raises(TenancyError):
            hub.workspace()

    def test_duplicate_and_unknown_tenants(self):
        hub, _ = self._hub()
        with pytest.raises(TenancyError):
            hub.create("team", owner="zed")
        with pytest.raises(TenancyError):
            hub.workspace("nope")


# ---------------------------------------------------------------------------
# concurrency stress + chaos
# ---------------------------------------------------------------------------


class TestConcurrentTenants:
    def test_many_threads_one_hub(self, tmp_path):
        n_tenants, pushes = 8, 4
        hub = WorkspaceHub(
            "hub",
            journal_path=str(tmp_path / "hub.jsonl"),
            executor_factory=lambda: ConcurrentExecutor(max_workers=2),
            workspace_defaults={"topology": False},
        )
        scripts = {
            f"t{i}": [(i + k) % 3 for k in range(pushes)] for i in range(n_tenants)
        }
        sessions = {n: hub.create(n, owner="op") for n in scripts}
        for s in sessions.values():
            _wire(s)
        errors = []

        def drive(name):
            try:
                for p in scripts[name]:
                    sessions[name].push("src", x=_payload(p))
            except Exception as e:  # pragma: no cover - surfaced below
                errors.append((name, e))

        threads = [
            threading.Thread(target=drive, args=(n,)) for n in scripts
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errors
        for name, script in scripts.items():
            want = _solo_fp(script, executor=ConcurrentExecutor(max_workers=2))
            assert sessions[name].fingerprint() == want, name
        # the shared working set deduped across the fleet
        assert hub.memo.stats()["executions_avoided"] > 0
        # every tenant's segment replays clean out of the shared seq space
        hub.flush()
        re = WorkspaceHub.from_journal(str(tmp_path / "hub.jsonl"))
        assert re.tenants() == sorted(scripts)
        for name, script in scripts.items():
            solo = _solo(
                script,
                executor=ConcurrentExecutor(max_workers=2),
                journal_path=str(tmp_path / f"solo-{name}.jsonl"),
            )
            solo.journal.flush()
            _stop(solo)
            assert tenant_fingerprint(re.workspace(name)) == tenant_fingerprint(
                Workspace.from_journal(str(tmp_path / f"solo-{name}.jsonl"))
            ), name
        hub.shutdown()

    def test_zone_runner_death_stays_contained(self, tmp_path):
        from repro.provenance import read_chain
        from repro.runtime import ZonedProcessExecutor, fork_context

        if fork_context() is None:
            pytest.skip("fork start method unavailable")
        hub = WorkspaceHub(
            "hub",
            journal_path=str(tmp_path / "hub.jsonl"),
            executor_factory=lambda: ZonedProcessExecutor(max_workers=2),
        )
        victim = hub.create("victim", owner="op", topology=_topo())
        bystander = hub.create("bystander", owner="op", topology=_topo())
        # the victim's ``left`` hard-kills its hosting edge-zone runner the
        # first time it fires in a *worker* — mid-wave, after the parent
        # reserved the journal seq window — then behaves on the retry
        crash_flag = str(tmp_path / "crash-once")
        open(crash_flag, "w").close()
        parent_pid = os.getpid()

        def _left_boom(v):
            if os.getpid() != parent_pid and os.path.exists(crash_flag):
                os.remove(crash_flag)
                os._exit(1)
            return {"y": [int(i) + 1 for i in v]}

        src = victim.task(_fx_src, name="src", inputs=["x"], outputs=["out"])
        left = victim.task(_left_boom, name="left", inputs=["v"], outputs=["y"])
        right = victim.task(_fx_right, name="right", inputs=["v"], outputs=["y"])
        join = victim.task(_fx_join, name="join", inputs=["a", "b"], outputs=["out"])
        src.place("edge")
        left.place("edge")
        right.place("cloud")
        join.place("cloud")
        victim.wire(src["out"], left["v"])
        victim.wire(src["out"], right["v"])
        victim.wire(left["y"], join["a"])
        victim.wire(right["y"], join["b"])
        _wire(bystander, zoned=True)
        bystander.push("src", x=_payload(5))
        victim.push("src", x=_payload(0))  # runner dies; window revoked; retried
        victim.push("src", x=_payload(1))  # life goes on on a fresh runner
        bystander.push("src", x=_payload(6))
        hub.flush()
        # the dead tenant's own journal carries the revocation...
        seg = os.path.join(
            str(tmp_path), os.path.basename(victim.ws.journal.path)
        )
        records, _, _ = read_chain(seg)
        assert any(r.get("kind") == "revoked" for r in records)
        # ...and both tenants' segments replay clean out of the hub chain
        re = WorkspaceHub.from_journal(str(tmp_path / "hub.jsonl"))
        solo = _solo([5, 6], topology=_topo(), zoned=True,
                     executor=ZonedProcessExecutor(max_workers=2),
                     journal_path=str(tmp_path / "solo.jsonl"))
        solo.journal.flush()
        solo_replay = Workspace.from_journal(
            [str(tmp_path / "solo.jsonl"), *solo.executor.segment_paths()]
        )
        assert tenant_fingerprint(re.workspace("bystander")) == tenant_fingerprint(
            solo_replay
        )
        dead = re.workspace("victim")
        notes = [a["note"] for a in dead.registry.anomalies]
        assert any(n.startswith("worker_died") for n in notes)
        # no duplicated AVs from the revoked window: every uid is unique
        uids = dead.registry.all_avs()
        assert len(uids) == len(set(uids))
        stop = getattr(solo.executor, "shutdown", None)
        if stop:
            stop()
        hub.shutdown()


# ---------------------------------------------------------------------------
# hub journal: control-plane replay + merged operator view
# ---------------------------------------------------------------------------


class TestHubReplay:
    def test_control_plane_rehydrates(self, tmp_path):
        path = str(tmp_path / "hub.jsonl")
        hub = WorkspaceHub("hub", journal_path=path,
                           workspace_defaults={"topology": False})
        a = hub.create("team-a", owner="alice",
                       quota=TenantQuota(hard_bytes=1 << 20))
        b = hub.create("team-b", owner="bev")
        hub.grant("team-a", "bob", "writer", by="alice")
        hub.set_quota("team-b", TenantQuota(soft_bytes=10), by="bev")
        _wire(a)
        _wire(b)
        a.push("src", x=_payload(3))
        b.push("src", x=_payload(3))  # hub-level cache_hit with memo_of
        hub.flush()
        re = WorkspaceHub.from_journal(path)
        assert re.tenants() == ["team-a", "team-b"]
        assert re.memberships["team-a"] == {"alice": "owner", "bob": "writer"}
        assert re.quotas["team-a"].hard_bytes == 1 << 20
        assert re.quotas["team-b"].soft_bytes == 10
        assert len(re.dedup_events) == 4  # src, left, right, join replayed
        ev = re.dedup_events[0]
        assert ev["tenant"] == "team-b" and ev["origin_tenant"] == "team-a"
        assert ev["memo_of"]  # lineage credit points at A's original AVs
        # the merged operator view holds both tenants' stories, by hub seq
        merged = re.merged_workspace()
        merged_avs = len(merged.registry.all_avs())
        assert merged_avs == len(a.ws.registry.all_avs()) + len(
            b.ws.registry.all_avs()
        )

    def test_tenant_compaction_in_hub_seq_space(self, tmp_path):
        path = str(tmp_path / "hub.jsonl")
        hub = WorkspaceHub("hub", journal_path=path,
                           workspace_defaults={"topology": False})
        s = hub.create("t", owner="u")
        _wire(s)
        for i in range(3):
            s.push("src", x=_payload(i))
        before = tenant_fingerprint(s.ws)
        s.ws.journal.rotate()
        report = s.compact_journal()
        assert report.get("checkpoint") or report.get("status") in (
            "noop", None,
        )
        hub.flush()
        re = WorkspaceHub.from_journal(path)
        replayed = re.workspace("t")
        # compaction must not change the replayed story (uid-free view)
        live_again = tenant_fingerprint(replayed)
        assert isinstance(live_again, str) and live_again
        assert len(replayed.registry.all_avs()) == len(s.ws.registry.all_avs())
        assert before  # sanity: live fingerprint built fine
