"""Koalja core layer: AVs, links, tasks, policies, pipeline trigger modes,
caching/make semantics, wiring language, wireframing, provenance stories."""

import time

import numpy as np
import pytest

from repro.core import (
    AnnotatedValue,
    ArtifactStore,
    ContentCache,
    InputSpec,
    Pipeline,
    PipelineManager,
    ProvenanceRegistry,
    RegionFenceError,
    SmartLink,
    SmartTask,
    SnapshotPolicy,
    content_hash,
    ghost_run,
    parse_wiring,
    software_version_of,
)


# ---------------------------------------------------------------------------
# Annotated values + store
# ---------------------------------------------------------------------------


def test_content_hash_stability_and_sensitivity():
    a = np.arange(100, dtype=np.int32)
    assert content_hash(a) == content_hash(a.copy())
    b = a.copy()
    b[3] += 1
    assert content_hash(a) != content_hash(b)
    assert content_hash({"x": 1}) == content_hash({"x": 1})
    assert content_hash({"x": 1}) != content_hash({"x": 2})


def test_av_travel_document_and_regions():
    store = ArtifactStore()
    uri, h = store.put(np.ones(4))
    av = AnnotatedValue.produce(h, uri, "src", "v-abc", region="eu")
    av.stamp("t1", "consumed", "v-def", region="eu")
    av.stamp("t2", "consumed", "v-ghi", region="us")
    assert av.journey == [("src", "produced"), ("t1", "consumed"), ("t2", "consumed")]
    assert av.crossed_regions() == [("eu", "us")]


def test_store_tiers_and_pinning(tmp_path):
    store = ArtifactStore(object_dir=str(tmp_path), local_bytes_limit=64)
    small_uri, _ = store.put(np.ones(4, np.int8))  # fits local
    big_uri, _ = store.put(np.ones(1024, np.float64))  # spills to object
    assert small_uri.startswith("local://")
    assert big_uri.startswith("object://")
    np.testing.assert_array_equal(store.get(big_uri), np.ones(1024))
    pinned = store.pin_local(big_uri)  # Principle 2
    assert pinned.startswith("local://")
    np.testing.assert_array_equal(store.get(pinned), np.ones(1024))
    assert store.rho >= 0.0


def test_region_fence():
    link = SmartLink("l", "a", "b", "x", region="us", fenced_regions=("eu",))
    store = ArtifactStore()
    uri, h = store.put(1)
    av = AnnotatedValue.produce(h, uri, "a", "v", region="eu")
    with pytest.raises(RegionFenceError):
        link.offer(av)


def test_link_notification_side_channel():
    link = SmartLink("l", "a", "b", "x")
    seen = []
    link.subscribe(lambda l, av: seen.append(av.uid))
    store = ArtifactStore()
    uri, h = store.put(42)
    av = AnnotatedValue.produce(h, uri, "a", "v")
    link.offer(av)
    assert seen == [av.uid]
    assert link.poll().uid == av.uid
    assert link.poll() is None


# ---------------------------------------------------------------------------
# Snapshot policies (paper §III.I)
# ---------------------------------------------------------------------------


def test_input_spec_parse():
    assert InputSpec.parse("x") == InputSpec("x")
    assert InputSpec.parse("x[5]") == InputSpec("x", 5)
    s = InputSpec.parse("x[10/2]")
    assert (s.buffer, s.slide) == (10, 2)
    with pytest.raises(ValueError):
        InputSpec.parse("x[2/5]")


def test_all_new_policy():
    p = SnapshotPolicy(["a", "b[2]"], mode="all_new")
    p.arrive("a", 1)
    assert not p.ready()
    p.arrive("b", 10)
    p.arrive("b", 11)
    assert p.ready()
    snap = p.snapshot()
    assert snap == {"a": 1, "b": [10, 11]}
    assert not p.ready()  # all consumed


def test_swap_new_for_old_policy():
    p = SnapshotPolicy(["a", "b"], mode="swap_new_for_old")
    p.arrive("a", 1)
    p.arrive("b", 2)
    assert p.ready()
    assert p.snapshot() == {"a": 1, "b": 2}
    p.arrive("b", 3)  # only b changes -> reuse old a (makefile semantics)
    assert p.ready()
    assert p.snapshot() == {"a": 1, "b": 3}
    assert not p.ready()  # 'changes to a do not lead to a new event'


def test_merge_policy_fcfs():
    p = SnapshotPolicy(["a", "b"], mode="merge")
    p.arrive("a", 1)
    p.arrive("b", 2)
    p.arrive("a", 3)
    assert p.ready()
    assert sorted(p.snapshot()["merged"]) == [1, 2, 3]


def test_sliding_window():
    p = SnapshotPolicy(["x[4/2]"], mode="all_new")
    for v in range(4):
        p.arrive("x", v)
    assert p.ready()
    assert p.snapshot() == {"x": [0, 1, 2, 3]}
    p.arrive("x", 4)
    assert not p.ready()  # needs k=2 fresh
    p.arrive("x", 5)
    assert p.ready()
    assert p.snapshot() == {"x": [2, 3, 4, 5]}  # advanced by 2


def test_rate_control():
    p = SnapshotPolicy(["a"], mode="all_new", min_interval_s=10.0)
    p.arrive("a", 1)
    assert p.ready()  # first fire allowed (last_fire=0)
    p.snapshot()
    p.arrive("a", 2)
    assert not p.ready()  # suppressed by rate control
    assert p.stats()["rate_suppressions"] >= 1


# ---------------------------------------------------------------------------
# Pipeline: push/pull trigger modes + make caching
# ---------------------------------------------------------------------------


def _double(x):
    return {"y": x * 2}


def _add(y, z):
    return {"w": y + z}


def build_simple():
    pipe = Pipeline("t")
    pipe.add_task(SmartTask("double", _double, ["x"], ["y"]))
    pipe.add_task(SmartTask("double2", lambda y: {"z": y + 1}, ["y"], ["z"]))
    pipe.add_task(SmartTask("add", _add, ["y", "z"], ["w"], mode="swap_new_for_old"))
    pipe.connect("double", "y", "double2", "y")
    pipe.connect("double", "y", "add", "y")
    pipe.connect("double2", "z", "add", "z")
    return pipe


def test_reactive_push():
    mgr = PipelineManager(build_simple())
    fired = mgr.push("double", x=21)
    assert "add" in fired
    w = mgr.value_of(fired["add"][-1]["w"])
    assert w == 42 + 43  # y=42, z=43


def test_make_pull_with_cache_hits():
    mgr = PipelineManager(build_simple())
    mgr.push("double", x=21)
    execs_before = mgr.pipeline.tasks["double2"].executions
    # pulling again with no new input resolves from prior outputs (no re-exec)
    out = mgr.pull("add")
    assert mgr.pipeline.tasks["double2"].executions == execs_before
    assert "w" in out


def test_content_cache_make_semantics():
    calls = []

    def slow(x):
        calls.append(x)
        return {"y": x * 2}

    pipe = Pipeline("c")
    pipe.add_task(SmartTask("slow", slow, ["x"], ["y"]))
    mgr = PipelineManager(pipe)
    mgr.push("slow", x=5)
    mgr.push("slow", x=5)  # identical input + same code -> cache hit
    assert calls == [5]
    assert mgr.pipeline.tasks["slow"].cache_hits == 1
    mgr.push("slow", x=6)  # changed input -> recompute
    assert calls == [5, 6]


def test_software_version_invalidates():
    def v1(x):
        return {"y": x + 1}

    def v2(x):
        return {"y": x + 2}

    assert software_version_of(v1) != software_version_of(v2)
    pipe = Pipeline("s")
    t = pipe.add_task(SmartTask("f", v1, ["x"], ["y"]))
    mgr = PipelineManager(pipe)
    f1 = mgr.push("f", x=1)
    # software update: swap the fn + version (the paper's recompute trigger)
    t.fn = v2
    t.version = software_version_of(v2)
    f2 = mgr.push("f", x=1)
    y1 = mgr.value_of(f1["f"][0]["y"])
    y2 = mgr.value_of(f2["f"][0]["y"])
    assert (y1, y2) == (2, 3)


def test_cycle_bounded():
    pipe = Pipeline("cyc")
    pipe.add_task(SmartTask("a", lambda x: {"y": x + 1}, ["x"], ["y"]))
    pipe.add_task(SmartTask("b", lambda y: {"x": y}, ["y"], ["x"]))
    pipe.connect("a", "y", "b", "y")
    pipe.connect("b", "x", "a", "x")
    mgr = PipelineManager(pipe, max_rounds=5, cache=False)
    fired = mgr.push("a", x=0)
    assert len(fired["a"]) <= 6  # round-limited, no hang


# ---------------------------------------------------------------------------
# Wiring language (paper fig. 5)
# ---------------------------------------------------------------------------


def test_parse_wiring_fig5():
    impls = {
        "learn-tf": lambda **kw: {"model": 1},
        "server": lambda **kw: {"lookup": 2},
        "convert": lambda **kw: {"json": 3},
        "predict": lambda **kw: {"result": 4},
    }
    text = """
    [tfmodel]
    (in) learn-tf (model)
    (model) server (lookup implicit)
    (in[10/2]) convert (json)
    (json, lookup implicit) predict (result)
    """
    pipe = parse_wiring(text, impls)
    assert pipe.name == "tfmodel"
    assert set(pipe.tasks) == {"learn-tf", "server", "convert", "predict"}
    # model wire auto-connected; implicit service edge recorded separately
    assert any(l.src_task == "learn-tf" and l.dst_task == "server" for l in pipe.links)
    assert ("lookup", "predict") in pipe.implicit_edges
    spec = [s for s in pipe.tasks["convert"].input_specs if s.name == "in"][0]
    assert (spec.buffer, spec.slide) == (10, 2)


# ---------------------------------------------------------------------------
# Wireframing (ghost batches)
# ---------------------------------------------------------------------------


def test_ghost_run_routes_without_data():
    import jax
    import jax.numpy as jnp

    def f(x):
        return {"y": jnp.asarray(x) * 2.0}

    pipe = Pipeline("g")
    pipe.add_task(SmartTask("f", f, ["x"], ["y"]))
    pipe.add_task(SmartTask("g", lambda y: {"z": y + 1}, ["y"], ["z"]))
    pipe.connect("f", "y", "g", "y")
    mgr = PipelineManager(pipe)
    report = ghost_run(mgr, {("f", "x"): jax.ShapeDtypeStruct((4, 4), jnp.float32)})
    assert report["tasks"]["f"]["executions"] == 1
    assert report["routes"]["f.y->g.y"]["carried"] == 1
    # no real data ever materialized in the store beyond ghosts
    assert all(
        not isinstance(v, np.ndarray) for v in mgr.store._local.values()
    )


# ---------------------------------------------------------------------------
# Provenance stories (paper §III.C)
# ---------------------------------------------------------------------------


def test_three_provenance_stories():
    mgr = PipelineManager(build_simple())
    fired = mgr.push("double", x=21)
    w_av = fired["add"][-1]["w"]
    reg = mgr.registry
    # 1. traveller log: the artifact's own journey
    log = reg.traveller_log(w_av.uid)
    assert log[0]["event"] == "produced"
    # 2. checkpoint visitor log: per-task interleaved timeline
    visits = reg.visitor_log("add")
    assert any(v["event"] == "emitted" for v in visits)
    # 3. design map: topology + promises
    dm = reg.design_map()
    assert ("double", "precedes", "add") in dm["edges"]
    assert "(double) --b(precedes)--> \"add\"" in reg.design_map_text()
    # lineage reconstructs the full causal ancestry
    lin = reg.lineage(w_av.uid)
    srcs = {p["source_task"] for p in lin["parents"]}
    assert srcs == {"double", "double2"}


def test_metadata_overhead_is_small():
    mgr = PipelineManager(build_simple())
    payload = np.zeros((256, 256), np.float32)  # 256 KB
    mgr.push("double", x=payload)
    overhead = mgr.registry.overhead_bytes()
    assert overhead < payload.nbytes / 4  # 'cheap to keep' (paper §III.L)
