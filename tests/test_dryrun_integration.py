"""Integration: the multi-pod dry-run machinery end to end, in a subprocess
(it needs the 512-fake-device XLA flag, which must not leak into this
process). One cheap cell per mesh proves lower+compile+roofline+record."""

import json
import os
import subprocess
import sys

import pytest


# multi-minute model/kernel path: runs in the full CI job only
pytestmark = pytest.mark.slow


REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_dryrun(args):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", *args],
        capture_output=True,
        text=True,
        timeout=540,
        env=env,
        cwd=REPO,
    )


@pytest.mark.parametrize("multipod", [False, True])
def test_dryrun_cell_compiles_and_records(multipod, tmp_path):
    args = [
        "--arch", "stablelm-1.6b", "--shape", "decode_32k", "--tag", "citest",
    ] + (["--multipod"] if multipod else [])
    r = _run_dryrun(args)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "[OK] stablelm-1.6b x decode_32k" in r.stdout

    mesh = "pod2x16x16" if multipod else "pod16x16"
    rec_path = os.path.join(
        REPO, "benchmarks", "results", "dryrun", mesh,
        "stablelm-1.6b__decode_32k__citest.json",
    )
    rec = json.load(open(rec_path))
    assert rec["n_devices"] == (512 if multipod else 256)
    assert rec["t_memory"] > 0 and rec["bottleneck"] in ("compute", "memory", "collective")
    assert rec["memory_analysis"] is not None
    assert rec["state_gb_per_device"] < 16.0
    assert rec["collectives"]["total_weighted"] >= 0


def test_dryrun_skip_row_recorded():
    r = _run_dryrun(["--arch", "internlm2-20b", "--shape", "long_500k", "--tag", "citest"])
    assert r.returncode == 0
    assert "[SKIP]" in r.stdout
    rec = json.load(
        open(
            os.path.join(
                REPO, "benchmarks", "results", "dryrun", "pod16x16",
                "internlm2-20b__long_500k__citest.json",
            )
        )
    )
    assert "skip" in rec


def test_dryrun_lever_overrides():
    r = _run_dryrun(
        [
            "--arch", "stablelm-1.6b", "--shape", "decode_32k",
            "--set", "block_kv=1024", "--tag", "citest2",
        ]
    )
    assert r.returncode == 0, r.stderr[-2000:]
    assert "[OK]" in r.stdout
