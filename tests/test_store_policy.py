"""ArtifactStore tier mechanics (LRU / spill / pin) and SnapshotPolicy
edge cases (merge FCFS ordering, first-window fill, rate suppression).

Satellite coverage for ISSUE 2: the store's local tier is a bounded LRU
over a durable object tier; pinning is idempotent and honors the byte
limit; policies behave at their boundaries.
"""

import time

import numpy as np
import pytest

from repro.core import ArtifactStore, SnapshotPolicy


def _arr(n, fill):
    return np.full(n, fill, dtype=np.uint8)  # n bytes exactly


# ---------------------------------------------------------------------------
# LRU eviction and spill
# ---------------------------------------------------------------------------


def test_lru_spills_oldest_to_object_tier(tmp_path):
    store = ArtifactStore(object_dir=str(tmp_path), local_bytes_limit=256)
    uris = [store.put(_arr(100, i))[0] for i in range(3)]  # 300B > 256B
    stats = store.stats()
    assert stats["evictions_local"] == 1
    assert stats["bytes_spilled"] == 100
    assert stats["local_bytes"] <= 256
    # the spilled artifact is still retrievable (now from the object tier)
    _, h0 = uris[0].split("://", 1)
    assert not store.has(f"local://{h0}")
    np.testing.assert_array_equal(store.get(f"object://{h0}"), _arr(100, 0))


def test_lru_get_refreshes_recency(tmp_path):
    store = ArtifactStore(object_dir=str(tmp_path), local_bytes_limit=256)
    uri_a, _ = store.put(_arr(100, 1))
    uri_b, _ = store.put(_arr(100, 2))
    store.get(uri_a)  # touch a: b becomes least recently used
    store.put(_arr(100, 3))  # forces one eviction
    assert store.has(uri_a), "recently-used entry must survive"
    assert not store.has(uri_b), "LRU entry must be the one evicted"


def test_oversized_artifact_goes_straight_to_object(tmp_path):
    store = ArtifactStore(object_dir=str(tmp_path), local_bytes_limit=64)
    uri, _ = store.put(_arr(1000, 7))
    assert uri.startswith("object://")
    assert store.stats()["local_bytes"] == 0


def test_no_object_tier_means_no_eviction():
    store = ArtifactStore(local_bytes_limit=64)
    for i in range(4):
        store.put(_arr(100, i))
    stats = store.stats()
    assert stats["evictions_local"] == 0
    assert stats["local_bytes"] == 400  # allowed past the limit: nowhere to spill


def test_evict_local_spills_only_copy(tmp_path):
    store = ArtifactStore(object_dir=str(tmp_path), local_bytes_limit=1 << 20)
    uri, h = store.put(_arr(50, 9))
    store.evict_local(uri)
    assert store.stats()["local_bytes"] == 0
    np.testing.assert_array_equal(store.get(f"object://{h}"), _arr(50, 9))


# ---------------------------------------------------------------------------
# pin_local: idempotence, limit, region accounting (ISSUE 2 satellite fix)
# ---------------------------------------------------------------------------


def test_pin_local_idempotent_no_double_count(tmp_path):
    store = ArtifactStore(object_dir=str(tmp_path), local_bytes_limit=1 << 20)
    uri, _ = store.put(_arr(100, 1), prefer="object")
    assert uri.startswith("object://")
    p1 = store.pin_local(uri)
    bytes_after_first = store.stats()["local_bytes"]
    p2 = store.pin_local(uri)
    assert p1 == p2
    assert store.stats()["local_bytes"] == bytes_after_first == 100
    assert store.stats()["pins"] == 1


def test_pin_local_respects_limit_by_evicting_others(tmp_path):
    store = ArtifactStore(object_dir=str(tmp_path), local_bytes_limit=256)
    store.put(_arr(100, 1))
    store.put(_arr(100, 2))
    big_uri, _ = store.put(_arr(200, 3), prefer="object")
    store.pin_local(big_uri)  # 200B pin into 200/256 used -> evicts LRU
    stats = store.stats()
    assert stats["local_bytes"] <= 256
    _, h = big_uri.split("://", 1)
    assert store.has(f"local://{h}"), "the pin itself must stick"


def test_pin_local_counts_cross_region_traffic(tmp_path):
    store = ArtifactStore(object_dir=str(tmp_path), region="us")
    uri, _ = store.put(_arr(100, 5), prefer="object")
    store.pin_local(uri, region="eu")  # artifact originated in eu
    stats = store.stats()
    assert stats["cross_region_pins"] == 1
    assert stats["cross_region_bytes"] == 100
    # same-region pins are free of audit weight
    uri2, _ = store.put(_arr(40, 6), prefer="object")
    store.pin_local(uri2, region="us")
    assert store.stats()["cross_region_pins"] == 1


def test_put_dedup_counts_bytes_not_moved():
    store = ArtifactStore()
    store.put(_arr(100, 1))
    store.put(_arr(100, 1))  # identical content: reference handover
    store.put(_arr(100, 1))
    assert store.stats()["bytes_not_moved"] == 200
    assert store.stats()["local_bytes"] == 100


def test_prefetch_pins_batch_and_skips_ghosts(tmp_path):
    store = ArtifactStore(object_dir=str(tmp_path))
    u1, _ = store.put(_arr(10, 1), prefer="object")
    u2, _ = store.put(_arr(10, 2), prefer="object")
    n = store.prefetch([(u1, "eu"), u2, "ghost://abc"])
    assert n == 2
    assert store.stats()["prefetches"] == 1
    assert store.has(u1.replace("object", "local"))


def test_ghost_uri_get_raises():
    store = ArtifactStore()
    with pytest.raises(KeyError, match="ghost"):
        store.get("ghost://deadbeef")


def test_stale_local_uri_falls_back_to_object_after_spill(tmp_path):
    """A local:// reference issued before an LRU spill must keep resolving:
    the hash is the identity, the tier is only a placement hint."""
    store = ArtifactStore(object_dir=str(tmp_path), local_bytes_limit=256)
    stale_uri, _ = store.put(_arr(100, 1))
    assert stale_uri.startswith("local://")
    store.put(_arr(100, 2))
    store.put(_arr(100, 3))  # spills the first artifact to the object tier
    assert not store.has(stale_uri)
    np.testing.assert_array_equal(store.get(stale_uri), _arr(100, 1))
    pinned = store.pin_local(stale_uri)
    assert store.has(pinned)


def test_missing_local_uri_without_object_copy_raises():
    store = ArtifactStore()
    with pytest.raises(KeyError):
        store.get("local://not-there")


def test_is_ghost_requires_explicit_opt_in():
    import jax
    import jax.numpy as jnp

    from repro.core import is_ghost
    from repro.core.wireframe import GhostValue

    assert is_ghost(jax.ShapeDtypeStruct((4,), jnp.float32))
    assert is_ghost(GhostValue("g"))
    assert not is_ghost(np.ones(4))

    class ShapedButNoNbytes:  # sparse-matrix-like: data, not a ghost
        shape = (4, 4)
        dtype = "float64"

    assert not is_ghost(ShapedButNoNbytes())


# ---------------------------------------------------------------------------
# SnapshotPolicy edge cases
# ---------------------------------------------------------------------------


def test_merge_is_fcfs_across_links():
    p = SnapshotPolicy(["a", "b"], mode="merge")
    p.arrive("b", 1)  # global arrival order: b, a, b, a
    p.arrive("a", 2)
    p.arrive("b", 3)
    p.arrive("a", 4)
    assert p.ready()
    assert p.snapshot() == {"merged": [1, 2, 3, 4]}
    assert not p.ready()


def test_merge_rejects_buffered_inputs():
    with pytest.raises(ValueError, match="FCFS"):
        SnapshotPolicy(["a[4]"], mode="merge")


def test_first_window_must_fill_completely():
    p = SnapshotPolicy(["x[3/1]"], mode="all_new")
    p.arrive("x", 1)
    p.arrive("x", 2)
    assert not p.ready(), "first snapshot needs the whole window (3 fresh)"
    p.arrive("x", 3)
    assert p.ready()
    assert p.snapshot() == {"x": [1, 2, 3]}
    # subsequent snapshots advance by k=1
    p.arrive("x", 4)
    assert p.ready()
    assert p.snapshot() == {"x": [2, 3, 4]}


def test_window_slide_consumes_exactly_k():
    p = SnapshotPolicy(["x[4/2]"], mode="all_new")
    for v in range(1, 5):
        p.arrive("x", v)
    assert p.snapshot() == {"x": [1, 2, 3, 4]}
    p.arrive("x", 5)
    assert not p.ready(), "k=2 fresh values required to advance"
    p.arrive("x", 6)
    assert p.snapshot() == {"x": [3, 4, 5, 6]}


def test_rate_suppression_counts_only_with_pending_data():
    p = SnapshotPolicy(["a"], mode="all_new", min_interval_s=30.0)
    p._last_fire = time.time()  # simulate a just-fired task
    assert not p.ready()
    assert p.stats()["rate_suppressions"] == 0, "no data, no suppression"
    p.arrive("a", 1)
    assert not p.ready()
    assert not p.ready()
    assert p.stats()["rate_suppressions"] == 2, "each denied check counts"
    assert p.stats()["pending"] == {"a": 1}


def test_swap_new_for_old_reuses_stale_inputs():
    p = SnapshotPolicy(["a", "b"], mode="swap_new_for_old")
    p.arrive("a", 1)
    p.arrive("b", 2)
    assert p.snapshot() == {"a": 1, "b": 2}
    p.arrive("b", 3)  # only b refreshed
    assert p.ready()
    assert p.snapshot() == {"a": 1, "b": 3}
