"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.mamba_scan import mamba_scan
from repro.kernels.moe_gmm import moe_gmm
from repro.kernels.ref import (
    reference_attention,
    reference_gmm,
    reference_selective_scan,
)


# multi-minute model/kernel path: runs in the full CI job only
pytestmark = pytest.mark.slow


RNG = np.random.RandomState(0)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize(
    "B,Lq,Lk,H,KVH,Dh,causal,window,bq,bkv",
    [
        (2, 128, 128, 4, 2, 64, True, 0, 64, 64),
        (1, 256, 256, 8, 8, 32, True, 0, 128, 64),
        (2, 200, 200, 4, 1, 64, True, 0, 64, 64),  # ragged lengths
        (1, 256, 256, 4, 2, 64, True, 96, 64, 64),  # sliding window
        (1, 64, 256, 4, 2, 64, False, 0, 64, 64),  # cross attention
        (1, 128, 128, 6, 2, 16, True, 0, 32, 32),  # small head dim
    ],
)
def test_flash_attention_sweep(B, Lq, Lk, H, KVH, Dh, causal, window, bq, bkv):
    q = jnp.asarray(RNG.randn(B, Lq, H, Dh), jnp.float32)
    k = jnp.asarray(RNG.randn(B, Lk, KVH, Dh), jnp.float32)
    v = jnp.asarray(RNG.randn(B, Lk, KVH, Dh), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, window=window, block_q=bq, block_kv=bkv)
    ref = reference_attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    B, L, H, KVH, Dh = 1, 128, 4, 2, 64
    q = jnp.asarray(RNG.randn(B, L, H, Dh)).astype(dtype)
    k = jnp.asarray(RNG.randn(B, L, KVH, Dh)).astype(dtype)
    v = jnp.asarray(RNG.randn(B, L, KVH, Dh)).astype(dtype)
    out = flash_attention(q, k, v, causal=True)
    ref = reference_attention(q, k, v, causal=True)
    np.testing.assert_allclose(
        out.astype(jnp.float32), ref.astype(jnp.float32), **_tol(dtype)
    )


@pytest.mark.parametrize(
    "B,L,Di,N,Lc,db,with_h0",
    [
        (2, 64, 32, 8, 16, 16, False),
        (1, 100, 48, 16, 32, 32, True),  # ragged L + seeded state
        (2, 256, 64, 16, 64, 64, False),
        (1, 32, 24, 4, 32, 8, True),  # d-blocked
    ],
)
def test_mamba_scan_sweep(B, L, Di, N, Lc, db, with_h0):
    xc = jnp.asarray(RNG.randn(B, L, Di), jnp.float32)
    dt = jnp.asarray(np.abs(RNG.randn(B, L, Di)) * 0.1, jnp.float32)
    Bm = jnp.asarray(RNG.randn(B, L, N), jnp.float32)
    Cm = jnp.asarray(RNG.randn(B, L, N), jnp.float32)
    a = jnp.asarray(-np.abs(RNG.randn(Di, N)) - 0.1, jnp.float32)
    h0 = jnp.asarray(RNG.randn(B, Di, N), jnp.float32) if with_h0 else None
    y, h = mamba_scan(xc, dt, Bm, Cm, a, h0, chunk_len=Lc, d_block=db)
    yr, hr = reference_selective_scan(xc, dt, Bm, Cm, a, h0)
    np.testing.assert_allclose(y, yr, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(h, hr, rtol=1e-4, atol=1e-4)


def test_mamba_scan_matches_model_chunked_scan():
    """The model's chunked associative scan and the kernel agree."""
    from repro.models.mamba import selective_scan

    B, L, Di, N = 2, 128, 32, 8
    xc = jnp.asarray(RNG.randn(B, L, Di), jnp.float32)
    dt = jnp.asarray(np.abs(RNG.randn(B, L, Di)) * 0.1, jnp.float32)
    Bm = jnp.asarray(RNG.randn(B, L, N), jnp.float32)
    Cm = jnp.asarray(RNG.randn(B, L, N), jnp.float32)
    a = jnp.asarray(-np.abs(RNG.randn(Di, N)) - 0.1, jnp.float32)
    y1, h1 = selective_scan(xc, dt, Bm, Cm, a, chunk_len=32)
    y2, h2 = mamba_scan(xc, dt, Bm, Cm, a, chunk_len=32, d_block=16)
    np.testing.assert_allclose(y1, y2, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(h1, h2, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize(
    "E,C,D,F,bc,bf",
    [
        (4, 32, 64, 96, 16, 32),
        (2, 100, 48, 80, 32, 32),  # ragged capacity
        (8, 16, 32, 32, 16, 16),
        (1, 64, 128, 64, 64, 64),
    ],
)
def test_moe_gmm_sweep(E, C, D, F, bc, bf):
    x = jnp.asarray(RNG.randn(E, C, D) * 0.5, jnp.float32)
    wg = jnp.asarray(RNG.randn(E, D, F) * 0.1, jnp.float32)
    wu = jnp.asarray(RNG.randn(E, D, F) * 0.1, jnp.float32)
    wd = jnp.asarray(RNG.randn(E, F, D) * 0.1, jnp.float32)
    out = moe_gmm(x, wg, wu, wd, block_c=bc, block_f=bf)
    ref = reference_gmm(x, wg, wu, wd)
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_moe_gmm_dtypes(dtype):
    E, C, D, F = 2, 32, 32, 48
    x = jnp.asarray(RNG.randn(E, C, D) * 0.5).astype(dtype)
    wg = jnp.asarray(RNG.randn(E, D, F) * 0.1).astype(dtype)
    wu = jnp.asarray(RNG.randn(E, D, F) * 0.1).astype(dtype)
    wd = jnp.asarray(RNG.randn(E, F, D) * 0.1).astype(dtype)
    out = moe_gmm(x, wg, wu, wd, block_c=16, block_f=16)
    ref = reference_gmm(x, wg, wu, wd)
    np.testing.assert_allclose(
        out.astype(jnp.float32), ref.astype(jnp.float32), **_tol(dtype)
    )


def test_blocked_attention_matches_reference():
    """The model's scan-blocked attention == naive reference (incl. GQA+SWA)."""
    from repro.models.attention import blocked_attention

    B, L, H, KVH, Dh = 2, 160, 8, 2, 32
    q = jnp.asarray(RNG.randn(B, L, H, Dh), jnp.float32)
    k = jnp.asarray(RNG.randn(B, L, KVH, Dh), jnp.float32)
    v = jnp.asarray(RNG.randn(B, L, KVH, Dh), jnp.float32)
    for window in (0, 48):
        out = blocked_attention(q, k, v, causal=True, window=window, block_q=64, block_kv=32)
        ref = reference_attention(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


def test_causal_skip_equivalence():
    """The growing-window unrolled attention (hillclimb lever) is exact."""
    from repro.models.attention import blocked_attention

    B, L, H, KVH, Dh = 1, 256, 4, 2, 32
    q = jnp.asarray(RNG.randn(B, L, H, Dh), jnp.float32)
    k = jnp.asarray(RNG.randn(B, L, KVH, Dh), jnp.float32)
    v = jnp.asarray(RNG.randn(B, L, KVH, Dh), jnp.float32)
    for window in (0, 96):
        base = blocked_attention(q, k, v, causal=True, window=window, block_q=64, block_kv=64)
        skip = blocked_attention(
            q, k, v, causal=True, window=window, block_q=64, block_kv=64, causal_skip=True
        )
        np.testing.assert_allclose(base, skip, rtol=2e-5, atol=2e-5)
