"""repro.cache — semantic memoization threaded through the engine.

Covers the §III.F sustainability pillar: snapshot keys (version + ordered
input hashes + policy mode), push/pull short-circuiting with cache_hit
visitor events, memo_of lineage back-pointers, version invalidation,
ghost-run zero-materialization, counter surfacing through Workspace.stats()
and executors, and the repeated-push benchmark acceptance numbers.
"""

import numpy as np
import pytest

from repro.cache import MemoCache, make_record, snapshot_key
from repro.workspace import Workspace


def _two_stage(calls=None):
    calls = calls if calls is not None else []
    ws = Workspace("memo")

    def stage_a(x):
        calls.append("a")
        return {"y": x * 2.0}

    def stage_b(y):
        calls.append("b")
        return {"z": float(np.sum(y))}

    a = ws.task(stage_a, name="a", inputs=["x"], outputs=["y"])
    b = ws.task(stage_b, name="b", inputs=["y"], outputs=["z"])
    a["y"] >> b["y"]
    return ws, calls


# ---------------------------------------------------------------------------
# snapshot_key semantics
# ---------------------------------------------------------------------------


def test_snapshot_key_includes_policy_mode():
    h = {"x": "abc123"}
    assert snapshot_key("v1", h, policy_mode="all_new") != snapshot_key(
        "v1", h, policy_mode="merge"
    )
    assert snapshot_key("v1", h) == snapshot_key("v1", dict(h))


def test_snapshot_key_buffer_order_significant():
    assert snapshot_key("v", {"x": ["h1", "h2"]}) != snapshot_key(
        "v", {"x": ["h2", "h1"]}
    )


# ---------------------------------------------------------------------------
# push-mode short-circuit
# ---------------------------------------------------------------------------


def test_repeated_push_short_circuits_and_logs_cache_hits():
    ws, calls = _two_stage()
    x = np.arange(8.0)
    ws.push("a", x=x)
    ws.push("a", x=x)
    ws.push("a", x=x)
    assert calls == ["a", "b"], "user code must run exactly once per task"

    for task in ("a", "b"):
        events = [e["event"] for e in ws.visitor_log(task)]
        assert events.count("cache_hit") == 2
        assert events.count("executed") == 1

    s = ws.stats()["sustainability"]
    assert s["executions"] == 2
    assert s["cache_hits"] == 4
    assert s["executions_avoided"] == 4
    assert s["bytes_not_moved"] > 0


def test_changed_content_misses():
    ws, calls = _two_stage()
    ws.push("a", x=np.arange(8.0))
    ws.push("a", x=np.arange(8.0) + 1)  # different content hash
    assert calls == ["a", "b", "a", "b"]


def test_memo_hit_payload_still_retrievable():
    ws, _ = _two_stage()
    x = np.arange(8.0)
    ws.push("a", x=x)
    second = ws.push("a", x=x)
    # the memo AV's (uri, chash) reference resolves to the original payload
    assert second["b"]["z"] == float(np.sum(x * 2.0))


# ---------------------------------------------------------------------------
# forensic reconstruction across a hit
# ---------------------------------------------------------------------------


def test_memo_lineage_points_at_original_run():
    ws, _ = _two_stage()
    x = np.arange(4.0)
    first = ws.push("a", x=x)
    second = ws.push("a", x=x)
    orig_av = first["b"].av("z")
    hit_av = second["b"].av("z")
    assert hit_av.uid != orig_av.uid
    assert hit_av.meta["cache_hit"] is True
    assert hit_av.meta["memo_of"] == orig_av.uid

    lin = ws.lineage(hit_av)
    assert lin["cache_hit"] is True
    assert lin["memo_of"]["uid"] == orig_av.uid
    assert lin["memo_of"]["chash"] == hit_av.chash
    assert lin["memo_of"]["parents"], "original inputs reconstruct"

    # the visitor-log entry names the original run too
    hits = [e for e in ws.visitor_log("b") if e["event"] == "cache_hit"]
    assert hits and hits[0]["note"] == f"memo_of={orig_av.uid}"


def test_invalidate_version_forces_recompute():
    ws, calls = _two_stage()
    x = np.arange(8.0)
    ws.push("a", x=x)
    version = ws.pipeline.tasks["a"].version
    assert ws.manager.cache.invalidate_version(version) == 1
    ws.push("a", x=x)
    # 'a' recomputes; its output content is unchanged so 'b' still hits
    assert calls == ["a", "b", "a"]


# ---------------------------------------------------------------------------
# pull mode and sensors
# ---------------------------------------------------------------------------


def test_pull_mode_uses_memo():
    ws, calls = _two_stage()
    x = np.arange(8.0)
    ws.push("a", x=x)
    ws.inject("a", "x", x)
    out = ws.pull("b")
    assert out["z"] == float(np.sum(x * 2.0))
    assert calls == ["a", "b"], "pull over unchanged inputs is all hits"


def test_source_tasks_never_cache():
    ws = Workspace("sensor")

    def clock():
        return {"t": 42}  # constant output — still must never memoize

    ws.source(clock, name="clock", outputs=["t"])
    ws.sample("clock")
    ws.sample("clock")
    assert ws.pipeline.tasks["clock"].executions == 2
    assert ws.pipeline.tasks["clock"].cache_hits == 0


def test_cache_disabled_executes_every_time():
    calls = []
    ws = Workspace("nocache", cache=False)

    def f(x):
        calls.append(1)
        return {"y": x + 1}

    ws.task(f, name="f", inputs=["x"], outputs=["y"])
    ws.push("f", x=3)
    ws.push("f", x=3)
    assert len(calls) == 2
    assert ws.stats()["cache"] is None


# ---------------------------------------------------------------------------
# ghost runs never materialize
# ---------------------------------------------------------------------------


def test_ghost_run_moves_zero_bytes():
    import jax
    import jax.numpy as jnp

    ws, _ = _two_stage()
    report = ws.ghost({("a", "x"): jax.ShapeDtypeStruct((8,), jnp.float32)})
    store = ws.store.stats()
    assert store["puts"] == 0 and store["gets"] == 0 and store["pins"] == 0
    assert store["local_bytes"] == 0
    assert report["tasks"]["a"]["executions"] == 1
    # ghost firings are not memoized: a later real push still executes
    ws.push("a", x=np.arange(8.0, dtype=np.float32))
    assert ws.pipeline.tasks["a"].executions == 2
    assert ws.pipeline.tasks["a"].cache_hits == 0


def test_shared_fn_different_output_names_do_not_collide():
    """Two tasks wrapping the same fn but promising different output names
    are different computations: a replayed record must not emit the wrong
    names (which would silently drop the emission downstream)."""

    def double(x):
        return x * 2

    ws = Workspace("twins")
    a = ws.task(double, name="a", inputs=["x"], outputs=["y"])
    b = ws.task(double, name="b", inputs=["x"], outputs=["z"])
    sink_calls = []
    sink = ws.task(lambda z: sink_calls.append(z) or {"ok": 1},
                   name="sink", inputs=["z"], outputs=["ok"])
    b["z"] >> sink["z"]

    x = np.arange(4.0)
    ws.push("a", x=x)
    ws.push("b", x=x)  # must not replay a's record under b's promise
    assert ws.pipeline.tasks["b"].last_outputs.keys() == {"z"}
    assert len(sink_calls) == 1, "b's downstream sink must fire"


def test_shared_memo_cache_across_stores_recomputes_not_crashes():
    """A MemoCache shared across workspaces (each with its own store) must
    treat foreign-store records as misses, not replay dangling URIs."""
    from repro.cache import MemoCache

    shared = MemoCache()
    calls = []

    def build():
        ws = Workspace("w", cache=shared)

        def f(x):
            calls.append(1)
            return {"y": x + 1}

        g = ws.task(lambda y: {"z": y * 3}, name="g", inputs=["y"], outputs=["z"])
        h = ws.task(f, name="f", inputs=["x"], outputs=["y"])
        h["y"] >> g["y"]
        return ws

    x = np.arange(4.0)
    ws1, ws2 = build(), build()
    r1 = ws1.push("f", x=x)
    r2 = ws2.push("f", x=x)  # ws2's store has none of ws1's payloads
    np.testing.assert_array_equal(r2["g"]["z"], (x + 1) * 3)
    assert len(calls) == 2, "foreign-store record must recompute"


# ---------------------------------------------------------------------------
# MemoCache unit behavior
# ---------------------------------------------------------------------------


def test_credit_hit_accounting():
    cache = MemoCache()
    rec = make_record("v1", {"y": ("local://h", "h")}, {"y": "av-1"}, {"y": 100})
    cache.insert("k", rec)
    assert cache.lookup("k") is rec
    assert cache.credit_hit(rec) == 100
    assert cache.stats()["executions_avoided"] == 1
    assert cache.stats()["bytes_saved"] == 100


def test_executor_stats_surface():
    ws, _ = _two_stage()
    ws.push("a", x=np.arange(4.0))
    ex = ws.stats()["executor"]
    # the default backend is env-selected (KOALJA_EXECUTOR): assert the
    # selection contract, not just self-reporting
    import os

    env = os.environ.get("KOALJA_EXECUTOR", "inline").strip().lower()
    if env in ("concurrent", "threads", "threadpool"):
        expected = "ConcurrentExecutor"
    elif env in ("zoned", "zoned-concurrent", "zoned_concurrent"):
        expected = "ZonedExecutor"
    else:
        expected = "InlineExecutor"
    assert ex["backend"] == expected
    assert ex["pushes"] == 1


# ---------------------------------------------------------------------------
# benchmark acceptance (ISSUE 2): >=5x fewer executions, bytes not moved > 0
# ---------------------------------------------------------------------------


def test_repeated_push_benchmark_acceptance():
    import sys
    from pathlib import Path

    sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
    try:
        from benchmarks.bench_koalja import bench_repeated_push
    finally:
        sys.path.pop(0)
    r = bench_repeated_push(pushes=10)
    assert r["execution_reduction_x"] >= 5.0
    assert r["bytes_not_moved"] > 0
    assert r["cache_hit_events"] > 0
