"""Wiring-language error paths + annotation parsing (core/wiring.py).

The breadboard DSL must fail loudly at parse time — a typo'd line or a
missing implementation is a design error, not a runtime surprise."""

import pytest

from repro.core import build_wiring
from repro.core.policy import InputSpec
from repro.workspace import Workspace

IMPLS = {
    "a": lambda **kw: {"x": 1},
    "b": lambda **kw: {"y": 2},
}


def test_unparseable_line_raises_with_content():
    text = """
    (in) a (x)
    this is not a wiring line
    """
    with pytest.raises(ValueError, match="unparseable wiring line.*not a wiring line"):
        build_wiring(text, IMPLS)


def test_missing_impl_raises_keyerror_naming_task():
    with pytest.raises(KeyError, match="no implementation supplied for task 'ghost'"):
        build_wiring("(in) ghost (out)", {})


def test_duplicate_task_rejected():
    text = """
    (in) a (x)
    (x) a (y)
    """
    with pytest.raises(ValueError, match="duplicate task a"):
        build_wiring(text, IMPLS)


def test_implicit_edges_recorded_not_wired():
    text = """
    (in) a (x)
    (x implicit) b (y)
    """
    pipe = build_wiring(text, IMPLS)
    # implicit input is a client-server side channel: no SmartLink, but the
    # edge lands in the design record
    assert ("x", "b") in pipe.implicit_edges
    assert not any(l.dst_task == "b" for l in pipe.links)
    # and 'b' has no wired inputs -> it parses as a source
    assert pipe.tasks["b"].source


def test_buffer_annotations_parse_into_specs():
    text = """
    (in[8]) a (x)
    (x[10/2]) b (y)
    """
    pipe = build_wiring(text, IMPLS)
    spec_a = pipe.tasks["a"].input_specs[0]
    assert (spec_a.name, spec_a.buffer, spec_a.slide) == ("in", 8, None)
    spec_b = pipe.tasks["b"].input_specs[0]
    assert (spec_b.name, spec_b.buffer, spec_b.slide) == ("x", 10, 2)
    assert str(spec_b) == "x[10/2]"


@pytest.mark.parametrize("bad", ["x[2/5]", "x[0/0]", "x[3/0]"])
def test_invalid_window_annotation_rejected(bad):
    with pytest.raises(ValueError, match="window slide must satisfy"):
        InputSpec.parse(bad)


def test_from_wiring_matches_parse_and_adds_typed_handles():
    text = """
    [named]
    (in) a (x)
    (x) b (y)
    """
    ws = Workspace.from_wiring(text, IMPLS)
    assert ws.name == "named"
    assert ws.tasks() == ["a", "b"]
    # typed ports resolve; unknown ports fail at access time
    assert ws["b"]["x"].direction == "in"
    assert ws["b"]["y"].direction == "out"
    with pytest.raises(KeyError, match="no port 'zz'"):
        ws["b"]["zz"]


def test_parse_wiring_shim_warns_deprecation():
    from repro.core import parse_wiring

    with pytest.warns(DeprecationWarning, match="Workspace.from_wiring"):
        pipe = parse_wiring("(in) a (x)", IMPLS)
    assert "a" in pipe.tasks
