"""Distribution layer: sharding rules (divisibility fallbacks), HLO cost
walker, elastic resharding, train-step numerics on the host mesh."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.dist.sharding import (
    cache_logical_axes,
    make_rules,
    pspec_for_axes,
)
from repro.roofline.hlo_costs import hlo_costs, parse_module


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape


MESH = FakeMesh({"data": 16, "model": 16})
MESH_POD = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_pspec_divisible_dims_shard():
    rules = {"embed": "data", "heads": "model"}
    spec = pspec_for_axes(("embed", "heads", None), (4096, 32, 128), rules, MESH)
    assert spec == P("data", "model", None)


def test_pspec_indivisible_falls_back():
    rules = {"heads": "model", "embed": "data"}
    # 40 heads % 16 -> replicate that dim only
    spec = pspec_for_axes(("embed", "heads", None), (5120, 40, 128), rules, MESH)
    assert spec == P("data", None, None)


def test_pspec_no_axis_reuse():
    rules = {"a": "model", "b": "model"}
    spec = pspec_for_axes(("a", "b"), (32, 32), rules, MESH)
    assert spec == P("model", None)  # second use of 'model' dropped


def test_pspec_tuple_axes():
    rules = {"batch": ("pod", "data")}
    spec = pspec_for_axes(("batch", None), (256, 128), rules, MESH_POD)
    assert spec == P(("pod", "data"), None)
    # batch=1 cannot shard
    spec = pspec_for_axes(("batch", None), (1, 128), rules, MESH_POD)
    assert spec == P(None, None)


def test_serve_rules_flash_decoding_fallback():
    cfg = get_config("qwen2.5-32b")  # kv=8 % 16 != 0
    rules = make_rules(cfg, MESH, "serve", global_batch=128)
    assert rules["kv_seq"] == ("model",)
    cfg2 = get_config("stablelm-1.6b")  # kv=32 divides
    rules2 = make_rules(cfg2, MESH, "serve", global_batch=128)
    assert rules2["kv_seq"] is None
    # batch=1 long-context: seq gets the batch axes too
    rules3 = make_rules(cfg, MESH, "serve", global_batch=1)
    assert set(rules3["kv_seq"]) == {"data", "model"}


def test_train_rules_fsdp_only_in_train():
    cfg = get_config("internlm2-20b")
    assert make_rules(cfg, MESH, "train")["embed"] == "data"
    assert make_rules(cfg, MESH, "serve")["embed"] is None


def test_cache_axes_match_cache_structure():
    from repro.models.registry import build_model, init_serve_state

    for arch in ("mixtral-8x7b", "minicpm3-4b", "falcon-mamba-7b"):
        cfg = get_config(arch).reduced()
        model = build_model(cfg)
        state = init_serve_state(model, 2, 16)
        axes = cache_logical_axes(cfg, max_len=16)
        # same tree structure (axes leaves are tuples)
        jax.tree.map(
            lambda a, c: None,
            axes,
            state["caches"],
            is_leaf=lambda x: isinstance(x, tuple)
            and all(isinstance(e, (str, type(None))) for e in x),
        )


# ---------------------------------------------------------------------------
# HLO cost walker on synthetic HLO
# ---------------------------------------------------------------------------

SYNTH_HLO = """
HloModule test

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8]{1,0} get-tuple-element(%p), index=1
  %d = f32[8,8]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,8]{1,0} all-reduce(%d), replica_groups=[16,16]<=[256], to_apply=%add
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,8]{1,0}) tuple(%i2, %ar)
}

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(10)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: f32[8,8]) -> (s32[], f32[8,8]) {
  %a = f32[8,8]{1,0} parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[8,8]{1,0}) tuple(%z, %a)
  ROOT %w = (s32[], f32[8,8]{1,0}) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"}}
}
"""


def test_walker_counts_trip_multiplied_dots_and_collectives():
    c = hlo_costs(SYNTH_HLO, 256)
    # dot: 2*8*8*8 flops per trip, 10 trips (+ trivial adds)
    assert c["flops"] == pytest.approx(2 * 8 * 8 * 8 * 10, rel=0.05)
    ar = c["collectives"]["all-reduce"]
    assert ar["count"] == 10
    assert ar["bytes"] == 8 * 8 * 4 * 10
    # ring factor 2*(16-1)/16 with group size 16 from iota groups
    assert ar["weighted"] == pytest.approx(8 * 8 * 4 * 10 * 2 * 15 / 16)
    assert c["unknown_trip_whiles"] == 0


def test_walker_parse_module_shapes():
    comps, entry = parse_module(SYNTH_HLO)
    assert entry == "main"
    assert {"body", "cond", "main"} <= set(comps)


# ---------------------------------------------------------------------------
# Elastic resharding (numeric identity on the host mesh)
# ---------------------------------------------------------------------------


def test_elastic_reshard_identity():
    from repro.dist.elastic import reshard_state
    from repro.dist.step import param_specs
    from repro.launch.mesh import make_host_mesh
    from repro.models.registry import build_model
    from repro.optim import adamw_init

    cfg = get_config("stablelm-1.6b").reduced()
    model = build_model(cfg)
    params, axes = model.init(jax.random.key(0))
    state = {
        "params": params,
        "opt": adamw_init(params),
        "step": jnp.int32(3),
    }
    mesh_a = make_host_mesh()
    mesh_b = make_host_mesh()  # same devices; exercises the machinery
    new_state, shardings = reshard_state(state, axes, mesh_a, mesh_b, cfg, "train")
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(new_state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# Train-step builder numerics (host mesh)
# ---------------------------------------------------------------------------


def test_train_step_builder_runs_and_descends():
    from repro.dist.step import make_train_step
    from repro.launch.mesh import make_host_mesh
    from repro.models.registry import build_model
    from repro.optim import adamw_init, constant_lr

    cfg = get_config("stablelm-1.6b").reduced()
    model = build_model(cfg)
    mesh = make_host_mesh()
    jitted, state_shapes, state_shard, batch_shard = make_train_step(
        model, mesh, constant_lr(1e-3), global_batch=4
    )
    params, _ = model.init(jax.random.key(0))
    state = {"params": params, "opt": adamw_init(params), "step": jnp.int32(0)}
    tokens = jax.random.randint(jax.random.key(1), (4, 32), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    losses = []
    for _ in range(8):
        state, metrics = jitted(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0]  # memorizes the fixed batch
    assert int(state["step"]) == 8


def test_train_step_microbatched_matches_full():
    from repro.dist.step import make_train_step
    from repro.launch.mesh import make_host_mesh
    from repro.models.registry import build_model
    from repro.optim import adamw_init, constant_lr

    cfg = get_config("stablelm-1.6b").reduced()
    model = build_model(cfg)
    mesh = make_host_mesh()
    params, _ = model.init(jax.random.key(0))

    def run(microbatches):
        jitted, *_ = make_train_step(
            model, mesh, constant_lr(1e-3), global_batch=4, microbatches=microbatches
        )
        p = jax.tree.map(jnp.copy, params)  # the step donates its state
        state = {"params": p, "opt": adamw_init(p), "step": jnp.int32(0)}
        tokens = jax.random.randint(jax.random.key(1), (4, 32), 0, cfg.vocab)
        state, metrics = jitted(state, {"tokens": tokens, "labels": tokens})
        return state, float(metrics["loss"])

    s1, l1 = run(1)
    s2, l2 = run(2)
    assert l1 == pytest.approx(l2, rel=2e-3)
    for a, b in zip(jax.tree.leaves(s1["params"]), jax.tree.leaves(s2["params"])):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), rtol=2e-2, atol=2e-4
        )
