"""Integration: the model trunk with Pallas kernels (interpret mode) must
match the pure-jnp reference path — the exact swap that happens on TPU."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels.ops import kernel_set
from repro.models.registry import build_model, train_loss


# multi-minute model/kernel path: runs in the full CI job only
pytestmark = pytest.mark.slow



@pytest.mark.parametrize("arch", ["mixtral-8x7b", "falcon-mamba-7b", "jamba-v0.1-52b"])
def test_trunk_with_pallas_kernels_matches_reference(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params, _ = model.init(jax.random.key(0))
    B, L = 2, 32
    toks = jax.random.randint(jax.random.key(1), (B, L), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}

    loss_ref, _ = train_loss(model, params, batch, kernels=None)
    loss_krn, _ = train_loss(
        model, params, batch, kernels=kernel_set(use_pallas=True, interpret=True)
    )
    assert float(loss_ref) == pytest.approx(float(loss_krn), rel=2e-4), arch


def test_flash_attention_op_jit_wrapper():
    from repro.kernels.ops import flash_attention_op
    from repro.kernels.ref import reference_attention

    rng = np.random.RandomState(0)
    q = jnp.asarray(rng.randn(1, 128, 4, 32), jnp.float32)
    k = jnp.asarray(rng.randn(1, 128, 2, 32), jnp.float32)
    v = jnp.asarray(rng.randn(1, 128, 2, 32), jnp.float32)
    out = flash_attention_op(q, k, v, causal=True, block_q=64, block_kv=64)
    ref = reference_attention(q, k, v, causal=True)
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)
