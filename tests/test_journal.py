"""Durable provenance journal (ISSUE 5): append-only write-through, seq
ordering, crash-safe `Workspace.from_journal` rehydration (torn final line
included), drop_oldest forensics, and the registry read-path thread-safety
sweep under ConcurrentExecutor."""

import json
import os
import threading

import numpy as np
import pytest

from repro.core.provenance import ProvenanceRegistry
from repro.provenance import (
    Journal,
    JournalCorruptError,
    read_chain,
    read_records,
    replay_journal,
)
from repro.topology import Topology
from repro.workspace import ConcurrentExecutor, Workspace


# ---------------------------------------------------------------------------
# circuits
# ---------------------------------------------------------------------------


def _chain_ws(tmp_path, name="journaled", topology=False, **kw):
    """source -> normalize -> score, journaling to tmp_path/<name>.jsonl."""
    ws = Workspace(
        name,
        journal_path=str(tmp_path / f"{name}.jsonl"),
        topology=topology,
        **kw,
    )
    norm = ws.task(
        lambda x: {"y": x / (np.linalg.norm(x) + 1e-9)},
        name="normalize", inputs=["x"], outputs=["y"],
    )
    score = ws.task(
        lambda y: {"s": float(y.sum())},
        name="score", inputs=["y"], outputs=["s"],
    )
    norm["y"] >> score["y"]
    return ws, norm, score


def _forensics(ws, av_uid, task="score"):
    """The rehydration equality contract: the three stories + visits_of."""
    return {
        "lineage": ws.registry.lineage(av_uid),
        "visitor_log": ws.visitor_log(task),
        "design_map": ws.design_map(),
        "design_map_text": ws.design_map_text(),
        "visits_of": ws.registry.visits_of(av_uid),
    }


# ---------------------------------------------------------------------------
# the journal file itself
# ---------------------------------------------------------------------------


class TestJournalFile:
    def test_append_assigns_monotonic_seq(self, tmp_path):
        j = Journal(tmp_path / "j.jsonl", flush_every_n=1)
        seqs = [j.append("visit", {"n": i}) for i in range(5)]
        assert seqs == sorted(seqs) and len(set(seqs)) == 5
        j.close()
        records, truncated = read_records(j.path)
        assert truncated == 0
        assert [r["seq"] for r in records] == list(range(len(records)))
        assert records[0]["kind"] == "meta"  # file header

    def test_flush_every_n_batches_fsync(self, tmp_path):
        j = Journal(tmp_path / "j.jsonl", flush_every_n=10)
        for i in range(25):
            j.append("visit", {"n": i})
        # 26 records incl. the meta header -> 2 full batches of 10
        assert j.flushes == 2
        j.flush()
        assert j.flushes == 3
        s = j.stats()
        assert s["records_written"] == 26
        assert s["bytes_on_disk"] > 0
        assert s["flush_every_n"] == 10
        j.close()

    def test_reopen_resumes_seq(self, tmp_path):
        j = Journal(tmp_path / "j.jsonl", flush_every_n=1)
        last = j.append("visit", {"n": 0})
        j.close()
        j2 = Journal(tmp_path / "j.jsonl", flush_every_n=1)
        assert j2.append("visit", {"n": 1}) == last + 1
        j2.close()

    def test_torn_final_line_is_dropped(self, tmp_path):
        j = Journal(tmp_path / "j.jsonl", flush_every_n=1)
        j.append("visit", {"n": 0})
        j.close()
        with open(j.path, "a") as f:
            f.write('{"seq": 2, "kind": "visit", "da')  # crash mid-write
        records, truncated = read_records(j.path)
        assert truncated == 1
        assert [r["seq"] for r in records] == [0, 1]

    def test_reopen_over_torn_tail_truncates_not_glues(self, tmp_path):
        """Resuming past a crash must drop the torn line before appending:
        'a' mode would glue the next record onto the partial tail, losing it
        (last line) or corrupting the whole journal (mid-file)."""
        j = Journal(tmp_path / "j.jsonl", flush_every_n=1)
        j.append("visit", {"n": 0})
        j.close()
        with open(j.path, "a") as f:
            f.write('{"seq": 2, "kind": "visit", "da')  # crash mid-write
        j2 = Journal(tmp_path / "j.jsonl", flush_every_n=1)
        s1 = j2.append("visit", {"n": 1})
        s2 = j2.append("visit", {"n": 2})
        j2.close()
        records, truncated = read_records(j2.path)
        assert truncated == 0  # the torn tail is gone, nothing glued
        assert [r["seq"] for r in records] == [0, 1, s1, s2]

    def test_mid_file_corruption_raises(self, tmp_path):
        path = tmp_path / "j.jsonl"
        path.write_text('{"seq": 0, "kind": "meta", "data": {}}\nnot json\n'
                        '{"seq": 2, "kind": "visit", "data": {}}\n')
        with pytest.raises(JournalCorruptError):
            read_records(str(path))

    def test_non_json_payloads_degrade_to_repr(self, tmp_path):
        j = Journal(tmp_path / "j.jsonl", flush_every_n=1)
        j.append("av", {"weird": object()})  # default=repr, never raises
        j.close()
        records, _ = read_records(j.path)
        assert "object object" in records[-1]["data"]["weird"]


# ---------------------------------------------------------------------------
# write-through: one typed record per event
# ---------------------------------------------------------------------------


class TestWriteThrough:
    def test_registry_cache_events_journaled(self, tmp_path):
        ws, norm, score = _chain_ws(tmp_path)
        x = np.arange(8.0)
        ws.push(norm, x=x)
        ws.push(norm, x=x)  # memo hits
        ws.registry.record_anomaly("score", "drift detected")
        ws.journal.flush()
        kinds = [r["kind"] for r in read_chain(ws.journal.path)[0]]
        for kind in ("meta", "task", "edge", "av", "visit", "cache_hit", "anomaly"):
            assert kind in kinds, f"missing journal record kind {kind!r}"

    def test_ledger_and_topology_journaled(self, tmp_path):
        ws, norm, score = _chain_ws(
            tmp_path, topology=Topology.three_zone(), placement="pin"
        )
        ws.push(norm, x=np.arange(8.0))
        ws.journal.flush()
        records = read_chain(ws.journal.path)[0]
        kinds = [r["kind"] for r in records]
        assert "topology" in kinds and "ledger" in kinds
        spec = next(r["data"] for r in records if r["kind"] == "topology")
        assert Topology.from_spec(spec).describe() == ws.topology.describe()

    def test_stats_surface(self, tmp_path):
        ws, norm, _ = _chain_ws(tmp_path)
        ws.push(norm, x=np.arange(4.0))
        s = ws.stats()["journal"]
        assert s["records_written"] > 0
        assert s["bytes_on_disk"] > 0
        assert {"flushes", "flush_every_n", "path", "next_seq"} <= set(s)

    def test_env_knob_creates_tempdir_journal(self, tmp_path, monkeypatch):
        monkeypatch.setenv("KOALJA_JOURNAL", str(tmp_path / "envdir"))
        ws = Workspace("envy")
        t = ws.task(lambda x: {"y": x + 1}, name="t", inputs=["x"], outputs=["y"])
        ws.push(t, x=1)
        assert ws.journal is not None
        assert ws.journal.path.startswith(str(tmp_path / "envdir"))
        assert ws.stats()["journal"]["records_written"] > 0

    def test_env_off_and_explicit_false(self, tmp_path, monkeypatch):
        monkeypatch.setenv("KOALJA_JOURNAL", "0")
        assert Workspace("off").journal is None
        monkeypatch.setenv("KOALJA_JOURNAL", "1")
        assert Workspace("forced-off", journal_path=False).journal is None


# ---------------------------------------------------------------------------
# rehydration: Workspace.from_journal
# ---------------------------------------------------------------------------


class TestFromJournal:
    def test_stories_identical_after_restart(self, tmp_path):
        ws, norm, score = _chain_ws(tmp_path)
        x = np.arange(16.0)
        ws.push(norm, x=x)
        av = ws.push(norm, x=x)[score].av("s")  # second push memo-hits
        live = _forensics(ws, av.uid)
        ws.journal.close()

        ws2 = Workspace.from_journal(ws.journal.path)
        assert ws2.name == "journaled"
        assert _forensics(ws2, av.uid) == live
        # the memoized lineage still reconstructs the original run
        lin = ws2.registry.lineage(av.uid)
        assert lin["cache_hit"] is True and lin["memo_of"]["parents"]

    def test_ledger_identical_after_restart(self, tmp_path):
        ws, norm, score = _chain_ws(
            tmp_path, topology=Topology.three_zone(), placement="pin"
        )
        norm.place("edge")
        score.place("cloud")
        ws.push(norm, x=np.arange(64.0))
        live = ws.stats()["topology"]["ledger"]
        assert live["bytes_moved_crosszone"] > 0  # the run must be non-trivial
        ws.journal.close()

        ws2 = Workspace.from_journal(ws.journal.path)
        assert ws2.stats()["topology"]["ledger"] == live
        assert ws2.ledger.stats() == live

    def test_crash_mid_write_keeps_prefix(self, tmp_path):
        """ISSUE 5 acceptance: a partial final JSONL line (killed mid-run)
        must not poison rehydration — the intact prefix answers exactly."""
        ws, norm, score = _chain_ws(tmp_path)
        av = ws.push(norm, x=np.arange(8.0))[score].av("s")
        live = _forensics(ws, av.uid)
        ws.journal.close()
        with open(ws.journal.path, "a") as f:
            f.write('{"seq": 424242, "kind": "visit", "data": {"task": "sco')

        ws2 = Workspace.from_journal(ws.journal.path)
        assert _forensics(ws2, av.uid) == live
        assert ws2.stats()["journal"]["truncated_lines"] == 1

    def test_rehydrated_registry_continues_seq(self, tmp_path):
        ws, norm, score = _chain_ws(tmp_path)
        ws.push(norm, x=np.arange(4.0))
        max_seq = max(e["seq"] for e in ws.visitor_log(score))
        ws.journal.close()
        ws2 = Workspace.from_journal(ws.journal.path)
        ws2.registry.log_visit("score", "-", "anomaly", "v", note="post-restart")
        assert ws2.visitor_log("score")[-1]["seq"] > max_seq

    def test_rehydration_never_rejournals(self, tmp_path, monkeypatch):
        monkeypatch.setenv("KOALJA_JOURNAL", "1")  # even with the env knob on
        ws, norm, _ = _chain_ws(tmp_path)
        ws.push(norm, x=np.arange(4.0))
        ws.journal.close()
        ws2 = Workspace.from_journal(ws.journal.path)
        assert ws2.journal is None
        assert ws2.registry.journal is None

    def test_resumed_run_keeps_visit_seq_total_order(self, tmp_path):
        """A second process journaling to the same path must not restart
        entry seqs at 0 — replayed visits_of would interleave its events
        among the first run's."""
        path = tmp_path / "resume.jsonl"
        for run in range(2):
            ws = Workspace("resumed", journal_path=str(path))
            t = ws.task(
                lambda x: {"y": x + 1}, name="t", inputs=["x"], outputs=["y"]
            )
            ws.push(t, x=float(run))
            ws.journal.close()
        rep = replay_journal(str(path))
        seqs = [e["seq"] for e in rep.registry.visitor_log("t")]
        assert seqs == sorted(seqs) and len(set(seqs)) == len(seqs)

    def test_resumed_topology_run_keeps_prior_ledger_charges(self, tmp_path):
        """A resumed run re-announces its topology spec; replay must keep
        the ledger charges accumulated from the pre-restart records."""
        path = tmp_path / "ledger-resume.jsonl"
        per_run = []
        for run in range(2):
            ws, norm, score = _chain_ws(
                tmp_path, name="lr", topology=Topology.three_zone(), placement="pin"
            )
            ws._journal.close()  # _chain_ws made its own; re-point at `path`
            from repro.provenance import Journal

            ws._journal = Journal(str(path), workspace="lr")
            norm.place("edge")
            score.place("cloud")
            ws.push(norm, x=np.arange(64.0) + run)  # fresh content: no memo
            per_run.append(ws.stats()["topology"]["ledger"]["bytes_moved_crosszone"])
            ws.journal.close()
        rep = replay_journal(str(path))
        assert rep.ledger.stats()["bytes_moved_crosszone"] == sum(per_run)

    def test_replay_counts(self, tmp_path):
        ws, norm, _ = _chain_ws(tmp_path)
        x = np.arange(4.0)
        ws.push(norm, x=x)
        ws.push(norm, x=x)
        ws.journal.close()
        rep = replay_journal(ws.journal.path)
        assert rep.counts["task"] == 2 and rep.counts["edge"] == 1
        assert rep.counts["cache_hit"] == 2  # one per memo-hitting task


# ---------------------------------------------------------------------------
# ordering: visits_of by seq, not wall clock
# ---------------------------------------------------------------------------


class TestSeqOrdering:
    def test_visits_of_orders_by_seq_on_tied_clocks(self):
        reg = ProvenanceRegistry()
        for i in range(10):
            reg.log_visit(f"t{i}", "av-x", "arrived", "v")
        # clobber every timestamp to one tick: the old timestamp sort had
        # nothing left to order by
        with reg._lock:
            for entries in reg._visitor_logs.values():
                for e in entries:
                    e.timestamp = 1234.5
        tasks = [v["task"] for v in reg.visits_of("av-x")]
        assert tasks == [f"t{i}" for i in range(10)]
        seqs = [v["seq"] for v in reg.visits_of("av-x")]
        assert seqs == sorted(seqs)

    def test_visitor_entries_carry_monotonic_seq(self):
        ws = Workspace("seq")
        t = ws.task(lambda x: {"y": x}, name="t", inputs=["x"], outputs=["y"])
        for i in range(3):
            ws.push(t, x=i)
        seqs = [e["seq"] for e in ws.visitor_log(t)]
        assert seqs == sorted(seqs) and len(set(seqs)) == len(seqs)


# ---------------------------------------------------------------------------
# drop_oldest forensics (no more silent disappearance)
# ---------------------------------------------------------------------------


class TestDropForensics:
    def _offer_through(self, ws, n=3):
        from repro.core.av import AnnotatedValue

        mgr = ws.manager
        link = mgr.pipeline.tasks["slow"].in_links["x"]
        avs = [AnnotatedValue.produce(f"h{i}", f"u{i}", "src", "v") for i in range(n)]
        for av in avs:
            mgr.registry.register_av(av)
            link.offer(av, software_version="v")
        return avs

    def _ring_ws(self, **ws_kwargs):
        ws = Workspace("ring", **ws_kwargs)
        src = ws.source(lambda: {"x": 0.0}, name="src", outputs=["x"])
        slow = ws.task(
            lambda x: {"y": x}, name="slow", inputs=["x[8]"], outputs=["y"]
        )
        ws.wire(src["x"], slow["x"], capacity=1, overflow="drop_oldest")
        return ws

    def test_drop_logs_visit_and_stamps_traveller(self):
        ws = self._ring_ws()
        avs = self._offer_through(ws, n=3)
        log = ws.visitor_log("slow")
        dropped = [e for e in log if e["event"] == "dropped"]
        assert [e["av_uid"] for e in dropped] == [avs[0].uid, avs[1].uid]
        assert "drop_oldest" in dropped[0]["note"]
        # the traveller log records the disappearance too
        journey = [(s["task"], s["event"]) for s in ws.traveller_log(avs[0])]
        assert journey[-1][1] == "dropped"
        # and the counter still agrees
        assert ws.manager.pipeline.tasks["slow"].in_links["x"].avs_dropped == 2

    def test_drop_survives_restart_via_journal(self, tmp_path):
        ws = self._ring_ws(journal_path=str(tmp_path / "ring.jsonl"))
        avs = self._offer_through(ws, n=2)
        ws.journal.close()
        ws2 = Workspace.from_journal(ws.journal.path)
        events = [(e["event"], e["av_uid"]) for e in ws2.visitor_log("slow")]
        assert ("dropped", avs[0].uid) in events


# ---------------------------------------------------------------------------
# thread-safety sweep: forensic reads under a concurrent writer
# ---------------------------------------------------------------------------


class TestConcurrentReads:
    def test_lineage_under_concurrent_waves(self):
        """Hammer every read path while an 8-wide ConcurrentExecutor circuit
        registers AVs; the unlocked reads died with 'dictionary changed size
        during iteration' or KeyError mid-lineage."""
        ws = Workspace("stress", executor=ConcurrentExecutor(max_workers=8))
        cam = ws.source(
            lambda: {"x": np.random.randn(32)}, name="cam", outputs=["x"]
        )
        for i in range(8):
            t = ws.task(
                lambda x, i=i: {"y": float(np.sum(x)) + i},
                name=f"t{i}", inputs=["x"], outputs=["y"],
            )
            cam["x"] >> t["x"]

        errors: list = []
        stop = threading.Event()

        def hammer():
            reg = ws.registry
            while not stop.is_set():
                try:
                    for uid in reg.all_avs():
                        reg.lineage(uid)
                        reg.visits_of(uid)
                    reg.overhead_bytes()
                    reg.design_map()
                    ws.design_map_text()
                except Exception as e:  # pragma: no cover - the regression
                    errors.append(e)
                    return

        threads = [threading.Thread(target=hammer) for _ in range(3)]
        for th in threads:
            th.start()
        try:
            for _ in range(40):
                ws.sample(cam)
        finally:
            stop.set()
            for th in threads:
                th.join()
        assert not errors, f"forensic read raced a writer: {errors[:1]}"
        assert len(ws.registry.all_avs()) >= 40 * 9

    def test_concurrent_journal_writes_keep_seq_total_order(self, tmp_path):
        ws = Workspace(
            "conc-journal",
            executor=ConcurrentExecutor(max_workers=8),
            journal_path=str(tmp_path / "conc.jsonl"),
        )
        cam = ws.source(lambda: {"x": np.arange(8.0)}, name="cam", outputs=["x"])
        for i in range(6):
            t = ws.task(
                lambda x, i=i: {"y": float(x.sum()) + i},
                name=f"t{i}", inputs=["x"], outputs=["y"],
            )
            cam["x"] >> t["x"]
        for _ in range(5):
            ws.sample(cam)
        ws.journal.flush()
        records, truncated, _info = read_chain(ws.journal.path)
        assert truncated == 0
        seqs = [r["seq"] for r in records]
        assert seqs == list(range(len(seqs)))  # gapless total order


# ---------------------------------------------------------------------------
# fused batch encode/append (ISSUE 8)
# ---------------------------------------------------------------------------


class TestBatchAppend:
    RECORDS = [
        ("visit", {"task": "score", "av_uid": "av-0001", "event": "executed",
                   "timestamp": 1723100000.123456, "software_version": "v1",
                   "note": "wall=0.000123s", "seq": 7}),
        ("av", {"av": {"uid": "av-0002", "chash": "ab" * 8, "uri": "mem://x",
                       "meta": None}, "parents": ["av-0001"]}),
        ("anomaly", {"task": "t", "note": 'quote " and \\ backslash\nnewline'},),
        ("ledger", {"bytes": 4096, "pair": ["cloud", "edge"], "energy_j": 0.05}),
        ("odd", {"nan": float("nan"), "inf": float("inf"), "neg0": -0.0,
                 "big": 10**40, "uni": "ünïcode ⚙", "obj": object()}),
        ("nest", {"a": [1, [2, {"b": (3, 4)}]], "flags": [True, False, None]}),
    ]

    def test_encode_record_matches_json_dumps(self):
        from repro.provenance.journal import encode_record

        for i, (kind, data) in enumerate(self.RECORDS):
            want = json.dumps(
                {"seq": i, "kind": kind, "data": data},
                default=repr, separators=(",", ":"),
            )
            assert encode_record(i, kind, data) == want

    def test_append_batch_bytes_identical_to_scalar_appends(self, tmp_path):
        a, b = tmp_path / "a.jsonl", tmp_path / "b.jsonl"
        ja = Journal(str(a), flush_every_n=1)
        for kind, data in self.RECORDS:
            ja.append(kind, data)
        ja.close()
        jb = Journal(str(b), flush_every_n=1)
        seqs = jb.append_batch(self.RECORDS)
        jb.close()
        # seq 0 is the journal's own meta header record
        assert seqs == list(range(1, len(self.RECORDS) + 1))
        strip = lambda p: [  # noqa: E731
            l for l in p.read_text().splitlines() if '"kind":"meta"' not in l
        ]
        assert strip(a) == strip(b)

    def test_staging_window_defers_and_flushes(self, tmp_path):
        j = Journal(str(tmp_path / "s.jsonl"), flush_every_n=1)
        with j.staging():
            assert j.append("visit", {"n": 0}) == -1  # deferred
            with j.staging():  # reentrant: joins the outer window
                assert j.append("visit", {"n": 1}) == -1
            assert j.records_written <= 1  # only the journal's own meta
        j.append("visit", {"n": 2})  # post-window: direct append
        j.close()
        records, truncated, _ = read_chain(j.path)
        assert truncated == 0
        body = [r for r in records if r["kind"] != "meta"]
        assert [r["data"]["n"] for r in body] == [0, 1, 2]
        assert [r["seq"] for r in records] == list(range(len(records)))

    def test_staging_window_flushes_on_exception(self, tmp_path):
        j = Journal(str(tmp_path / "exc.jsonl"), flush_every_n=1)
        with pytest.raises(RuntimeError):
            with j.staging():
                j.append("visit", {"n": 0})
                raise RuntimeError("user fn failed")
        j.close()
        records, _, _ = read_chain(j.path)
        assert any(
            r["kind"] == "visit" and r["data"]["n"] == 0 for r in records
        ), "records staged before the failure must still be durable"

    def test_append_batch_rotates(self, tmp_path):
        j = Journal(str(tmp_path / "rot.jsonl"), rotate_records=10)
        j.append_batch([("visit", {"n": i}) for i in range(25)])
        j.close()
        assert j.stats()["rotations"] >= 1
        records, truncated, _ = read_chain(j.path)
        assert truncated == 0
        assert [r["seq"] for r in records] == list(range(len(records)))

    def test_encode_wall_s_counter(self, tmp_path):
        j = Journal(str(tmp_path / "w.jsonl"))
        j.append_batch([("visit", {"n": i}) for i in range(100)])
        st = j.stats()
        j.close()
        assert st["encode_wall_s"] > 0.0
