"""Regression tests for the hillclimb levers: group-local MoE dispatch,
causal block-skipping, head padding — each must be numerically equivalent
(or exactly characterized) vs the faithful baseline."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.common import ParamBuilder, grad_cast
from repro.models.moe import expert_capacity, init_moe, moe_ffn
from repro.models.registry import build_model, train_loss


# multi-minute model/kernel path: runs in the full CI job only
pytestmark = pytest.mark.slow



def _moe_params(cfg, dtype=jnp.float32):
    pb = ParamBuilder(jax.random.key(0), dtype)
    return jax.tree.map(
        lambda x: x[0],
        init_moe(pb, cfg),
        is_leaf=lambda x: isinstance(x, tuple) and hasattr(x[0], "dtype"),
    )


def test_moe_groups_exact_with_generous_capacity():
    cfg = dataclasses.replace(get_config("mixtral-8x7b").reduced(), capacity_factor=8.0)
    p = _moe_params(cfg)
    x = jax.random.normal(jax.random.key(1), (4, 16, cfg.d_model))
    y1, a1 = moe_ffn(p, cfg, x)
    for g in (2, 4):
        y2, a2 = moe_ffn(p, dataclasses.replace(cfg, moe_groups=g), x)
        np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
        assert float(a2["dropped_frac"]) == 0.0


def test_moe_groups_nondivisible_falls_back():
    cfg = dataclasses.replace(get_config("mixtral-8x7b").reduced(), moe_groups=7)
    p = _moe_params(cfg)
    x = jax.random.normal(jax.random.key(1), (4, 16, cfg.d_model))  # 64 % 7 != 0
    y, _ = moe_ffn(p, cfg, x)  # must not crash (G falls back to 1)
    assert y.shape == x.shape


def test_moe_group_capacity_scales():
    cfg = get_config("mixtral-8x7b").reduced()
    c_global = expert_capacity(1024, cfg)
    c_group = expert_capacity(1024 // 4, cfg)
    assert c_group <= c_global


def test_causal_skip_train_loss_identical():
    cfg = get_config("internlm2-20b").reduced()
    m0 = build_model(cfg)
    m1 = build_model(dataclasses.replace(cfg, causal_skip=True, block_q=16, block_kv=16))
    params, _ = m0.init(jax.random.key(0))
    B, L = 2, 64
    toks = jax.random.randint(jax.random.key(1), (B, L), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    l0, _ = train_loss(m0, params, batch)
    l1, _ = train_loss(m1, params, batch)
    assert float(l0) == pytest.approx(float(l1), rel=1e-5)


def test_pad_heads_zero_contribution_at_init():
    """With identical real-head weights, padded heads must not change the
    output (their wo rows are zero)."""
    cfg = get_config("minicpm3-4b").reduced()
    cfgp = dataclasses.replace(cfg, pad_heads=2)
    m0, mp = build_model(cfg), build_model(cfgp)
    p0, _ = m0.init(jax.random.key(0))
    pp, _ = mp.init(jax.random.key(0))

    # splice the unpadded weights into the padded tree (pad rows keep init)
    def splice(path_p, pad_leaf, real_leaf):
        if pad_leaf.shape == real_leaf.shape:
            return real_leaf
        # head-padded dim: copy real heads, zero the rest where wo-like
        idx = [i for i, (a, b) in enumerate(zip(pad_leaf.shape, real_leaf.shape)) if a != b]
        assert len(idx) == 1
        ax = idx[0]
        pad = pad_leaf
        sl = [slice(None)] * pad.ndim
        sl[ax] = slice(0, real_leaf.shape[ax])
        pad = pad.at[tuple(sl)].set(real_leaf)
        slp = [slice(None)] * pad.ndim
        slp[ax] = slice(real_leaf.shape[ax], None)
        return pad.at[tuple(slp)].set(0.0)

    pp2 = jax.tree.map(lambda a, b: splice(None, a, b), pp, p0)
    B, L = 2, 16
    toks = jax.random.randint(jax.random.key(1), (B, L), 0, cfg.vocab)
    x0 = m0.embed(p0, toks)
    xp = mp.embed(pp2, toks)
    pos = jnp.broadcast_to(jnp.arange(L)[None], (B, L))
    o0, _, _ = m0.trunk(p0, x0, pos)
    op, _, _ = mp.trunk(pp2, xp, pos)
    np.testing.assert_allclose(np.asarray(o0), np.asarray(op), rtol=1e-4, atol=1e-5)


def test_grad_cast_casts_cotangent():
    x = jnp.ones(4, jnp.bfloat16)

    def f(x):
        return (grad_cast(x).astype(jnp.float32) ** 2).sum()

    g = jax.grad(f)(x)
    assert g.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(g, np.float32), 2.0 * np.ones(4))


def test_grad_cast_is_identity_forward():
    x = jax.random.normal(jax.random.key(0), (8,))
    np.testing.assert_array_equal(np.asarray(grad_cast(x)), np.asarray(x))
