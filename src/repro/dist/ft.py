"""Fault tolerance: heartbeats, straggler/dead-host detection, replay.

The Koalja make-mode posture applied to training: a failure is not an
emergency, it is a missing build artifact. ``run_with_recovery`` restores
the latest checkpoint AV and replays — the provenance registry already
names exactly which data batches the restored state had consumed.

Straggler detection uses a robust z-score (median / MAD with a relative
floor) over per-host mean step durations, so one slow host cannot inflate
the scale estimate that is supposed to expose it.
"""

from __future__ import annotations

import statistics
import time
from typing import Callable, Optional


class SimulatedFailure(RuntimeError):
    """Injected host failure (tests / chaos drills)."""

    def __init__(self, host: int, msg: str = ""):
        self.host = host
        super().__init__(msg or f"simulated failure on host {host}")


class FaultToleranceManager:
    def __init__(
        self,
        n_hosts: int,
        straggler_zscore: float = 3.0,
        heartbeat_timeout_s: float = 60.0,
    ) -> None:
        self.n_hosts = n_hosts
        self.straggler_zscore = straggler_zscore
        self.heartbeat_timeout_s = heartbeat_timeout_s
        self._durations: dict = {h: [] for h in range(n_hosts)}
        self._last_seen: dict = {}
        self.restarts = 0

    # -- heartbeats -----------------------------------------------------------
    def heartbeat(self, host: int, step_duration_s: float) -> None:
        self._durations.setdefault(host, []).append(float(step_duration_s))
        self._last_seen[host] = time.time()

    # -- detection ------------------------------------------------------------
    def stragglers(self) -> list:
        """Hosts whose mean step duration is a robust-z outlier above the
        fleet median. Returns [(host, zscore)] sorted worst-first."""
        means = {
            h: statistics.fmean(d) for h, d in self._durations.items() if d
        }
        if len(means) < 3:
            return []
        med = statistics.median(means.values())
        mad = statistics.median(abs(m - med) for m in means.values())
        scale = max(1.4826 * mad, 0.02 * abs(med), 1e-12)
        out = [
            (h, (m - med) / scale)
            for h, m in means.items()
            if (m - med) / scale > self.straggler_zscore
        ]
        return sorted(out, key=lambda hz: -hz[1])

    def dead_hosts(self, now: Optional[float] = None) -> list:
        now = time.time() if now is None else now
        return sorted(
            h
            for h, t in self._last_seen.items()
            if now - t > self.heartbeat_timeout_s
        )

    # -- recovery -------------------------------------------------------------
    def run_with_recovery(
        self,
        run: Callable,
        restore: Callable,
        max_restarts: int = 16,
    ):
        """restore() -> start token; run(start) -> result. On failure,
        restore-and-replay (make semantics), bounded by max_restarts."""
        while True:
            start = restore()
            try:
                return run(start)
            except SimulatedFailure:
                self.restarts += 1
                if self.restarts > max_restarts:
                    raise
