"""Jitted, sharded train/serve step builders.

``make_train_step`` and ``make_serve_fns`` take a Model plus a mesh and
return donated jitted functions together with the shape/shard trees the
callers need for checkpointing, dry-run lowering (``jit(...).lower(ghost
shapes).compile()``), and per-device memory accounting. The model code never
sees the mesh — logical axis rules are installed around the traced call
(``axis_rules``) so the ``shard()`` hints inside the model bind here.
"""

from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.common import axis_rules
from repro.models.registry import (
    decode_step as _decode_step,
    init_serve_state,
    prefill as _prefill,
    train_loss,
)
from repro.optim import adamw_update, compress_state_init, ef_compress

from .sharding import cache_logical_axes, make_rules, pspec_for_axes, shardings_for


# ---------------------------------------------------------------------------
# Shape / spec trees
# ---------------------------------------------------------------------------


def param_specs(model):
    """(ShapeDtypeStruct tree, logical-axes tree) for the model's params —
    derived abstractly (no parameter is ever allocated)."""
    captured = {}

    def _init(key):
        params, axes = model.init(key)
        captured["axes"] = axes
        return params

    shapes = jax.eval_shape(_init, jax.random.key(0))
    return shapes, captured["axes"]


def make_train_state_specs(model):
    """(state shapes, state logical axes) for {params, opt, step}.

    AdamW moments mirror the param tree, so they inherit the param axes —
    FSDP shards optimizer state exactly like the weights (ZeRO posture)."""
    pshapes, paxes = param_specs(model)
    f32 = lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32)
    state_shapes = {
        "params": pshapes,
        "opt": {
            "m": jax.tree.map(f32, pshapes),
            "v": jax.tree.map(f32, pshapes),
            "count": jax.ShapeDtypeStruct((), jnp.int32),
        },
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }
    state_axes = {
        "params": paxes,
        "opt": {"m": paxes, "v": paxes, "count": ()},
        "step": (),
    }
    return state_shapes, state_axes


def make_batch_specs(cfg, kind: str, global_batch: int, seq_len: int) -> dict:
    """Ghost batch (ShapeDtypeStructs) for one input shape cell."""
    sds = jax.ShapeDtypeStruct
    batch = {"tokens": sds((global_batch, seq_len), jnp.int32)}
    if kind == "train":
        batch["labels"] = sds((global_batch, seq_len), jnp.int32)
    if cfg.encoder_layers:
        batch["frames"] = sds(
            (global_batch, cfg.frontend_len, cfg.d_model), cfg.compute_dtype()
        )
    if cfg.frontend == "vision":
        batch["prefix"] = sds(
            (global_batch, cfg.frontend_len, cfg.d_model), cfg.compute_dtype()
        )
    return batch


def _batch_shardings(cfg, kind: str, rules: dict, mesh) -> dict:
    tok = NamedSharding(mesh, P(rules.get("batch"), None))
    three = NamedSharding(mesh, P(rules.get("batch"), None, None))
    out = {"tokens": tok}
    if kind == "train":
        out["labels"] = tok
    if cfg.encoder_layers:
        out["frames"] = three
    if cfg.frontend == "vision":
        out["prefix"] = three
    return out


# ---------------------------------------------------------------------------
# Train step
# ---------------------------------------------------------------------------


def make_train_step(
    model,
    mesh,
    schedule: Callable,
    *,
    rules: Optional[dict] = None,
    global_batch: int,
    microbatches: int = 1,
    compress_pods: bool = False,
):
    """Build the donated, sharded train step.

    Returns (jitted, state_shapes, state_shard, batch_shard) where
    ``jitted(state, batch) -> (state, metrics)`` donates its state argument.

    microbatches > 1 accumulates gradients over equal batch splits (mean of
    per-microbatch means == full-batch mean when splits are equal).
    compress_pods applies int8 error-feedback compression to the gradient
    payload crossing the ``pod`` axis (adds a ``compress`` residual tree to
    the state).
    """
    cfg = model.cfg
    rules = dict(rules) if rules is not None else make_rules(cfg, mesh, "train", global_batch)
    state_shapes, state_axes = make_train_state_specs(model)

    compress = bool(compress_pods) and dict(mesh.shape).get("pod", 1) > 1
    if compress:
        f32 = lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32)
        state_shapes["compress"] = {
            "residual": jax.tree.map(f32, state_shapes["params"])
        }
        state_axes["compress"] = {"residual": state_axes["params"]}
    n_pods = dict(mesh.shape).get("pod", 1)

    state_shard = shardings_for(state_axes, state_shapes, rules, mesh)
    batch_shard = _batch_shardings(cfg, "train", rules, mesh)

    if microbatches > 1 and global_batch % microbatches != 0:
        raise ValueError(
            f"global_batch {global_batch} not divisible by microbatches {microbatches}"
        )

    def train_step(state, batch):
        with axis_rules(rules, mesh):
            lr = schedule(state["step"]).astype(jnp.float32)
            grad_fn = jax.value_and_grad(
                lambda p, b: train_loss(model, p, b), has_aux=True
            )

            if microbatches > 1:
                mb = jax.tree.map(
                    lambda x: x.reshape(
                        (microbatches, x.shape[0] // microbatches) + x.shape[1:]
                    ),
                    batch,
                )

                def acc(carry, b):
                    gsum, lsum = carry
                    (l, _), g = grad_fn(state["params"], b)
                    gsum = jax.tree.map(
                        lambda a, x: a + x.astype(jnp.float32), gsum, g
                    )
                    return (gsum, lsum + l), None

                zeros = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), state["params"]
                )
                (gsum, lsum), _ = jax.lax.scan(acc, (zeros, jnp.zeros((), jnp.float32)), mb)
                grads = jax.tree.map(lambda g: g / microbatches, gsum)
                loss = lsum / microbatches
            else:
                (loss, _), grads = grad_fn(state["params"], batch)

            new_state = {}
            if compress:
                # gradients crossing the slow pod links go int8 + error
                # feedback; in-pod reductions stay f32 (XLA native)
                from jax.experimental.shard_map import shard_map

                gspecs = jax.tree.map(lambda s: s.spec, state_shard["params"])
                cspecs = {"residual": gspecs}
                grads, cstate, _ = shard_map(
                    functools.partial(ef_compress, axis_name="pod", n_pods=n_pods),
                    mesh=mesh,
                    in_specs=(gspecs, cspecs),
                    out_specs=(gspecs, cspecs, P()),
                    check_rep=False,
                )(grads, state["compress"])
                new_state["compress"] = cstate

            new_params, new_opt, om = adamw_update(
                state["params"], grads, state["opt"], lr
            )
            new_state.update(
                {"params": new_params, "opt": new_opt, "step": state["step"] + 1}
            )
            metrics = {
                "loss": loss,
                "lr": lr,
                "grad_norm": om["grad_norm"],
                "clip_scale": om["clip_scale"],
            }
        return new_state, metrics

    jitted = jax.jit(
        train_step,
        in_shardings=(state_shard, batch_shard),
        out_shardings=(state_shard, None),
        donate_argnums=0,
    )
    return jitted, state_shapes, state_shard, batch_shard


# ---------------------------------------------------------------------------
# Serve fns (prefill + decode)
# ---------------------------------------------------------------------------


def make_serve_fns(
    model,
    mesh,
    *,
    max_len: int,
    global_batch: int,
    rules: Optional[dict] = None,
):
    """Build jitted (prefill, decode) against sharded KV/SSM caches.

    Returns (prefill_jit, decode_jit, st_shapes, shards):
      prefill_jit(params, tokens, state, frames=None, prefix=None)
      decode_jit(params, tokens, state)
    both donate their state argument. ``shards`` = {"params": ...,
    "state": {"caches": ..., "t": ...}} (NamedShardings for accounting).

    Shardings are applied as in-function constraints (not ``in_shardings``)
    so callers may thread extra state entries (e.g. encoder "memory")
    through untouched.
    """
    cfg = model.cfg
    rules = dict(rules) if rules is not None else make_rules(cfg, mesh, "serve", global_batch)
    pshapes, paxes = param_specs(model)
    param_shard = shardings_for(paxes, pshapes, rules, mesh)

    st_shapes = jax.eval_shape(lambda: init_serve_state(model, global_batch, max_len))
    cache_axes = cache_logical_axes(cfg, max_len)
    cache_shard = shardings_for(cache_axes, st_shapes["caches"], rules, mesh)
    state_shard = {"caches": cache_shard, "t": NamedSharding(mesh, P())}
    shards = {"params": param_shard, "state": state_shard}
    logits_shard = NamedSharding(
        mesh, pspec_for_axes(("batch", "vocab"), (global_batch, cfg.vocab), rules, mesh)
    )

    def _constrain(tree_, shard_tree):
        return jax.tree.map(jax.lax.with_sharding_constraint, tree_, shard_tree)

    def prefill_fn(params, tokens, state, frames=None, prefix=None):
        params = _constrain(params, param_shard)
        state = {**state, "caches": _constrain(state["caches"], cache_shard)}
        with axis_rules(rules, mesh):
            logits, new_state = _prefill(
                model, params, tokens, state, frames=frames, prefix=prefix
            )
        new_state = {**new_state, "caches": _constrain(new_state["caches"], cache_shard)}
        return jax.lax.with_sharding_constraint(logits, logits_shard), new_state

    def decode_fn(params, tokens, state):
        params = _constrain(params, param_shard)
        state = {**state, "caches": _constrain(state["caches"], cache_shard)}
        with axis_rules(rules, mesh):
            logits, new_state = _decode_step(model, params, tokens, state)
        new_state = {**new_state, "caches": _constrain(new_state["caches"], cache_shard)}
        return jax.lax.with_sharding_constraint(logits, logits_shard), new_state

    prefill_jit = jax.jit(prefill_fn, donate_argnums=(2,))
    decode_jit = jax.jit(decode_fn, donate_argnums=(2,))
    return prefill_jit, decode_jit, st_shapes, shards
