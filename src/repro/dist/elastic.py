"""Elastic resharding: move a train state onto a different mesh.

Koalja's underlay transparency applied to capacity changes: the state is a
pytree of arrays plus a logical-axes tree; a new mesh just means new rules
and a ``device_put`` onto the derived shardings. Works for growing (more
hosts join), shrinking (hosts lost, after restore), and axis reshape
(e.g. trading data for model parallelism at a config change).
"""

from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from .sharding import make_rules, shardings_for


def _state_shardings(state: dict, axes, rules: dict, mesh) -> dict:
    """Sharding tree matching a {params, opt, step, ...} train state.

    params / opt.m / opt.v follow the logical param axes; every other leaf
    (step, opt.count, auxiliary scalars) is replicated."""
    repl = NamedSharding(mesh, P())
    out: dict = {}
    for key, sub in state.items():
        if key == "params":
            out[key] = shardings_for(axes, sub, rules, mesh)
        elif key == "opt":
            out[key] = {
                k: (
                    shardings_for(axes, v, rules, mesh)
                    if k in ("m", "v")
                    else jax.tree.map(lambda _: repl, v)
                )
                for k, v in sub.items()
            }
        else:
            out[key] = jax.tree.map(lambda _: repl, sub)
    return out


def reshard_state(
    state: dict,
    axes,
    mesh_from,
    mesh_to,
    cfg,
    mode: str,
    global_batch: Optional[int] = None,
):
    """Reshard {params, opt, step} from mesh_from onto mesh_to.

    axes: the logical-axes tree returned by ``model.init`` (params layout).
    Returns (new_state, shardings). mesh_from is accepted for symmetry /
    audit logging; the transfer itself is expressed purely as target
    shardings (XLA emits the minimal resharding collective).
    """
    rules = make_rules(cfg, mesh_to, mode, global_batch)
    shardings = _state_shardings(state, axes, rules, mesh_to)
    new_state = jax.device_put(state, shardings)
    return new_state, shardings
