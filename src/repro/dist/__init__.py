"""Distribution layer: logical-axis sharding rules, jitted train/serve step
builders, fault tolerance, and elastic resharding.

This package is the bridge between the model substrate (``repro.models``,
pure functions with logical-axis annotations) and a concrete JAX mesh. The
Koalja framing: the mesh is the underlay, and this layer is what makes it
transparent — the same circuit runs on a laptop host mesh or a multi-pod
production mesh because only the rules change, never the model code.

  - :mod:`repro.dist.sharding` — logical axis name -> mesh axis rules per
    (arch, mode), PartitionSpec derivation with divisibility fallbacks.
  - :mod:`repro.dist.step` — ``make_train_step`` / ``make_serve_fns``:
    donated, sharded, jitted step functions plus their shape/shard trees.
  - :mod:`repro.dist.ft` — heartbeat-based fault tolerance (stragglers,
    dead hosts, simulated failures, restore-and-replay).
  - :mod:`repro.dist.elastic` — reshard a train state onto a new mesh.
"""

from .elastic import reshard_state
from .ft import FaultToleranceManager, SimulatedFailure
from .sharding import cache_logical_axes, make_rules, pspec_for_axes, shardings_for
from .step import (
    make_batch_specs,
    make_serve_fns,
    make_train_state_specs,
    make_train_step,
    param_specs,
)

__all__ = [
    "reshard_state",
    "FaultToleranceManager", "SimulatedFailure",
    "cache_logical_axes", "make_rules", "pspec_for_axes", "shardings_for",
    "make_batch_specs", "make_serve_fns", "make_train_state_specs",
    "make_train_step", "param_specs",
]
