"""Logical-axis sharding rules and PartitionSpec derivation.

Models annotate every parameter and activation with *logical* axis names
("embed", "heads", "kv_seq", ...). This module maps logical names to mesh
axes per (arch config, mesh, mode) and derives concrete PartitionSpecs with
two safety properties:

  - **divisibility fallback** — a dimension that does not divide evenly over
    its assigned mesh axes is replicated (that dim only), so an arch with 40
    heads on a 16-way model axis lowers instead of crashing;
  - **no mesh-axis reuse** — a mesh axis consumed by an earlier dimension of
    the same tensor is dropped from later dimensions (XLA requires each mesh
    axis to appear at most once per spec).

The rules encode the placement policy:

  train — Megatron tensor parallelism over ``model`` (heads / mlp / vocab,
  or experts when the expert count divides), FSDP over ``data`` (the
  ``embed`` dim of every weight, optimizer state included for free because
  AdamW state mirrors the param tree), batch over ``(pod, data)``.

  serve — no FSDP (weights stay whole per model shard: decode is latency
  bound and all-gathering weights every token would dominate), batch over
  the data axes, and the KV cache placed by the *flash-decoding fallback*:
  when the KV head count does not divide the model axis, the cache shards
  over its sequence axis instead (``kv_seq``), turning decode attention into
  per-shard partial softmax + cross-shard combine. A batch too small to
  occupy the data axes (long-context ``global_batch=1``) donates those axes
  to ``kv_seq`` as well.

Only ``mesh.shape`` (a name->size mapping) is consulted, so rules can be
computed for meshes that do not exist yet (capacity planning).
"""

from __future__ import annotations

import math
from typing import Optional

from jax.sharding import NamedSharding, PartitionSpec

from repro.models.common import ArchConfig


def _axis_size(mesh_shape: dict, entry) -> int:
    axes = entry if isinstance(entry, (tuple, list)) else (entry,)
    return math.prod(mesh_shape.get(a, 1) for a in axes)


def pspec_for_axes(axes: tuple, shape: tuple, rules: dict, mesh) -> PartitionSpec:
    """Derive a PartitionSpec for one tensor.

    axes: logical axis name (or None) per dimension.
    shape: concrete dimension sizes (for divisibility checks).
    rules: logical name -> mesh axis (str), mesh axes (tuple), or None.

    A tuple assignment is reduced greedily from the right until the dimension
    divides (e.g. batch=8 over ("pod", "data")=(2, 16) falls back to "pod").
    """
    mesh_shape = dict(mesh.shape)
    used: set = set()
    entries = []
    for ax, dim in zip(axes, shape):
        assign = rules.get(ax) if ax is not None else None
        if assign is None:
            entries.append(None)
            continue
        cand = tuple(assign) if isinstance(assign, (tuple, list)) else (assign,)
        cand = tuple(a for a in cand if a not in used and mesh_shape.get(a, 1) > 1)
        while cand and dim % _axis_size(mesh_shape, cand) != 0:
            cand = cand[:-1]  # greedy fallback: drop trailing axes
        if not cand:
            entries.append(None)
            continue
        used.update(cand)
        entries.append(cand if len(cand) > 1 else cand[0])
    return PartitionSpec(*entries)


def _is_axes_leaf(x) -> bool:
    return isinstance(x, tuple) and all(e is None or isinstance(e, str) for e in x)


def shardings_for(axes_tree, shapes_tree, rules: dict, mesh):
    """NamedSharding tree from parallel (logical axes, shapes) trees."""
    import jax

    return jax.tree.map(
        lambda ax, s: NamedSharding(mesh, pspec_for_axes(ax, s.shape, rules, mesh)),
        axes_tree,
        shapes_tree,
        is_leaf=_is_axes_leaf,
    )


def make_rules(
    cfg: ArchConfig,
    mesh,
    mode: str,
    global_batch: Optional[int] = None,
) -> dict:
    """Logical axis name -> mesh axis assignment for one (arch, mesh, mode).

    mode: "train" | "serve". global_batch=None assumes a batch large enough
    to occupy the data axes (capacity-planning default).
    """
    if mode not in ("train", "serve"):
        raise ValueError(f"unknown mode {mode!r} (want 'train' or 'serve')")
    mesh_shape = dict(mesh.shape)
    model = "model" if mesh_shape.get("model", 1) > 1 else None
    tp = mesh_shape.get("model", 1)
    data_axes = tuple(a for a in ("pod", "data") if mesh_shape.get(a, 1) > 1)
    dp = _axis_size(mesh_shape, data_axes)
    batch_ok = bool(data_axes) and (
        global_batch is None or (global_batch >= dp and global_batch % dp == 0)
    )

    rules: dict = {
        "layers": None,
        "seq": None,
        "head_dim": None,
        "q_lora": None,
        "kv_lora": None,
        "vocab": model if cfg.vocab % tp == 0 else None,
        "heads": model if cfg.n_heads_eff % tp == 0 else None,
        "kv_heads": model if cfg.n_kv_heads % tp == 0 else None,
        "inner": model if cfg.d_inner % tp == 0 else None,
        "batch": (
            (data_axes if len(data_axes) > 1 else data_axes[0]) if batch_ok else None
        ),
        "moe_group": None,
    }

    # MoE FFN: expert parallelism when the expert count divides the model
    # axis; otherwise replicate experts and tensor-shard the ffn dim.
    if cfg.n_experts and cfg.n_experts % tp == 0:
        rules["experts"], rules["mlp"] = model, None
    else:
        rules["experts"] = None
        rules["mlp"] = model if (cfg.d_ff and cfg.d_ff % tp == 0) else None
    if cfg.moe_groups and "data" in mesh_shape:
        rules["moe_group"] = "data"

    # FSDP (ZeRO-3 posture) is a throughput lever: train only.
    rules["embed"] = "data" if (mode == "train" and "data" in mesh_shape) else None

    # serve: KV-cache placement (flash-decoding fallback on the seq axis)
    kv_seq: list = []
    if mode == "serve":
        if model and cfg.n_kv_heads % tp != 0:
            kv_seq.append("model")
        if data_axes and not batch_ok:
            kv_seq.extend(data_axes)
    rules["kv_seq"] = tuple(kv_seq) if kv_seq else None
    return rules


def cache_logical_axes(cfg: ArchConfig, max_len: int) -> list:
    """Logical-axes tree mirroring ``Model.init_cache(batch, max_len)``.

    Per layout position: a dict whose leaves are tuples of logical axis
    names, one entry per array dimension (the leading entry is "layers" —
    caches are stacked over the scan groups exactly like the params).
    """

    def attention_axes() -> dict:
        if cfg.attention == "mla":
            return {
                "c_kv": ("batch", "kv_seq", "kv_lora"),
                "k_rope": ("batch", "kv_seq", None),
                "index": (),
            }
        c = {
            "k": ("batch", "kv_seq", "kv_heads", "head_dim"),
            "v": ("batch", "kv_seq", "kv_heads", "head_dim"),
            "index": (),
        }
        S = (
            min(max_len, cfg.window)
            if (cfg.attention == "swa" and cfg.window)
            else max_len
        )
        if cfg.attention == "swa" and cfg.window and S == cfg.window:
            c["pos"] = ("batch", "kv_seq")  # ring-buffer slot positions
        return c

    def mamba_axes() -> dict:
        return {
            "h": ("batch", "inner", None),
            "conv": ("batch", None, "inner"),
        }

    out = []
    for spec in cfg.layout:
        tree = mamba_axes() if spec.mixer == "mamba" else attention_axes()
        out.append({k: ("layers",) + v for k, v in tree.items()})
    return out
