"""Durable provenance journal — crash-safe persistence for the three
forensic stories (paper §III.C / §III.L).

The paper's enterprise claim is "full tracing of provenance and forensic
reconstruction of transactional processes", but a registry that lives only
in process memory forgets everything on restart. This module is the fix: an
**append-only on-disk JSONL event log** that the :class:`ProvenanceRegistry`,
:class:`MemoCache`, and :class:`TransferLedger` write through. One typed
record per event:

  ========== ==========================================================
  kind       emitted by
  ========== ==========================================================
  meta       Journal itself (file header: workspace name, format version)
  task       ProvenanceRegistry.register_task   (design-map promises)
  edge       ProvenanceRegistry.add_design_edge (design-map topology)
  av         ProvenanceRegistry.register_av     (travel documents + lineage)
  visit      ProvenanceRegistry.log_visit       (checkpoint visitor logs)
  anomaly    ProvenanceRegistry.record_anomaly
  cache_hit  MemoCache.lookup                   (memo short-circuits)
  topology   PipelineManager                    (zone/tier/link-cost spec)
  ledger     TransferLedger                     (residency + byte charges)
  ========== ==========================================================

Every record carries a **monotonically increasing global sequence number**
(``seq``) — not a wall-clock float — so replays order events exactly as the
run emitted them, regardless of clock granularity. Writes are buffered and
fsync'd every ``flush_every_n`` records (the durability/throughput knob), so
the hot path stays cheap; ``close()``/``flush()`` force the tail out.

Crash safety is the append-only contract: a process killed mid-write leaves
at most one torn final line, which :func:`read_records` detects and drops.
:func:`replay_journal` then rebuilds a fresh registry (and, when a topology
record is present, a transfer ledger) from the intact prefix, so
``lineage()`` / ``visitor_log()`` / ``design_map()`` / ledger stats answer
identically to the pre-crash process. ``Workspace.from_journal(path)`` is
the user-facing rehydrator.
"""

from __future__ import annotations

import dataclasses
import json
import os
import threading
import time
from typing import Any, Iterable, Optional

FORMAT_VERSION = 1


class JournalCorruptError(ValueError):
    """A journal line *before* the final one failed to parse — the file was
    edited or damaged, not merely torn by a crash."""


class Journal:
    """Append-only JSONL event log with batched fsync.

    Thread-safe: producers (registry, cache, ledger — possibly on concurrent
    wave workers) serialize through one lock, which is also what makes the
    global ``seq`` a total order over events.
    """

    def __init__(
        self,
        path: str,
        flush_every_n: Optional[int] = None,
        workspace: str = "",
        segment: Optional[str] = None,
    ) -> None:
        self.path = str(path)
        # Non-None marks this file as a *segment* of a parent journal (one
        # per remote zone runner): its records carry seqs reserved from the
        # parent's global sequence space, and merge_segments later folds the
        # files back into one totally-ordered stream. The segment's own meta
        # header is bookkeeping, not history — merges drop it.
        self.segment = segment
        if flush_every_n is None:
            flush_every_n = int(os.environ.get("KOALJA_JOURNAL_FLUSH", "64"))
        self.flush_every_n = max(1, int(flush_every_n))
        self._lock = threading.Lock()
        self.records_written = 0
        self.flushes = 0
        self._pending = 0
        self.closed = False
        parent = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(parent, exist_ok=True)
        # Resume an existing journal after its last intact record: the seq
        # must stay monotonic across restarts for replays to stay ordered.
        self._next_seq = 0
        # Highest visitor-entry seq already on disk: a resuming registry
        # seeds its event counter past this, so entry seqs stay a total
        # order across restarts too (visits_of sorts by them).
        self.resumed_visit_seq = -1
        fresh = not os.path.exists(self.path) or os.path.getsize(self.path) == 0
        if not fresh:
            records, truncated = read_records(self.path)
            if records:
                self._next_seq = int(records[-1].get("seq", -1)) + 1
                self.resumed_visit_seq = max(
                    (
                        int(r["data"]["seq"])
                        for r in records
                        if r.get("kind") == "visit"
                        and isinstance(r.get("data"), dict)
                        and "seq" in r["data"]
                    ),
                    default=-1,
                )
            if truncated:
                # Drop the torn tail *before* reopening for append: 'a' mode
                # would glue the next record onto the partial line, losing it
                # (or corrupting every later record) on the next replay.
                self._truncate_to_intact_prefix()
        self._fh = open(self.path, "a", encoding="utf-8")
        if fresh:
            meta = {
                "workspace": workspace,
                "format": FORMAT_VERSION,
                "created_at": time.time(),
            }
            if segment is not None:
                meta["segment"] = segment
            self.append("meta", meta)

    def _truncate_to_intact_prefix(self) -> None:
        """Cut the file back to the end of its last whole, parseable line
        (callers have already established the damage is only a torn tail)."""
        with open(self.path, "rb") as fh:
            blob = fh.read()
        good = 0
        for line in blob.splitlines(keepends=True):
            if not line.endswith(b"\n"):
                break
            if line.strip():
                try:
                    json.loads(line)
                except json.JSONDecodeError:
                    break
            good += len(line)
        if good < len(blob):
            with open(self.path, "r+b") as fh:
                fh.truncate(good)

    # -- write path ---------------------------------------------------------
    def reserve(self, n: int) -> int:
        """Claim ``n`` consecutive sequence numbers without writing records;
        returns the first. A parent journal reserves a window per remote
        firing and ships it with the work order — the zone runner writes the
        records (with those seqs) into its own *segment* file, and the
        merge re-establishes the total order. Gaps from failed/retried
        remote work are harmless: replay orders by seq, it never requires
        density."""
        with self._lock:
            if self.closed:
                raise ValueError(f"journal {self.path} is closed")
            start = self._next_seq
            self._next_seq += max(0, int(n))
            return start

    def append(self, kind: str, data: dict, seq: Optional[int] = None) -> int:
        """Append one typed record; returns its global sequence number.

        ``seq`` overrides the auto-assigned number — segment journals write
        records under sequence numbers their parent reserved, so the merged
        stream stays a total order across processes."""
        with self._lock:
            if self.closed:
                raise ValueError(f"journal {self.path} is closed")
            if seq is None:
                seq = self._next_seq
                self._next_seq += 1
            else:
                self._next_seq = max(self._next_seq, seq + 1)
            line = json.dumps(
                {"seq": seq, "kind": kind, "data": data},
                default=repr,
                separators=(",", ":"),
            )
            self._fh.write(line + "\n")
            self.records_written += 1
            self._pending += 1
            if self._pending >= self.flush_every_n:
                self._flush_locked()
            return seq

    def _flush_locked(self) -> None:
        self._fh.flush()
        os.fsync(self._fh.fileno())
        self.flushes += 1
        self._pending = 0

    def flush(self) -> None:
        """Force buffered records to disk (flush + fsync)."""
        with self._lock:
            if not self.closed and self._pending:
                self._flush_locked()

    def close(self) -> None:
        with self._lock:
            if self.closed:
                return
            if self._pending:
                self._flush_locked()
            self._fh.close()
            self.closed = True

    def __del__(self) -> None:  # journals are per-workspace; don't leak fds
        try:
            self.close()
        except Exception:
            pass

    # -- introspection ------------------------------------------------------
    def stats(self) -> dict:
        with self._lock:
            if not self.closed:
                self._fh.flush()  # so bytes_on_disk reflects buffered writes
            return {
                "path": self.path,
                "records_written": self.records_written,
                "bytes_on_disk": (
                    os.path.getsize(self.path) if os.path.exists(self.path) else 0
                ),
                "flushes": self.flushes,
                "flush_every_n": self.flush_every_n,
                "next_seq": self._next_seq,
            }

    def __repr__(self) -> str:
        return (
            f"Journal({self.path!r}, records={self.records_written}, "
            f"flush_every_n={self.flush_every_n})"
        )


# ---------------------------------------------------------------------------
# read / replay
# ---------------------------------------------------------------------------


def read_records(path: str) -> tuple:
    """Parse a journal file, tolerating a torn final line.

    Returns ``(records, truncated)`` where ``truncated`` counts dropped
    trailing partial lines (0 or 1 — the most a crash mid-``write`` can
    leave). A malformed line *followed by intact ones* is real corruption
    and raises :class:`JournalCorruptError`.
    """
    records: list = []
    truncated = 0
    with open(path, "r", encoding="utf-8") as fh:
        lines = fh.read().split("\n")
    last = max(
        (i for i, line in enumerate(lines) if line.strip()), default=-1
    )
    for i, line in enumerate(lines):
        if not line.strip():
            continue
        try:
            records.append(json.loads(line))
        except json.JSONDecodeError:
            if i == last:
                truncated = 1  # torn tail from a crash mid-append
                break
            raise JournalCorruptError(
                f"{path}:{i + 1}: unparseable journal line before end of file"
            ) from None
    return records, truncated


@dataclasses.dataclass
class ReplayedJournal:
    """Result of :func:`replay_journal`: a fresh registry (and ledger, when
    the run had a topology) rebuilt from the intact journal prefix."""

    registry: Any
    ledger: Any = None
    topology: Any = None
    workspace: str = ""
    records: int = 0
    truncated: int = 0
    counts: dict = dataclasses.field(default_factory=dict)

    def __repr__(self) -> str:
        return (
            f"ReplayedJournal(workspace={self.workspace!r}, "
            f"records={self.records}, truncated={self.truncated}, "
            f"counts={self.counts})"
        )


def merge_segments(path: str, segment_paths: Iterable[str]) -> tuple:
    """Fold one or more runner *segment* files back into the main journal's
    record stream, ordered by the global ``seq`` protocol.

    Each zone runner wrote its records under sequence numbers the parent
    reserved from one shared counter, so sorting the union by ``seq``
    reconstructs the exact total order a single-process run would have
    journaled. Segment ``meta`` headers are per-file bookkeeping (their
    seq 0 would collide with the main header) and are dropped. A torn tail
    in any file — main or segment — is tolerated per-file, exactly like
    :func:`read_records` on a single journal.

    ``revoked`` records in the *main* journal void a seq window: a runner
    that died mid-flight may have appended records for a firing the parent
    then retried under fresh seqs, and replaying both copies would
    duplicate AVs. Segment records whose seq falls in a revoked window are
    dropped (the revocation marker itself carries no registry state).

    Returns ``(records, truncated)`` where ``truncated`` sums the dropped
    torn lines across all files.
    """
    records, truncated = read_records(path)
    revoked: set = set()
    for r in records:
        if r.get("kind") == "revoked":
            d = r.get("data") or {}
            start = int(d.get("start", 0))
            revoked.update(range(start, start + int(d.get("count", 0))))
    for seg in segment_paths:
        seg_records, seg_truncated = read_records(seg)
        truncated += seg_truncated
        records.extend(
            r
            for r in seg_records
            if r.get("kind") != "meta" and int(r.get("seq", -1)) not in revoked
        )
    records.sort(key=lambda r: int(r.get("seq", -1)))
    return records, truncated


def replay_segments(path: str, segment_paths: Iterable[str]) -> ReplayedJournal:
    """Rebuild provenance state from a main journal plus its runner
    segments: :func:`merge_segments` then the same record application as
    :func:`replay_journal`. The result's ``lineage`` / ``visits_of`` /
    ledger answers match the live multi-process registry — and the
    single-process oracle."""
    records, truncated = merge_segments(path, segment_paths)
    return _apply_records(records, truncated)


def replay_journal(path: str) -> ReplayedJournal:
    """Rebuild provenance state from a journal file.

    Replays every intact record, in sequence order, into a fresh
    :class:`~repro.core.provenance.ProvenanceRegistry` — and, if the run
    recorded a ``topology`` spec, into a fresh
    :class:`~repro.topology.TransferLedger` — so the three forensic stories
    and the transfer scorecard answer exactly as the writing process would
    have. The replayed objects carry **no** journal binding: rehydration
    never re-journals history.
    """
    records, truncated = read_records(path)
    return _apply_records(records, truncated)


def _apply_records(records: list, truncated: int) -> ReplayedJournal:
    from repro.core.provenance import ProvenanceRegistry

    registry = ProvenanceRegistry()
    ledger = topology = None
    workspace = ""
    counts: dict = {}
    for rec in records:
        kind = rec.get("kind")
        data = rec.get("data") or {}
        counts[kind] = counts.get(kind, 0) + 1
        if kind == "meta":
            workspace = data.get("workspace", workspace)
        elif kind == "task":
            registry.register_task(
                data["task"], data["inputs"], data["outputs"], data["version"]
            )
        elif kind == "edge":
            registry.add_design_edge(data["src"], data["relation"], data["dst"])
        elif kind == "av":
            registry.restore_av(data)
        elif kind == "visit":
            registry.restore_visit(data)
        elif kind == "anomaly":
            registry.restore_anomaly(data)
        elif kind == "topology":
            from repro.topology import Topology, TransferLedger

            new_topo = Topology.from_spec(data)
            if topology is None or new_topo.describe() != topology.describe():
                topology = new_topo
                ledger = TransferLedger(topology)
            # else: a resumed run re-announced the same spec — keep the
            # ledger charges accumulated from the pre-restart records
        elif kind == "ledger" and ledger is not None:
            if data.get("op") == "resident":
                ledger.register_resident(data["chash"], data["zone"])
            elif data.get("op") == "materialize":
                ledger.on_materialize(
                    data["chash"], int(data["nbytes"]), data["src"], data["dst"]
                )
        # cache_hit records are counted (counts) but carry no registry state:
        # the memo short-circuit already journaled its visitor-log entries.
    return ReplayedJournal(
        registry=registry,
        ledger=ledger,
        topology=topology,
        workspace=workspace,
        records=len(records),
        truncated=truncated,
        counts=counts,
    )
