"""Durable provenance journal — crash-safe persistence for the three
forensic stories (paper §III.C / §III.L), at production scale.

The paper's enterprise claim is "full tracing of provenance and forensic
reconstruction of transactional processes", but a registry that lives only
in process memory forgets everything on restart. This module is the fix: an
**append-only on-disk JSONL event log** that the :class:`ProvenanceRegistry`,
:class:`MemoCache`, and :class:`TransferLedger` write through. One typed
record per event:

  ========== ==========================================================
  kind       emitted by
  ========== ==========================================================
  meta       Journal itself (file header: workspace name, format version)
  task       ProvenanceRegistry.register_task   (design-map promises)
  edge       ProvenanceRegistry.add_design_edge (design-map topology)
  av         ProvenanceRegistry.register_av     (travel documents + lineage)
  visit      ProvenanceRegistry.log_visit       (checkpoint visitor logs)
  anomaly    ProvenanceRegistry.record_anomaly
  retired    ProvenanceRegistry.retire_avs      (forensic-horizon trims)
  cache_hit  MemoCache.lookup                   (memo short-circuits)
  memo       MemoCache.insert                   (memo table contents)
  topology   PipelineManager                    (zone/tier/link-cost spec)
  ledger     TransferLedger                     (residency + byte charges)
  scale      AdaptiveExecutor                   (pool-resize decisions)
  checkpoint Journal.compact                    (folded-history snapshot)
  ========== ==========================================================

Every record carries a **monotonically increasing global sequence number**
(``seq``) — not a wall-clock float — so replays order events exactly as the
run emitted them, regardless of clock granularity. Writes are buffered and
fsync'd every ``flush_every_n`` records (the durability/throughput knob), so
the hot path stays cheap; ``close()``/``flush()`` force the tail out.

Production scale is the **segment chain**. A long-running sensor pipeline
appending one JSONL forever pays O(lifetime) on every restart; instead the
journal *rotates*: when the live file crosses ``rotate_bytes`` /
``rotate_records`` (``KOALJA_JOURNAL_ROTATE`` bytes; default off) it is
renamed to a numbered segment ``<path>.000N`` and a fresh live file
continues the same global seq. :func:`Journal.compact` then folds the
rotated history — superseded ledger charges, re-announced topology specs,
overwritten memo entries, retired AVs and their stale visits — into one
``checkpoint`` snapshot record (``<path>.ckpt-<seq>``), written
new-file-then-``os.replace`` so a crash at any byte offset leaves a
replayable chain, and garbage-collects the folded segments. Replay cost
becomes *last checkpoint + tail* — proportional to live state, not history.

Crash safety is the append-only contract: a process killed mid-write leaves
at most one torn final line per file, which :func:`read_records` detects and
drops. :func:`replay_journal` then rebuilds a fresh registry (and, when a
topology record is present, a transfer ledger) from the intact prefix of
the whole chain, so ``lineage()`` / ``visitor_log()`` / ``design_map()`` /
ledger stats answer identically to the pre-crash process.
``Workspace.from_journal(path)`` is the user-facing rehydrator.
"""

from __future__ import annotations

import dataclasses
import json
import os
import re
import threading
import time
from typing import Any, Iterable, Optional

FORMAT_VERSION = 1

# rotated segments: <path>.0001, <path>.0002, ... (live tail is <path>)
_SEGMENT_RE = re.compile(r"\.(\d{4,})$")
# checkpoint snapshots: <path>.ckpt-<upto_seq>; *.tmp are in-flight writes
_CHECKPOINT_RE = re.compile(r"\.ckpt-(\d+)$")


class JournalCorruptError(ValueError):
    """A journal line *before* the final one failed to parse — the file was
    edited or damaged, not merely torn by a crash."""


# One shared encoder instance: json.dumps() with non-default arguments
# constructs a fresh JSONEncoder per call, which costs more than the
# encode itself for hot-path-sized records.
_ENCODER = json.JSONEncoder(separators=(",", ":"), default=repr).encode
# record kinds are literal identifiers (visit/av/ledger/...); anything that
# would need escaping inside the template's "kind" slot takes the slow path.
# Kinds seen to match are memoized — the engine uses fewer than a dozen.
_SAFE_KIND_RE = re.compile(r"^[A-Za-z0-9_.-]+$")
_SAFE_KINDS: set = set()


def encode_record(seq: int, kind: str, data: dict) -> str:
    """One journal line (no trailing newline), byte-identical to the seed-era
    ``json.dumps(..., default=repr, separators=(",", ":"))`` call. The
    wrapper object is assembled by template (int seq and identifier kinds
    never need escaping) so only ``data`` goes through the encoder — and
    through a shared instance, not a per-call ``json.dumps``. Record
    constructors already emit canonical key order (dataclass field order for
    visits/AVs, literal order everywhere else), so there is no per-record
    ``sort_keys`` re-sort on the hot path."""
    if type(seq) is int and (
        kind in _SAFE_KINDS or _SAFE_KIND_RE.match(kind)
    ):
        _SAFE_KINDS.add(kind)
        return '{"seq":%d,"kind":"%s","data":%s}' % (seq, kind, _ENCODER(data))
    return json.dumps(
        {"seq": seq, "kind": kind, "data": data},
        default=repr,
        separators=(",", ":"),
    )


class _StagingWindow:
    """Reentrant per-thread batching window for :meth:`Journal.staging`."""

    __slots__ = ("_journal", "_outermost")

    def __init__(self, journal: "Journal") -> None:
        self._journal = journal
        self._outermost = False

    def __enter__(self) -> "_StagingWindow":
        tl = self._journal._staging
        if getattr(tl, "buf", None) is None:
            tl.buf = []
            self._outermost = True
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if not self._outermost:
            return
        tl = self._journal._staging
        buf, tl.buf = tl.buf, None
        if buf:
            self._journal.append_batch(buf)


def _rotate_bytes_env() -> Optional[int]:
    """Parse ``KOALJA_JOURNAL_ROTATE`` (a byte threshold; off by default).
    Raises at construction on a non-integer value, naming the knob."""
    v = os.environ.get("KOALJA_JOURNAL_ROTATE", "").strip().lower()
    if v in ("", "0", "false", "no", "off"):
        return None
    try:
        n = int(v)
    except ValueError:
        raise ValueError(
            f"KOALJA_JOURNAL_ROTATE={v!r} is not a rotation threshold "
            "(expected a byte count integer, or 0/off to disable)"
        ) from None
    return n if n > 0 else None


def discover_chain(path: str) -> dict:
    """Enumerate the on-disk segment chain of a journal base path:
    rotated segments (ascending), checkpoint files (newest first), and
    whether the live tail exists. ``*.tmp`` checkpoint writes that a crash
    abandoned mid-compaction are ignored (they were never renamed into the
    chain)."""
    path = str(path)
    parent = os.path.dirname(os.path.abspath(path)) or "."
    base = os.path.basename(path)
    segments: list = []
    checkpoints: list = []
    if os.path.isdir(parent):
        for name in os.listdir(parent):
            if not name.startswith(base + "."):
                continue
            suffix = name[len(base):]
            m = _SEGMENT_RE.fullmatch(suffix)
            if m:
                segments.append((int(m.group(1)), os.path.join(parent, name)))
                continue
            m = _CHECKPOINT_RE.fullmatch(suffix)
            if m:
                checkpoints.append((int(m.group(1)), os.path.join(parent, name)))
    segments.sort()
    checkpoints.sort(reverse=True)
    return {
        "live": path if os.path.exists(path) else None,
        "segments": [p for _, p in segments],
        "segment_indices": [i for i, _ in segments],
        "checkpoints": [p for _, p in checkpoints],
    }


def _load_checkpoint(path: str) -> Optional[dict]:
    """Read one checkpoint file; returns its single ``checkpoint`` record or
    None if the file is unreadable/torn (the atomic-rename protocol never
    produces one, but discovery stays defensive)."""
    try:
        records, _ = read_records(path)
    except (OSError, JournalCorruptError):
        return None
    for r in records:
        if r.get("kind") == "checkpoint" and isinstance(r.get("data"), dict):
            return r
    return None


def read_chain(path: str) -> tuple:
    """Parse a journal's whole segment chain: best checkpoint (if any) +
    every record *after* it from rotated segments and the live tail, in seq
    order. A torn final line is tolerated per file — a crash can tear the
    tail of whichever file was being written, including a segment later
    stranded by a mid-compaction kill. Returns ``(records, truncated,
    info)`` where ``info`` describes the chain (files read, checkpoint
    used, fold boundary)."""
    chain = discover_chain(path)
    ck_rec = None
    ck_path = None
    for p in chain["checkpoints"]:
        ck_rec = _load_checkpoint(p)
        if ck_rec is not None:
            ck_path = p
            break
    upto = int(ck_rec["data"].get("upto_seq", -1)) if ck_rec else -1
    records: list = [ck_rec] if ck_rec else []
    truncated = 0
    files = [ck_path] if ck_path else []
    for f in chain["segments"] + ([chain["live"]] if chain["live"] else []):
        rs, tr = read_records(f)
        truncated += tr
        # a checkpoint covers everything at or below its fold boundary;
        # segments left behind by a crash between rename and GC replay as
        # harmless no-ops because every record they hold is filtered here
        records.extend(r for r in rs if int(r.get("seq", -1)) > upto)
        files.append(f)
    records.sort(key=lambda r: int(r.get("seq", -1)))
    info = {
        "files": files,
        "checkpoint": ck_path,
        "checkpoint_data": ck_rec["data"] if ck_rec else None,
        "upto_seq": upto,
        "segments": len(chain["segments"]) + (1 if chain["live"] else 0),
        "checkpoints": len(chain["checkpoints"]),
    }
    return records, truncated, info


class Journal:
    """Append-only JSONL event log with batched fsync, segment rotation,
    and checkpoint compaction.

    Thread-safe: producers (registry, cache, ledger — possibly on concurrent
    wave workers) serialize through one lock, which is also what makes the
    global ``seq`` a total order over events.
    """

    def __init__(
        self,
        path: str,
        flush_every_n: Optional[int] = None,
        workspace: str = "",
        segment: Optional[str] = None,
        rotate_bytes: Optional[int] = None,
        rotate_records: Optional[int] = None,
        seq_source: Optional["Journal"] = None,
    ) -> None:
        self.path = str(path)
        # Non-None marks this file as a *segment* of a parent journal (one
        # per remote zone runner): its records carry seqs reserved from the
        # parent's global sequence space, and merge_segments later folds the
        # files back into one totally-ordered stream. The segment's own meta
        # header is bookkeeping, not history — merges drop it.
        self.segment = segment
        # Non-None delegates sequence-number assignment to another journal
        # (multi-tenant hubs: every per-tenant journal draws seqs from the
        # hub journal's one counter via ``reserve``, so records across all
        # tenant files form a single total order while each tenant's file
        # stays strictly its own history). Lock order is always
        # tenant-journal -> source-journal; the source never calls back.
        self._seq_source = seq_source
        self._workspace = workspace
        if flush_every_n is None:
            flush_every_n = int(os.environ.get("KOALJA_JOURNAL_FLUSH", "64"))
        self.flush_every_n = max(1, int(flush_every_n))
        # Rotation thresholds: cross either and the live file is renamed to
        # <path>.000N, a fresh tail continuing the same seq space. Explicit
        # kwargs win; otherwise KOALJA_JOURNAL_ROTATE (bytes) decides.
        if rotate_bytes is None and rotate_records is None:
            rotate_bytes = _rotate_bytes_env()
        self.rotate_bytes = int(rotate_bytes) if rotate_bytes else None
        self.rotate_records = int(rotate_records) if rotate_records else None
        self._lock = threading.Lock()
        # Per-thread staging buffer (see staging()): while active, append()
        # enqueues instead of writing, and the context exit flushes the whole
        # firing through append_batch under ONE lock acquisition.
        self._staging = threading.local()
        self.records_written = 0
        self.flushes = 0
        self.encode_wall_s = 0.0  # cumulative record-encode time (stats())
        self.rotations = 0
        self.compactions = 0
        # cumulative across the journal's lifetime (reseeded from the
        # checkpoint on resume — the checkpoint carries the totals)
        self.records_compacted = 0
        self.bytes_reclaimed = 0
        self._pending = 0
        self.closed = False
        parent = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(parent, exist_ok=True)
        # Resume an existing journal after its last intact record — scanning
        # the FULL chain (checkpoint + rotated segments + live tail), not
        # just the newest file: the seq must stay monotonic across restarts
        # for replays to stay ordered, and the highest seq may live in a
        # rotated segment when the live tail is young.
        self._next_seq = 0
        # Highest visitor-entry seq already on disk (chain-wide): a resuming
        # registry seeds its event counter past this, so entry seqs stay a
        # total order across restarts too (visits_of sorts by them).
        self.resumed_visit_seq = -1
        self._live_records = 0
        self._live_start_seq = 0
        chain = discover_chain(self.path)
        self._rotation_index = (
            max(chain["segment_indices"]) + 1 if chain["segment_indices"] else 1
        )
        fresh = (
            (chain["live"] is None or os.path.getsize(self.path) == 0)
            and not chain["segments"]
            and not chain["checkpoints"]
        )
        if not fresh:
            records, _, info = read_chain(self.path)
            if records:
                self._next_seq = max(int(r.get("seq", -1)) for r in records) + 1
                self.resumed_visit_seq = max(
                    (
                        int(r["data"]["seq"])
                        for r in records
                        if r.get("kind") == "visit"
                        and isinstance(r.get("data"), dict)
                        and "seq" in r["data"]
                    ),
                    default=-1,
                )
            ck = info.get("checkpoint_data")
            if ck:
                # folded visitor entries don't appear as records anymore;
                # the checkpointed registry counter carries their high water
                reg_state = ck.get("registry") or {}
                self.resumed_visit_seq = max(
                    self.resumed_visit_seq, int(reg_state.get("next_seq", 0)) - 1
                )
                self.records_compacted = int(ck.get("records_compacted", 0))
                self.bytes_reclaimed = int(ck.get("bytes_reclaimed", 0))
                self.compactions = int(ck.get("compactions", 0))
            if chain["live"] is not None:
                live_records, live_truncated = read_records(self.path)
                self._live_records = len(live_records)
                self._live_start_seq = (
                    int(live_records[0].get("seq", 0))
                    if live_records
                    else self._next_seq
                )
                if live_truncated:
                    # Drop the torn tail *before* reopening for append: 'a'
                    # mode would glue the next record onto the partial line,
                    # losing it (or corrupting every later record) on the
                    # next replay.
                    self._truncate_to_intact_prefix()
            else:
                self._live_start_seq = self._next_seq
        self._fh = open(self.path, "a", encoding="utf-8")
        if fresh:
            meta = {
                "workspace": workspace,
                "format": FORMAT_VERSION,
                "created_at": time.time(),
            }
            if segment is not None:
                meta["segment"] = segment
            self.append("meta", meta)

    def _truncate_to_intact_prefix(self) -> None:
        """Cut the file back to the end of its last whole, parseable line
        (callers have already established the damage is only a torn tail)."""
        with open(self.path, "rb") as fh:
            blob = fh.read()
        good = 0
        for line in blob.splitlines(keepends=True):
            if not line.endswith(b"\n"):
                break
            if line.strip():
                try:
                    json.loads(line)
                except json.JSONDecodeError:
                    break
            good += len(line)
        if good < len(blob):
            with open(self.path, "r+b") as fh:
                fh.truncate(good)

    def _fsync_dir(self) -> None:
        """fsync the containing directory so renames (rotation, checkpoint
        publication) survive a power cut, not just process death."""
        try:
            fd = os.open(os.path.dirname(os.path.abspath(self.path)) or ".", os.O_RDONLY)
            try:
                os.fsync(fd)
            finally:
                os.close(fd)
        except OSError:  # pragma: no cover - platform-dependent
            pass

    # -- write path ---------------------------------------------------------
    def reserve(self, n: int) -> int:
        """Claim ``n`` consecutive sequence numbers without writing records;
        returns the first. A parent journal reserves a window per remote
        firing and ships it with the work order — the zone runner writes the
        records (with those seqs) into its own *segment* file, and the
        merge re-establishes the total order. Gaps from failed/retried
        remote work are harmless: replay orders by seq, it never requires
        density."""
        with self._lock:
            if self.closed:
                raise ValueError(f"journal {self.path} is closed")
            if self._seq_source is not None:
                start = self._seq_source.reserve(n)
                self._next_seq = max(self._next_seq, start + max(0, int(n)))
                return start
            start = self._next_seq
            self._next_seq += max(0, int(n))
            return start

    def append(self, kind: str, data: dict, seq: Optional[int] = None) -> int:
        """Append one typed record; returns its global sequence number.

        ``seq`` overrides the auto-assigned number — segment journals write
        records under sequence numbers their parent reserved, so the merged
        stream stays a total order across processes.

        Inside a :meth:`staging` window the record is enqueued on the
        calling thread's buffer instead (flushed as one batch at window
        exit) and ``-1`` is returned — every engine write-through ignores
        the return value."""
        buf = getattr(self._staging, "buf", None)
        if buf is not None:
            buf.append((kind, data, seq))
            return -1
        with self._lock:
            if self.closed:
                raise ValueError(f"journal {self.path} is closed")
            out = self._append_locked(kind, data, seq)
            self._maybe_rotate_locked()
            return out

    def append_batch(self, records: Iterable[tuple]) -> list:
        """Append many records under **one** lock acquisition: seqs are
        assigned monotonically in order, every line is encoded into one
        ``"\\n".join``-ed buffer, the file sees one ``write``, and the
        flush/fsync and rotation thresholds are consulted once per batch
        instead of once per record. Each item is ``(kind, data)`` or
        ``(kind, data, seq)``; returns the assigned seqs."""
        records = list(records)
        if not records:
            return []
        with self._lock:
            if self.closed:
                raise ValueError(f"journal {self.path} is closed")
            t0 = time.perf_counter()
            seqs: list = []
            lines: list = []
            # Delegated seq space: claim the whole batch's numbers from the
            # source in ONE reserve call, so a firing's records stay
            # contiguous in the hub's total order and the source lock is
            # taken once per batch, not once per record.
            delegated = iter(())
            if self._seq_source is not None:
                need = sum(
                    1 for rec in records if len(rec) == 2 or rec[2] is None
                )
                if need:
                    base = self._seq_source.reserve(need)
                    delegated = iter(range(base, base + need))
            for rec in records:
                if len(rec) == 3:
                    kind, data, seq = rec
                else:
                    kind, data = rec
                    seq = None
                if seq is None:
                    seq = next(delegated, None)
                    if seq is None:
                        seq = self._next_seq
                        self._next_seq += 1
                    else:
                        self._next_seq = max(self._next_seq, seq + 1)
                else:
                    self._next_seq = max(self._next_seq, seq + 1)
                lines.append(encode_record(seq, kind, data))
                seqs.append(seq)
            self.encode_wall_s += time.perf_counter() - t0
            self._fh.write("\n".join(lines) + "\n")
            n = len(lines)
            self.records_written += n
            self._live_records += n
            self._pending += n
            if self._pending >= self.flush_every_n:
                self._flush_locked()
            self._maybe_rotate_locked()
            return seqs

    def staging(self):
        """Context manager that batches this thread's appends: while active,
        :meth:`append` enqueues onto a thread-local buffer, and exit flushes
        the buffer through :meth:`append_batch` (one lock, one encode buffer,
        one write/fsync decision). The engine wraps each task firing in a
        staging window so a firing's records — visits, AVs, ledger charges,
        memo inserts — land as one fused batch. Nested windows join the
        outermost one; flush happens even if the body raises, so anomaly
        records from a failing firing still reach disk."""
        return _StagingWindow(self)

    def _append_locked(self, kind: str, data: dict, seq: Optional[int] = None) -> int:
        if seq is None:
            if self._seq_source is not None:
                seq = self._seq_source.reserve(1)
                self._next_seq = max(self._next_seq, seq + 1)
            else:
                seq = self._next_seq
                self._next_seq += 1
        else:
            self._next_seq = max(self._next_seq, seq + 1)
        t0 = time.perf_counter()
        line = encode_record(seq, kind, data)
        self.encode_wall_s += time.perf_counter() - t0
        self._fh.write(line + "\n")
        self.records_written += 1
        self._live_records += 1
        self._pending += 1
        if self._pending >= self.flush_every_n:
            self._flush_locked()
        return seq

    def _maybe_rotate_locked(self) -> None:
        if self.rotate_bytes is None and self.rotate_records is None:
            return
        # never rotate a file down to just-a-header: a pathological
        # threshold must not spin out empty segments
        if self._live_records < 2:
            return
        over = (
            self.rotate_records is not None
            and self._live_records >= self.rotate_records
        )
        if not over and self.rotate_bytes is not None:
            over = self._fh.tell() >= self.rotate_bytes
        if over:
            self._rotate_locked()

    def _rotate_locked(self) -> Optional[str]:
        """Seal the live file as the next numbered segment and start a fresh
        tail (with a continuation header) under the same seq space. Returns
        the sealed segment's path, or None if the live file had no records."""
        if self._live_records == 0:
            return None
        self._flush_locked()
        self._fh.close()
        idx = self._rotation_index
        self._rotation_index += 1
        target = f"{self.path}.{idx:04d}"
        os.replace(self.path, target)
        self._fsync_dir()
        self._fh = open(self.path, "a", encoding="utf-8")
        self.rotations += 1
        self._live_records = 0
        self._pending = 0
        self._live_start_seq = self._next_seq
        header = {
            "workspace": self._workspace,
            "format": FORMAT_VERSION,
            "rotated_from": idx,
        }
        if self.segment is not None:
            header["segment"] = self.segment
        self._append_locked("meta", header)
        return target

    def rotate(self) -> Optional[str]:
        """Force a rotation now (used by compaction to make the fold
        boundary 'everything so far'); no-op on an empty live file."""
        with self._lock:
            if self.closed:
                raise ValueError(f"journal {self.path} is closed")
            return self._rotate_locked()

    # -- compaction ---------------------------------------------------------
    def compact(
        self,
        segment_paths: Iterable[str] = (),
        archive_dir: Optional[str] = None,
        fault: Optional[Any] = None,
    ) -> dict:
        """Fold all rotated history into one checkpoint snapshot record, so
        replay = last checkpoint + live tail.

        Superseded records collapse into state: thousands of ``ledger``
        charges become per-pair byte totals, re-announced ``topology`` specs
        and resumed ``task``/``edge`` registrations dedup, overwritten
        ``memo`` entries keep only the last record (expired ones are purged),
        and AVs retired by :meth:`ProvenanceRegistry.retire_avs` — dropped
        travellers, store-evicted payloads, aged-out ``[N/k]`` window
        members — vanish along with their stale visits and the ``retired``
        markers themselves.

        ``segment_paths`` are per-zone runner segment files (multi-process
        runs): their records at or below the fold boundary are folded into
        the checkpoint too (minus revoked windows), after which
        :func:`merge_segments` drops them as already-covered. Call at
        quiescence — between drains — so no reserved seq window is still in
        flight below the boundary.

        Atomicity: the checkpoint is written to a ``.tmp`` file, fsync'd,
        then published with one ``os.replace``; folded segments and older
        checkpoints are garbage-collected only after the rename (or moved
        into ``archive_dir`` when given — the cold-tier/oracle hook). A
        crash at any byte offset leaves a replayable chain: before the
        rename the old chain is intact (the ``.tmp`` is ignored), after it
        the leftover segments replay as no-ops below the boundary.

        ``fault`` is a test hook: called with a stage name at each crash
        window (``fold``, ``pre-rename``, ``post-rename``, ``mid-gc``,
        ``post-gc``); raising from it simulates dying there.
        """
        fault = fault or (lambda stage: None)
        with self._lock:
            if self.closed:
                raise ValueError(f"journal {self.path} is closed")
            if self.segment is not None:
                raise ValueError(
                    f"journal {self.path} is a zone segment — segments are "
                    "merged by the parent, never compacted in place"
                )
            self._rotate_locked()  # fold boundary = everything before the tail
            boundary = self._live_start_seq
            chain = discover_chain(self.path)
            if not chain["segments"] and not chain["checkpoints"]:
                return {"checkpoint": None, "noop": True}
            ck_rec = None
            for p in chain["checkpoints"]:
                ck_rec = _load_checkpoint(p)
                if ck_rec is not None:
                    break
            prev = ck_rec["data"] if ck_rec else {}
            prev_upto = int(prev.get("upto_seq", -1))
            records: list = [ck_rec] if ck_rec else []
            folded_raw = 0
            for f in chain["segments"]:
                rs, _ = read_records(f)
                kept = [r for r in rs if int(r.get("seq", -1)) > prev_upto]
                records.extend(kept)
                folded_raw += len(kept)
            # revoked windows void zone-segment records a dead runner left
            # behind; the set rides the checkpoint so later merges can still
            # drop orphans below the boundary
            revoked = {int(s) for s in prev.get("revoked", [])}
            for r in records:
                if r.get("kind") == "revoked":
                    d = r.get("data") or {}
                    start = int(d.get("start", 0))
                    revoked.update(range(start, start + int(d.get("count", 0))))
            for seg in segment_paths:
                seg_chain = discover_chain(seg)
                for f in seg_chain["segments"] + (
                    [seg_chain["live"]] if seg_chain["live"] else []
                ):
                    rs, _ = read_records(f)
                    kept = [
                        r
                        for r in rs
                        if r.get("kind") not in ("meta", "checkpoint")
                        and prev_upto < int(r.get("seq", -1)) < boundary
                        and int(r.get("seq", -1)) not in revoked
                    ]
                    records.extend(kept)
                    folded_raw += len(kept)
            records.sort(key=lambda r: int(r.get("seq", -1)))
            fault("fold")
            rep = _apply_records(records, 0)
            counts = dict(rep.counts)
            counts.pop("checkpoint", None)
            doomed = list(chain["checkpoints"]) + list(chain["segments"])
            reclaim = sum(
                os.path.getsize(f) for f in doomed if os.path.exists(f)
            )
            upto = boundary - 1
            data = {
                "upto_seq": upto,
                "workspace": rep.workspace or self._workspace,
                "registry": rep.registry.snapshot_state(),
                "topology": rep.topology.describe() if rep.topology else None,
                "ledger": rep.ledger.snapshot_state() if rep.ledger else None,
                "cache": rep.cache.snapshot_state() if rep.cache else None,
                "counts": counts,
                "revoked": sorted(s for s in revoked if s <= upto),
                "records_compacted": self.records_compacted + folded_raw,
                "bytes_reclaimed": self.bytes_reclaimed + reclaim,
                "compactions": self.compactions + 1,
                "compacted_at": time.time(),
            }
            final = f"{self.path}.ckpt-{upto}"
            tmp = final + ".tmp"
            with open(tmp, "w", encoding="utf-8") as fh:
                fh.write(
                    json.dumps(
                        {"seq": upto, "kind": "checkpoint", "data": data},
                        default=repr,
                        separators=(",", ":"),
                    )
                    + "\n"
                )
                fh.flush()
                os.fsync(fh.fileno())
            fault("pre-rename")
            os.replace(tmp, final)
            self._fsync_dir()
            fault("post-rename")
            removed = 0
            for f in doomed:
                try:
                    if archive_dir is not None and _SEGMENT_RE.search(f):
                        os.makedirs(archive_dir, exist_ok=True)
                        os.replace(
                            f, os.path.join(archive_dir, os.path.basename(f))
                        )
                    else:
                        os.unlink(f)
                    removed += 1
                except OSError:  # pragma: no cover - GC is best-effort
                    pass
                fault("mid-gc")
            self._fsync_dir()
            fault("post-gc")
            self.compactions = data["compactions"]
            self.records_compacted = data["records_compacted"]
            self.bytes_reclaimed = data["bytes_reclaimed"]
            return {
                "checkpoint": final,
                "upto_seq": upto,
                "records_folded": folded_raw,
                "segments_removed": removed,
                "bytes_reclaimed": reclaim,
                "avs_live": len(data["registry"].get("avs", [])),
            }

    def _flush_locked(self) -> None:
        self._fh.flush()
        os.fsync(self._fh.fileno())
        self.flushes += 1
        self._pending = 0

    def flush(self) -> None:
        """Force buffered records to disk (flush + fsync)."""
        with self._lock:
            if not self.closed and self._pending:
                self._flush_locked()

    def close(self) -> None:
        with self._lock:
            if self.closed:
                return
            if self._pending:
                self._flush_locked()
            self._fh.close()
            self.closed = True

    def __del__(self) -> None:  # journals are per-workspace; don't leak fds
        try:
            self.close()
        except Exception:
            pass

    # -- introspection ------------------------------------------------------
    def chain_files(self) -> list:
        """Every live file of the on-disk chain: best-first checkpoints,
        rotated segments, and the live tail."""
        chain = discover_chain(self.path)
        return (
            list(chain["checkpoints"])
            + list(chain["segments"])
            + ([chain["live"]] if chain["live"] else [])
        )

    def stats(self) -> dict:
        with self._lock:
            if not self.closed:
                self._fh.flush()  # so bytes_on_disk reflects buffered writes
            chain = discover_chain(self.path)
            files = (
                list(chain["checkpoints"])
                + list(chain["segments"])
                + ([chain["live"]] if chain["live"] else [])
            )
            return {
                "path": self.path,
                "records_written": self.records_written,
                # the whole chain, not just the live tail: rotated segments
                # and checkpoints are as much "the journal" as the tail is
                "bytes_on_disk": sum(
                    os.path.getsize(f) for f in files if os.path.exists(f)
                ),
                "flushes": self.flushes,
                "flush_every_n": self.flush_every_n,
                "encode_wall_s": self.encode_wall_s,
                "next_seq": self._next_seq,
                "segments": len(chain["segments"])
                + (1 if chain["live"] else 0),
                "checkpoints": len(chain["checkpoints"]),
                "rotations": self.rotations,
                "compactions": self.compactions,
                "records_compacted": self.records_compacted,
                "bytes_reclaimed": self.bytes_reclaimed,
            }

    def __repr__(self) -> str:
        return (
            f"Journal({self.path!r}, records={self.records_written}, "
            f"flush_every_n={self.flush_every_n})"
        )


# ---------------------------------------------------------------------------
# read / replay
# ---------------------------------------------------------------------------


def read_records(path: str) -> tuple:
    """Parse a journal file, tolerating a torn final line.

    Returns ``(records, truncated)`` where ``truncated`` counts dropped
    trailing partial lines (0 or 1 — the most a crash mid-``write`` can
    leave). A malformed line *followed by intact ones* is real corruption
    and raises :class:`JournalCorruptError`.
    """
    records: list = []
    truncated = 0
    with open(path, "r", encoding="utf-8") as fh:
        lines = fh.read().split("\n")
    last = max(
        (i for i, line in enumerate(lines) if line.strip()), default=-1
    )
    for i, line in enumerate(lines):
        if not line.strip():
            continue
        try:
            records.append(json.loads(line))
        except json.JSONDecodeError:
            if i == last:
                truncated = 1  # torn tail from a crash mid-append
                break
            raise JournalCorruptError(
                f"{path}:{i + 1}: unparseable journal line before end of file"
            ) from None
    return records, truncated


@dataclasses.dataclass
class ReplayedJournal:
    """Result of :func:`replay_journal`: a fresh registry (and ledger, when
    the run had a topology) rebuilt from the intact journal prefix."""

    registry: Any
    ledger: Any = None
    topology: Any = None
    cache: Any = None
    workspace: str = ""
    records: int = 0
    truncated: int = 0
    counts: dict = dataclasses.field(default_factory=dict)
    # AdaptiveExecutor pool-resize decisions, in journal order — the
    # autoscaling story replays alongside the provenance it never affects
    scales: list = dataclasses.field(default_factory=list)
    # segment-chain provenance of the replay itself
    segments: int = 1
    checkpoints: int = 0
    records_compacted: int = 0

    def __repr__(self) -> str:
        return (
            f"ReplayedJournal(workspace={self.workspace!r}, "
            f"records={self.records}, truncated={self.truncated}, "
            f"counts={self.counts})"
        )


def _segment_files(seg: str) -> list:
    """A zone segment plus its own rotated parts (segments rotate under the
    same env knob as the main journal)."""
    chain = discover_chain(seg)
    return chain["segments"] + ([chain["live"]] if chain["live"] else [])


def _merged(path: str, segment_paths: Iterable[str]) -> tuple:
    records, truncated, info = read_chain(path)
    upto = int(info.get("upto_seq", -1))
    ck = info.get("checkpoint_data") or {}
    revoked: set = {int(s) for s in ck.get("revoked", [])}
    seg_batches = []
    for seg in segment_paths:
        for f in _segment_files(seg):
            seg_records, seg_truncated = read_records(f)
            truncated += seg_truncated
            seg_batches.append(seg_records)
    # Sweep revocation markers from *every* file before filtering any:
    # in a multi-tenant hub merge the segments are themselves per-tenant
    # journals, and it is the tenant (not the hub) that revoked its dead
    # runners' windows.
    for batch in [records] + seg_batches:
        for r in batch:
            if r.get("kind") == "revoked":
                d = r.get("data") or {}
                start = int(d.get("start", 0))
                revoked.update(range(start, start + int(d.get("count", 0))))
    for seg_records in seg_batches:
        records.extend(
            r
            for r in seg_records
            if r.get("kind") not in ("meta", "checkpoint")
            and int(r.get("seq", -1)) not in revoked
            and int(r.get("seq", -1)) > upto
        )
    records.sort(key=lambda r: int(r.get("seq", -1)))
    return records, truncated, info


def merge_segments(path: str, segment_paths: Iterable[str]) -> tuple:
    """Fold one or more runner *segment* files back into the main journal's
    record stream, ordered by the global ``seq`` protocol.

    Each zone runner wrote its records under sequence numbers the parent
    reserved from one shared counter, so sorting the union by ``seq``
    reconstructs the exact total order a single-process run would have
    journaled. Segment ``meta`` headers are per-file bookkeeping (their
    seq 0 would collide with the main header) and are dropped. A torn tail
    in any file — main or segment — is tolerated per-file, exactly like
    :func:`read_records` on a single journal.

    The *main* side is read as a full chain: rotated segments, live tail,
    and — when the main journal has been compacted — its best checkpoint.
    Zone-segment records at or below the checkpoint's fold boundary were
    folded into the checkpoint by :meth:`Journal.compact` and are dropped
    here as already-covered.

    ``revoked`` records in the main journal (or the revoked set a
    checkpoint carries forward) void a seq window: a runner that died
    mid-flight may have appended records for a firing the parent then
    retried under fresh seqs, and replaying both copies would duplicate
    AVs. Segment records whose seq falls in a revoked window are dropped
    (the revocation marker itself carries no registry state).

    Returns ``(records, truncated)`` where ``truncated`` sums the dropped
    torn lines across all files.
    """
    records, truncated, _ = _merged(path, segment_paths)
    return records, truncated


def replay_segments(path: str, segment_paths: Iterable[str]) -> ReplayedJournal:
    """Rebuild provenance state from a main journal plus its runner
    segments: :func:`merge_segments` then the same record application as
    :func:`replay_journal`. The result's ``lineage`` / ``visits_of`` /
    ledger answers match the live multi-process registry — and the
    single-process oracle."""
    records, truncated, info = _merged(path, segment_paths)
    return _apply_records(records, truncated, chain=info)


def replay_journal(path: str) -> ReplayedJournal:
    """Rebuild provenance state from a journal's segment chain.

    Replays the best checkpoint (if the journal has been compacted) and
    every intact record after it, in sequence order, into a fresh
    :class:`~repro.core.provenance.ProvenanceRegistry` — and, if the run
    recorded a ``topology`` spec, into a fresh
    :class:`~repro.topology.TransferLedger` — so the three forensic stories
    and the transfer scorecard answer exactly as the writing process would
    have. The replayed objects carry **no** journal binding: rehydration
    never re-journals history.
    """
    records, truncated, info = read_chain(path)
    return _apply_records(records, truncated, chain=info)


def replay_files(paths: Iterable[str]) -> ReplayedJournal:
    """Replay an explicit list of journal files — no chain discovery, no
    checkpoint required: read each (torn tails tolerated), union, order by
    seq, apply. This is the *uncompacted oracle* primitive: replaying every
    archived segment (``compact(archive_dir=...)``) plus the live tail
    reconstructs full history for byte-identical comparison against a
    checkpointed replay. Files must share one seq space (one journal's
    chain) — zone segment files belong in :func:`replay_segments` instead."""
    records: list = []
    truncated = 0
    for p in paths:
        rs, tr = read_records(p)
        records.extend(rs)
        truncated += tr
    records.sort(key=lambda r: int(r.get("seq", -1)))
    return _apply_records(records, truncated)


def _apply_records(records: list, truncated: int, chain: Optional[dict] = None) -> ReplayedJournal:
    from repro.core.provenance import ProvenanceRegistry

    registry = ProvenanceRegistry()
    ledger = topology = cache = None
    workspace = ""
    counts: dict = {}
    scales: list = []
    records_compacted = 0
    for rec in records:
        kind = rec.get("kind")
        data = rec.get("data") or {}
        counts[kind] = counts.get(kind, 0) + 1
        if kind == "meta":
            workspace = data.get("workspace") or workspace
        elif kind == "checkpoint":
            # folded history: restore state wholesale instead of replaying
            # the records the fold superseded
            workspace = data.get("workspace") or workspace
            registry.restore_state(data.get("registry") or {})
            if data.get("topology"):
                from repro.topology import Topology, TransferLedger

                topology = Topology.from_spec(data["topology"])
                ledger = TransferLedger(topology)
                if data.get("ledger"):
                    ledger.restore_state(data["ledger"])
            if data.get("cache"):
                from repro.cache import MemoCache

                cache = MemoCache()
                cache.restore_state(data["cache"])
            for k, v in (data.get("counts") or {}).items():
                counts[k] = counts.get(k, 0) + int(v)
            records_compacted = int(data.get("records_compacted", 0))
        elif kind == "task":
            registry.register_task(
                data["task"], data["inputs"], data["outputs"], data["version"]
            )
        elif kind == "edge":
            registry.add_design_edge(data["src"], data["relation"], data["dst"])
        elif kind == "av":
            registry.restore_av(data)
        elif kind == "visit":
            registry.restore_visit(data)
        elif kind == "anomaly":
            registry.restore_anomaly(data)
        elif kind == "retired":
            registry.restore_retired(data)
        elif kind == "memo":
            if cache is None:
                from repro.cache import MemoCache

                cache = MemoCache()
            cache.restore_entry(
                data["key"], data.get("record"), data.get("expires_at")
            )
        elif kind == "topology":
            from repro.topology import Topology, TransferLedger

            new_topo = Topology.from_spec(data)
            if topology is None or new_topo.describe() != topology.describe():
                topology = new_topo
                ledger = TransferLedger(topology)
            # else: a resumed run re-announced the same spec — keep the
            # ledger charges accumulated from the pre-restart records
        elif kind == "ledger" and ledger is not None:
            if data.get("op") == "resident":
                ledger.register_resident(data["chash"], data["zone"])
            elif data.get("op") == "materialize":
                ledger.on_materialize(
                    data["chash"], int(data["nbytes"]), data["src"], data["dst"]
                )
            elif data.get("op") == "execute":
                ledger.on_execute(data["zone"], int(data["nbytes"]))
            elif data.get("op") == "zone_local":
                ledger.credit_zone_local(
                    data["chash"], int(data["nbytes"]), data["zone"]
                )
        elif kind == "scale":
            scales.append(dict(data))
        # cache_hit records are counted (counts) but carry no registry state:
        # the memo short-circuit already journaled its visitor-log entries.
    return ReplayedJournal(
        registry=registry,
        ledger=ledger,
        topology=topology,
        cache=cache,
        workspace=workspace,
        records=len(records),
        truncated=truncated,
        counts=counts,
        scales=scales,
        segments=(chain or {}).get("segments", 1),
        checkpoints=(chain or {}).get("checkpoints", 0),
        records_compacted=records_compacted,
    )
