"""repro.provenance — durable persistence for the forensic stories.

The in-memory registry lives in :mod:`repro.core.provenance` (it is part of
the engine); this package holds what makes it *survive the process*: the
append-only :class:`Journal` (with segment rotation and checkpoint
compaction, so replay cost tracks live state rather than history), the
crash-tolerant readers, and the :func:`replay_journal` rehydrator behind
``Workspace.from_journal``.
"""

from .journal import (
    FORMAT_VERSION,
    Journal,
    JournalCorruptError,
    ReplayedJournal,
    discover_chain,
    merge_segments,
    read_chain,
    read_records,
    replay_files,
    replay_journal,
    replay_segments,
)

__all__ = [
    "FORMAT_VERSION",
    "Journal",
    "JournalCorruptError",
    "ReplayedJournal",
    "discover_chain",
    "merge_segments",
    "read_chain",
    "read_records",
    "replay_files",
    "replay_journal",
    "replay_segments",
]
