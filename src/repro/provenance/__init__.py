"""repro.provenance — durable persistence for the forensic stories.

The in-memory registry lives in :mod:`repro.core.provenance` (it is part of
the engine); this package holds what makes it *survive the process*: the
append-only :class:`Journal`, the crash-tolerant reader, and the
:func:`replay_journal` rehydrator behind ``Workspace.from_journal``.
"""

from .journal import (
    FORMAT_VERSION,
    Journal,
    JournalCorruptError,
    ReplayedJournal,
    merge_segments,
    read_records,
    replay_journal,
    replay_segments,
)

__all__ = [
    "FORMAT_VERSION",
    "Journal",
    "JournalCorruptError",
    "ReplayedJournal",
    "merge_segments",
    "read_records",
    "replay_journal",
    "replay_segments",
]
