"""repro.runtime — multi-process worker pools and remote zone runners.

Everything below the :class:`~repro.workspace.executors.Executor` seam so far
ran in one OS process; this package breaks that boundary while keeping the
engine's determinism contract intact:

  - :class:`ProcessExecutor` drives each multi-task wave on a persistent pool
    of **forked worker processes**. Workers inherit the task registry and a
    handle to the shared object-store tier at fork time; after that, only
    ``(uri, chash)`` references plus AV metadata ever cross the pipe —
    payload bytes move exclusively through the store's object directory
    (``publish`` on the parent side, ``export``/``adopt`` on the way back).
    All provenance side effects (AV minting, visitor logs, ledger charges,
    memo inserts) stay in the parent via ``SmartTask.finish_remote``, so a
    worker that dies mid-task leaves no state to undo and the wave retries
    on a fresh worker (``worker_died`` anomaly, bounded budget, inline
    fallback when the budget is spent).

  - :class:`ZonedProcessExecutor` promotes each extended-cloud
    :class:`~repro.topology.Topology` zone to its own :class:`ZoneRunner`
    process: the zone's partition (tasks, pins, internal/boundary links —
    :func:`~repro.topology.extract_partitions`) is journaled as a
    ``partition`` record, and every remote firing carries a **reserved
    window** of global journal seqs, visitor-log seqs, and AV uid numbers.
    The runner mints its zone's AVs and visit entries inside that window,
    appends them to its own journal *segment* file, and streams the typed
    records back; the parent restores them verbatim. A deterministic merge
    (:func:`repro.provenance.replay_segments`, ordered by the global seq
    protocol) rebuilds a single registry identical to the in-process run.

Fork is the required start method (task functions are arbitrary closures —
not picklable); on platforms without it both executors degrade to inline
execution. Determinism fingerprints — merge-FCFS arrival order, lineage,
visitor logs, transfer-ledger byte/energy totals — are bit-identical across
Inline, Concurrent, Zoned, Process, and ZonedProcess backends; see
docs/runtime.md for the runnable walkthrough.
"""

from .process import ProcessExecutor
from .worker import WorkerProcess, fork_context
from .zoned import ZonedProcessExecutor, ZoneRunner

__all__ = [
    "ProcessExecutor",
    "ZonedProcessExecutor",
    "ZoneRunner",
    "WorkerProcess",
    "fork_context",
]
