"""Worker-process side of the multi-process runtime.

One forked child per :class:`WorkerProcess` handle. The protocol over the
pipe is deliberately narrow — pickled dicts via ``send_bytes``/``recv_bytes``
(framed, so control-plane bytes are exactly countable) — and **never carries
payloads**: requests ship the plan's reference view
(:meth:`~repro.core.task.ExecutionPlan.snapshot_refs`), replies ship
per-output ``(uri, chash, nbytes, existed)`` specs. The payload channel is
the store's shared object directory.

Request kinds:

  ==========  ===========================================================
  op          semantics
  ==========  ===========================================================
  ping        liveness probe; replies with the worker pid
  exec        run one task's user fn; export outputs; reply specs only
              (flat pool — the parent mints all provenance afterwards)
  exec_zoned  ``exec`` plus zone-runner provenance: mint output AVs and
              visitor entries inside the parent-reserved uid/seq window,
              append them to this runner's journal *segment*, stream the
              typed records back for the parent to restore verbatim
  stop        acknowledge and exit cleanly
  ==========  ===========================================================

Fork discipline: the parent flushes its journal before every spawn (a
buffered line must not be double-written by two processes), and the child's
first act is to *neutralize* every inherited journal binding — close the fd,
mark the journal closed, unhook registry/cache/ledger write-through — so the
only file a child ever appends to is its own segment.
"""

from __future__ import annotations

import os
import pickle
import time
import traceback
from typing import Optional

from repro.core.av import AnnotatedValue, is_ghost
from repro.core.hashing import content_hash_batch
from repro.core.provenance import VisitorEntry

try:
    from multiprocessing import get_context

    _CTX = get_context("fork")
except (ImportError, ValueError):  # pragma: no cover - non-POSIX platforms
    _CTX = None


def fork_context():
    """The ``fork`` multiprocessing context, or ``None`` where the platform
    has no fork. Fork is required (not preferred): task functions are
    arbitrary closures — lambdas, locally-defined fns — which ``spawn``
    could never pickle. Callers degrade to inline execution on ``None``."""
    return _CTX


# Parent-side pipe ends currently open, module-global so a newly forked
# child can close the copies it inherited: a sibling worker holding the
# write end of another worker's pipe would keep that pipe from EOF-ing
# when its owner dies, breaking crash detection.
_OPEN_PARENT_CONNS: list = []


def _send(conn, obj) -> int:
    blob = pickle.dumps(obj, protocol=4)
    conn.send_bytes(blob)
    return len(blob)


def _recv(conn) -> tuple:
    blob = conn.recv_bytes()
    return pickle.loads(blob), len(blob)


class WorkerProcess:
    """Parent-side handle on one forked worker: the pipe, the process, and
    the control-plane byte counters (which is all that ever crosses)."""

    def __init__(
        self,
        manager,
        worker_id,
        segment_path: Optional[str] = None,
        segment_zone: Optional[str] = None,
    ) -> None:
        ctx = fork_context()
        if ctx is None:
            raise RuntimeError(
                "repro.runtime requires the 'fork' start method (POSIX only)"
            )
        if manager.journal is not None:
            # buffered journal lines must reach disk before the fork — the
            # child closes its inherited fd without flushing, and a line
            # held in both copies of the buffer would otherwise double-write
            manager.journal.flush()
        parent_conn, child_conn = ctx.Pipe()
        self.conn = parent_conn
        _OPEN_PARENT_CONNS.append(parent_conn)
        self.proc = ctx.Process(
            target=_child_main,
            args=(child_conn, manager, segment_path, segment_zone),
            daemon=True,
            name=f"koalja-worker-{worker_id}",
        )
        self.proc.start()
        child_conn.close()
        self.worker_id = worker_id
        self.pid = self.proc.pid
        self.segment_path = segment_path
        self.bytes_sent = 0
        self.bytes_received = 0
        self.requests = 0

    # -- control plane -------------------------------------------------------
    def send(self, msg: dict) -> None:
        self.requests += 1
        self.bytes_sent += _send(self.conn, msg)

    def recv(self) -> dict:
        msg, n = _recv(self.conn)
        self.bytes_received += n
        return msg

    def call(self, msg: dict) -> dict:
        self.send(msg)
        return self.recv()

    # -- lifecycle -----------------------------------------------------------
    def alive(self) -> bool:
        return self.proc.is_alive()

    def kill(self) -> None:
        """SIGKILL the worker (crash cleanup and chaos testing)."""
        try:
            self.proc.kill()
        except Exception:
            pass
        self.proc.join(timeout=5)
        self._close()

    def stop(self) -> None:
        """Graceful shutdown: stop request, short grace, then terminate."""
        try:
            self.send({"op": "stop"})
            self.recv()
        except Exception:
            pass
        self.proc.join(timeout=2)
        if self.proc.is_alive():
            self.proc.terminate()
            self.proc.join(timeout=2)
        self._close()

    def _close(self) -> None:
        try:
            self.conn.close()
        except Exception:
            pass
        try:
            _OPEN_PARENT_CONNS.remove(self.conn)
        except ValueError:
            pass

    def __repr__(self) -> str:
        return (
            f"WorkerProcess({self.worker_id!r}, pid={self.pid}, "
            f"alive={self.alive()})"
        )


# ---------------------------------------------------------------------------
# child side
# ---------------------------------------------------------------------------


def _neutralize_journal(manager) -> None:
    """Disarm every inherited journal binding in a freshly forked child: the
    parent's journal file has exactly one writer (the parent), and nothing
    in the child — registry, memo cache, transfer ledger — may write
    through. The fd is closed raw (no flush: the parent flushed pre-fork,
    and a racing buffer copy must not be written twice)."""
    journal = getattr(manager, "journal", None)
    if journal is not None:
        try:
            os.close(journal._fh.fileno())
        except Exception:
            pass
        journal.closed = True
    registry = getattr(manager, "registry", None)
    if registry is not None:
        registry._journal = None
    for holder in (getattr(manager, "cache", None), getattr(manager, "ledger", None)):
        if holder is not None and hasattr(holder, "_journal"):
            holder._journal = None


def _child_main(conn, manager, segment_path, segment_zone) -> None:
    # Close inherited parent-side pipe ends (this worker's own and any
    # earlier siblings'): see _OPEN_PARENT_CONNS.
    for c in list(_OPEN_PARENT_CONNS):
        try:
            c.close()
        except Exception:
            pass
    _OPEN_PARENT_CONNS.clear()
    _neutralize_journal(manager)
    segment = None
    if segment_path is not None:
        from repro.provenance import Journal

        # flush_every_n=1: a record is durable before the reply that
        # references it leaves this process — "parent saw the outcome"
        # implies "the segment holds its records", even if this runner is
        # later killed without a clean stop.
        segment = Journal(
            segment_path,
            flush_every_n=1,
            workspace=getattr(manager.pipeline, "name", ""),
            segment=segment_zone,
        )
    pid = os.getpid()
    while True:
        try:
            msg, _ = _recv(conn)
        except (EOFError, OSError):
            break
        op = msg.get("op")
        if op == "stop":
            try:
                _send(conn, {"ok": True})
            except Exception:
                pass
            break
        try:
            if op == "ping":
                reply = {"ok": True, "pid": pid, "zone": segment_zone}
            elif op == "exec":
                reply = {"ok": True, "result": _execute_request(manager, msg)}
            elif op == "exec_zoned":
                reply = {"ok": True, "result": _execute_zoned(manager, msg, segment)}
            else:
                reply = {"ok": False, "error": f"unknown op {op!r}"}
        except BaseException as exc:
            reply = {"ok": False, "error": traceback.format_exc(), "exc": exc}
        try:
            _send(conn, reply)
        except (EOFError, OSError, BrokenPipeError):
            break
        except Exception:
            # reply not picklable (exotic exception / ghost spec): degrade
            # to the traceback string so the parent still gets an answer
            fallback = {
                "ok": False,
                "error": reply.get("error") or "worker reply was not picklable",
            }
            try:
                _send(conn, fallback)
            except Exception:
                break
    if segment is not None:
        try:
            segment.close()
        except Exception:
            pass
    try:
        conn.close()
    except Exception:
        pass
    os._exit(0)


def _resolve(store, ref: dict):
    """Materialize one shipped reference: ghosts resolve from metadata
    (zero bytes, ever); real artifacts pin into this worker's private local
    tier from the shared object directory the parent published into."""
    uri = ref["uri"]
    if uri.startswith("ghost://"):
        return (ref.get("meta") or {}).get("ghost_spec")
    return store.get(store.pin_local(uri, region=ref.get("region")))


def _normalize_result(task, result):
    # same contract checks as SmartTask.finish_execution — fail here, in the
    # worker, so the parent-side retry machinery never sees a malformed
    # outcome as a crash
    if not isinstance(result, dict):
        if len(task.outputs) != 1:
            raise TypeError(
                f"task {task.name} returned a single value but declares "
                f"outputs {task.outputs}"
            )
        result = {task.outputs[0]: result}
    missing = set(task.outputs) - set(result)
    if missing:
        raise KeyError(f"task {task.name} missing outputs {sorted(missing)}")
    return result


def _execute_request(manager, msg: dict) -> dict:
    """Run one task's user fn against a shipped reference snapshot; export
    outputs to the shared object tier; reply with specs only."""
    task = manager.pipeline.tasks[msg["task"]]
    task.zone = msg.get("zone")  # placement was decided on the parent
    kwargs = {}
    for name, val in msg["snapshot"].items():
        if isinstance(val, list):
            kwargs[name] = [_resolve(manager.store, r) for r in val]
        else:
            kwargs[name] = _resolve(manager.store, val)
    svc_base = {n: len(s.frozen_responses) for n, s in task.services.items()}
    for sname, svc in task.services.items():
        kwargs[sname] = svc
    t0 = time.perf_counter()
    result = task.fn(**kwargs)
    dt = time.perf_counter() - t0
    result = _normalize_result(task, result)
    # hash the whole firing's outputs in one fused call, then export the
    # non-ghosts as a batch with the digests precomputed (hash work is not
    # repeated inside the store)
    payloads = [result[oname] for oname in task.outputs]
    hashes = content_hash_batch(payloads)
    ghost_flags = [is_ghost(p) for p in payloads]
    exported = iter(
        manager.store.export_batch(
            [p for p, g in zip(payloads, ghost_flags) if not g],
            hashes=[h for h, g in zip(hashes, ghost_flags) if not g],
        )
    )
    outputs = {}
    for oname, payload, chash, ghost in zip(task.outputs, payloads, hashes, ghost_flags):
        if ghost:
            outputs[oname] = {
                "ghost": True,
                "chash": chash,
                "ghost_spec": payload,
            }
        else:
            uri, chash, nbytes, existed = next(exported)
            outputs[oname] = {
                "uri": uri,
                "chash": chash,
                "nbytes": int(nbytes),
                "existed": bool(existed),
            }
    services = {
        n: task.services[n].frozen_responses[base:]
        for n, base in svc_base.items()
        if len(task.services[n].frozen_responses) > base
    }
    return {"task": task.name, "outputs": outputs, "wall_s": dt, "services": services}


def _execute_zoned(manager, msg: dict, segment) -> dict:
    """``exec`` plus zone-runner provenance: mint the output AVs and visitor
    entries inside the uid/seq window the parent reserved, append each
    record (under its reserved global seq) to this runner's segment, and
    stream the records back for verbatim restoration.

    Record layout per firing — exactly the journal shape an in-process run
    writes, so the seq-ordered merge is indistinguishable from one:
    ``visit(executed)`` then, per output, ``av`` + ``visit(emitted)``;
    1 + 2·n_outputs journal seqs, 1 + n_outputs visitor seqs, n_outputs
    uid numbers."""
    base = _execute_request(manager, msg)
    task = manager.pipeline.tasks[msg["task"]]
    zone = msg.get("zone")
    uid_nos = list(msg["uid_nos"])
    vseq = int(msg["visit_seq"])
    jseq = msg.get("journal_seq")
    records: list = []

    def emit_record(kind: str, data: dict) -> None:
        nonlocal jseq
        seq = None
        if jseq is not None:
            seq = jseq
            jseq += 1
            if segment is not None:
                segment.append(kind, data, seq=seq)
        records.append({"seq": seq, "kind": kind, "data": data})

    entry = VisitorEntry(
        task=task.name,
        av_uid="-",
        event="executed",
        timestamp=time.time(),
        software_version=task.version,
        note=f"wall={base['wall_s']:.6f}s",
        seq=vseq,
    )
    emit_record("visit", entry.to_record())
    parents = list(msg.get("parent_uids", []))
    for i, oname in enumerate(task.outputs):
        spec = base["outputs"][oname]
        if spec.get("ghost"):
            meta = {"ghost": True, "ghost_spec": spec.get("ghost_spec")}
            if zone is not None:
                meta["zone"] = zone
            av = AnnotatedValue.produce(
                spec["chash"],
                f"ghost://{spec['chash']}",
                task.name,
                task.version,
                region=task.region,
                meta=meta,
                uid_no=uid_nos[i],
            )
        else:
            meta = None
            if zone is not None:
                meta = {"zone": zone, "nbytes": spec["nbytes"]}
            av = AnnotatedValue.produce(
                spec["chash"],
                spec["uri"],
                task.name,
                task.version,
                region=task.region,
                meta=meta,
                uid_no=uid_nos[i],
            )
        emit_record("av", {"av": av.to_record(), "parents": parents})
        entry = VisitorEntry(
            task=task.name,
            av_uid=av.uid,
            event="emitted",
            timestamp=time.time(),
            software_version=task.version,
            seq=vseq + 1 + i,
        )
        emit_record("visit", entry.to_record())
        spec["uid"] = av.uid
    base["records"] = records
    return base
