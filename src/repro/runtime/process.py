"""ProcessExecutor — scheduler waves on a pool of forked worker processes.

The multi-process sibling of
:class:`~repro.workspace.executors.ConcurrentExecutor`: same ``run_wave``
seam, same single-task-wave inline shortcut, but the wave's user code runs
on *processes*, so CPU-bound plugin code actually parallelizes instead of
serializing on the GIL (benchmark B12 measures exactly this).

Determinism comes from a strict phase split, all in wave order on the
calling thread:

  1. ``begin_execution`` for every task (arrival visits, memo lookups —
     cache hits never leave the parent);
  2. ``publish`` each remote plan's inputs to the shared object tier
     (reference handover: a worker resolves payloads by content hash);
  3. dispatch the plans round-robin over the pool and collect replies;
  4. ``finish_remote`` per task, in wave order — every AV mint, visitor
     entry, ledger charge, and memo insert happens *here*, in the parent.

Because step 4 is the only provenance-producing step and it runs after a
worker's outcome is fully in hand, a worker crash mid-task leaves nothing
to roll back: the parent journals a ``worker_died`` anomaly, respawns the
slot, and retries the task on a fresh worker (bounded by ``retry_budget``),
finally degrading to an inline run — no lost and no duplicated AVs, and
the determinism fingerprint matches a crash-free run.
"""

from __future__ import annotations

from repro.workspace.executors import InlineExecutor

from .worker import WorkerProcess, fork_context

# exception set that means "the worker at the far end is gone"
_DEAD = (BrokenPipeError, ConnectionResetError, EOFError, OSError)


def _plan_all_real(plan) -> bool:
    """Remote-eligibility: plans with ghost inputs stay inline — a ghost run
    moves zero bytes by design, so a process hop buys nothing and the spec
    objects (which may not pickle) never need to cross the pipe. Plans
    carrying a dedup closure stay inline too: the replay is a store read
    plus parent-side provenance, and the closure itself never pickles."""
    if getattr(plan, "dedup", None) is not None:
        return False
    for val in plan.snap.values():
        for av in val if isinstance(val, list) else [val]:
            if av.uri.startswith("ghost://"):
                return False
    return True


def _publish_inputs(store, plan) -> None:
    for val in plan.snap.values():
        for av in val if isinstance(val, list) else [val]:
            if av.uri.startswith("ghost://"):
                continue
            try:
                store.publish(av.chash)
            except KeyError:
                # resident in neither tier — the worker's own resolution
                # will raise the same KeyError the inline path would have
                pass


class ProcessExecutor(InlineExecutor):
    """Execute multi-task waves across a persistent forked worker pool.

    ``KOALJA_EXECUTOR=process`` selects this backend;
    ``KOALJA_MAX_WORKERS`` sizes the pool. Workers fork lazily at the first
    multi-task wave (single-task waves and pull-mode nodes stay on the
    calling thread, like ConcurrentExecutor), against the manager they will
    serve — the fork snapshot carries the task registry and the store
    handle; per-request state arrives as references over the pipe.
    """

    def __init__(self, max_workers: int = 8, retry_budget: int = 2) -> None:
        super().__init__()
        if max_workers < 1:
            raise ValueError(f"max_workers must be >= 1, got {max_workers}")
        self.max_workers = int(max_workers)
        self.retry_budget = max(0, int(retry_budget))
        self._workers: list = [None] * self.max_workers  # slot -> WorkerProcess
        self._manager = None
        self.parallel_waves = 0
        self.tasks_remote = 0
        self.tasks_inline = 0
        self.worker_restarts = 0
        self.retries = 0
        self.inline_fallbacks = 0
        self._retired_bytes_sent = 0
        self._retired_bytes_received = 0

    # -- pool management -----------------------------------------------------
    def _prepare(self, manager) -> None:
        if self._manager is manager:
            return
        if self._manager is not None:
            # rebinding to a new workspace: the old forks hold the old
            # manager's registry — useless and wrong for the new circuit
            self.shutdown()
        manager.store.ensure_object_dir()
        self._manager = manager

    def _worker(self, slot: int) -> WorkerProcess:
        w = self._workers[slot]
        if w is None or not w.alive():
            if w is not None:
                self._retire(slot)
            w = WorkerProcess(self._manager, worker_id=slot)
            self._workers[slot] = w
        return w

    def _retire(self, slot: int) -> None:
        w = self._workers[slot]
        if w is None:
            return
        self._retired_bytes_sent += w.bytes_sent
        self._retired_bytes_received += w.bytes_received
        w.kill()
        self._workers[slot] = None
        self.worker_restarts += 1

    def resize(self, max_workers: int) -> None:
        """Adopt a new pool size between waves (the
        :class:`~repro.workspace.executors.AdaptiveExecutor` seam). Growing
        appends empty slots — workers fork lazily when a wave first needs
        them; shrinking stops the excess workers gracefully. All provenance
        is minted parent-side in wave order, so pool size never affects
        merge order, ledgers, or the journal's forensic stories."""
        if max_workers < 1:
            raise ValueError(f"max_workers must be >= 1, got {max_workers}")
        max_workers = int(max_workers)
        if max_workers == self.max_workers:
            return
        if max_workers > self.max_workers:
            self._workers.extend([None] * (max_workers - self.max_workers))
        else:
            for slot in range(max_workers, self.max_workers):
                w = self._workers[slot]
                if w is not None:
                    self._retired_bytes_sent += w.bytes_sent
                    self._retired_bytes_received += w.bytes_received
                    w.stop()
            del self._workers[max_workers:]
        self.max_workers = max_workers

    def kill_worker(self, slot: int = 0) -> bool:
        """Chaos/test helper: SIGKILL one pool worker. The next wave (or the
        in-flight one) detects the death, journals the anomaly, and
        retries on a fresh fork."""
        w = self._workers[slot] if 0 <= slot < len(self._workers) else None
        if w is not None and w.alive():
            w.proc.kill()
            w.proc.join(timeout=5)
            return True
        return False

    def shutdown(self) -> None:
        """Stop every worker gracefully and unbind the manager."""
        for slot, w in enumerate(self._workers):
            if w is not None:
                self._retired_bytes_sent += w.bytes_sent
                self._retired_bytes_received += w.bytes_received
                w.stop()
                self._workers[slot] = None
        self._manager = None

    def __del__(self) -> None:  # daemonized forks die with us, but be tidy
        try:
            for w in self._workers:
                if w is not None:
                    w.kill()
        except Exception:
            pass

    # -- wave execution ------------------------------------------------------
    def run_wave(self, manager, tasks: list) -> list:
        if len(tasks) <= 1 or fork_context() is None:
            # single-task waves (and platforms without fork) stay inline:
            # no pipe hop, and outer context managers remain visible
            return super().run_wave(manager, tasks)
        self._prepare(manager)
        self.waves_run += 1
        self.parallel_waves += 1
        results: dict = {}
        pending: list = []
        for t in tasks:
            status, payload = t.begin_execution(
                manager.store, manager.registry, manager.cache
            )
            if status == "hit":
                results[t.name] = payload
            else:
                pending.append((t, payload))
        remote = [(t, plan) for t, plan in pending if _plan_all_real(plan)]
        outcomes, errors = self._run_remote(manager, remote)
        if errors:
            task_name, exc, tb = errors[0]
            if exc is not None:
                raise exc
            raise RuntimeError(
                f"task {task_name!r} failed in worker process:\n{tb}"
            )
        remote_names = {t.name for t, _ in remote}
        for t, plan in pending:
            outcome = outcomes.get(t.name)
            if outcome is not None:
                results[t.name] = t.finish_remote(
                    plan, outcome, manager.store, manager.registry,
                    manager.cache, emit=False,
                )
                self.tasks_remote += 1
            else:
                # ghost-flavoured plan, or a casualty past its retry budget
                if t.name in remote_names:
                    self.inline_fallbacks += 1
                result, dt = t.run_user_fn(plan, manager.store)
                results[t.name] = t.finish_execution(
                    plan, result, dt, manager.store, manager.registry,
                    manager.cache, emit=False,
                )
                self.tasks_inline += 1
        return [(t.name, results[t.name]) for t in tasks]

    def _run_remote(self, manager, items: list) -> tuple:
        """Dispatch ``(task, plan)`` items across the pool; returns
        ``({task_name: outcome | None}, [(task_name, exc, traceback)])``.
        ``None`` outcomes are crash casualties past their retry budget —
        the caller runs them inline."""
        outcomes: dict = {t.name: None for t, _ in items}
        errors: list = []
        if not items:
            return outcomes, errors
        for _t, plan in items:
            _publish_inputs(manager.store, plan)
        todo = list(items)
        attempts = {t.name: 0 for t, _ in items}
        while todo:
            n = min(self.max_workers, len(todo))
            slots: list = [[] for _ in range(n)]
            for i, item in enumerate(todo):
                slots[i % n].append(item)
            retry: list = []
            workers, sent = [], []
            for s in range(n):
                w = self._worker(s)
                workers.append(w)
                ssent: list = []
                for t, plan in slots[s]:
                    try:
                        w.send(
                            {
                                "op": "exec",
                                "task": t.name,
                                "zone": t.zone,
                                "snapshot": plan.snapshot_refs(),
                            }
                        )
                        ssent.append((t, plan))
                    except _DEAD:
                        break
                sent.append(ssent)
            for s in range(n):
                w = workers[s]
                answered = 0
                for t, _plan in sent[s]:
                    try:
                        reply = w.recv()
                    except _DEAD:
                        break
                    answered += 1
                    if reply.get("ok"):
                        outcomes[t.name] = reply["result"]
                    else:
                        errors.append(
                            (t.name, reply.get("exc"), reply.get("error", ""))
                        )
                # everything sent but unanswered, plus never-sent: casualties
                casualties = sent[s][answered:] + slots[s][len(sent[s]):]
                if casualties:
                    pid = w.pid
                    self._retire(s)
                    for t, plan in casualties:
                        attempts[t.name] += 1
                        manager.registry.record_anomaly(
                            t.name,
                            f"worker_died pid={pid} slot={s} "
                            f"attempt={attempts[t.name]}",
                        )
                        if attempts[t.name] <= self.retry_budget:
                            self.retries += 1
                            retry.append((t, plan))
                        # else: outcome stays None -> inline fallback
            todo = retry
        return outcomes, errors

    # -- introspection -------------------------------------------------------
    def _pipe_bytes(self) -> tuple:
        sent, received = self._retired_bytes_sent, self._retired_bytes_received
        for w in self._workers:
            if w is not None:
                sent += w.bytes_sent
                received += w.bytes_received
        return sent, received

    def stats(self) -> dict:
        out = super().stats()
        sent, received = self._pipe_bytes()
        out.update(
            {
                "max_workers": self.max_workers,
                "retry_budget": self.retry_budget,
                "parallel_waves": self.parallel_waves,
                "tasks_remote": self.tasks_remote,
                "tasks_inline": self.tasks_inline,
                "workers_alive": sum(
                    1 for w in self._workers if w is not None and w.alive()
                ),
                "worker_restarts": self.worker_restarts,
                "retries": self.retries,
                "inline_fallbacks": self.inline_fallbacks,
                "control_bytes_sent": sent,
                "control_bytes_received": received,
                # payloads cross via the shared object tier, never the pipe
                # — the refs-only contract benchmark B12 verifies
                "payload_bytes_over_pipe": 0,
            }
        )
        return out

    def __repr__(self) -> str:
        return (
            f"ProcessExecutor(max_workers={self.max_workers}, "
            f"retry_budget={self.retry_budget})"
        )
