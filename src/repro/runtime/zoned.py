"""ZoneRunner + ZonedProcessExecutor — remote runners per extended-cloud zone.

Where :class:`~repro.runtime.process.ProcessExecutor` is a flat pool, this
backend promotes each :class:`~repro.topology.Topology` zone to its own
**runner process** — the in-machine stand-in for dispatching a zone's
partition to that zone's physical site. On first use the executor extracts
every zone's partition (:func:`~repro.topology.extract_partitions`) and
journals it as a typed ``partition`` record: the deployment story survives
the processes.

Provenance is minted *where the work runs*. Each remote firing carries a
parent-reserved window — ``1 + 2·n_outputs`` global journal seqs
(``Journal.reserve``), ``1 + n_outputs`` visitor-log seqs
(``ProvenanceRegistry.reserve_seqs``), and ``n_outputs`` AV uid numbers
(``reserve_uid_numbers``) — so the runner's records are born with their
final position in the global order. The runner appends them to its own
journal *segment* file (``<journal>.seg-<zone>``) and streams them back;
the parent restores them verbatim (``restore_av`` / ``restore_visit``,
which never re-journal). :func:`repro.provenance.replay_segments` later
merges main + segments by seq into a registry identical to the live one —
and to the single-process oracle. The merge is chain-aware on both sides:
a rotated main journal (numbered segments + live tail + best checkpoint)
and rotated zone segments replay the same stream, and zone records already
folded into a main checkpoint by ``Journal.compact`` are dropped as
covered.

Crash story: a runner killed mid-flight may have already appended records
for firings the parent will retry under *fresh* reservations. The parent
therefore appends a ``revoked`` record (the dead window) to the main
journal; the segment merge drops revoked seqs, so the merged history holds
each firing exactly once — no lost, no duplicated AVs.
"""

from __future__ import annotations

import os
import re
from typing import Optional

from repro.core.av import reserve_uid_numbers
from repro.topology import extract_partitions
from repro.workspace.executors import InlineExecutor

from .process import _DEAD, ProcessExecutor, _plan_all_real, _publish_inputs
from .worker import WorkerProcess, fork_context


class ZoneRunner:
    """One remote runner process hosting a topology zone's partition."""

    def __init__(self, manager, zone: str, partition, segment_path) -> None:
        self.zone = zone
        self.partition = partition
        self.segment_path = segment_path
        self.worker = WorkerProcess(
            manager,
            worker_id=f"zone-{zone}",
            segment_path=segment_path,
            segment_zone=zone,
        )
        self.firings = 0

    def describe(self) -> dict:
        return {
            "zone": self.zone,
            "pid": self.worker.pid,
            "alive": self.worker.alive(),
            "segment": self.segment_path,
            "firings": self.firings,
            "tasks": list(self.partition.tasks) if self.partition else [],
        }

    def __repr__(self) -> str:
        return f"ZoneRunner({self.zone!r}, pid={self.worker.pid})"


class ZonedProcessExecutor(InlineExecutor):
    """Partition each wave by zone and run every partition in that zone's
    runner process. ``KOALJA_EXECUTOR=zoned-process`` selects it.

    Single-task waves stay inline (the ConcurrentExecutor precedent — no
    pipe hop for pull-mode nodes), and circuits without a topology degrade
    to a flat :class:`ProcessExecutor` pool: the zone concept needs zones.
    Zone groups dispatch in topology declaration order and results return
    in wave order, so merge-FCFS arrival seqs — and the whole determinism
    fingerprint — stay bit-identical to the in-process backends.
    """

    def __init__(
        self,
        topology=None,
        *,
        max_workers: int = 8,
        retry_budget: int = 2,
    ) -> None:
        super().__init__()
        self.topology = topology
        self.max_workers = int(max_workers)
        self.retry_budget = max(0, int(retry_budget))
        self._manager = None
        self._runners: dict = {}  # zone -> ZoneRunner
        self._flat: Optional[ProcessExecutor] = None  # topology-less fallback
        self.partitions: dict = {}
        self.zone_waves: dict = {}  # zone -> {"waves": n, "tasks": n}
        self.tasks_remote = 0
        self.tasks_inline = 0
        self.worker_restarts = 0
        self.retries = 0
        self.inline_fallbacks = 0
        self.revoked_windows = 0
        self._retired_bytes_sent = 0
        self._retired_bytes_received = 0

    # -- runner fleet --------------------------------------------------------
    def _prepare(self, manager, topo) -> None:
        if self._manager is manager:
            return
        if self._manager is not None:
            self.shutdown()
        manager.store.ensure_object_dir()
        self._manager = manager
        # the deployment snapshot: one partition per zone, journaled so a
        # replay can answer "which tasks were shipped where"
        self.partitions = extract_partitions(topo, manager.pipeline)
        if manager.journal is not None:
            for zone in topo.zone_names():
                manager.journal.append(
                    "partition", self.partitions[zone].describe()
                )

    @staticmethod
    def _segment_path(journal, zone: str) -> Optional[str]:
        if journal is None:
            return None
        safe = re.sub(r"[^A-Za-z0-9_.-]", "-", zone)
        return f"{journal.path}.seg-{safe}"

    def _runner(self, manager, zone: str) -> ZoneRunner:
        r = self._runners.get(zone)
        if r is None or not r.worker.alive():
            if r is not None:
                self._retire(zone)
            r = ZoneRunner(
                manager,
                zone,
                self.partitions.get(zone),
                self._segment_path(manager.journal, zone),
            )
            self._runners[zone] = r
        return r

    def _retire(self, zone: str) -> None:
        r = self._runners.pop(zone, None)
        if r is None:
            return
        self._retired_bytes_sent += r.worker.bytes_sent
        self._retired_bytes_received += r.worker.bytes_received
        r.worker.kill()
        self.worker_restarts += 1

    def kill_runner(self, zone: str) -> bool:
        """Chaos/test helper: SIGKILL one zone's runner process."""
        r = self._runners.get(zone)
        if r is not None and r.worker.alive():
            r.worker.proc.kill()
            r.worker.proc.join(timeout=5)
            return True
        return False

    def segment_paths(self) -> list:
        """Every segment *base* path the runner fleet has written (for
        ``replay_segments`` / ``Workspace.from_journal([main, *segments])``
        / ``Journal.compact``). Base paths, not files: a long-lived zone
        segment rotates under ``KOALJA_JOURNAL_ROTATE`` just like the main
        journal, and the chain-aware readers expand each base into its
        rotated parts + live tail."""
        from repro.provenance import discover_chain

        out = []
        if self._manager is not None and self._manager.journal is not None:
            for zone in sorted(self.partitions):
                path = self._segment_path(self._manager.journal, zone)
                if path is None:
                    continue
                chain = discover_chain(path)
                if chain["live"] or chain["segments"]:
                    out.append(path)
        return out

    def runners(self) -> dict:
        return {z: r.describe() for z, r in sorted(self._runners.items())}

    def shutdown(self) -> None:
        for zone in list(self._runners):
            r = self._runners.pop(zone)
            self._retired_bytes_sent += r.worker.bytes_sent
            self._retired_bytes_received += r.worker.bytes_received
            r.worker.stop()
        if self._flat is not None:
            self._flat.shutdown()
        self._manager = None

    def __del__(self) -> None:
        try:
            for r in self._runners.values():
                r.worker.kill()
        except Exception:
            pass

    # -- wave execution ------------------------------------------------------
    def run_wave(self, manager, tasks: list) -> list:
        topo = self.topology or getattr(manager, "topology", None)
        if fork_context() is None:
            return super().run_wave(manager, tasks)
        if topo is None:
            # flat circuit: no zones to partition by — behave as a pool
            if self._flat is None:
                self._flat = ProcessExecutor(
                    max_workers=self.max_workers, retry_budget=self.retry_budget
                )
            self.waves_run += 1
            return self._flat.run_wave(manager, tasks)
        if len(tasks) <= 1:
            for t in tasks:
                zone = t.zone or topo.default_zone
                zw = self.zone_waves.setdefault(zone, {"waves": 0, "tasks": 0})
                zw["waves"] += 1
                zw["tasks"] += 1
            return super().run_wave(manager, tasks)
        self._prepare(manager, topo)
        self.waves_run += 1
        results: dict = {}
        pending: list = []
        for t in tasks:
            status, payload = t.begin_execution(
                manager.store, manager.registry, manager.cache
            )
            if status == "hit":
                results[t.name] = payload
            else:
                pending.append((t, payload))
        # group by zone, in topology declaration order (the ZonedExecutor
        # convention — partition order must not leak downstream)
        groups: dict = {}
        for t, plan in pending:
            groups.setdefault(t.zone or topo.default_zone, []).append((t, plan))
        order = {z: i for i, z in enumerate(topo.zone_names())}
        zones = sorted(groups, key=lambda z: (order.get(z, len(order)), z))
        remote_items: list = []
        for zone in zones:
            zw = self.zone_waves.setdefault(zone, {"waves": 0, "tasks": 0})
            zw["waves"] += 1
            zw["tasks"] += len(groups[zone])
            for t, plan in groups[zone]:
                if _plan_all_real(plan):
                    remote_items.append((zone, t, plan))
        outcomes, errors = self._run_remote(manager, remote_items)
        if errors:
            task_name, exc, tb = errors[0]
            if exc is not None:
                raise exc
            raise RuntimeError(
                f"task {task_name!r} failed in zone runner:\n{tb}"
            )
        remote_names = {t.name for _z, t, _p in remote_items}
        for t, plan in pending:
            outcome = outcomes.get(t.name)
            if outcome is not None:
                results[t.name] = self._adopt(manager, t, plan, outcome)
                self.tasks_remote += 1
            else:
                if t.name in remote_names:
                    self.inline_fallbacks += 1
                result, dt = t.run_user_fn(plan, manager.store)
                results[t.name] = t.finish_execution(
                    plan, result, dt, manager.store, manager.registry,
                    manager.cache, emit=False,
                )
                self.tasks_inline += 1
        return [(t.name, results[t.name]) for t in tasks]

    # -- remote protocol -----------------------------------------------------
    def _make_request(self, manager, t, plan) -> tuple:
        """Reserve this firing's seq/uid windows and build the work order.
        Reservations happen at dispatch, on the scheduler thread, in
        deterministic (zone-group, wave) order."""
        n_out = len(t.outputs)
        jseq = None
        if manager.journal is not None:
            jseq = manager.journal.reserve(1 + 2 * n_out)
        vseq = manager.registry.reserve_seqs(1 + n_out)
        uid_nos = reserve_uid_numbers(n_out)
        req = {
            "op": "exec_zoned",
            "task": t.name,
            "zone": t.zone,
            "snapshot": plan.snapshot_refs(),
            "parent_uids": list(plan.parent_uids),
            "uid_nos": uid_nos,
            "visit_seq": vseq,
            "journal_seq": jseq,
        }
        return req, {"jseq": jseq, "count": 1 + 2 * n_out}

    def _revoke(self, manager, task_name: str, resv) -> None:
        """Void a dead runner's reserved journal window: it may have
        appended records for a firing the parent is about to retry under
        fresh seqs, and the merge must not resurrect them."""
        if resv is None or resv.get("jseq") is None or manager.journal is None:
            return
        manager.journal.append(
            "revoked",
            {"task": task_name, "start": resv["jseq"], "count": resv["count"]},
        )
        self.revoked_windows += 1

    def _run_remote(self, manager, items: list) -> tuple:
        """items: ``[(zone, task, plan)]`` in dispatch order. Same retry
        contract as ProcessExecutor._run_remote, plus per-casualty
        revocation of the reserved journal windows."""
        outcomes: dict = {t.name: None for _z, t, _p in items}
        errors: list = []
        if not items:
            return outcomes, errors
        for _z, _t, plan in items:
            _publish_inputs(manager.store, plan)
        todo = list(items)
        attempts = {t.name: 0 for _z, t, _p in items}
        while todo:
            by_zone: dict = {}
            for zone, t, plan in todo:
                by_zone.setdefault(zone, []).append((t, plan))
            retry: list = []
            sent: dict = {}
            runners: dict = {}
            reservations: dict = {}
            for zone, batch in by_zone.items():
                r = self._runner(manager, zone)
                runners[zone] = r
                ssent: list = []
                for t, plan in batch:
                    req, resv = self._make_request(manager, t, plan)
                    reservations[t.name] = resv
                    try:
                        r.worker.send(req)
                        ssent.append((t, plan))
                    except _DEAD:
                        break
                sent[zone] = ssent
            for zone, batch in by_zone.items():
                r = runners[zone]
                answered = 0
                for t, _plan in sent[zone]:
                    try:
                        reply = r.worker.recv()
                    except _DEAD:
                        break
                    answered += 1
                    if reply.get("ok"):
                        outcomes[t.name] = reply["result"]
                        r.firings += 1
                    else:
                        self._revoke(manager, t.name, reservations.get(t.name))
                        errors.append(
                            (t.name, reply.get("exc"), reply.get("error", ""))
                        )
                casualties = sent[zone][answered:] + batch[len(sent[zone]):]
                if casualties:
                    pid = r.worker.pid
                    self._retire(zone)
                    for t, plan in casualties:
                        attempts[t.name] += 1
                        self._revoke(manager, t.name, reservations.get(t.name))
                        manager.registry.record_anomaly(
                            t.name,
                            f"worker_died zone={zone} pid={pid} "
                            f"attempt={attempts[t.name]}",
                        )
                        if attempts[t.name] <= self.retry_budget:
                            self.retries += 1
                            retry.append((zone, t, plan))
            todo = retry
        return outcomes, errors

    def _adopt(self, manager, t, plan, outcome: dict) -> dict:
        """Complete a zone-remote firing in the parent: restore the runner's
        streamed records verbatim, then replicate the non-registry side
        effects (ledger charges, counters, store adoption, memo insert) in
        exactly ``finish_execution``'s order."""
        t.account_remote_inputs(manager.store, plan)
        for sname, calls in (outcome.get("services") or {}).items():
            svc = t.services.get(sname)
            if svc is not None:
                svc.frozen_responses.extend(calls)
        t.executions += 1
        if t.zone is not None:
            t.zone_executions[t.zone] = t.zone_executions.get(t.zone, 0) + 1
        # the runner's forked ledger is invisible here: replicate the
        # compute-account charge exactly like account_remote_inputs does
        # for the transfer charges (finish_remote's order)
        t._charge_compute(manager.store, plan)
        for rec in outcome.get("records", ()):
            if rec["kind"] == "av":
                manager.registry.restore_av(rec["data"])
            elif rec["kind"] == "visit":
                manager.registry.restore_visit(rec["data"])
        out_avs, outputs_rec, out_uids, out_nbytes = {}, {}, {}, {}
        any_ghost = False
        for oname in t.outputs:
            spec = outcome["outputs"][oname]
            av = manager.registry.get_av(spec["uid"])
            if spec.get("ghost"):
                any_ghost = True
            else:
                nbytes = int(spec["nbytes"])
                manager.store.adopt(
                    spec["chash"], nbytes, existed=spec.get("existed", False)
                )
                if t.ledger is not None:
                    t.ledger.register_resident(spec["chash"], t.zone)
                outputs_rec[oname] = (spec["uri"], spec["chash"])
                out_uids[oname] = av.uid
                out_nbytes[oname] = nbytes
            out_avs[oname] = av
        if plan.use_cache and manager.cache is not None and not any_ghost:
            from repro.cache import make_record

            manager.cache.insert(
                plan.key,
                make_record(
                    t.version, outputs_rec, out_uids, out_nbytes,
                    birth_zone=t.zone,
                ),
                ttl_s=t.cache_ttl_s,
            )
        return out_avs

    # -- introspection -------------------------------------------------------
    def stats(self) -> dict:
        out = super().stats()
        sent, received = self._retired_bytes_sent, self._retired_bytes_received
        for r in self._runners.values():
            sent += r.worker.bytes_sent
            received += r.worker.bytes_received
        out.update(
            {
                "max_workers": self.max_workers,
                "retry_budget": self.retry_budget,
                "zones": {z: dict(v) for z, v in sorted(self.zone_waves.items())},
                "runners": self.runners(),
                "tasks_remote": self.tasks_remote,
                "tasks_inline": self.tasks_inline,
                "worker_restarts": self.worker_restarts,
                "retries": self.retries,
                "inline_fallbacks": self.inline_fallbacks,
                "revoked_windows": self.revoked_windows,
                "control_bytes_sent": sent,
                "control_bytes_received": received,
                "payload_bytes_over_pipe": 0,
            }
        )
        if self._flat is not None:
            out["flat"] = self._flat.stats()
        return out

    def __repr__(self) -> str:
        return (
            f"ZonedProcessExecutor(runners={sorted(self._runners)}, "
            f"retry_budget={self.retry_budget})"
        )
