"""repro: Koalja-JAX — provenance-first data circuitry for multi-pod TPU ML."""

__version__ = "0.1.0"
