"""repro: Koalja-JAX — provenance-first data circuitry for multi-pod TPU ML.

Public entry point: ``from repro import Workspace`` (lazy import — the
circuit layer loads without pulling in JAX model code until needed).
"""

__version__ = "0.2.0"

_LAZY = {
    "Workspace": ("repro.workspace", "Workspace"),
    "InlineExecutor": ("repro.workspace", "InlineExecutor"),
    "MeshExecutor": ("repro.workspace", "MeshExecutor"),
}


def __getattr__(name):
    if name in _LAZY:
        import importlib

        mod, attr = _LAZY[name]
        return getattr(importlib.import_module(mod), attr)
    raise AttributeError(f"module 'repro' has no attribute {name!r}")
