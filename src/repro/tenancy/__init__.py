"""repro.tenancy — multi-tenant workspace control plane (hub).

One :class:`WorkspaceHub` hosts many named workspaces over a shared
content-addressed store and a shared cross-tenant memo index, with
per-tenant memberships/roles, journal segments, and transfer quotas::

    from repro.tenancy import WorkspaceHub, TenantQuota

    hub = WorkspaceHub("prod", journal_path="/var/log/koalja/hub.jsonl")
    alice = hub.create("team-a", owner="alice",
                       quota=TenantQuota(hard_bytes=1 << 30))
    hub.grant("team-a", "bob", "reader", by="alice")
    bob = hub.workspace("team-a", user="bob")

See :mod:`repro.tenancy.hub` for the architecture and ``docs/tenancy.md``
for the runnable walkthrough.
"""

from .fingerprint import tenant_fingerprint
from .hub import ROLES, RehydratedHub, TenantSession, WorkspaceHub
from .memo import HubMemoStore, TenantMemoCache
from .quota import (
    PermissionDeniedError,
    QuotaExceededError,
    TenancyError,
    TenantMeter,
    TenantQuota,
)

__all__ = [
    "HubMemoStore",
    "PermissionDeniedError",
    "QuotaExceededError",
    "ROLES",
    "RehydratedHub",
    "TenancyError",
    "TenantMemoCache",
    "TenantMeter",
    "TenantQuota",
    "TenantSession",
    "WorkspaceHub",
    "tenant_fingerprint",
]
