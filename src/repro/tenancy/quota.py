"""Per-tenant transfer quotas — the billing half of the multi-tenant hub.

A tenant's resource story has two meters, both fed by machinery that
already exists for single-tenant workspaces:

* **bytes** — payload ingress (every ``push``/``inject`` payload, priced by
  the same :meth:`~repro.core.store.ArtifactStore._nbytes` rule the store
  itself uses) plus cross-zone movement the tenant's
  :class:`~repro.topology.ledger.TransferLedger` actually billed
  (``bytes_moved_crosszone`` — reference handovers are free, exactly as in
  the single-tenant sustainability story);
* **joules** — the ledger's derived ``transfer_energy_j``. Flat-topology
  tenants never spend joules, which mirrors the paper's claim that energy
  cost is a *placement* consequence, not a compute one.

Each meter has a soft and a hard limit:

* crossing a **soft** limit journals a ``quota_warning`` anomaly — exactly
  once per crossing, because usage is monotone within a run — and work
  continues;
* a **hard** limit is a deterministic *rejection*: the offending push is
  refused with :class:`QuotaExceededError` before any payload enters the
  store, a ``quota_rejected`` anomaly is journaled (so replay sees the
  refusal too), and **zero** bytes are charged for the rejected attempt.

Determinism contract: both checks run on the facade thread, before/after
the engine call, using only deterministic quantities (payload sizes,
ledger byte totals, the order-independent energy sum) — so the same
session script trips the same warnings and rejections under every
executor backend, and a journal replay reconstructs the same anomaly
trail.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Optional


class TenancyError(RuntimeError):
    """Base class for multi-tenant control-plane failures."""


class PermissionDeniedError(TenancyError):
    """Caller's role on the tenant workspace does not cover the operation."""


class QuotaExceededError(TenancyError):
    """A hard per-tenant limit would be crossed; the operation was refused
    deterministically and nothing was charged."""


@dataclasses.dataclass(frozen=True)
class TenantQuota:
    """Limits for one tenant. ``None`` means unlimited on that axis."""

    hard_bytes: Optional[int] = None
    soft_bytes: Optional[int] = None
    hard_joules: Optional[float] = None
    soft_joules: Optional[float] = None

    def to_record(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_record(cls, data: Optional[dict]) -> Optional["TenantQuota"]:
        if not data:
            return None
        return cls(
            hard_bytes=data.get("hard_bytes"),
            soft_bytes=data.get("soft_bytes"),
            hard_joules=data.get("hard_joules"),
            soft_joules=data.get("soft_joules"),
        )


class TenantMeter:
    """Usage accounting + limit enforcement for one tenant.

    The meter owns only the ingress-byte counter; cross-zone bytes and
    joules are read off the tenant's ledger at check time, so the numbers
    always agree with ``ledger.stats()`` (part of the determinism
    fingerprint). Thread-safe: a tenant's own session calls are sequential,
    but the hub's stats/billing reads may race them.
    """

    def __init__(self, tenant: str, quota: Optional[TenantQuota] = None) -> None:
        self.tenant = tenant
        self.quota = quota
        self.ingress_bytes = 0
        self.rejections = 0
        self._warned_bytes = False
        self._warned_joules = False
        self._lock = threading.Lock()

    # -- usage readings ------------------------------------------------------
    def bytes_used(self, ledger=None) -> int:
        moved = int(ledger.stats()["bytes_moved_crosszone"]) if ledger is not None else 0
        return self.ingress_bytes + moved

    def joules_used(self, ledger=None) -> float:
        if ledger is None:
            return 0.0
        return float(ledger.stats()["transfer_energy_j"])

    # -- enforcement ---------------------------------------------------------
    def charge_ingress(self, nbytes: int, task: str, registry, ledger=None) -> None:
        """Admit (and bill) ``nbytes`` of payload ingress for ``task``, or
        refuse with :class:`QuotaExceededError` — journaling the refusal —
        if a hard limit would be crossed. Refusals charge nothing."""
        nbytes = int(nbytes)
        with self._lock:
            q = self.quota
            if q is not None:
                used_b = self.bytes_used(ledger)
                if q.hard_bytes is not None and used_b + nbytes > q.hard_bytes:
                    self.rejections += 1
                    registry.record_anomaly(
                        task,
                        f"quota_rejected axis=bytes requested={nbytes} "
                        f"used={used_b} hard={q.hard_bytes}",
                    )
                    raise QuotaExceededError(
                        f"tenant {self.tenant!r}: push of {nbytes} B refused — "
                        f"{used_b} B used of hard limit {q.hard_bytes} B"
                    )
                used_j = self.joules_used(ledger)
                if q.hard_joules is not None and used_j >= q.hard_joules:
                    self.rejections += 1
                    registry.record_anomaly(
                        task,
                        f"quota_rejected axis=joules used={used_j:.6f} "
                        f"hard={q.hard_joules}",
                    )
                    raise QuotaExceededError(
                        f"tenant {self.tenant!r}: push refused — "
                        f"{used_j:.6f} J spent of hard limit {q.hard_joules} J"
                    )
            self.ingress_bytes += nbytes

    def observe(self, task: str, registry, ledger=None) -> None:
        """Post-operation soft-limit sweep: journal one ``quota_warning``
        anomaly per axis the first time usage crosses the soft line."""
        with self._lock:
            q = self.quota
            if q is None:
                return
            if q.soft_bytes is not None and not self._warned_bytes:
                used_b = self.bytes_used(ledger)
                if used_b > q.soft_bytes:
                    self._warned_bytes = True
                    registry.record_anomaly(
                        task,
                        f"quota_warning axis=bytes used={used_b} "
                        f"soft={q.soft_bytes}",
                    )
            if q.soft_joules is not None and not self._warned_joules:
                used_j = self.joules_used(ledger)
                if used_j > q.soft_joules:
                    self._warned_joules = True
                    registry.record_anomaly(
                        task,
                        f"quota_warning axis=joules used={used_j:.6f} "
                        f"soft={q.soft_joules}",
                    )

    # -- introspection -------------------------------------------------------
    def stats(self, ledger=None) -> dict:
        with self._lock:
            return {
                "tenant": self.tenant,
                "quota": self.quota.to_record() if self.quota else None,
                "ingress_bytes": self.ingress_bytes,
                "bytes_used": self.bytes_used(ledger),
                "joules_used": self.joules_used(ledger),
                "rejections": self.rejections,
                "soft_warned_bytes": self._warned_bytes,
                "soft_warned_joules": self._warned_joules,
            }
