"""Cross-tenant memo dedup — shared results, private provenance.

Tenants of one :class:`~repro.tenancy.WorkspaceHub` share the
content-addressed :class:`~repro.core.store.ArtifactStore`, so identical
payloads are already stored once. This module extends the sharing to
*compute*: a :class:`HubMemoStore` indexes every tenant's memo records by
their content key (software version + input content hashes + policy mode —
no tenant identity anywhere in the key), and a tenant's
:class:`TenantMemoCache` consults it on a local miss. When tenant B pushes
bytes tenant A already computed, B's task never runs: the hub hands back a
**dedup closure** (see ``ExecutionPlan.dedup`` in :mod:`repro.core.task`)
that replays A's output references out of the shared store.

The scoping rule that makes this safe for multi-tenant forensics:

* **Tenant-scoped provenance is written as if the tenant computed the
  result itself.** The replay flows through the ordinary
  ``finish_execution`` path — executed visit, freshly minted AVs, emitted
  visits, ledger charges, memo insert — so the tenant's lineage and
  visitor logs are byte-identical to a solo run and never mention the
  other tenant. Lineage/visitor-log reads stay strictly tenant-scoped.
* **The cross-tenant credit lives only at hub level.** ``credit`` journals
  a hub-scope ``cache_hit`` record naming beneficiary, origin tenant, and
  the origin run's AV uids (``memo_of``), and bumps the hub's
  ``executions_avoided``/``bytes_saved`` counters — the billing story that
  credits the original run without leaking it into anyone's workspace.
"""

from __future__ import annotations

import threading
from typing import Any, Optional

from repro.cache.memo import MemoCache


class HubMemoStore:
    """Hub-wide, first-writer-wins index of memo records by content key.

    Thread-safe: tenants insert and peek concurrently. Optionally writes
    through to the hub journal (``hub_memo`` on first offer per key,
    hub-scope ``cache_hit`` on every cross-tenant credit) so
    :meth:`WorkspaceHub.from_journal` can rebuild the dedup story.
    """

    def __init__(self) -> None:
        self._entries: dict = {}  # key -> {"tenant": origin, "record": memo}
        self._lock = threading.Lock()
        self._journal = None
        self.offers = 0
        self.dedup_hits = 0
        self.executions_avoided = 0
        self.bytes_saved = 0
        self.by_tenant: dict = {}  # beneficiary -> {"hits", "bytes_saved"}

    def bind_journal(self, journal) -> None:
        with self._lock:
            self._journal = journal

    # -- writes --------------------------------------------------------------
    def offer(self, tenant: str, key: str, record: Any) -> bool:
        """Register one tenant's memo record under its content key. First
        writer wins — later offers for the same key (same content ⇒ same
        outputs) are dropped, keeping the origin credit stable."""
        if not isinstance(record, dict) or not record.get("outputs"):
            return False
        with self._lock:
            self.offers += 1
            if key in self._entries:
                return False
            self._entries[key] = {"tenant": tenant, "record": record}
            if self._journal is not None:
                self._journal.append(
                    "hub_memo", {"tenant": tenant, "key": key, "record": record}
                )
            return True

    def restore_offer(self, tenant: str, key: str, record: Any) -> None:
        """Replay-side ``offer`` — no counters, no re-journaling."""
        with self._lock:
            self._entries.setdefault(key, {"tenant": tenant, "record": record})

    # -- reads ---------------------------------------------------------------
    def peek(self, key: str) -> Optional[dict]:
        with self._lock:
            return self._entries.get(key)

    def credit(self, key: str, entry: dict, beneficiary: str) -> int:
        """Account one cross-tenant dedup replay; returns bytes saved. The
        hub journal gets the only record that names both tenants."""
        record = entry.get("record") or {}
        saved = sum(int(n) for n in record.get("out_nbytes", {}).values())
        with self._lock:
            self.dedup_hits += 1
            self.executions_avoided += 1
            self.bytes_saved += saved
            bt = self.by_tenant.setdefault(
                beneficiary, {"hits": 0, "bytes_saved": 0}
            )
            bt["hits"] += 1
            bt["bytes_saved"] += saved
            if self._journal is not None:
                self._journal.append(
                    "cache_hit",
                    {
                        "scope": "hub",
                        "tenant": beneficiary,
                        "origin_tenant": entry.get("tenant"),
                        "key": key,
                        "memo_of": dict(record.get("out_uids", {})),
                        "bytes_saved": saved,
                    },
                )
        return saved

    def stats(self) -> dict:
        with self._lock:
            return {
                "entries": len(self._entries),
                "offers": self.offers,
                "dedup_hits": self.dedup_hits,
                "executions_avoided": self.executions_avoided,
                "bytes_saved": self.bytes_saved,
                "by_tenant": {t: dict(v) for t, v in self.by_tenant.items()},
            }


class TenantMemoCache(MemoCache):
    """A tenant's :class:`MemoCache` that shares results through the hub.

    ``lookup`` is untouched (tenant-scoped, journals into the tenant's own
    segment). ``insert`` additionally offers the record to the hub store.
    ``plan_dedup`` is the hook ``SmartTask._begin_execution`` consults after
    a *local* miss: it peeks the hub index and, when another tenant already
    computed this key, returns the replay closure the execution plan
    carries. Same-tenant entries return ``None`` — a tenant's own TTL
    expiry must recompute exactly as it would solo, or fingerprints drift.
    """

    def __init__(
        self,
        hub_store: HubMemoStore,
        tenant: str,
        default_ttl_s: Optional[float] = None,
    ) -> None:
        super().__init__(default_ttl_s)
        self._hub = hub_store
        self.tenant = tenant

    def insert(self, key: str, value: Any, ttl_s: Optional[float] = None) -> None:
        super().insert(key, value, ttl_s=ttl_s)
        self._hub.offer(self.tenant, key, value)

    def plan_dedup(self, key: str):
        entry = self._hub.peek(key)
        if entry is None or entry.get("tenant") == self.tenant:
            return None
        record = entry.get("record") or {}
        outputs = record.get("outputs") or {}
        if not outputs:
            return None
        hub, tenant = self._hub, self.tenant

        def _replay(store):
            # Every output must still be resolvable in the shared store; a
            # store-evicted origin falls through to a real run (closure
            # returns None, run_user_fn proceeds as if no dedup existed).
            refs = {}
            for oname, ref in outputs.items():
                uri, _chash = ref[0], ref[1]
                if not store.resolvable(uri):
                    return None
                refs[oname] = uri
            out = {oname: store.get(uri) for oname, uri in refs.items()}
            hub.credit(key, entry, beneficiary=tenant)
            return out

        return _replay
