"""Tenant determinism fingerprint — the isolation test's measuring stick.

``tenant_fingerprint(ws)`` serializes one workspace's complete forensic
story (AV graph, visitor logs, promises, design edges, anomalies, transfer
ledger) as canonical JSON, with every run-incidental quantity scrubbed:

* **uids** — AV uid numbers come from a process-global counter, so two
  tenants interleaving on one hub draw different numbers than a solo run
  would. Every uid reference (lineage parents, visit subjects, ``memo_of``
  pointers) is rewritten to the AV's *content hash*, which is identical
  wherever the bytes came from.
* **timestamps / wall clocks** — AV ``created_at``, stamp and visit
  timestamps, and ``wall=…`` notes vary per run; dropped or starred.
* **storage URIs** — artifacts identify by content hash; the URI *scheme*
  (``local://`` vs ``object://``) records which store tier a copy landed
  in, which on a shared hub depends on whether another tenant's identical
  bytes already occupied a tier at adoption time. Placement is a store
  artifact, not tenant provenance, so AV rows carry the chash only.
* **global event seqs** — visitor entries serialize as *per-task* logs in
  seq order, without the seq values. Within one task the event stream is
  totally ordered and backend-invariant; the cross-task interleaving is a
  wave-scheduling detail (thread pools race it, zoned executors partition
  waves by zone) that the engine's own determinism contract
  (``tests/test_topology._fingerprint``) likewise excludes.

What remains is exactly the paper's three provenance stories plus the
sustainability ledger — the content a tenant could subpoena. The tenancy
property test asserts this string is **byte-identical** between a tenant's
run on a shared hub and the same session script on a private solo hub,
under every executor backend.
"""

from __future__ import annotations

import json
import re


_UID_RE = re.compile(r"av-\d{8}-[0-9a-f]+")


def _scrub_note(note: str, uid_chash: dict) -> str:
    if note.startswith("wall="):
        return "wall=*"
    note = re.sub(r"pid=\d+", "pid=*", note)
    # uid references embedded in notes (e.g. ``memo_of=av-…``) rewrite to
    # the referenced AV's content hash, like every other uid in the doc
    return _UID_RE.sub(lambda m: uid_chash.get(m.group(0), "?"), note)


def _journey(stamps: list, uid_chash: dict) -> list:
    """Registration-time view of a travel document: stamps up to and
    including ``produced``. Later stamps (``consumed``, ``transit``) are
    link/task-side mutations that happen wherever the consumer ran — a
    worker process mutates its own copy — so they are neither
    backend-invariant nor journaled; the visitor log carries the
    consumption story instead."""
    out = []
    for s in stamps:
        out.append(
            [
                s["task"],
                s["event"],
                s["software_version"],
                s.get("region", "local"),
                _scrub_note(s.get("note", ""), uid_chash),
            ]
        )
        if s["event"] == "produced":
            break
    return out


def tenant_fingerprint(ws) -> str:
    """Canonical, uid-free, clock-free serialization of one workspace's
    forensic + ledger state. Works on live and journal-rehydrated
    workspaces alike (both expose a registry and a ledger)."""
    state = ws.registry.snapshot_state()
    uid_chash = {item["av"]["uid"]: item["av"]["chash"] for item in state["avs"]}

    def ref(uid):
        if uid == "-":
            return "-"
        return uid_chash.get(uid, "?")

    avs = []
    for item in state["avs"]:
        rec = item["av"]
        meta = dict(rec.get("meta") or {})
        if "memo_of" in meta:
            meta["memo_of"] = ref(meta["memo_of"])
        avs.append(
            {
                "task": rec["source_task"],
                "chash": rec["chash"],
                "region": rec.get("region", "local"),
                "meta": meta,
                "journey": _journey(rec.get("travel_document", []), uid_chash),
                "parents": [ref(p) for p in item.get("parents", [])],
            }
        )
    avs.sort(key=lambda row: json.dumps(row, sort_keys=True))
    # Per-task visitor logs: within a task the event stream is totally
    # ordered and backend-invariant; the global cross-task interleaving is
    # a wave-scheduling artifact and deliberately excluded (see module doc).
    visits: dict = {}
    for v in state["visits"]:
        visits.setdefault(v["task"], []).append(
            [
                ref(v["av_uid"]),
                v["event"],
                v["software_version"],
                _scrub_note(v.get("note", ""), uid_chash),
            ]
        )
    anomalies = sorted(
        (
            {"task": a.get("task"), "note": _scrub_note(a.get("note", ""), uid_chash)}
            for a in state.get("anomalies", [])
        ),
        key=lambda row: json.dumps(row, sort_keys=True),
    )
    ledger = None
    try:
        led = ws.ledger
    except Exception:
        led = None
    if led is not None:
        ledger = led.stats()
    doc = {
        "avs": avs,
        "visits": visits,
        "tasks": state.get("tasks") or {},
        "edges": state.get("edges") or [],
        "anomalies": anomalies,
        "ledger": ledger,
    }
    return json.dumps(doc, sort_keys=True, separators=(",", ":"))
