"""WorkspaceHub — the multi-tenant workspace control plane.

One hub hosts many named workspaces ("tenants") over **shared substrate**
and **private control state**:

* shared: one content-addressed :class:`~repro.core.store.ArtifactStore`
  (identical payloads stored once, hub-wide) and one
  :class:`~repro.tenancy.memo.HubMemoStore` (identical *computations* run
  once, hub-wide — see :mod:`repro.tenancy.memo` for the scoping rules);
* private: per-tenant :class:`~repro.workspace.Workspace` with its own
  registry (lineage/visitor-log reads are strictly tenant-scoped), its own
  executor, its own :class:`~repro.tenancy.quota.TenantMeter`, and its own
  journal *segment*.

Journal layout reuses the reserved-seq-window machinery the zoned runtime
already trusts: the hub owns one :class:`~repro.provenance.Journal` whose
monotonic counter is the **hub seq space**; each tenant gets a
``<hub>.seg-t-<name>`` journal constructed with ``seq_source=hub`` so every
tenant record carries a hub-unique seq, and zone-runner sub-segments
(``<hub>.seg-t-<name>.seg-<zone>``) nest for free because the runner
reserves windows through the tenant journal, which forwards to the hub.
One tenant's chain replays alone (``Workspace.from_journal``) for the
tenant-scoped story; all chains merge by seq for the operator's hub-wide
story (:meth:`WorkspaceHub.from_journal` → :class:`RehydratedHub`).

Memberships follow the EOEPCA workspace model the paper's ecosystem grew
into: a tenant workspace is (membership, storage, sessions) — here roles
``reader < writer < owner`` enforced per operation on a
:class:`TenantSession`, shared storage with tenant-scoped views, and
sessions bound to a (tenant, user) pair via :meth:`WorkspaceHub.workspace`
(``KOALJA_TENANT`` names the default tenant, mirroring how
``KOALJA_EXECUTOR`` names the default backend).
"""

from __future__ import annotations

import os
import re
import threading
from typing import Any, Callable, Optional

from repro.core.store import ArtifactStore
from repro.provenance import Journal, read_chain
from repro.workspace import Workspace

from .fingerprint import tenant_fingerprint
from .memo import HubMemoStore, TenantMemoCache
from .quota import (
    PermissionDeniedError,
    TenancyError,
    TenantMeter,
    TenantQuota,
)

ROLES = ("reader", "writer", "owner")
_RANK = {role: i for i, role in enumerate(ROLES)}


def _safe(name: str) -> str:
    return re.sub(r"[^A-Za-z0-9_.-]", "-", name)


def _zone_segments(base: str) -> list:
    """Discover a tenant segment's zone-runner sub-segments on disk:
    ``<base>.seg-<zone>`` files, excluding rotated parts (``.NNNN``),
    checkpoints, and temp files — mirror of what the tenant's own
    ``executor.segment_paths()`` would have answered live."""
    parent = os.path.dirname(base) or "."
    prefix = os.path.basename(base) + ".seg-"
    out = []
    try:
        names = os.listdir(parent)
    except OSError:
        return out
    for name in names:
        if not name.startswith(prefix):
            continue
        rest = name[len(prefix):]
        if ".ckpt-" in rest or rest.endswith(".tmp"):
            continue
        if re.search(r"\.\d{4,}$", rest):
            continue  # rotated part of a sub-segment; chain-read from base
        out.append(os.path.join(parent, name))
    return sorted(out)


class _Tenant:
    """Hub-internal record for one hosted workspace."""

    def __init__(self, name: str, ws: Workspace, owner: str, meter: TenantMeter,
                 segment: Optional[str]) -> None:
        self.name = name
        self.ws = ws
        self.members = {owner: "owner"}
        self.meter = meter
        self.segment = segment  # basename of the tenant journal, or None


class WorkspaceHub:
    """Host thousands of named workspaces over one store + one seq space."""

    def __init__(
        self,
        name: str = "hub",
        *,
        store: Optional[ArtifactStore] = None,
        journal_path=None,
        journal_flush_every_n: Optional[int] = None,
        default_quota: Optional[TenantQuota] = None,
        executor_factory: Optional[Callable[[], Any]] = None,
        workspace_defaults: Optional[dict] = None,
    ) -> None:
        self.name = name
        self.store = store or ArtifactStore()
        self._flush_every_n = journal_flush_every_n
        self._journal = self._make_journal(journal_path, journal_flush_every_n)
        self.memo = HubMemoStore()
        if self._journal is not None:
            self.memo.bind_journal(self._journal)
        self.default_quota = default_quota
        # executor_factory builds one *fresh* executor per tenant (executors
        # bind to a single manager); None -> each Workspace defers to
        # KOALJA_EXECUTOR exactly as a standalone one would.
        self._executor_factory = executor_factory
        self._ws_defaults = dict(workspace_defaults or {})
        self._tenants: dict = {}
        self._lock = threading.RLock()

    def _make_journal(self, journal_path, flush_every_n):
        # Same env contract as Workspace._make_journal: False -> off,
        # a Journal instance -> adopt, None -> defer to KOALJA_JOURNAL.
        if journal_path is False:
            return None
        if hasattr(journal_path, "append_batch"):
            return journal_path
        if journal_path is None:
            env = os.environ.get("KOALJA_JOURNAL", "").strip()
            if env.lower() in ("", "0", "false", "no", "off"):
                return None
            import tempfile
            import uuid

            if env.lower() in ("1", "true", "yes", "on"):
                root = os.path.join(tempfile.gettempdir(), "koalja-journals")
            else:
                root = env
            os.makedirs(root, exist_ok=True)
            journal_path = os.path.join(
                root, f"{self.name}-hub-{os.getpid()}-{uuid.uuid4().hex[:8]}.jsonl"
            )
        return Journal(journal_path, flush_every_n=flush_every_n, workspace=self.name)

    @property
    def journal(self):
        return self._journal

    # -- tenant lifecycle ----------------------------------------------------
    def create(
        self,
        name: str,
        owner: str,
        *,
        quota: Optional[TenantQuota] = None,
        **ws_kwargs: Any,
    ) -> "TenantSession":
        """Provision a tenant workspace; returns the owner's session."""
        with self._lock:
            if name in self._tenants:
                raise TenancyError(f"tenant {name!r} already exists")
            safe = _safe(name)
            if any(_safe(t) == safe for t in self._tenants):
                raise TenancyError(
                    f"tenant {name!r} collides with an existing tenant's "
                    f"segment name {safe!r}"
                )
            tjournal = None
            segment = None
            if self._journal is not None:
                seg_path = f"{self._journal.path}.seg-t-{safe}"
                tjournal = Journal(
                    seg_path,
                    flush_every_n=self._flush_every_n,
                    workspace=name,
                    seq_source=self._journal,
                )
                segment = os.path.basename(seg_path)
            kw = dict(self._ws_defaults)
            kw.update(ws_kwargs)
            executor = kw.pop("executor", None)
            if executor is None and self._executor_factory is not None:
                executor = self._executor_factory()
            cache = kw.pop("cache", None)
            if cache is None:
                cache = TenantMemoCache(self.memo, tenant=name)
            ws = Workspace(
                name,
                executor=executor,
                store=self.store,
                cache=cache,
                journal_path=tjournal if tjournal is not None else False,
                **kw,
            )
            q = quota if quota is not None else self.default_quota
            tenant = _Tenant(name, ws, owner, TenantMeter(name, q), segment)
            self._tenants[name] = tenant
            if self._journal is not None:
                self._journal.append(
                    "tenant",
                    {
                        "name": name,
                        "owner": owner,
                        "segment": segment,
                        "quota": q.to_record() if q is not None else None,
                    },
                )
            return TenantSession(self, tenant, owner)

    def _tenant(self, name: str) -> _Tenant:
        t = self._tenants.get(name)
        if t is None:
            raise TenancyError(f"no tenant named {name!r} on hub {self.name!r}")
        return t

    def tenants(self) -> list:
        with self._lock:
            return sorted(self._tenants)

    # -- memberships ---------------------------------------------------------
    def role_of(self, tenant: str, user: str) -> Optional[str]:
        with self._lock:
            return self._tenant(tenant).members.get(user)

    def _require(self, tenant: "_Tenant", user: str, role: str) -> None:
        have = tenant.members.get(user)
        if have is None or _RANK[have] < _RANK[role]:
            raise PermissionDeniedError(
                f"user {user!r} needs role {role!r} on tenant "
                f"{tenant.name!r} (has {have!r})"
            )

    def grant(self, tenant: str, user: str, role: str, *, by: str) -> None:
        if role not in _RANK:
            raise TenancyError(f"unknown role {role!r} (choose from {ROLES})")
        with self._lock:
            t = self._tenant(tenant)
            self._require(t, by, "owner")
            if (
                t.members.get(user) == "owner"
                and role != "owner"
                and sum(1 for r in t.members.values() if r == "owner") == 1
            ):
                raise TenancyError(
                    f"cannot demote {user!r}: last owner of {tenant!r}"
                )
            t.members[user] = role
            if self._journal is not None:
                self._journal.append(
                    "grant", {"tenant": tenant, "user": user, "role": role, "by": by}
                )

    def revoke(self, tenant: str, user: str, *, by: str) -> None:
        with self._lock:
            t = self._tenant(tenant)
            self._require(t, by, "owner")
            if user not in t.members:
                raise TenancyError(f"{user!r} is not a member of {tenant!r}")
            if (
                t.members[user] == "owner"
                and sum(1 for r in t.members.values() if r == "owner") == 1
            ):
                raise TenancyError(
                    f"cannot revoke {user!r}: last owner of {tenant!r}"
                )
            del t.members[user]
            if self._journal is not None:
                self._journal.append(
                    "revoke_grant", {"tenant": tenant, "user": user, "by": by}
                )

    def set_quota(self, tenant: str, quota: Optional[TenantQuota], *, by: str) -> None:
        with self._lock:
            t = self._tenant(tenant)
            self._require(t, by, "owner")
            t.meter.quota = quota
            if self._journal is not None:
                self._journal.append(
                    "quota",
                    {
                        "tenant": tenant,
                        "quota": quota.to_record() if quota is not None else None,
                        "by": by,
                    },
                )

    # -- sessions ------------------------------------------------------------
    def workspace(
        self, name: Optional[str] = None, user: Optional[str] = None
    ) -> "TenantSession":
        """Open a session on a tenant workspace. ``name=None`` reads the
        ``KOALJA_TENANT`` env var; ``user=None`` binds as the tenant's
        (first) owner."""
        if name is None:
            name = os.environ.get("KOALJA_TENANT", "").strip() or None
        if name is None:
            raise TenancyError(
                "no tenant named and KOALJA_TENANT is unset — pass "
                "hub.workspace('tenant-name') or export KOALJA_TENANT"
            )
        with self._lock:
            t = self._tenant(name)
            if user is None:
                owners = sorted(u for u, r in t.members.items() if r == "owner")
                user = owners[0]
            if user not in t.members:
                raise PermissionDeniedError(
                    f"user {user!r} is not a member of tenant {name!r}"
                )
            return TenantSession(self, t, user)

    # -- hub-wide operations -------------------------------------------------
    def flush(self) -> None:
        """Flush every tenant segment, then the hub journal."""
        with self._lock:
            tenants = list(self._tenants.values())
        for t in tenants:
            if t.ws.journal is not None:
                t.ws.journal.flush()
        if self._journal is not None:
            self._journal.flush()

    def shutdown(self) -> None:
        """Stop tenant executors and flush all journals (hub stays usable;
        executors refork lazily on the next wave)."""
        with self._lock:
            tenants = list(self._tenants.values())
        for t in tenants:
            stop = getattr(t.ws.executor, "shutdown", None)
            if stop is not None:
                stop()
        self.flush()

    def stats(self) -> dict:
        with self._lock:
            tenants = dict(self._tenants)
        out = {
            "hub": self.name,
            "tenants": len(tenants),
            "memberships": sum(len(t.members) for t in tenants.values()),
            "memo": self.memo.stats(),
            "store": self.store.stats(),
            "by_tenant": {
                name: t.meter.stats(
                    t.ws._manager.ledger if t.ws._manager is not None else None
                )
                for name, t in tenants.items()
            },
        }
        if self._journal is not None:
            out["journal"] = self._journal.stats()
        return out

    @classmethod
    def from_journal(cls, path: str) -> "RehydratedHub":
        """Rehydrate the hub control plane (tenants, grants, quotas, the
        cross-tenant dedup story) plus per-tenant forensic workspaces from
        a hub journal chain written by a previous process."""
        return RehydratedHub(path)


class TenantSession:
    """Role-enforced facade over one tenant's Workspace.

    Every operation checks the binding user's role first (reader for
    forensic reads, writer for anything that moves data or edits the
    circuit, owner for compaction and membership/quota changes — those last
    two live on the hub), then meters quota around the engine call. The
    underlying Workspace is never handed out by accident: escape through
    ``.ws`` is deliberate and bypasses the control plane.
    """

    def __init__(self, hub: WorkspaceHub, tenant: _Tenant, user: str) -> None:
        self._hub = hub
        self._t = tenant
        self.tenant = tenant.name
        self.user = user

    # deliberate escape hatch (no enforcement beyond this point)
    @property
    def ws(self) -> Workspace:
        return self._t.ws

    @property
    def role(self) -> Optional[str]:
        return self._t.members.get(self.user)

    def _require(self, role: str) -> None:
        self._hub._require(self._t, self.user, role)

    def _ledger(self):
        # Only consult a ledger that already exists: touching ws.ledger
        # would build (and freeze) the circuit mid-declaration. Before the
        # first build the ledger is empty anyway, so the meter reading is
        # identical.
        mgr = self._t.ws._manager
        return mgr.ledger if mgr is not None else None

    # -- breadboard (writer) -------------------------------------------------
    def task(self, *args: Any, **kwargs: Any):
        self._require("writer")
        return self._t.ws.task(*args, **kwargs)

    def source(self, *args: Any, **kwargs: Any):
        self._require("writer")
        return self._t.ws.source(*args, **kwargs)

    def wire(self, *args: Any, **kwargs: Any):
        self._require("writer")
        return self._t.ws.wire(*args, **kwargs)

    def implicit(self, *args: Any, **kwargs: Any):
        self._require("writer")
        return self._t.ws.implicit(*args, **kwargs)

    def __getitem__(self, task: str):
        self._require("reader")
        return self._t.ws[task]

    # -- runtime (writer, metered) -------------------------------------------
    def push(self, task, *, region: str = "local", **payloads: Any):
        self._require("writer")
        ws = self._t.ws
        nbytes = sum(ArtifactStore._nbytes(p) for p in payloads.values())
        self._t.meter.charge_ingress(
            nbytes, ws._name_of(task), ws.registry, self._ledger()
        )
        out = ws.push(task, region=region, **payloads)
        self._t.meter.observe(ws._name_of(task), ws.registry, self._ledger())
        return out

    def inject(self, task, input_name: str, payload: Any, *, region: str = "local"):
        self._require("writer")
        ws = self._t.ws
        self._t.meter.charge_ingress(
            ArtifactStore._nbytes(payload), ws._name_of(task), ws.registry,
            self._ledger(),
        )
        out = ws.inject(task, input_name, payload, region=region)
        self._t.meter.observe(ws._name_of(task), ws.registry, self._ledger())
        return out

    def sample(self, source):
        self._require("writer")
        ws = self._t.ws
        out = ws.sample(source)
        self._t.meter.observe(ws._name_of(source), ws.registry, self._ledger())
        return out

    def ghost(self, *args: Any, **kwargs: Any):
        self._require("writer")
        return self._t.ws.ghost(*args, **kwargs)

    # -- runtime (reader) ----------------------------------------------------
    def pull(self, target):
        self._require("reader")
        ws = self._t.ws
        out = ws.pull(target)
        self._t.meter.observe(ws._name_of(target), ws.registry, self._ledger())
        return out

    def watch(self, target, callback: Optional[Callable] = None):
        self._require("reader")
        return self._t.ws.watch(target, callback)

    # -- forensics (reader; strictly tenant-scoped) --------------------------
    def value_of(self, av):
        self._require("reader")
        return self._t.ws.value_of(av)

    def lineage(self, av):
        self._require("reader")
        return self._t.ws.lineage(av)

    def visitor_log(self, task):
        self._require("reader")
        return self._t.ws.visitor_log(task)

    def traveller_log(self, av):
        self._require("reader")
        return self._t.ws.traveller_log(av)

    def design_map(self):
        self._require("reader")
        return self._t.ws.design_map()

    def stats(self) -> dict:
        self._require("reader")
        return self._t.ws.stats()

    def quota_stats(self) -> dict:
        self._require("reader")
        return self._t.meter.stats(self._ledger())

    def fingerprint(self) -> str:
        self._require("reader")
        return tenant_fingerprint(self._t.ws)

    # -- maintenance (owner) -------------------------------------------------
    def compact_journal(self, **kwargs: Any) -> dict:
        self._require("owner")
        return self._t.ws.compact_journal(**kwargs)


class RehydratedHub:
    """Forensic view of a hub journal chain: the control-plane story (who
    owned what, which grants and quotas applied, which pushes deduped
    against whose runs) plus per-tenant workspace rehydration — each tenant
    replays **alone** from its own segment chain, so the isolation contract
    survives rehydration too. :meth:`merged_workspace` is the operator's
    escape hatch: every segment merged into one hub-wide registry."""

    def __init__(self, path: str) -> None:
        self.path = path
        records, self.truncated, self.chain = read_chain(path)
        self.memberships: dict = {}  # tenant -> {user: role}
        self.quotas: dict = {}
        self.segments: dict = {}  # tenant -> segment basename (or None)
        self.memo = HubMemoStore()
        self.dedup_events: list = []
        for r in records:
            kind, data = r.get("kind"), r.get("data") or {}
            if kind == "tenant":
                name = data.get("name")
                self.memberships[name] = {data.get("owner"): "owner"}
                self.segments[name] = data.get("segment")
                self.quotas[name] = TenantQuota.from_record(data.get("quota"))
            elif kind == "grant":
                self.memberships.setdefault(data.get("tenant"), {})[
                    data.get("user")
                ] = data.get("role")
            elif kind == "revoke_grant":
                self.memberships.get(data.get("tenant"), {}).pop(
                    data.get("user"), None
                )
            elif kind == "quota":
                self.quotas[data.get("tenant")] = TenantQuota.from_record(
                    data.get("quota")
                )
            elif kind == "hub_memo":
                self.memo.restore_offer(
                    data.get("tenant"), data.get("key"), data.get("record")
                )
            elif kind == "cache_hit" and data.get("scope") == "hub":
                self.dedup_events.append(dict(data))

    def tenants(self) -> list:
        return sorted(self.memberships)

    def _segment_path(self, tenant: str) -> str:
        seg = self.segments.get(tenant)
        if seg is None:
            raise TenancyError(
                f"tenant {tenant!r} has no journal segment in {self.path!r}"
            )
        return os.path.join(os.path.dirname(self.path) or ".", seg)

    def workspace(self, tenant: str) -> Workspace:
        """Rehydrate one tenant's workspace from its own chain only."""
        if tenant not in self.memberships:
            raise TenancyError(f"no tenant named {tenant!r} in {self.path!r}")
        base = self._segment_path(tenant)
        zones = _zone_segments(base)
        if zones:
            return Workspace.from_journal([base, *zones])
        return Workspace.from_journal(base)

    def merged_workspace(self) -> Workspace:
        """Operator view: all tenants' records merged into one registry by
        hub seq. Crosses tenant boundaries by design — gate access to this
        the way you would gate root."""
        segs: list = []
        for tenant in self.tenants():
            if self.segments.get(tenant) is None:
                continue
            base = self._segment_path(tenant)
            segs.append(base)
            segs.extend(_zone_segments(base))
        return Workspace.from_journal([self.path, *segs])
