"""The Workspace facade — one typed entry point over the Koalja circuit.

The paper's promise is that users wire plugin code on a breadboard and
promote it "with a minimum of infrastructure knowledge". The seed exposed
four disjoint idioms (``Pipeline.add_task``/``connect``,
``PipelineManager.push/pull/inject``, ``parse_wiring``, ``EvalLoop``); this
facade subsumes them:

    ws = Workspace("demo")
    camera = ws.source(read_sensor, name="camera", outputs=["image"])
    detect = ws.task(detect_fn, name="detect", inputs=["frame"],
                     outputs=["boxes"])
    camera["image"] >> detect["frame"]          # typed operator wiring
    detect["frame"].buffer(10, slide=2)         # the paper's [N/k]

    ws.push(camera, image=img)                  # reactive (event-driven)
    boxes = ws.pull(detect)["boxes"]            # make-mode (result-oriented)

Both trigger modes run on the *same* engine (PipelineManager) — the facade
adds types, declarativity, and a pluggable executor backend
(:class:`InlineExecutor` in-process today, :class:`MeshExecutor` on a JAX
mesh through ``repro.dist``), not new semantics. Provenance (travel
documents, visitor logs, design map) is captured on every run and queryable
from the same object.
"""

from __future__ import annotations

import os
from typing import Any, Callable, Iterable, Mapping, Optional, Union

from repro.cache import MemoCache
from repro.core.av import AnnotatedValue
from repro.core.pipeline import Pipeline, PipelineManager
from repro.core.policy import InputSpec
from repro.core.provenance import ProvenanceRegistry
from repro.core.store import ArtifactStore
from repro.core.task import ServiceCall, SmartTask
from repro.topology import Topology, default_topology

from .executors import Executor, InlineExecutor, default_executor
from .handles import Port, TaskDecl, TaskHandle, Wire, WireDecl, WiringError

TaskRef = Union[str, TaskHandle, Port]


class WorkspaceFrozenError(RuntimeError):
    """Structural edit after the circuit was materialized."""


class TaskResult(Mapping):
    """Outputs of one task firing: ``result["out"]`` is the payload;
    ``result.av("out")`` is the AnnotatedValue (provenance handle)."""

    def __init__(self, ws: "Workspace", task: str, out_avs: dict) -> None:
        self._ws = ws
        self.task = task
        self._avs = dict(out_avs)

    def __getitem__(self, output: str) -> Any:
        return self._ws.value_of(self._avs[output])

    def __iter__(self):
        return iter(self._avs)

    def __len__(self) -> int:
        return len(self._avs)

    def av(self, output: str) -> AnnotatedValue:
        return self._avs[output]

    @property
    def avs(self) -> dict:
        return dict(self._avs)

    def lineage(self, output: str) -> dict:
        return self._ws.registry.lineage(self._avs[output].uid)

    def __repr__(self) -> str:
        return f"TaskResult({self.task}: {sorted(self._avs)})"


class RunResult(Mapping):
    """Everything that fired during one reactive run, keyed by task name.
    ``run[task]`` is the latest :class:`TaskResult` of that task."""

    def __init__(self, ws: "Workspace", fired: dict) -> None:
        self._ws = ws
        self._fired = fired  # task -> [ {output: AV} ]

    def __getitem__(self, task: TaskRef) -> TaskResult:
        name = self._ws._name_of(task)
        return TaskResult(self._ws, name, self._fired[name][-1])

    def __iter__(self):
        return iter(self._fired)

    def __len__(self) -> int:
        return len(self._fired)

    def firings(self, task: TaskRef) -> list:
        name = self._ws._name_of(task)
        return [
            TaskResult(self._ws, name, avs) for avs in self._fired.get(name, [])
        ]

    def value(self, task: TaskRef, output: str) -> Any:
        return self[task][output]

    def __repr__(self) -> str:
        return f"RunResult(fired={sorted(self._fired)})"


class Watcher:
    """Reactive observer on a task's output: collects a TaskResult per
    firing and invokes the callback (the facade's replacement for hand-rolled
    EvalLoop-style polling)."""

    def __init__(self, ws: "Workspace", task: str, callback: Optional[Callable]) -> None:
        self._ws = ws
        self.task = task
        self.callback = callback
        self.events: list = []
        self.active = True

    def _notify(self, result: TaskResult) -> None:
        if not self.active:
            return
        self.events.append(result)
        if self.callback is not None:
            self.callback(result)

    def latest(self) -> Optional[TaskResult]:
        return self.events[-1] if self.events else None

    def cancel(self) -> None:
        self.active = False


class Workspace:
    """Declarative breadboard + typed runtime over the Koalja engine."""

    def __init__(
        self,
        name: str = "workspace",
        *,
        executor: Optional[Executor] = None,
        store: Optional[ArtifactStore] = None,
        registry: Optional[ProvenanceRegistry] = None,
        cache=None,
        max_rounds: int = 100,
        topology: Union[Topology, bool, None] = None,
        placement=None,
        journal_path: Union[str, bool, None] = None,
        journal_flush_every_n: Optional[int] = None,
        journal_rotate_bytes: Optional[int] = None,
        journal_rotate_records: Optional[int] = None,
    ) -> None:
        self.name = name
        # executor=None defers to KOALJA_EXECUTOR (inline | concurrent) so
        # whole suites can smoke the threaded scheduler path via env.
        self.executor = executor or default_executor()
        # topology=None defers to KOALJA_TOPOLOGY (flat | 3zone);
        # topology=False forces flat regardless of env. placement is
        # "pin" | "data_gravity" | a PlacementPolicy; None defers to
        # KOALJA_PLACEMENT, then to the data_gravity default.
        if topology is False:
            self._topology = None
        else:
            self._topology = topology if topology is not None else default_topology()
        self._placement = (
            placement
            if placement is not None
            else (os.environ.get("KOALJA_PLACEMENT", "").strip().lower() or None)
        )
        # Validate the policy *name* now, at construction — not lazily at
        # first build (and never at all on flat circuits, where a typo'd
        # KOALJA_PLACEMENT used to be silently ignored).
        if isinstance(self._placement, str):
            from repro.topology.placement import _POLICIES

            if self._placement not in _POLICIES:
                source = (
                    "placement=" if placement is not None else "KOALJA_PLACEMENT="
                )
                raise ValueError(
                    f"{source}{self._placement!r} is not a known placement "
                    f"policy (choose from {' | '.join(sorted(_POLICIES))})"
                )
        self._store = store or ArtifactStore()
        self._registry = registry or ProvenanceRegistry()
        # cache=None -> default MemoCache; cache=False -> caching disabled
        self._cache = MemoCache() if cache is None else cache
        # journal_path=None defers to KOALJA_JOURNAL ("1" -> a per-workspace
        # file under the system tempdir; any other non-empty value -> a
        # directory to create per-workspace journals in); journal_path=False
        # forces the journal off regardless of env.
        self._journal = self._make_journal(
            journal_path,
            journal_flush_every_n,
            journal_rotate_bytes,
            journal_rotate_records,
        )
        self._replay = None  # set by from_journal (rehydrated workspaces)
        self._max_rounds = max_rounds
        self._decls: dict = {}
        self._wires: list = []
        self._implicit_edges: list = []
        self._handles: dict = {}
        self._manager: Optional[PipelineManager] = None
        self._watchers: list = []

    def _make_journal(
        self, journal_path, flush_every_n, rotate_bytes=None, rotate_records=None
    ):
        if journal_path is False:
            return None
        if hasattr(journal_path, "append_batch"):
            # a pre-built Journal instance (multi-tenant hubs hand each
            # workspace a per-tenant journal drawing seqs from the hub)
            return journal_path
        if journal_path is None:
            env = os.environ.get("KOALJA_JOURNAL", "").strip()
            if env.lower() in ("", "0", "false", "no", "off"):
                return None
            import tempfile

            if env.lower() in ("1", "true", "yes", "on"):
                root = os.path.join(tempfile.gettempdir(), "koalja-journals")
            else:
                root = env  # a directory to keep per-workspace journals in
            os.makedirs(root, exist_ok=True)
            import uuid

            journal_path = os.path.join(
                root, f"{self.name}-{os.getpid()}-{uuid.uuid4().hex[:8]}.jsonl"
            )
        from repro.provenance import Journal

        return Journal(
            journal_path,
            flush_every_n=flush_every_n,
            workspace=self.name,
            rotate_bytes=rotate_bytes,
            rotate_records=rotate_records,
        )

    @classmethod
    def from_journal(cls, path, **ws_kwargs: Any) -> "Workspace":
        """Rehydrate the forensic stories from a provenance journal written
        by a previous (possibly crashed) process.

        ``path`` is a journal *base* path — the whole segment chain is
        discovered from it: rotated segments (``<path>.000N``), the best
        checkpoint snapshot (``<path>.ckpt-*``, if the journal was
        compacted), and the live tail replay as one seq-ordered stream, so
        restart cost after compaction is checkpoint + tail rather than full
        history. For a multi-process run under
        :class:`~repro.runtime.ZonedProcessExecutor`, pass a list/tuple of
        ``[main_journal, *runner_segments]``: the segments merge back into
        one seq-ordered stream before replay
        (:func:`repro.provenance.replay_segments`).

        The returned workspace holds a replayed registry — ``lineage()``,
        ``visitor_log()``, ``design_map()``, ``visits_of`` and, when the run
        had a topology, ``stats()["topology"]["ledger"]`` answer exactly as
        the writing process would have (a torn final line from a mid-write
        crash is detected and dropped, per file). It is a forensic view,
        not a runnable circuit: the journal records events, not user code,
        so declare tasks on a fresh Workspace to compute again."""
        from repro.provenance import replay_journal, replay_segments

        if isinstance(path, (list, tuple)):
            main, *segments = path
            replayed = replay_segments(main, segments)
        else:
            replayed = replay_journal(path)
        ws = cls(
            name=replayed.workspace or "rehydrated",
            registry=replayed.registry,
            topology=False,  # the replayed ledger is the topology story
            cache=False,
            journal_path=False,  # rehydration must never re-journal history
            **ws_kwargs,
        )
        ws._replay = replayed
        return ws

    # ------------------------------------------------------------------
    # breadboard: declaring tasks and wires
    # ------------------------------------------------------------------

    def _assert_mutable(self) -> None:
        if self._manager is not None:
            raise WorkspaceFrozenError(
                "the circuit is already materialized — a run (push/pull/"
                "sample) or an engine access (.pipeline, .stats(), "
                ".design_map()) happened; declare tasks, wires, and buffers "
                "before that"
            )

    def task(
        self,
        fn: Optional[Callable] = None,
        *,
        name: Optional[str] = None,
        inputs: Iterable = (),
        outputs: Iterable = ("out",),
        mode: str = "all_new",
        region: str = "local",
        source: Optional[bool] = None,
        services: Optional[dict] = None,
        min_interval_s: float = 0.0,
        cache_ttl_s: Optional[float] = None,
    ) -> TaskHandle:
        """Declare a task (direct call or decorator). Inputs accept the
        paper's ``name[N]`` / ``name[N/k]`` annotations."""

        def register(f: Callable) -> TaskHandle:
            self._assert_mutable()
            tname = name or f.__name__
            if tname in self._decls:
                raise WiringError(f"duplicate task {tname!r}")
            specs = [
                s if isinstance(s, InputSpec) else InputSpec.parse(s) for s in inputs
            ]
            decl = TaskDecl(
                name=tname,
                fn=f,
                inputs=specs,
                outputs=list(outputs),
                mode=mode,
                region=region,
                source=(len(specs) == 0) if source is None else bool(source),
                services=dict(services) if services else None,
                min_interval_s=min_interval_s,
                cache_ttl_s=cache_ttl_s,
            )
            self._decls[tname] = decl
            handle = TaskHandle(self, decl)
            self._handles[tname] = handle
            return handle

        return register if fn is None else register(fn)

    def source(
        self,
        fn: Optional[Callable] = None,
        *,
        name: Optional[str] = None,
        outputs: Iterable = ("out",),
        **kwargs: Any,
    ) -> TaskHandle:
        """Declare an edge sensor: no inputs, fires when sampled/pulled."""
        return self.task(fn, name=name, inputs=(), outputs=outputs, source=True, **kwargs)

    def wire(self, src: Port, dst: Port, **link_kwargs: Any) -> Wire:
        """Connect an output port to an input port (``>>`` sugar calls this)."""
        self._assert_mutable()
        if src.direction != "out" or dst.direction != "in":
            raise WiringError(
                f"wire needs (output, input) ports, got "
                f"({src.direction}, {dst.direction})"
            )
        decl = WireDecl(
            src_task=src.task.name,
            output=src.name,
            dst_task=dst.task.name,
            dst_input=dst.name,
            link_kwargs=dict(link_kwargs),
        )
        self._wires.append(decl)
        return Wire(self, decl)

    def implicit(self, service: str, task: TaskRef) -> None:
        """Record a client-server side channel in the design map (§III.D)."""
        self._assert_mutable()
        self._implicit_edges.append((service, self._name_of(task)))

    @classmethod
    def from_wiring(
        cls,
        text: str,
        impls: dict,
        *,
        default_mode: str = "all_new",
        modes: Optional[dict] = None,
        **ws_kwargs: Any,
    ) -> "Workspace":
        """Build a Workspace from the paper's breadboard DSL (fig. 5) —
        the wiring language becomes one constructor.

        The parsed circuit is lifted back into *declarations*, so the
        result is indistinguishable from a hand-built breadboard: ports,
        ``.buffer(...)`` edits, and extra wires all still work before the
        first run."""
        from repro.core.wiring import build_wiring

        ws = cls(**ws_kwargs)
        pipe = build_wiring(text, impls, default_mode=default_mode, modes=modes)
        ws.name = pipe.name
        ws._implicit_edges = list(getattr(pipe, "implicit_edges", []))
        for t in pipe.tasks.values():
            decl = TaskDecl(
                name=t.name,
                fn=t.fn,
                inputs=list(t.input_specs),
                outputs=list(t.outputs),
                mode=t.policy.mode,
                region=t.region,
                source=t.source,
                services=dict(t.services) if t.services else None,
                min_interval_s=t.policy.min_interval_s,
                cache_ttl_s=t.cache_ttl_s,
            )
            ws._decls[t.name] = decl
            ws._handles[t.name] = TaskHandle(ws, decl)
        for t in pipe.tasks.values():
            for oname, links in t.out_links.items():
                for link in links:
                    ws._wires.append(
                        WireDecl(
                            src_task=t.name,
                            output=oname,
                            dst_task=link.dst_task,
                            dst_input=link.dst_input,
                            link_kwargs={
                                "region": link.region,
                                "fenced_regions": link.fenced_regions,
                                "notify_threshold_s": link.notify_threshold_s,
                            },
                        )
                    )
        return ws

    def __getitem__(self, task: str) -> TaskHandle:
        try:
            return self._handles[task]
        except KeyError:
            raise KeyError(
                f"no task {task!r} in workspace {self.name!r} "
                f"(tasks: {sorted(self._handles)})"
            ) from None

    # ------------------------------------------------------------------
    # materialization
    # ------------------------------------------------------------------

    def _build(self) -> PipelineManager:
        if self._manager is not None:
            return self._manager
        pipe = Pipeline(self.name)
        for decl in self._decls.values():
            pipe._add_task(
                SmartTask(
                    name=decl.name,
                    fn=decl.fn,
                    inputs=list(decl.inputs),
                    outputs=list(decl.outputs),
                    mode=decl.mode,
                    region=decl.region,
                    source=decl.source,
                    services=decl.services,
                    min_interval_s=decl.min_interval_s,
                    cache_ttl_s=decl.cache_ttl_s,
                    zone=decl.zone,
                    coalesce_max=decl.coalesce_max,
                )
            )
        for w in self._wires:
            pipe._connect(w.src_task, w.output, w.dst_task, w.dst_input, **w.link_kwargs)
        pipe.implicit_edges = list(self._implicit_edges)
        self._manager = PipelineManager(
            pipe,
            store=self._store,
            registry=self._registry,
            cache=self._cache,
            max_rounds=self._max_rounds,
            # the scheduler hands waves of ready tasks to this backend
            executor=self.executor,
            topology=self._topology,
            placement=self._placement,
            journal=self._journal,
        )
        return self._manager

    def validate(self) -> list:
        """Unwired-input problems (empty list = breadboard is complete).

        Works on the declarations, so the breadboard stays editable: fix
        the reported problems and validate again before the first run."""
        if self._manager is not None:
            return self._manager.pipeline.validate()
        wired = {(w.dst_task, w.dst_input) for w in self._wires}
        problems = []
        for decl in self._decls.values():
            if decl.source:
                continue
            for spec in decl.inputs:
                if (decl.name, spec.name) not in wired:
                    problems.append(f"{decl.name}.{spec.name} unwired")
        return problems

    def _name_of(self, task: TaskRef) -> str:
        if isinstance(task, TaskHandle):
            return task.name
        if isinstance(task, Port):
            return task.task.name
        return str(task)

    # ------------------------------------------------------------------
    # runtime: the two trigger modes (one engine)
    # ------------------------------------------------------------------

    def push(self, task: TaskRef, *, region: str = "local", **payloads: Any) -> RunResult:
        """Reactive mode: deliver payloads to the task's inputs and let the
        event drive computation downstream."""
        mgr = self._build()
        fired = self.executor.push(mgr, self._name_of(task), payloads, region)
        self._notify_watchers(fired)
        return RunResult(self, fired)

    def sample(self, source: TaskRef) -> RunResult:
        """Fire an edge sensor once and propagate."""
        mgr = self._build()
        fired = self.executor.sample(mgr, self._name_of(source))
        self._notify_watchers(fired)
        return RunResult(self, fired)

    def pull(self, target: TaskRef) -> TaskResult:
        """Make mode: name the result you want; dependencies rebuild
        backwards, unchanged subtrees resolve as cache hits."""
        mgr = self._build()
        name = self._name_of(target)
        before = self._watch_counts(mgr)
        out = self.executor.pull(mgr, name)
        # watchers observe make-mode firings too (fresh AVs, incl. cache
        # hits, are events — the EvalLoop contract)
        for w in self._watchers:
            if not w.active:
                continue
            t = mgr.pipeline.tasks.get(w.task)
            if t is not None and self._fire_count(t) > before.get(w.task, 0):
                if t.last_outputs:
                    w._notify(TaskResult(self, w.task, dict(t.last_outputs)))
        return TaskResult(self, name, out)

    def inject(
        self, task: TaskRef, input_name: str, payload: Any, *, region: str = "local"
    ) -> AnnotatedValue:
        """Deliver one external payload without propagating (edge sampling)."""
        mgr = self._build()
        return self.executor.inject(mgr, self._name_of(task), input_name, payload, region)

    def watch(self, target: TaskRef, callback: Optional[Callable] = None) -> Watcher:
        """Observe a task reactively: each firing appends a TaskResult and
        invokes the callback."""
        w = Watcher(self, self._name_of(target), callback)
        self._watchers.append(w)
        return w

    @staticmethod
    def _fire_count(task) -> int:
        return task.executions + task.cache_hits

    def _watch_counts(self, mgr: PipelineManager) -> dict:
        return {
            w.task: self._fire_count(mgr.pipeline.tasks[w.task])
            for w in self._watchers
            if w.active and w.task in mgr.pipeline.tasks
        }

    def _notify_watchers(self, fired: dict) -> None:
        for w in self._watchers:
            if not w.active:
                continue
            for out_avs in fired.get(w.task, []):
                w._notify(TaskResult(self, w.task, out_avs))

    def ghost(self, injections: dict, pulls: Optional[list] = None) -> dict:
        """Wireframe the circuit with ghost batches (ShapeDtypeStructs):
        expose routing and shape contracts without moving a byte (§III.K).
        injection keys: Port, (task, input), or "task.input"."""
        from repro.core.wireframe import ghost_run

        mgr = self._build()
        normalized = {}
        for key, spec in injections.items():
            if isinstance(key, Port):
                normalized[(key.task.name, key.name)] = spec
            elif isinstance(key, tuple):
                normalized[(self._name_of(key[0]), key[1])] = spec
            else:
                task, _, iname = str(key).partition(".")
                normalized[(task, iname)] = spec
        return ghost_run(mgr, normalized, pulls=[self._name_of(p) for p in pulls or []])

    # ------------------------------------------------------------------
    # introspection & provenance (the three stories, one surface)
    # ------------------------------------------------------------------

    @property
    def pipeline(self) -> Pipeline:
        return self._build().pipeline

    @property
    def manager(self) -> PipelineManager:
        """The underlying engine (escape hatch; prefer the facade)."""
        return self._build()

    @property
    def registry(self) -> ProvenanceRegistry:
        return self._registry

    @property
    def store(self) -> ArtifactStore:
        return self._store

    @property
    def topology(self) -> Optional[Topology]:
        return self._topology

    @property
    def ledger(self):
        """The extended-cloud transfer ledger (None on flat circuits; the
        replayed ledger on a journal-rehydrated workspace)."""
        if self._replay is not None:
            return self._replay.ledger
        return self._build().ledger

    @property
    def journal(self):
        """The durable provenance journal (None when journaling is off)."""
        return self._journal

    def compact_journal(
        self,
        *,
        retire_evicted: bool = False,
        archive_dir: Optional[str] = None,
    ) -> dict:
        """Fold the journal's rotated history into a checkpoint snapshot
        (:meth:`repro.provenance.Journal.compact`), so the next
        ``from_journal`` replays checkpoint + tail instead of full history.

        ``retire_evicted=True`` first trims the forensic horizon: AVs whose
        payloads the store can no longer resolve (evicted local-only
        artifacts) and AVs stamped ``dropped`` (streaming-window members the
        merge policy aged out) are retired from the registry — journaled as
        a ``retired`` record, so replays agree — before the fold. That is
        what keeps checkpoint size proportional to *live* state on an
        unbounded stream; the default keeps the drop-forensics story intact
        (dropped travellers stay queryable forever).

        Per-zone runner segment files (multi-process runs) are folded in
        automatically; call between drains, not mid-flight. ``archive_dir``
        moves folded segments aside instead of deleting them — the
        cold-tier hook, and the uncompacted oracle for audits
        (:func:`repro.provenance.replay_files`). Returns the compaction
        report."""
        if self._journal is None:
            raise ValueError(
                f"workspace {self.name!r} has no journal to compact "
                "(enable with journal_path= or KOALJA_JOURNAL=1)"
            )
        if retire_evicted:
            doomed = []
            for uid in self._registry.all_avs():
                av = self._registry.get_av(uid)
                if any(s.event == "dropped" for s in av.travel_document):
                    doomed.append(uid)
                elif not av.uri.startswith("ghost://") and not self._store.resolvable(
                    av.uri
                ):
                    doomed.append(uid)
            if doomed:
                self._registry.retire_avs(
                    doomed, note="compaction horizon: evicted/dropped payloads"
                )
        self._journal.flush()
        seg_fn = getattr(self.executor, "segment_paths", None)
        segments = seg_fn() if seg_fn is not None else ()
        return self._journal.compact(
            segment_paths=segments, archive_dir=archive_dir
        )

    def value_of(self, av: AnnotatedValue) -> Any:
        return self._store.get(av.uri)

    def traveller_log(self, av: AnnotatedValue) -> list:
        return self._registry.traveller_log(av.uid)

    def visitor_log(self, task: TaskRef) -> list:
        return self._registry.visitor_log(self._name_of(task))

    def lineage(self, av: AnnotatedValue) -> dict:
        return self._registry.lineage(av.uid)

    def design_map(self) -> dict:
        self._build()
        return self._registry.design_map()

    def design_map_text(self) -> str:
        self._build()
        return self._registry.design_map_text()

    def stats(self) -> dict:
        """Engine stats plus this workspace's executor counters. The
        ``sustainability`` block is the paper's §III.F scorecard: executions
        avoided by the memo layer and bytes the circuit never moved. The
        ``scheduler`` block is the trigger-work scorecard: waves, queue
        depth high-water, and tasks-enqueued vs the polling-scan equivalent
        the seed's round-robin engine would have burned. The ``topology``
        block (None on flat circuits) is the extended-cloud scorecard:
        per-zone residents/executions, placement decisions, and the
        transfer ledger's cross-zone bytes and energy."""
        out = self._build().stats()
        stats_fn = getattr(self.executor, "stats", None)
        out["executor"] = stats_fn() if stats_fn is not None else None
        # a ZonedExecutor partitions waves by zone; surface its per-zone
        # wave counters inside the topology block where readers look first
        zone_waves = getattr(self.executor, "zone_waves", None)
        if out.get("topology") is not None and zone_waves is not None:
            out["topology"]["executor_zones"] = {
                z: dict(v) for z, v in sorted(zone_waves.items())
            }
        # durable-journal scorecard: what the forensic stories cost on disk
        out["journal"] = self._journal.stats() if self._journal is not None else None
        if self._replay is not None:
            out["journal"] = {
                "path": None,
                "rehydrated": True,
                "replayed_records": self._replay.records,
                "truncated_lines": self._replay.truncated,
                "replayed_counts": dict(self._replay.counts),
                # segment-chain shape of the replayed journal: how many
                # files held the history and how much of it compaction had
                # already folded into checkpoints before this replay
                "segments": self._replay.segments,
                "checkpoints": self._replay.checkpoints,
                "records_compacted": self._replay.records_compacted,
                # AdaptiveExecutor resize decisions, in journal order — the
                # autoscaling history survives restarts like everything else
                "scale_events": list(self._replay.scales),
            }
            if self._replay.ledger is not None:
                # the replayed transfer ledger answers where the engine's
                # would have — same stats shape readers already know
                out["topology"] = {
                    "name": self._replay.topology.name,
                    "default_zone": self._replay.topology.default_zone,
                    "rehydrated": True,
                    "ledger": self._replay.ledger.stats(),
                }
        return out

    def tasks(self) -> list:
        return sorted(self._handles)

    def __repr__(self) -> str:
        state = "materialized" if self._manager is not None else "breadboard"
        return f"Workspace({self.name!r}, tasks={self.tasks()}, {state}, executor={self.executor!r})"


def service(name: str, fn: Callable) -> ServiceCall:
    """Wrap an out-of-band client-server lookup as a traceable ServiceCall
    (frozen responses, §III.D) for ``ws.task(..., services={...})``."""
    return ServiceCall(name, fn)
