"""Executor backends: where a Workspace's circuit actually runs.

The executor protocol is the underlay-transparency seam from the paper: the
breadboard (Workspace) and the trigger semantics (push/pull/sample) are
fixed; *where* task code executes is a backend choice. ``InlineExecutor``
runs everything in-process (the paper's single-node breadboard).
``MeshExecutor`` binds the same circuit to a JAX device mesh: logical-axis
sharding rules are installed around every task execution, and model-step
tasks can be compiled through :mod:`repro.dist` (the Kubernetes-underlay
story mapped onto meshes).
"""

from __future__ import annotations

from typing import Any, Optional, Protocol, runtime_checkable


@runtime_checkable
class Executor(Protocol):
    """Minimal backend contract: drive one PipelineManager engine call."""

    def push(self, manager, task: str, payloads: dict, region: str) -> dict: ...

    def pull(self, manager, target: str) -> dict: ...

    def sample(self, manager, source: str) -> dict: ...

    def inject(self, manager, task: str, input_name: str, payload: Any, region: str): ...

    def stats(self) -> dict: ...


class InlineExecutor:
    """Run tasks in-process on the shared trigger engine.

    Counts every engine call it drives, so ``Workspace.stats()`` can report
    how much *triggering* happened alongside how much work and transport the
    memo/store layers avoided (§III.F)."""

    def __init__(self) -> None:
        self.pushes = 0
        self.pulls = 0
        self.samples = 0
        self.injects = 0

    def push(self, manager, task: str, payloads: dict, region: str) -> dict:
        self.pushes += 1
        return manager._push(task, region=region, **payloads)

    def pull(self, manager, target: str) -> dict:
        self.pulls += 1
        return manager._pull(target)

    def sample(self, manager, source: str) -> dict:
        self.samples += 1
        return manager._sample(source)

    def inject(self, manager, task: str, input_name: str, payload: Any, region: str):
        self.injects += 1
        return manager._inject(task, input_name, payload, region=region)

    def stats(self) -> dict:
        return {
            "backend": type(self).__name__,
            "pushes": self.pushes,
            "pulls": self.pulls,
            "samples": self.samples,
            "injects": self.injects,
        }

    def __repr__(self) -> str:
        return "InlineExecutor()"


class MeshExecutor(InlineExecutor):
    """Execute the circuit against a JAX mesh via :mod:`repro.dist`.

    Every engine call runs under ``axis_rules(rules, mesh)``, so any
    ``shard()`` hints inside plugin task code bind to this mesh; model-step
    tasks get their jitted sharded implementations from the dist layer
    (``train_step`` / ``serve_fns``). The circuit, its provenance, and the
    trigger modes are untouched — only the substrate changes.
    """

    def __init__(
        self,
        mesh=None,
        *,
        rules: Optional[dict] = None,
        cfg=None,
        mode: str = "train",
        global_batch: Optional[int] = None,
    ) -> None:
        super().__init__()
        if mesh is None:
            from repro.launch.mesh import make_host_mesh

            mesh = make_host_mesh()
        self.mesh = mesh
        if rules is None and cfg is not None:
            from repro.dist.sharding import make_rules

            rules = make_rules(cfg, mesh, mode, global_batch)
        self.rules = rules
        self.mode = mode
        self.global_batch = global_batch

    def _ctx(self):
        from contextlib import nullcontext

        from repro.models.common import axis_rules

        return axis_rules(self.rules, self.mesh) if self.rules else nullcontext()

    def push(self, manager, task: str, payloads: dict, region: str) -> dict:
        with self._ctx():
            return super().push(manager, task, payloads, region)

    def pull(self, manager, target: str) -> dict:
        with self._ctx():
            return super().pull(manager, target)

    def sample(self, manager, source: str) -> dict:
        with self._ctx():
            return super().sample(manager, source)

    # -- dist-layer step builders (model tasks) -----------------------------
    def train_step(self, model, schedule, **kwargs):
        """Jitted sharded train step on this executor's mesh (repro.dist)."""
        from repro.dist.step import make_train_step

        kwargs.setdefault("global_batch", self.global_batch)
        if self.rules is not None:
            kwargs.setdefault("rules", self.rules)
        return make_train_step(model, self.mesh, schedule, **kwargs)

    def serve_fns(self, model, **kwargs):
        """Jitted sharded (prefill, decode) on this executor's mesh."""
        from repro.dist.step import make_serve_fns

        kwargs.setdefault("global_batch", self.global_batch)
        if self.rules is not None:
            kwargs.setdefault("rules", self.rules)
        return make_serve_fns(model, self.mesh, **kwargs)

    def stats(self) -> dict:
        out = super().stats()
        out["mesh"] = dict(self.mesh.shape)
        out["mode"] = self.mode
        return out

    def __repr__(self) -> str:
        shape = dict(self.mesh.shape)
        return f"MeshExecutor(mesh={shape}, mode={self.mode!r})"
