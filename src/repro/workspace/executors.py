"""Executor backends: where a Workspace's circuit actually runs.

The executor protocol is the underlay-transparency seam from the paper: the
breadboard (Workspace) and the trigger semantics (push/pull/sample) are
fixed; *where* task code executes is a backend choice. ``InlineExecutor``
runs everything in-process (the paper's single-node breadboard);
``ConcurrentExecutor`` fans a wave of simultaneously-ready tasks across a
thread pool. ``ZonedExecutor`` partitions each wave by extended-cloud zone
(placement decided by the scheduler's ``PlacementPolicy``) and runs each
partition through its ``inner=`` backend. ``MeshExecutor`` binds the same
circuit to a JAX device mesh:
logical-axis sharding rules are installed around every engine call, and
model-step tasks can be compiled through :mod:`repro.dist` (the
Kubernetes-underlay story mapped onto meshes); it composes with either wave
backend via ``inner=``.

The scheduling seam is ``run_wave(manager, tasks)``: the event scheduler
(:mod:`repro.core.scheduler`) computes *waves* of ready tasks and hands each
wave here. Backends run the user code however they like, but emission is
always serialized by the scheduler in wave order, so provenance and
merge-FCFS snapshots are identical across backends.
"""

from __future__ import annotations

import os
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Optional, Protocol, runtime_checkable


@runtime_checkable
class Executor(Protocol):
    """Minimal backend contract: drive one PipelineManager engine call and
    execute scheduler waves."""

    def push(self, manager, task: str, payloads: dict, region: str) -> dict: ...

    def pull(self, manager, target: str) -> dict: ...

    def sample(self, manager, source: str) -> dict: ...

    def inject(self, manager, task: str, input_name: str, payload: Any, region: str): ...

    def run_wave(self, manager, tasks: list) -> list: ...

    def stats(self) -> dict: ...


class InlineExecutor:
    """Run tasks in-process on the shared trigger engine.

    Counts every engine call it drives, so ``Workspace.stats()`` can report
    how much *triggering* happened alongside how much work and transport the
    memo/store layers avoided (§III.F)."""

    def __init__(self) -> None:
        self.pushes = 0
        self.pulls = 0
        self.samples = 0
        self.injects = 0
        self.waves_run = 0

    def push(self, manager, task: str, payloads: dict, region: str) -> dict:
        self.pushes += 1
        return manager._push(task, region=region, **payloads)

    def pull(self, manager, target: str) -> dict:
        self.pulls += 1
        return manager._pull(target)

    def sample(self, manager, source: str) -> dict:
        self.samples += 1
        return manager._sample(source)

    def inject(self, manager, task: str, input_name: str, payload: Any, region: str):
        self.injects += 1
        return manager._inject(task, input_name, payload, region=region)

    def run_wave(self, manager, tasks: list) -> list:
        """Execute one scheduler wave serially (today's semantics, minus the
        full-graph scans). Emission is deferred to the scheduler."""
        self.waves_run += 1
        return [
            (t.name, t.execute(manager.store, manager.registry, manager.cache, emit=False))
            for t in tasks
        ]

    def stats(self) -> dict:
        return {
            "backend": type(self).__name__,
            "pushes": self.pushes,
            "pulls": self.pulls,
            "samples": self.samples,
            "injects": self.injects,
            "waves_run": self.waves_run,
        }

    def __repr__(self) -> str:
        return "InlineExecutor()"


class ConcurrentExecutor(InlineExecutor):
    """Execute independent tasks of a wave in parallel on a thread pool.

    The tasks of one wave are, by construction, independent (each consumes
    its own already-formed snapshot), so user code runs concurrently; the
    scheduler then emits outputs serially in wave order, which keeps
    downstream arrival seqs — and with them merge-FCFS determinism and the
    provenance stories — bit-identical to :class:`InlineExecutor`.

    Thread-compatibility contract for plugin code: tasks in one wave may run
    on different threads, so user fns should not share unguarded mutable
    state across *tasks* (state inside one task is safe — a task is never in
    two waves at once). Registry, memo cache, store, and policies are all
    lock-protected.
    """

    def __init__(self, max_workers: int = 8) -> None:
        super().__init__()
        if max_workers < 1:
            raise ValueError(f"max_workers must be >= 1, got {max_workers}")
        self.max_workers = max_workers
        self._pool: Optional[ThreadPoolExecutor] = None
        self.parallel_waves = 0
        self.tasks_parallel = 0

    def _ensure_pool(self) -> ThreadPoolExecutor:
        if self._pool is None:
            self._pool = ThreadPoolExecutor(
                max_workers=self.max_workers, thread_name_prefix="koalja-wave"
            )
        return self._pool

    def run_wave(self, manager, tasks: list) -> list:
        if len(tasks) <= 1:
            # single-task waves (and pull-mode nodes) stay on the calling
            # thread: no pool hop, and context managers installed by outer
            # backends (e.g. MeshExecutor's axis rules) remain visible.
            return super().run_wave(manager, tasks)
        self.waves_run += 1
        self.parallel_waves += 1
        self.tasks_parallel += len(tasks)
        pool = self._ensure_pool()
        futures = [
            pool.submit(
                t.execute, manager.store, manager.registry, manager.cache, emit=False
            )
            for t in tasks
        ]
        # zip back in wave order — not completion order — so the caller's
        # serialized emission is deterministic.
        return [(t.name, f.result()) for t, f in zip(tasks, futures)]

    def resize(self, max_workers: int) -> None:
        """Adopt a new pool size between waves (the
        :class:`AdaptiveExecutor` seam). The old pool is drained and a new
        one is built lazily at the next multi-task wave; results are always
        zipped back in wave order, so pool size never affects merge order
        or provenance."""
        if max_workers < 1:
            raise ValueError(f"max_workers must be >= 1, got {max_workers}")
        if max_workers == self.max_workers:
            return
        self.max_workers = max_workers
        if self._pool is not None:
            self._pool.shutdown(wait=True)
            self._pool = None

    def shutdown(self) -> None:
        if self._pool is not None:
            self._pool.shutdown(wait=True)
            self._pool = None

    def __del__(self) -> None:
        # workspaces are created freely (tests, short-lived circuits); drop
        # the worker threads with the executor instead of leaking them
        try:
            if self._pool is not None:
                self._pool.shutdown(wait=False)
                self._pool = None
        except Exception:
            pass

    def stats(self) -> dict:
        out = super().stats()
        out["max_workers"] = self.max_workers
        out["parallel_waves"] = self.parallel_waves
        out["tasks_parallel"] = self.tasks_parallel
        return out

    def __repr__(self) -> str:
        return f"ConcurrentExecutor(max_workers={self.max_workers})"


class ZonedExecutor(InlineExecutor):
    """Partition each wave by extended-cloud zone (paper §IV).

    The scheduler's placement policy has already assigned every task of the
    wave a zone (on the scheduler thread, before ``run_wave``); this backend
    groups the wave by zone and runs one zone's partition at a time, in
    topology declaration order — the in-process stand-in for dispatching
    each partition to that zone's physical site. Within a partition the
    ``inner=`` backend decides serial vs thread-pool execution
    (``ZonedExecutor(inner=ConcurrentExecutor(8))`` composes, exactly like
    ``MeshExecutor``'s ``inner=``).

    Results are re-ordered back to wave order before returning, and emission
    stays with the scheduler — so arrival seqs, merge-FCFS snapshots, and
    the provenance stories are bit-identical to Inline/Concurrent backends.
    Per-zone wave/task counts surface in ``Workspace.stats()["topology"]
    ["executor_zones"]``.
    """

    def __init__(self, topology=None, *, inner: Optional[InlineExecutor] = None) -> None:
        super().__init__()
        self.topology = topology
        self.inner = inner
        self.zone_waves: dict = {}  # zone -> {"waves": n, "tasks": n}

    def _inner_run(self, manager, tasks: list) -> list:
        if self.inner is not None:
            return self.inner.run_wave(manager, tasks)
        return [
            (t.name, t.execute(manager.store, manager.registry, manager.cache, emit=False))
            for t in tasks
        ]

    def run_wave(self, manager, tasks: list) -> list:
        # one scheduler wave = one waves_run tick, however many zone
        # partitions it splits into (those are counted in zone_waves)
        self.waves_run += 1
        topo = self.topology or getattr(manager, "topology", None)
        if topo is None:
            return self._inner_run(manager, tasks)
        groups: dict = {}
        for t in tasks:
            groups.setdefault(t.zone or topo.default_zone, []).append(t)
        order = {z: i for i, z in enumerate(topo.zone_names())}
        results: dict = {}
        for zone in sorted(groups, key=lambda z: (order.get(z, len(order)), z)):
            part = groups[zone]
            zw = self.zone_waves.setdefault(zone, {"waves": 0, "tasks": 0})
            zw["waves"] += 1
            zw["tasks"] += len(part)
            for name, out_avs in self._inner_run(manager, part):
                results[name] = out_avs
        # back to wave order: the scheduler zips results against the wave
        # and emits serially, so partition order must not leak downstream
        return [(t.name, results[t.name]) for t in tasks]

    def stats(self) -> dict:
        out = super().stats()
        out["zones"] = {z: dict(v) for z, v in sorted(self.zone_waves.items())}
        if self.inner is not None:
            out["inner"] = self.inner.stats()
        return out

    def __repr__(self) -> str:
        inner = f"inner={self.inner!r}" if self.inner is not None else "inner=serial"
        return f"ZonedExecutor({inner})"


class AdaptiveExecutor(InlineExecutor):
    """Feedback-driven autoscaler around a pool-bearing backend.

    Between waves — never inside one — the wrapper reads the scheduler's
    :class:`~repro.core.scheduler.LoadSignals` and resizes the ``inner``
    pool (thread or process) toward the p95 wave width, clamped to
    ``[min_workers, max_workers]``:

      - **scale up** immediately when the signals want a bigger pool (a
        burst is presenting work right now);
      - **scale down** only after ``scale_down_patience`` consecutive waves
        wanted a smaller one (hysteresis: troughs must prove themselves
        before workers are released).

    Pool size never affects merge order or provenance — the scheduler
    serializes emission in wave order regardless — so the decision sequence
    is free to act on live signals. Wave widths are a pure function of the
    push schedule, hence so are the decisions: the same run produces the
    same resize sequence under every backend. Every resize is journaled as
    a typed ``scale`` record, and ``Workspace.from_journal`` replays the
    decision history (``ReplayedJournal.scales``).
    """

    def __init__(
        self,
        inner: Optional[InlineExecutor] = None,
        *,
        min_workers: int = 1,
        max_workers: int = 8,
        scale_down_patience: int = 3,
    ) -> None:
        super().__init__()
        if min_workers < 1:
            raise ValueError(f"min_workers must be >= 1, got {min_workers}")
        if max_workers < min_workers:
            raise ValueError(
                f"max_workers ({max_workers}) must be >= min_workers ({min_workers})"
            )
        if scale_down_patience < 1:
            raise ValueError(
                f"scale_down_patience must be >= 1, got {scale_down_patience}"
            )
        if inner is None:
            inner = ConcurrentExecutor(max_workers=min_workers)
        if not callable(getattr(inner, "resize", None)):
            raise TypeError(
                f"AdaptiveExecutor needs a pool-bearing inner executor with a "
                f"resize(n) method (ConcurrentExecutor or ProcessExecutor), "
                f"got {inner!r}"
            )
        self.inner = inner
        self.min_workers = min_workers
        self.max_workers = max_workers
        self.scale_down_patience = scale_down_patience
        self._calm = 0  # consecutive waves that wanted a smaller pool
        self.resizes = 0
        self.scale_ups = 0
        self.scale_downs = 0
        self.scale_history: list = []  # journaled scale events, in order
        start = min(max(inner.max_workers, min_workers), max_workers)
        if start != inner.max_workers:
            inner.resize(start)

    @property
    def current_workers(self) -> int:
        return self.inner.max_workers

    def run_wave(self, manager, tasks: list) -> list:
        self._maybe_resize(manager, len(tasks))
        self.waves_run += 1
        return self.inner.run_wave(manager, tasks)

    def _maybe_resize(self, manager, wave_width: int) -> None:
        sched = getattr(manager, "scheduler", None)
        load = getattr(sched, "load", None)
        if load is None:
            return
        current = self.inner.max_workers
        # signals include the wave about to run (observe_wave precedes
        # run_wave); take the larger of p95 and this wave's width so a
        # burst wider than recent history is served, not queued
        target = max(int(load.recommended_workers), int(wave_width))
        target = max(self.min_workers, min(self.max_workers, target))
        if target > current:
            self._calm = 0
            self._apply(manager, load, current, target, "up")
        elif target < current:
            self._calm += 1
            if self._calm >= self.scale_down_patience:
                self._calm = 0
                self._apply(manager, load, current, target, "down")
        else:
            self._calm = 0

    def _apply(self, manager, load, current: int, target: int, direction: str) -> None:
        self.inner.resize(target)
        self.resizes += 1
        if direction == "up":
            self.scale_ups += 1
        else:
            self.scale_downs += 1
        event = {
            "executor": type(self.inner).__name__,
            "wave": self.waves_run,
            "from": current,
            "to": target,
            "direction": direction,
            "width_p95": int(load.wave_width_p95),
            "queue_high_water": int(load.queue_depth_high_water),
        }
        self.scale_history.append(event)
        journal = getattr(manager, "journal", None)
        if journal is not None and not getattr(journal, "closed", False):
            journal.append("scale", event)

    def shutdown(self) -> None:
        shut = getattr(self.inner, "shutdown", None)
        if shut is not None:
            shut()

    def stats(self) -> dict:
        out = super().stats()
        out["current_workers"] = self.inner.max_workers
        out["min_workers"] = self.min_workers
        out["max_workers"] = self.max_workers
        out["resizes"] = self.resizes
        out["scale_ups"] = self.scale_ups
        out["scale_downs"] = self.scale_downs
        out["last_scale"] = self.scale_history[-1] if self.scale_history else None
        out["inner"] = self.inner.stats()
        return out

    def __repr__(self) -> str:
        return (
            f"AdaptiveExecutor(inner={self.inner!r}, "
            f"band=[{self.min_workers},{self.max_workers}])"
        )


EXECUTOR_CHOICES = (
    "inline",
    "concurrent",
    "zoned",
    "zoned-concurrent",
    "process",
    "zoned-process",
    "adaptive",
    "zoned-adaptive",
)


def _env_max_workers() -> int:
    raw = os.environ.get("KOALJA_MAX_WORKERS", "8").strip()
    try:
        workers = int(raw)
    except ValueError:
        raise ValueError(
            f"KOALJA_MAX_WORKERS={raw!r} is not an integer (pool size, >= 1)"
        ) from None
    if workers < 1:
        raise ValueError(f"KOALJA_MAX_WORKERS={workers} must be >= 1")
    return workers


def default_executor() -> InlineExecutor:
    """Backend selected by the ``KOALJA_EXECUTOR`` env var (one of
    ``inline | concurrent | zoned | zoned-concurrent | process |
    zoned-process | adaptive | zoned-adaptive``); ``KOALJA_MAX_WORKERS``
    sizes thread and process pools (for adaptive backends it is the upper
    bound of the autoscaling band). Lets CI smoke every execution substrate
    across the whole suite without code changes."""
    name = os.environ.get("KOALJA_EXECUTOR", "inline").strip().lower()
    if name in ("concurrent", "threads", "threadpool"):
        return ConcurrentExecutor(max_workers=_env_max_workers())
    if name in ("zoned",):
        return ZonedExecutor()
    if name in ("zoned-concurrent", "zoned_concurrent"):
        return ZonedExecutor(inner=ConcurrentExecutor(max_workers=_env_max_workers()))
    if name in ("process", "process-pool", "process_pool"):
        from repro.runtime import ProcessExecutor

        return ProcessExecutor(max_workers=_env_max_workers())
    if name in ("zoned-process", "zoned_process"):
        from repro.runtime import ZonedProcessExecutor

        return ZonedProcessExecutor(max_workers=_env_max_workers())
    if name in ("adaptive",):
        return AdaptiveExecutor(max_workers=_env_max_workers())
    if name in ("zoned-adaptive", "zoned_adaptive"):
        return ZonedExecutor(inner=AdaptiveExecutor(max_workers=_env_max_workers()))
    if name in ("", "inline"):
        return InlineExecutor()
    raise ValueError(
        f"KOALJA_EXECUTOR={name!r} is not a known backend "
        f"(choose from {' | '.join(EXECUTOR_CHOICES)})"
    )


class MeshExecutor(InlineExecutor):
    """Execute the circuit against a JAX mesh via :mod:`repro.dist`.

    Every engine call runs under ``axis_rules(rules, mesh)``, so any
    ``shard()`` hints inside plugin task code bind to this mesh; model-step
    tasks get their jitted sharded implementations from the dist layer
    (``train_step`` / ``serve_fns``). The circuit, its provenance, and the
    trigger modes are untouched — only the substrate changes.

    Wave execution composes with either in-process backend: the default is
    serial (inherited), and ``inner=ConcurrentExecutor(...)`` fans waves
    across threads. Note the axis-rules context is installed on the engine
    thread; with a concurrent inner backend, multi-task waves run on pool
    threads *outside* that context (single-task waves and pull-mode nodes
    stay on the engine thread and keep it).
    """

    def __init__(
        self,
        mesh=None,
        *,
        rules: Optional[dict] = None,
        cfg=None,
        mode: str = "train",
        global_batch: Optional[int] = None,
        inner: Optional[InlineExecutor] = None,
    ) -> None:
        super().__init__()
        if mesh is None:
            from repro.launch.mesh import make_host_mesh

            mesh = make_host_mesh()
        self.mesh = mesh
        if rules is None and cfg is not None:
            from repro.dist.sharding import make_rules

            rules = make_rules(cfg, mesh, mode, global_batch)
        self.rules = rules
        self.mode = mode
        self.global_batch = global_batch
        self.inner = inner

    def _ctx(self):
        from contextlib import nullcontext

        from repro.models.common import axis_rules

        return axis_rules(self.rules, self.mesh) if self.rules else nullcontext()

    def push(self, manager, task: str, payloads: dict, region: str) -> dict:
        with self._ctx():
            return super().push(manager, task, payloads, region)

    def pull(self, manager, target: str) -> dict:
        with self._ctx():
            return super().pull(manager, target)

    def sample(self, manager, source: str) -> dict:
        with self._ctx():
            return super().sample(manager, source)

    def run_wave(self, manager, tasks: list) -> list:
        if self.inner is not None:
            return self.inner.run_wave(manager, tasks)
        return super().run_wave(manager, tasks)

    # -- dist-layer step builders (model tasks) -----------------------------
    def train_step(self, model, schedule, **kwargs):
        """Jitted sharded train step on this executor's mesh (repro.dist)."""
        from repro.dist.step import make_train_step

        kwargs.setdefault("global_batch", self.global_batch)
        if self.rules is not None:
            kwargs.setdefault("rules", self.rules)
        return make_train_step(model, self.mesh, schedule, **kwargs)

    def serve_fns(self, model, **kwargs):
        """Jitted sharded (prefill, decode) on this executor's mesh."""
        from repro.dist.step import make_serve_fns

        kwargs.setdefault("global_batch", self.global_batch)
        if self.rules is not None:
            kwargs.setdefault("rules", self.rules)
        return make_serve_fns(model, self.mesh, **kwargs)

    def stats(self) -> dict:
        out = super().stats()
        out["mesh"] = dict(self.mesh.shape)
        out["mode"] = self.mode
        if self.inner is not None:
            out["inner"] = self.inner.stats()
        return out

    def __repr__(self) -> str:
        shape = dict(self.mesh.shape)
        inner = f", inner={self.inner!r}" if self.inner is not None else ""
        return f"MeshExecutor(mesh={shape}, mode={self.mode!r}{inner})"
