"""Typed handles for the Workspace breadboard: TaskHandle, Port, Wire.

These are *declarations*, not live objects — a Workspace materializes them
into SmartTasks and SmartLinks on first run. That split is what makes the
facade fluent: ``camera["image"] >> detect["frame"]`` and
``detect["frame"].buffer(10, slide=2)`` edit the breadboard; nothing touches
the engine until data moves.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

from repro.core.policy import InputSpec


class WiringError(ValueError):
    """A breadboard edit that cannot be realized (bad port, direction, ...)."""


@dataclasses.dataclass
class TaskDecl:
    """Declarative description of one task (pre-materialization)."""

    name: str
    fn: Callable
    inputs: list  # [InputSpec]
    outputs: list  # [str]
    mode: str = "all_new"
    region: str = "local"
    source: bool = False
    services: Optional[dict] = None
    min_interval_s: float = 0.0
    cache_ttl_s: Optional[float] = None
    zone: Optional[str] = None  # extended-cloud pin (TaskHandle.place)
    coalesce_max: Optional[int] = None  # arrival coalescing (TaskHandle.coalesce)

    def input_named(self, name: str) -> Optional[InputSpec]:
        for s in self.inputs:
            if s.name == name:
                return s
        return None

    def replace_input(self, spec: InputSpec) -> None:
        for i, s in enumerate(self.inputs):
            if s.name == spec.name:
                self.inputs[i] = spec
                return
        raise WiringError(f"task {self.name!r} has no input {spec.name!r}")


@dataclasses.dataclass
class WireDecl:
    src_task: str
    output: str
    dst_task: str
    dst_input: str
    link_kwargs: dict = dataclasses.field(default_factory=dict)


class Wire:
    """Handle on a declared wire — lets link policy be set fluently:
    ``(a["s"] >> b["t"]).region("us").fence("eu")``."""

    def __init__(self, ws, decl: WireDecl) -> None:
        self._ws = ws
        self.decl = decl

    def region(self, region: str) -> "Wire":
        self._ws._assert_mutable()
        self.decl.link_kwargs["region"] = region
        return self

    def fence(self, *regions: str) -> "Wire":
        """Refuse AVs originating in the given regions (paper §III.L)."""
        self._ws._assert_mutable()
        self.decl.link_kwargs["fenced_regions"] = tuple(regions)
        return self

    def notify_threshold(self, seconds: float) -> "Wire":
        """Poll-mode fast path (§III.J): arrivals faster than this coalesce
        instead of notifying per event (suppressions are counted in link
        stats; the scheduler batch-polls them at quiescence)."""
        self._ws._assert_mutable()
        self.decl.link_kwargs["notify_threshold_s"] = seconds
        return self

    def capacity(
        self, n: int, overflow: str = "block", block_timeout_s: Optional[float] = None
    ) -> "Wire":
        """Bound this wire's queue to n AVs with a backpressure policy:
        ``block`` (wait for the consumer), ``drop_oldest`` (ring buffer),
        or ``error`` (fail fast)."""
        self._ws._assert_mutable()
        self.decl.link_kwargs["capacity"] = n
        self.decl.link_kwargs["overflow"] = overflow
        if block_timeout_s is not None:
            self.decl.link_kwargs["block_timeout_s"] = block_timeout_s
        return self

    def __repr__(self) -> str:
        d = self.decl
        return f"Wire({d.src_task}.{d.output} >> {d.dst_task}.{d.dst_input})"


class Port:
    """One named input or output of a task. ``>>`` wires output to input."""

    def __init__(self, task: "TaskHandle", name: str, direction: str) -> None:
        assert direction in ("in", "out")
        self.task = task
        self.name = name
        self.direction = direction

    def buffer(self, n: int, slide: Optional[int] = None) -> "Port":
        """Declare the paper's ``[N]`` buffer / ``[N/k]`` sliding window on
        this input: snapshots carry the last N values, advancing by k."""
        if self.direction != "in":
            raise WiringError(
                f"{self.task.name}.{self.name} is an output; buffers apply to inputs"
            )
        self.task._ws._assert_mutable()
        self.task._decl.replace_input(InputSpec(self.name, n, slide))
        return self

    def __rshift__(self, other) -> Wire:
        if self.direction != "out":
            raise WiringError(
                f"wire must start at an output port, got input "
                f"{self.task.name}.{self.name}"
            )
        if isinstance(other, TaskHandle):
            dst = other._input_port(self.name)
        elif isinstance(other, Port):
            dst = other
        else:
            raise WiringError(f"cannot wire into {other!r}")
        if dst.direction != "in":
            raise WiringError(
                f"wire must end at an input port, got output "
                f"{dst.task.name}.{dst.name}"
            )
        return self.task._ws.wire(self, dst)

    def __repr__(self) -> str:
        arrow = "->" if self.direction == "out" else "<-"
        return f"Port({self.task.name}{arrow}{self.name})"


class TaskHandle:
    """Typed reference to a declared task. ``handle["port"]`` resolves a
    Port (KeyError on unknown names — typos fail at wiring time, not at
    run time)."""

    def __init__(self, ws, decl: TaskDecl) -> None:
        self._ws = ws
        self._decl = decl

    @property
    def name(self) -> str:
        return self._decl.name

    @property
    def outputs(self) -> tuple:
        return tuple(self._decl.outputs)

    @property
    def inputs(self) -> tuple:
        return tuple(s.name for s in self._decl.inputs)

    def __getitem__(self, port: str) -> Port:
        if port in self._decl.outputs:
            return Port(self, port, "out")
        if self._decl.input_named(port) is not None:
            return Port(self, port, "in")
        raise KeyError(
            f"task {self.name!r} has no port {port!r} "
            f"(inputs={list(self.inputs)}, outputs={list(self.outputs)})"
        )

    def _input_port(self, name: str) -> Port:
        if self._decl.input_named(name) is None:
            raise WiringError(
                f"task {self.name!r} has no input {name!r} to receive the wire "
                f"(inputs={list(self.inputs)})"
            )
        return Port(self, name, "in")

    def place(self, zone: str) -> "TaskHandle":
        """Pin this task to an extended-cloud zone (paper §IV). Pinned tasks
        always execute there; under ``data_gravity`` placement only
        *unpinned* tasks are pulled toward their input bytes. Requires the
        workspace to carry a :class:`repro.topology.Topology`."""
        self._ws._assert_mutable()
        topo = getattr(self._ws, "_topology", None)
        if topo is None:
            raise WiringError(
                f"cannot place task {self.name!r}: workspace {self._ws.name!r} "
                f"has no topology (pass Workspace(topology=...))"
            )
        if not topo.has_zone(zone):
            raise WiringError(
                f"cannot place task {self.name!r}: topology {topo.name!r} has "
                f"no zone {zone!r} (zones: {topo.zone_names()})"
            )
        self._decl.zone = zone
        return self

    @property
    def zone(self) -> Optional[str]:
        """The declared pin (None = unpinned; placement policy decides)."""
        return self._decl.zone

    def coalesce(self, max_batch: int) -> "TaskHandle":
        """Opt in to arrival coalescing: when a scheduler wave finds this
        task ready with several snapshots buffered, it fires up to
        ``max_batch`` of them in one ``execute`` call — one journal staging
        window per firing, batched hashing per firing — instead of one
        wave round-trip each. Firing order, emissions, and provenance are
        bit-identical to the uncoalesced schedule."""
        self._ws._assert_mutable()
        if max_batch < 1:
            raise WiringError(
                f"coalesce(max_batch={max_batch}) on task {self.name!r}: "
                f"max_batch must be >= 1"
            )
        self._decl.coalesce_max = int(max_batch)
        return self

    def buffer(self, n: int, slide: Optional[int] = None) -> "TaskHandle":
        """Buffer/window annotation on this task's sole input."""
        if len(self._decl.inputs) != 1:
            raise WiringError(
                f"task {self.name!r} has {len(self._decl.inputs)} inputs; "
                f"use handle['input'].buffer(...) to pick one"
            )
        Port(self, self._decl.inputs[0].name, "in").buffer(n, slide)
        return self

    def __rshift__(self, other) -> Wire:
        """Name-matched wiring: ``a >> b`` connects a's single output to
        b's same-named input ('each promise of an output is matched by the
        promise to consume it')."""
        if len(self._decl.outputs) == 1:
            return Port(self, self._decl.outputs[0], "out") >> other
        if isinstance(other, (TaskHandle, Port)):
            dst_decl = other._decl if isinstance(other, TaskHandle) else other.task._decl
            matches = [o for o in self._decl.outputs if dst_decl.input_named(o)]
            if len(matches) == 1:
                return Port(self, matches[0], "out") >> other
        raise WiringError(
            f"task {self.name!r} has outputs {list(self.outputs)}; "
            f"pick one with handle['output'] >> ..."
        )

    def __repr__(self) -> str:
        ins = ", ".join(str(s) for s in self._decl.inputs)
        return f"TaskHandle(({ins}) {self.name} ({', '.join(self.outputs)}))"
