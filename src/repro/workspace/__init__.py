"""repro.workspace — the typed public API over the Koalja circuit layer.

    from repro.workspace import Workspace

    ws = Workspace("demo")
    cam = ws.source(read_fn, name="camera", outputs=["image"])
    det = ws.task(detect_fn, name="detect", inputs=["frame"], outputs=["boxes"])
    cam["image"] >> det["frame"]
    ws.push(cam, image=img)
    boxes = ws.pull(det)["boxes"]

See :class:`Workspace` for the full surface (push / pull / sample / watch /
ghost / provenance queries) and :mod:`repro.workspace.executors` for the
backend protocol (InlineExecutor, MeshExecutor).
"""

from .executors import (
    AdaptiveExecutor,
    ConcurrentExecutor,
    Executor,
    InlineExecutor,
    MeshExecutor,
    ZonedExecutor,
    default_executor,
)
from .handles import Port, TaskHandle, Wire, WiringError
from .workspace import (
    RunResult,
    TaskResult,
    Watcher,
    Workspace,
    WorkspaceFrozenError,
    service,
)

__all__ = [
    "AdaptiveExecutor", "ConcurrentExecutor", "Executor", "InlineExecutor",
    "MeshExecutor", "ZonedExecutor", "default_executor",
    "Port", "TaskHandle", "Wire", "WiringError",
    "RunResult", "TaskResult", "Watcher", "Workspace",
    "WorkspaceFrozenError", "service",
]
