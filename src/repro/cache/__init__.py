"""repro.cache — semantic memoization for the Koalja circuit (§III.F).

The engine (``repro.core.pipeline`` / ``repro.core.task``) consults a
:class:`MemoCache` before firing any non-source task: a snapshot whose
(software version, input content hashes, policy mode) key was seen before
short-circuits to the stored output references, emitting ``cache_hit``
visitor-log entries and ``memo_of`` lineage pointers instead of recomputing
and re-transporting payloads.
"""

from .memo import ContentCache, MemoCache, make_record, snapshot_key

__all__ = ["ContentCache", "MemoCache", "make_record", "snapshot_key"]
