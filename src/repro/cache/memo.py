"""Semantic memoization — the sustainability pillar (paper §III.F / §III.J).

A memo entry keys one task execution on *content identity*:

    key = (task software version, ordered input content hashes,
           snapshot-policy mode)

Unchanged inputs + unchanged code + unchanged aggregation semantics ⇒ hit ⇒
no recompute ("it's unnecessary to recompile binaries that are unchanged").
The policy mode is part of the key because the same input hashes mean
different things under ``all_new`` vs ``merge`` aggregation. A
software-version change invalidates downstream results exactly as the paper
prescribes for "software updates trigger recomputation".

A hit is *not* lossy for forensics: each record remembers the uids of the
AVs the original run produced (``out_uids``), so the short-circuited AV can
carry a ``memo_of`` pointer and :meth:`ProvenanceRegistry.lineage` still
reconstructs the producing run, software version and all.

Sustainability accounting: ``executions_avoided`` counts short-circuited
firings and ``bytes_saved`` the output payload bytes that never had to be
recomputed or re-transported (the "bytes not moved" half that belongs to the
memo layer; the :class:`~repro.core.store.ArtifactStore` counts the
reference-dedup half).

Purge policy: per-entry TTL classes so caches can "purge at different rates
depending on the risk of recomputation" (§III.F Principle 2 discussion).
"""

from __future__ import annotations

import hashlib
import threading
import time
from typing import Any, Optional


def snapshot_key(
    software_version: str,
    input_hashes: dict,
    extra: str = "",
    policy_mode: str = "",
) -> str:
    """Content key for one snapshot execution.

    ``input_hashes`` maps input name -> content hash (or ordered list of
    hashes for buffered/window inputs); ordering inside a buffer is
    significant, ordering of input names is not (they are sorted).
    """
    parts = [software_version, extra]
    if policy_mode:
        parts.append(f"mode={policy_mode}")
    for name in sorted(input_hashes):
        v = input_hashes[name]
        if isinstance(v, (list, tuple)):
            parts.append(f"{name}=[{','.join(v)}]")
        else:
            parts.append(f"{name}={v}")
    return hashlib.sha256("|".join(parts).encode()).hexdigest()[:24]


def make_record(
    software_version: str,
    outputs: dict,
    out_uids: Optional[dict] = None,
    out_nbytes: Optional[dict] = None,
    birth_zone: Optional[str] = None,
) -> dict:
    """Build a memo record: {output_name: (uri, chash)} plus the forensic
    back-pointers (original AV uids) and size accounting. ``birth_zone`` is
    the extended-cloud zone the producing run executed in — a later memo
    hit replays references to payloads still resident *there*, so the
    transfer ledger must bill from the birth zone, not the replay zone."""
    return {
        "software_version": software_version,
        "outputs": dict(outputs),
        "out_uids": dict(out_uids or {}),
        "out_nbytes": dict(out_nbytes or {}),
        "birth_zone": birth_zone,
        "produced_at": time.time(),
    }


class MemoCache:
    """Content-addressed memo table with TTL purge classes and
    sustainability counters. (Exported as ``ContentCache`` for the original
    seed name; the two are the same class.)"""

    def __init__(self, default_ttl_s: Optional[float] = None) -> None:
        self._entries: dict = {}  # key -> (record, expiry)
        self.default_ttl_s = default_ttl_s
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.executions_avoided = 0
        self.bytes_saved = 0
        # hits served from a replica already resident in the consumer's
        # zone (the zone-local tier): no cross-zone transfer was implied
        self.zone_local_hits = 0
        # Concurrent waves consult the memo table from worker threads.
        self._lock = threading.RLock()
        # optional durable write-through (repro.provenance.Journal)
        self._journal = None

    def bind_journal(self, journal) -> None:
        """Attach a provenance journal: every memo hit appends a typed
        ``cache_hit`` record, so short-circuited work is reconstructable
        after a restart alongside the visitor-log entries it produced."""
        with self._lock:
            self._journal = journal

    def lookup(self, key: str) -> Optional[Any]:
        with self._lock:
            rec = self._entries.get(key)
            if rec is None:
                self.misses += 1
                return None
            value, expiry = rec
            if expiry is not None and time.time() > expiry:
                del self._entries[key]
                self.evictions += 1
                self.misses += 1
                return None
            self.hits += 1
            if self._journal is not None:
                self._journal.append(
                    "cache_hit",
                    {
                        "key": key,
                        "software_version": (
                            value.get("software_version")
                            if isinstance(value, dict)
                            else None
                        ),
                    },
                )
            return value

    def insert(self, key: str, value: Any, ttl_s: Optional[float] = None) -> None:
        ttl = ttl_s if ttl_s is not None else self.default_ttl_s
        expiry = (time.time() + ttl) if ttl is not None else None
        with self._lock:
            self._entries[key] = (value, expiry)
            if self._journal is not None:
                # typed ``memo`` record: the table itself survives restarts
                # (re-inserting under the same key supersedes the old record
                # on replay — exactly the last-writer-wins the dict applies
                # here — which is what lets compaction fold overwrites away)
                self._journal.append(
                    "memo", {"key": key, "record": value, "expires_at": expiry}
                )

    # -- replay / checkpoint (journal rehydration + compaction support) ------
    def restore_entry(
        self, key: str, record: Any, expires_at: Optional[float] = None
    ) -> None:
        """Rebuild one memo entry from a journaled ``memo`` record without
        re-journaling; last record per key wins, matching live overwrite
        semantics."""
        with self._lock:
            self._entries[key] = (record, expires_at)

    def snapshot_state(self) -> dict:
        """Serialize the live (non-expired) memo table as the ``cache``
        payload of a journal checkpoint — expired entries are the memo
        layer's superseded records and are purged at the fold."""
        now = time.time()
        with self._lock:
            return {
                "entries": [
                    {"key": k, "record": v, "expires_at": e}
                    for k, (v, e) in self._entries.items()
                    if e is None or now <= e
                ]
            }

    def restore_state(self, state: dict) -> None:
        """Rehydrate from a checkpoint snapshot (inverse of
        :meth:`snapshot_state`); tail ``memo`` records replayed afterwards
        overwrite on top."""
        with self._lock:
            self._entries.clear()
            for item in state.get("entries", []):
                self._entries[item["key"]] = (
                    item.get("record"),
                    item.get("expires_at"),
                )

    def credit_hit(self, record: Any) -> int:
        """Account one short-circuited execution; returns bytes saved."""
        saved = 0
        if isinstance(record, dict):
            saved = sum(int(n) for n in record.get("out_nbytes", {}).values())
        with self._lock:
            self.executions_avoided += 1
            self.bytes_saved += saved
        return saved

    def note_zone_local_hit(self) -> None:
        """Count a hit served from a same-zone replica (see
        ``ArtifactStore.zone_resident``; the ledger credits the bytes)."""
        with self._lock:
            self.zone_local_hits += 1

    def invalidate_version(self, software_version_prefix: str) -> int:
        """Purge entries produced by a given software version (forensic
        recall: 'a change may be due to software errors, indicating that
        recomputation is needed')."""
        with self._lock:
            doomed = [
                k
                for k, (v, _) in self._entries.items()
                if isinstance(v, dict)
                and v.get("software_version", "").startswith(software_version_prefix)
            ]
            for k in doomed:
                del self._entries[k]
                self.evictions += 1
            return len(doomed)

    def purge_expired(self) -> int:
        now = time.time()
        with self._lock:
            doomed = [
                k for k, (_, e) in self._entries.items() if e is not None and now > e
            ]
            for k in doomed:
                del self._entries[k]
                self.evictions += 1
            return len(doomed)

    def stats(self) -> dict:
        with self._lock:
            return {
                "entries": len(self._entries),
                "hits": self.hits,
                "misses": self.misses,
                "evictions": self.evictions,
                "executions_avoided": self.executions_avoided,
                "bytes_saved": self.bytes_saved,
                "zone_local_hits": self.zone_local_hits,
            }


# Seed-era name; kept so `from repro.core import ContentCache` stays valid.
ContentCache = MemoCache
