"""Production mesh builders.

Single pod: (16, 16) = ("data", "model") — 256 chips (one v5e pod).
Multi-pod: (2, 16, 16) = ("pod", "data", "model") — 512 chips across 2 pods.

Functions, not module constants: importing this module never touches jax
device state (the dry-run sets XLA_FLAGS before first jax init; smoke tests
must keep seeing 1 device).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Tiny mesh over whatever devices exist (tests / examples on CPU)."""
    n = len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))
