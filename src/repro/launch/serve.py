"""Batched serving driver (prefill + decode against KV/SSM caches).

  PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b --reduced \
      --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.dist.sharding import make_rules
from repro.dist.step import make_serve_fns
from repro.launch.mesh import make_host_mesh
from repro.models.registry import build_model, init_serve_state


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    mesh = make_host_mesh()
    max_len = args.prompt_len + args.gen + 8

    prefill_jit, decode_jit, st_shapes, shards = make_serve_fns(
        model, mesh, max_len=max_len, global_batch=args.batch,
        rules=make_rules(cfg, mesh, "serve", args.batch),
    )
    params, _ = model.init(jax.random.key(args.seed))
    state = init_serve_state(model, args.batch, max_len)
    prompts = jax.random.randint(
        jax.random.key(args.seed + 1), (args.batch, args.prompt_len), 0, cfg.vocab
    )
    frames = (
        jax.random.normal(jax.random.key(2), (args.batch, cfg.frontend_len, cfg.d_model))
        if cfg.encoder_layers
        else None
    )
    prefix = (
        jax.random.normal(jax.random.key(3), (args.batch, cfg.frontend_len, cfg.d_model))
        if cfg.frontend == "vision"
        else None
    )

    t0 = time.time()
    logits, state = prefill_jit(params, prompts, state, frames, prefix)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    prefill_s = time.time() - t0

    outs = [tok]
    t0 = time.time()
    for _ in range(args.gen - 1):
        logits, state = decode_jit(params, tok, state)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        outs.append(tok)
    decode_s = time.time() - t0
    gen = jnp.concatenate(outs, axis=1)

    print(f"prefill {args.batch}x{args.prompt_len}: {prefill_s:.3f}s")
    print(
        f"decode  {args.gen - 1} steps: {decode_s:.3f}s "
        f"({(args.gen - 1) * args.batch / max(decode_s, 1e-9):.1f} tok/s)"
    )
    print("sample generations (token ids):")
    for row in gen[: min(4, args.batch)]:
        print("  ", row.tolist())
    return gen


if __name__ == "__main__":
    main()
