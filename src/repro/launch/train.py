"""End-to-end training driver.

The training loop is Koalja circuitry end to end: batches arrive as
AnnotatedValues from the data pipeline, each optimizer step is a SmartTask
execution stamped into the provenance registry, and checkpoints are AVs
whose travel documents name the exact code version, config and data batches
that produced them. Fault tolerance is make-mode: on (simulated) failure the
driver restores the latest checkpoint AV and replays.

CPU quickstart (reduced config):
  PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b \
      --reduced --steps 20 --batch 8 --seq 128
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.core import ProvenanceRegistry, software_version_of
from repro.data.pipeline import build_data_pipeline, next_batch
from repro.dist.ft import FaultToleranceManager, SimulatedFailure
from repro.launch.mesh import make_host_mesh
from repro.models.registry import build_model, train_loss
from repro.optim import adamw_init, cosine_warmup
from repro.workspace import MeshExecutor


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--fail-at-step", type=int, default=-1,
                    help="inject a simulated host failure (tests recovery)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    schedule = cosine_warmup(args.lr, max(2, args.steps // 10), args.steps)

    # the executor backend owns the mesh + sharding rules; the same call
    # targets a production mesh by swapping the executor, nothing else
    executor = MeshExecutor(
        make_host_mesh(), cfg=cfg, mode="train", global_batch=args.batch
    )
    jitted, state_shapes, state_shard, batch_shard = executor.train_step(
        model, schedule, microbatches=args.microbatches
    )

    registry = ProvenanceRegistry()
    sw = software_version_of(train_loss)
    registry.register_task("train_step", ["batch"], ["state", "metrics"], sw)
    ckpt = CheckpointManager(args.ckpt_dir, software_version=sw)
    data = build_data_pipeline(cfg, args.batch, args.seq, seed=args.seed)
    ft = FaultToleranceManager(n_hosts=jax.process_count())

    def fresh_state():
        params, _ = model.init(jax.random.key(args.seed))
        return {
            "params": params,
            "opt": adamw_init(params),
            "step": jax.numpy.zeros((), jax.numpy.int32),
        }

    def restore():
        last = ckpt.latest_step()
        if args.resume and last is not None:
            state, manifest = ckpt.restore(fresh_state())
            print(f"[restore] step {last} (sw={manifest['software_version']})")
            return state, last
        return fresh_state(), 0

    def run(start_state, start_step):
        state = start_state
        for step in range(start_step, args.steps):
            t0 = time.time()
            batch = next_batch(data, cfg)
            batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
            if cfg.encoder_layers and "frames" not in batch:
                batch["frames"] = jax.numpy.asarray(
                    np.random.RandomState(step).randn(
                        args.batch, cfg.frontend_len, cfg.d_model
                    ),
                    dtype=jax.numpy.float32,
                )
            if cfg.frontend == "vision" and "prefix" not in batch:
                batch["prefix"] = jax.numpy.asarray(
                    np.random.RandomState(step).randn(
                        args.batch, cfg.frontend_len, cfg.d_model
                    ),
                    dtype=jax.numpy.float32,
                )
            state, metrics = jitted(state, batch)
            dt = time.time() - t0
            ft.heartbeat(0, dt)
            registry.log_visit("train_step", f"step-{step}", "executed", sw,
                               note=f"loss={float(metrics['loss']):.4f} wall={dt:.3f}s")
            if step == args.fail_at_step:
                ckpt.wait()
                raise SimulatedFailure(host=0, msg=f"injected at step {step}")
            print(
                f"step {step:5d} loss {float(metrics['loss']):.4f} "
                f"lr {float(metrics['lr']):.2e} gnorm {float(metrics['grad_norm']):.3f} "
                f"({dt:.2f}s)"
            )
            if (step + 1) % args.ckpt_every == 0 or step + 1 == args.steps:
                ckpt.save_async(state, step + 1, meta={"loss": float(metrics["loss"])})
        ckpt.wait()
        return state

    # make-mode recovery loop
    attempts = 0
    while True:
        state, start = restore()
        try:
            state = run(state, start)
            break
        except SimulatedFailure as e:
            attempts += 1
            args.resume = True
            args.fail_at_step = -1  # replacement host joins; don't re-fail
            print(f"[ft] {e} -> restart from latest checkpoint (attempt {attempts})")
            if attempts > 3:
                raise

    print(f"[done] {args.steps} steps; checkpoints: {[a.meta['step'] for a in ckpt.saved]}")
    print(f"[provenance] visitor log entries: {len(registry.visitor_log('train_step'))}")
    return state


if __name__ == "__main__":
    main()
