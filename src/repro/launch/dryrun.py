import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This is Koalja wireframing (§III.K) applied to the TPU program: ghost batches
(ShapeDtypeStructs) are pushed through the full distributed train/serve step —
``jit(...).lower(...).compile()`` — proving the sharded wiring (collective
schedule, per-device memory, FLOPs) without allocating a byte of real data.

  python -m repro.launch.dryrun --arch mixtral-8x7b --shape train_4k
  python -m repro.launch.dryrun --all                # 40-cell baseline table
  python -m repro.launch.dryrun --all --multipod     # 2-pod (512 chip) pass

Results append to benchmarks/results/dryrun/<mesh>/<arch>__<shape>.json; the
roofline table in EXPERIMENTS.md is generated from those records.
"""

import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, all_cells, cell_skip_reason, get_config
from repro.dist.step import (
    make_batch_specs,
    make_serve_fns,
    make_train_state_specs,
    make_train_step,
)
from repro.dist.sharding import make_rules
from repro.launch.mesh import make_production_mesh
from repro.models.registry import build_model
from repro.optim import cosine_warmup
from repro.roofline import analyze_compiled

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "benchmarks", "results", "dryrun")


def _mesh_name(multi_pod: bool) -> str:
    return "pod2x16x16" if multi_pod else "pod16x16"


def dryrun_cell(
    arch: str,
    shape: str,
    *,
    multi_pod: bool = False,
    overrides: dict | None = None,
    compress_pods: bool = False,
    microbatches: int = 1,
    verbose: bool = True,
    save: bool = True,
    tag: str = "",
):
    """Lower+compile one cell; returns the roofline record (dict)."""
    import dataclasses

    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    spec = SHAPES[shape]
    skip = cell_skip_reason(cfg, shape)
    if skip:
        rec = {"arch": arch, "shape": shape, "mesh": _mesh_name(multi_pod), "skip": skip}
        if save:
            _save(rec, multi_pod, arch, shape, tag)
        if verbose:
            print(f"[SKIP] {arch} x {shape}: {skip}")
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    model = build_model(cfg)
    mode = "train" if spec.kind == "train" else "serve"
    rules = make_rules(cfg, mesh, mode, spec.global_batch)
    t0 = time.time()

    if spec.kind == "train":
        jitted, state_shapes, state_shard, batch_shard = make_train_step(
            model,
            mesh,
            cosine_warmup(3e-4, 2000, 100_000),
            rules=rules,
            global_batch=spec.global_batch,
            microbatches=microbatches,
            compress_pods=compress_pods,
        )
        batch = make_batch_specs(cfg, "train", spec.global_batch, spec.seq_len)
        with mesh:
            lowered = jitted.lower(state_shapes, batch)
            compiled = lowered.compile()
    else:
        max_len = spec.seq_len
        if spec.kind == "prefill" and cfg.frontend == "vision":
            max_len += cfg.frontend_len  # image prefix occupies cache slots
        prefill_jit, decode_jit, st_shapes, shards = make_serve_fns(
            model, mesh, max_len=max_len, global_batch=spec.global_batch, rules=rules
        )
        if spec.kind == "prefill":
            batch = make_batch_specs(cfg, "prefill", spec.global_batch, spec.seq_len)
            frames = batch.get("frames")
            prefix = batch.get("prefix")
            with mesh:
                lowered = prefill_jit.lower(
                    _param_shapes(model), batch["tokens"], st_shapes, frames, prefix
                )
                compiled = lowered.compile()
        else:  # decode: one new token against a seq_len-deep cache
            dec_state = dict(st_shapes)
            if cfg.encoder_layers:
                dec_state["memory"] = jax.ShapeDtypeStruct(
                    (spec.global_batch, cfg.frontend_len, cfg.d_model),
                    cfg.compute_dtype(),
                )
            tokens = jax.ShapeDtypeStruct((spec.global_batch, 1), jnp.int32)
            with mesh:
                lowered = decode_jit.lower(_param_shapes(model), tokens, dec_state)
                compiled = lowered.compile()

    compile_s = time.time() - t0

    # analytic per-device state size from the actual shardings (params +
    # optimizer state for train; params + caches for serve)
    def _sharded_gb(shapes_tree, shard_tree):
        import math as _math

        total = 0
        for s, sh in zip(jax.tree.leaves(shapes_tree), jax.tree.leaves(shard_tree)):
            n = s.size * s.dtype.itemsize
            div = 1
            for entry in sh.spec:
                if entry is None:
                    continue
                axes = entry if isinstance(entry, tuple) else (entry,)
                div *= _math.prod(mesh.shape[a] for a in axes)
            total += n / div
        return total / 1e9

    if spec.kind == "train":
        state_gb = _sharded_gb(state_shapes, state_shard)
    else:
        from repro.dist.step import param_specs as _ps

        pshapes, _ = _ps(model)
        state_gb = _sharded_gb(pshapes, shards["params"]) + _sharded_gb(
            st_shapes["caches"], shards["state"]["caches"]
        )

    report = analyze_compiled(
        compiled,
        arch=arch,
        shape=shape,
        mesh_name=_mesh_name(multi_pod),
        n_devices=n_dev,
        kind=spec.kind,
        cfg=cfg,
        seq_len=spec.seq_len,
        global_batch=spec.global_batch,
        mesh_shape=dict(mesh.shape),
        rules=rules,
    )
    rec = report.to_record()
    rec["roofline_frac"] = report.roofline_frac
    rec["compile_seconds"] = compile_s
    rec["state_gb_per_device"] = state_gb
    if state_gb > 16.0:
        print(f"[WARN] {arch} x {shape}: state {state_gb:.1f} GB/device exceeds v5e HBM")
    try:
        ma = compiled.memory_analysis()
        rec["memory_analysis"] = {
            k: int(getattr(ma, k))
            for k in (
                "temp_size_in_bytes",
                "argument_size_in_bytes",
                "output_size_in_bytes",
                "generated_code_size_in_bytes",
                "alias_size_in_bytes",
            )
            if hasattr(ma, k)
        }
    except Exception:
        rec["memory_analysis"] = None

    if verbose:
        raw = (
            f" memory_raw={report.t_memory_raw*1e3:.2f}ms"
            if report.t_memory_raw and abs(report.t_memory_raw - report.t_memory) > 1e-9
            else ""
        )
        print(
            f"[OK] {arch} x {shape} ({_mesh_name(multi_pod)}): "
            f"compute={report.t_compute*1e3:.2f}ms memory={report.t_memory*1e3:.2f}ms{raw} "
            f"collective={report.t_collective*1e3:.2f}ms -> {report.bottleneck}-bound; "
            f"useful/HLO={report.useful_flops_frac:.3f} roofline_frac={report.roofline_frac:.3f} "
            f"(compile {compile_s:.1f}s)"
        )
    if save:
        _save(rec, multi_pod, arch, shape, tag)
    return rec


def _param_shapes(model):
    from repro.dist.step import param_specs

    shapes, _ = param_specs(model)
    return shapes


def _save(rec: dict, multi_pod: bool, arch: str, shape: str, tag: str = ""):
    d = os.path.join(os.path.abspath(RESULTS_DIR), _mesh_name(multi_pod))
    os.makedirs(d, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    path = os.path.join(d, f"{arch}__{shape}{suffix}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=2, default=str)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--compress-pods", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--tag", default="")
    ap.add_argument(
        "--set", action="append", default=[],
        help="ArchConfig override, e.g. --set causal_skip=True --set block_kv=1024",
    )
    args = ap.parse_args(argv)

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        overrides[k] = json.loads(v.lower()) if v.lower() in ("true", "false") else (
            int(v) if v.lstrip("-").isdigit() else v
        )

    failures = []
    if args.all:
        for arch, shape, skip in all_cells():
            try:
                dryrun_cell(
                    arch, shape,
                    multi_pod=args.multipod,
                    overrides=overrides or None,
                    compress_pods=args.compress_pods,
                    microbatches=args.microbatches,
                    tag=args.tag,
                )
            except Exception as e:
                traceback.print_exc()
                failures.append((arch, shape, repr(e)))
                print(f"[FAIL] {arch} x {shape}: {e}")
        if failures:
            print(f"\n{len(failures)} cell(s) FAILED:")
            for a, s, e in failures:
                print(f"  {a} x {s}: {e}")
            sys.exit(1)
        print("\nAll cells passed.")
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required (or --all)")
        dryrun_cell(
            args.arch, args.shape,
            multi_pod=args.multipod,
            overrides=overrides or None,
            compress_pods=args.compress_pods,
            microbatches=args.microbatches,
            tag=args.tag,
        )


if __name__ == "__main__":
    main()
