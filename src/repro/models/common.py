"""Shared model substrate: config schema, logical-axis sharding hooks,
parameter init, RMSNorm, RoPE.

Sharding is expressed with *logical axis names* on params and activations;
``repro.dist.sharding`` maps logical names -> mesh axes per (arch, shape).
On CPU (no mesh context) all sharding hooks are no-ops so smoke tests and
kernels run unmodified.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Config schema
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One layer position inside a repeating group."""

    mixer: str = "attention"  # "attention" | "mamba"
    ffn: str = "dense"  # "dense" | "moe" | "none"


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0  # 0 -> d_model // n_heads
    # repeating layout (len(layout) must divide n_layers)
    layout: tuple = (LayerSpec(),)
    # attention
    attention: str = "full"  # full | swa | mla
    window: int = 0  # SWA window (0 = unlimited)
    qkv_bias: bool = False
    rope_theta: float = 1e4
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # MLA (minicpm3-style)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # Mamba
    ssm_state: int = 16
    ssm_conv: int = 4
    ssm_expand: int = 2
    # encoder-decoder
    encoder_layers: int = 0  # 0 -> decoder-only
    cross_attention: bool = False
    # modality frontend (stub per assignment): "none" | "vision" | "audio"
    frontend: str = "none"
    frontend_len: int = 0  # patches / frames provided by input_specs()
    # numerics & structure
    dtype: str = "bfloat16"
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    # runtime knobs (hillclimb levers; not architecture)
    remat: str = "block"  # none | block | full
    block_q: int = 512
    block_kv: int = 512
    causal_skip: bool = False  # unrolled growing-window causal attention
    moe_groups: int = 0  # >0: group-local MoE dispatch (GShard groups = data shards)
    pad_heads: int = 0  # pad attention heads for TP divisibility (zero wo rows)
    moe_block_tokens: int = 0  # 0 = no token chunking in MoE
    moe_exact_tokens: int = 512  # decode/smoke-scale calls dispatch drop-free
    use_pallas: bool = False  # TPU path; CPU tests use jnp references

    # -- derived -----------------------------------------------------------
    @property
    def head_dim(self) -> int:
        return self.d_head or (self.d_model // self.n_heads)

    @property
    def n_heads_eff(self) -> int:
        """Padded head count (TP-divisibility lever; pad wo rows are zero at
        init so padded heads contribute nothing)."""
        return self.n_heads + self.pad_heads

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return max(1, self.d_model // 16)

    @property
    def n_groups(self) -> int:
        assert self.n_layers % len(self.layout) == 0, (
            f"{self.name}: layout len {len(self.layout)} !| n_layers {self.n_layers}"
        )
        return self.n_layers // len(self.layout)

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k: SSM-dominated (pure or hybrid) or bounded
        attention window. Pure full-attention archs are skipped per the
        assignment."""
        if any(s.mixer == "mamba" for s in self.layout):
            return True  # ssm / hybrid
        return self.attention == "swa" and self.window > 0

    def compute_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    def n_params(self) -> int:
        """Analytic parameter count (for 6ND MODEL_FLOPS)."""
        d, dh = self.d_model, self.head_dim
        total = self.vocab * d  # embed
        if not self.tie_embeddings:
            total += self.vocab * d
        for spec in self.layout:
            p = 0
            if spec.mixer == "attention":
                if self.attention == "mla":
                    qr = self.q_lora_rank or d
                    p += d * qr + qr * self.n_heads * (self.qk_nope_dim + self.qk_rope_dim)
                    p += d * (self.kv_lora_rank + self.qk_rope_dim)
                    p += self.kv_lora_rank * self.n_heads * (self.qk_nope_dim + self.v_head_dim)
                    p += self.n_heads * self.v_head_dim * d
                else:
                    p += d * self.n_heads * dh + 2 * d * self.n_kv_heads * dh
                    p += self.n_heads * dh * d
            elif spec.mixer == "mamba":
                di, N = self.d_inner, self.ssm_state
                p += d * 2 * di + di * self.ssm_conv
                p += di * (self.dt_rank + 2 * N) + self.dt_rank * di
                p += di * N + di + di * d
            if spec.ffn == "dense":
                p += 3 * d * self.d_ff  # SwiGLU
            elif spec.ffn == "moe":
                p += d * self.n_experts  # router
                p += self.n_experts * 3 * d * self.d_ff
            p += 2 * d  # two norms
            total += p * self.n_groups
        if self.encoder_layers:
            enc = self.encoder_layers * (
                d * self.n_heads * dh + 2 * d * self.n_kv_heads * dh
                + self.n_heads * dh * d + 3 * d * self.d_ff + 2 * d
            )
            # decoder cross-attention adds one attention block per layer
            cross = self.n_layers * (
                d * self.n_heads * dh + 2 * d * self.n_kv_heads * dh
                + self.n_heads * dh * d + d
            )
            total += enc + cross
        return int(total)

    def n_active_params(self) -> int:
        """Active params per token (MoE: top_k of n_experts)."""
        if self.n_experts == 0:
            return self.n_params()
        d = self.d_model
        moe_layers = sum(1 for s in self.layout if s.ffn == "moe") * self.n_groups
        inactive = moe_layers * (self.n_experts - self.top_k) * 3 * d * self.d_ff
        return int(self.n_params() - inactive)

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=len(self.layout) * 2,
            d_model=64,
            n_heads=4,
            n_kv_heads=max(1, min(self.n_kv_heads, 2)) if self.n_kv_heads < self.n_heads else 4,
            d_head=16,
            d_ff=128 if self.d_ff else 0,
            vocab=256,
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            q_lora_rank=32 if self.q_lora_rank else 0,
            kv_lora_rank=16 if self.kv_lora_rank else 0,
            qk_nope_dim=8 if self.qk_nope_dim else 0,
            qk_rope_dim=8 if self.qk_rope_dim else 0,
            v_head_dim=16 if self.v_head_dim else 0,
            ssm_state=8,
            encoder_layers=2 if self.encoder_layers else 0,
            frontend_len=8 if self.frontend_len else 0,
            window=min(self.window, 64) if self.window else 0,
            block_q=16,
            block_kv=16,
            dtype="float32",
            remat="none",
        )


# ---------------------------------------------------------------------------
# Logical-axis sharding hooks
# ---------------------------------------------------------------------------

_AXIS_RULES = threading.local()


def set_axis_rules(rules: Optional[dict], mesh=None) -> None:
    """rules: logical axis name -> mesh axis (str/tuple/None)."""
    _AXIS_RULES.ctx = None if rules is None else (rules, mesh)


def get_axis_rules():
    return getattr(_AXIS_RULES, "ctx", None)


class axis_rules:
    """Context manager for logical->mesh axis rules (+ the mesh itself)."""

    def __init__(self, rules: Optional[dict], mesh=None):
        self.rules, self.mesh = rules, mesh

    def __enter__(self):
        self.prev = get_axis_rules()
        set_axis_rules(self.rules, self.mesh)
        return self

    def __exit__(self, *exc):
        _AXIS_RULES.ctx = self.prev


def logical_to_pspec(axes: tuple):
    from jax.sharding import PartitionSpec

    ctx = get_axis_rules()
    if ctx is None:
        return None
    rules, _ = ctx
    return PartitionSpec(*[rules.get(a) for a in axes])


def shard(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """Constrain activation sharding by logical axes. No-op without rules."""
    ctx = get_axis_rules()
    if ctx is None:
        return x
    rules, mesh = ctx
    from jax.sharding import NamedSharding, PartitionSpec

    spec = PartitionSpec(*[rules.get(a) for a in axes])
    if mesh is not None:
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
    return jax.lax.with_sharding_constraint(x, spec)


# ---------------------------------------------------------------------------
# Param init: params pytree + parallel logical-axes pytree
# ---------------------------------------------------------------------------


class ParamBuilder:
    """Accumulates (params, logical axes) pytrees with a split key stream."""

    def __init__(self, key: jax.Array, dtype: Any):
        self._key = key
        self.dtype = dtype

    def next_key(self) -> jax.Array:
        self._key, k = jax.random.split(self._key)
        return k

    def dense(self, shape: tuple, axes: tuple, scale: Optional[float] = None):
        fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
        s = scale if scale is not None else fan_in ** -0.5
        w = (jax.random.normal(self.next_key(), shape, jnp.float32) * s).astype(self.dtype)
        return w, axes

    def zeros(self, shape: tuple, axes: tuple, dtype: Any = None):
        return jnp.zeros(shape, dtype or self.dtype), axes

    def ones(self, shape: tuple, axes: tuple, dtype: Any = None):
        return jnp.ones(shape, dtype or self.dtype), axes

    def const(self, value: np.ndarray, axes: tuple, dtype: Any = None):
        return jnp.asarray(value, dtype or self.dtype), axes


def split_tree(tree_of_pairs):
    """Split a pytree whose leaves are (param, axes) into two pytrees."""
    params = jax.tree.map(lambda p: p[0], tree_of_pairs, is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2 and hasattr(x[0], "dtype"))
    axes = jax.tree.map(lambda p: p[1], tree_of_pairs, is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2 and hasattr(x[0], "dtype"))
    return params, axes


def stack_groups(pairs_list):
    """Stack a list of identical (param, axes) trees along a new leading
    'layers' axis (for scan-over-groups)."""
    is_pair = lambda x: isinstance(x, tuple) and len(x) == 2 and hasattr(x[0], "dtype")

    def _stack(*leaves):
        ps = jnp.stack([l[0] for l in leaves])
        return (ps, ("layers",) + leaves[0][1])

    return jax.tree.map(_stack, *pairs_list, is_leaf=is_pair)


# ---------------------------------------------------------------------------
# Norms & RoPE
# ---------------------------------------------------------------------------


@jax.custom_vjp
def grad_cast(x: jax.Array) -> jax.Array:
    """Identity whose cotangent is cast to the primal dtype. Placed at layer
    boundaries so tensor-parallel backward all-reduces move bf16, not the f32
    that norm/loss chains would otherwise propagate (halves those payloads)."""
    return x


def _grad_cast_fwd(x):
    return x, jnp.zeros((0,), x.dtype)  # zero-size dtype token


def _grad_cast_bwd(token, g):
    return (g.astype(token.dtype),)


grad_cast.defvjp(_grad_cast_fwd, _grad_cast_bwd)


import functools


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-5) -> jax.Array:
    """RMSNorm with a hand-written backward (the fused-layernorm pattern):
    residuals are (x in its own dtype, w, rstd) instead of autodiff's chain
    of f32 (B, L, D) intermediates — backward HBM traffic drops ~2x and the
    dx cotangent leaves in the activation dtype (bf16 TP all-reduces)."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    rstd = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * rstd * weight.astype(jnp.float32)).astype(dt)


def _rms_norm_fwd(x, weight, eps):
    xf = x.astype(jnp.float32)
    rstd = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    y = (xf * rstd * weight.astype(jnp.float32)).astype(x.dtype)
    return y, (x, weight, rstd)


def _rms_norm_bwd(eps, res, g):
    x, weight, rstd = res
    xf = x.astype(jnp.float32)
    gf = g.astype(jnp.float32)
    xhat = xf * rstd
    dw = jnp.sum(gf * xhat, axis=tuple(range(x.ndim - 1)))
    dxhat = gf * weight.astype(jnp.float32)
    dx = rstd * (dxhat - xhat * jnp.mean(dxhat * xhat, axis=-1, keepdims=True))
    return dx.astype(x.dtype), dw.astype(weight.dtype)


rms_norm.defvjp(_rms_norm_fwd, _rms_norm_bwd)


def rope_frequencies(dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., L, Dh) rotated pairwise-half style. positions: (..., L)."""
    dh = x.shape[-1]
    freqs = rope_frequencies(dh, theta)  # (dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., L, dh/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def cross_entropy_loss(logits: jax.Array, labels: jax.Array, ignore_id: int = -1):
    """Mean token CE in f32; logits (..., V), labels (...,) int."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None].clip(0), axis=-1)[..., 0]
    mask = (labels != ignore_id).astype(jnp.float32)
    loss = (lse - gold) * mask
    return loss.sum() / jnp.maximum(mask.sum(), 1.0)
