"""Public model API: build a Model from an ArchConfig and expose the three
step functions the runtime lowers — ``train_loss``, ``prefill``,
``decode_step``. These are pure functions of (params, batch/state); the
distribution layer (repro.dist) jits them with shardings and the Koalja layer
(repro.core) wraps them as SmartTasks.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from .common import ArchConfig
from .transformer import Model


def build_model(cfg: ArchConfig) -> Model:
    return Model(cfg)


# ---------------------------------------------------------------------------
# Training
# ---------------------------------------------------------------------------


def train_loss(
    model: Model,
    params: dict,
    batch: dict,
    kernels: Optional[dict] = None,
    aux_weight: float = 0.01,
):
    """batch: tokens (B,L) int32, labels (B,L) int32 (-1 ignore), plus
    'frames' (B,T,D) for enc-dec or 'prefix' (B,Lf,D) for VLM stubs.
    Returns (loss, metrics)."""
    cfg = model.cfg
    tokens, labels = batch["tokens"], batch["labels"]
    x = model.embed(params, tokens)
    positions = jnp.broadcast_to(jnp.arange(tokens.shape[1])[None], tokens.shape)

    memory = None
    if cfg.encoder_layers:
        memory = model.encode(params, batch["frames"])
    if cfg.frontend != "none" and "prefix" in batch:
        prefix = batch["prefix"].astype(x.dtype)  # (B, Lf, D) stub embeddings
        x = jnp.concatenate([prefix, x], axis=1)
        Lf = prefix.shape[1]
        positions = jnp.broadcast_to(
            jnp.arange(x.shape[1])[None], (x.shape[0], x.shape[1])
        )
        labels = jnp.concatenate(
            [jnp.full((labels.shape[0], Lf), -1, labels.dtype), labels], axis=1
        )

    x, aux, _ = model.trunk(params, x, positions, memory=memory, kernels=kernels)
    ce = model.chunked_loss(params, x, labels)
    loss = ce + aux_weight * aux
    return loss, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------


def init_serve_state(model: Model, batch: int, max_len: int) -> dict:
    return {
        "caches": model.init_cache(batch, max_len),
        "t": jnp.zeros((), jnp.int32),
    }


def prefill(
    model: Model,
    params: dict,
    tokens: jax.Array,  # (B, Lp)
    state: dict,
    frames: Optional[jax.Array] = None,
    prefix: Optional[jax.Array] = None,
    kernels: Optional[dict] = None,
):
    """Run the prompt through the trunk filling the caches; returns
    (last_logits (B, V), state)."""
    cfg = model.cfg
    x = model.embed(params, tokens)
    if prefix is not None:
        x = jnp.concatenate([prefix.astype(x.dtype), x], axis=1)
    B, L, _ = x.shape
    positions = state["t"] + jnp.broadcast_to(jnp.arange(L)[None], (B, L))
    memory = model.encode(params, frames) if cfg.encoder_layers else None
    x, _, caches = model.trunk(
        params, x, positions, caches=state["caches"], memory=memory, kernels=kernels
    )
    logits = model.logits(params, x[:, -1:])[:, 0]
    new_state = {"caches": caches, "t": state["t"] + L}
    if memory is not None:
        new_state["memory"] = memory
    return logits, new_state


def decode_step(
    model: Model,
    params: dict,
    tokens: jax.Array,  # (B, 1) the latest sampled token
    state: dict,
    kernels: Optional[dict] = None,
):
    """One autoregressive step against the KV/SSM caches."""
    x = model.embed(params, tokens)
    B = tokens.shape[0]
    positions = jnp.broadcast_to(state["t"][None, None], (B, 1))
    x, _, caches = model.trunk(
        params,
        x,
        positions,
        caches=state["caches"],
        memory=state.get("memory"),
        kernels=kernels,
    )
    logits = model.logits(params, x)[:, 0]  # (B, V)
    return logits, {**state, "caches": caches, "t": state["t"] + 1}


def greedy_generate(
    model: Model,
    params: dict,
    prompt: jax.Array,  # (B, Lp)
    n_steps: int,
    max_len: int,
    frames: Optional[jax.Array] = None,
    prefix: Optional[jax.Array] = None,
) -> jax.Array:
    """Reference sampler used by tests/examples (greedy, jit-scanned)."""
    state = init_serve_state(model, prompt.shape[0], max_len)
    logits, state = prefill(model, params, prompt, state, frames=frames, prefix=prefix)
    tok0 = jnp.argmax(logits, axis=-1).astype(prompt.dtype)[:, None]

    def body(carry, _):
        tok, st = carry
        lg, st = decode_step(model, params, tok, st)
        nxt = jnp.argmax(lg, axis=-1).astype(tok.dtype)[:, None]
        return (nxt, st), nxt

    (_, _), toks = jax.lax.scan(body, (tok0, state), None, length=n_steps - 1)
    return jnp.concatenate([tok0, toks[:, :, 0].T], axis=1)  # (B, n_steps)
