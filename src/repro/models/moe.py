"""Mixture-of-Experts FFN with static sort-based dispatch.

Top-k routing with per-expert capacity bins (GShard-style drops, MegaBlocks-
style grouped matmul). Everything is static-shaped so the layer lowers under
pjit on any mesh:

  1. router: logits -> top-k (weight, expert) per token
  2. dispatch: stable-sort token-slots by expert, take the first C per expert
     (overflow dropped), scatter token vectors into an (E, C, D) buffer
  3. grouped matmul: SwiGLU per expert over its capacity bin — this einsum is
     the ``repro.kernels.moe_gmm`` Pallas hook
  4. combine: gather outputs back per token slot, weight, and sum over k

The (E, C, D) buffer is the unit the sharding rules place: experts over the
'model' axis when E % tp == 0 (expert parallelism), else tensor-parallel over
the ffn dim within replicated experts.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .common import ArchConfig, ParamBuilder, shard


def init_moe(pb: ParamBuilder, cfg: ArchConfig) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {
        "router": pb.dense((d, e), ("embed", "experts"), scale=d**-0.5),
        "w_gate": pb.dense((e, d, f), ("experts", "embed", "mlp")),
        "w_up": pb.dense((e, d, f), ("experts", "embed", "mlp")),
        "w_down": pb.dense((e, f, d), ("experts", "mlp", "embed")),
    }


def expert_capacity(n_tokens: int, cfg: ArchConfig) -> int:
    """Per-expert capacity bin size for an n_tokens dispatch call.

    Calls at or below ``cfg.moe_exact_tokens`` (decode steps — one token per
    sequence — and CPU smoke scale) get capacity = n_tokens: no expert can
    overflow (each token occupies an expert at most once), so the dispatch
    is *drop-free* and decode logits match the teacher-forced trunk exactly.
    Above the threshold — statistical scale, where load balancing holds —
    capacity is proportional (``capacity_factor``) and overflow tokens are
    dropped (GShard semantics, a throughput lever). The threshold is kept at
    decode scale (512) deliberately: raising it would silently change
    training numerics and grow the (E, C, D) dispatch buffers for mid-size
    batches."""
    if n_tokens <= cfg.moe_exact_tokens:
        return n_tokens
    c = int(n_tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(8, -(-c // 8) * 8)  # round up to 8 for layout friendliness


def _dispatch(xf, gate_e, K, E, C):
    """Sort-based dispatch for one token group.

    xf: (T, D); gate_e: (T, K). Returns (xe (E, C, D), slot_by_flat (T*K,),
    keep_count) where slot E*C is the overflow dump."""
    T = xf.shape[0]
    flat_e = gate_e.reshape(-1)  # (T*K,)
    sort_idx = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[sort_idx]
    counts = jnp.bincount(flat_e, length=E)
    starts = jnp.cumsum(counts) - counts
    pos_in_e = jnp.arange(T * K) - starts[sorted_e]
    keep = pos_in_e < C
    dest = jnp.where(keep, sorted_e * C + pos_in_e, E * C)
    token_of = sort_idx // K
    xbuf = jnp.zeros((E * C + 1, xf.shape[1]), xf.dtype).at[dest].set(xf[token_of])
    xe = xbuf[: E * C].reshape(E, C, xf.shape[1])
    slot_by_flat = jnp.zeros((T * K,), jnp.int32).at[sort_idx].set(
        jnp.where(keep, dest, E * C).astype(jnp.int32)
    )
    return xe, slot_by_flat, keep.sum()


def moe_ffn(
    p: dict,
    cfg: ArchConfig,
    x: jax.Array,  # (B, L, D)
    gmm: Optional[object] = None,  # grouped-matmul impl (Pallas on TPU)
):
    """Returns (y, aux) where aux carries the load-balancing loss terms.

    ``cfg.moe_groups > 1`` enables GShard-style group-local dispatch: tokens
    split into G groups aligned with the data shards, each group sorted and
    capacity-binned locally, so the dispatch scatter never crosses the data
    axis and per-device gemm work is 1/G of the global capacity (the baseline
    global sort makes every device touch every token when experts cannot
    shard — e.g. mixtral's 8 experts on a 16-way model axis)."""
    B, L, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * L
    G = max(1, cfg.moe_groups)
    if T % G:
        G = 1
    Tg = T // G
    C = expert_capacity(Tg, cfg)
    xf = x.reshape(T, D)

    # 1. route (router math in f32 — routing is precision-sensitive)
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)  # (T, E)
    gate_w, gate_e = jax.lax.top_k(probs, K)  # (T, K)
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)  # renorm

    # aux loss (Switch): E * sum_e fraction_tokens_e * mean_prob_e
    onehot = jax.nn.one_hot(gate_e[:, 0], E, dtype=jnp.float32)  # top-1 fraction
    aux_loss = E * jnp.mean(probs.mean(0) * onehot.mean(0))

    # 2. dispatch (per group, vmapped; G=1 == the global baseline)
    xg = xf.reshape(G, Tg, D)
    eg = gate_e.reshape(G, Tg, K)
    xe, slot_by_flat, kept = jax.vmap(
        lambda xx, ee: _dispatch(xx, ee, K, E, C)
    )(xg, eg)  # xe: (G, E, C, D)
    xe = shard(xe, "moe_group", "experts", None, None)

    # 3. grouped SwiGLU — the moe_gmm hook
    with jax.named_scope("pallas_moe_gmm"):
        if gmm is not None and G == 1:
            h = gmm(xe[0], p["w_gate"], p["w_up"], p["w_down"])[None]
        else:
            g = jnp.einsum("gecd,edf->gecf", xe, p["w_gate"])
            u = jnp.einsum("gecd,edf->gecf", xe, p["w_up"])
            h = jnp.einsum("gecf,efd->gecd", jax.nn.silu(g) * u, p["w_down"])
    h = shard(h, "moe_group", "experts", None, None)

    # 4. combine: slot -> token, weighted sum over K (per group). The gather
    # stays group-local: constrain operand and result to the group sharding
    # so SPMD does not distribute the gather over the model axis and
    # all-reduce the (Tg*K, D) result back.
    hb = h.reshape(G, E * C, D)
    ybuf = jnp.concatenate([hb, jnp.zeros((G, 1, D), h.dtype)], axis=1)
    ybuf = shard(ybuf, "moe_group", None, None)
    y = jnp.take_along_axis(
        ybuf, slot_by_flat[..., None].astype(jnp.int32), axis=1
    )  # (G, Tg*K, D)
    y = shard(y, "moe_group", None, None)
    y = y.reshape(T, K, D)
    y = (y * gate_w[..., None].astype(y.dtype)).sum(axis=1)

    dropped = (T * K) - kept.sum()
    return y.reshape(B, L, D).astype(x.dtype), {
        "aux_loss": aux_loss,
        "dropped_frac": dropped.astype(jnp.float32) / (T * K),
    }


def init_dense_ffn(pb: ParamBuilder, cfg: ArchConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "w_gate": pb.dense((d, f), ("embed", "mlp")),
        "w_up": pb.dense((d, f), ("embed", "mlp")),
        "w_down": pb.dense((f, d), ("mlp", "embed")),
    }


def dense_ffn(p: dict, x: jax.Array) -> jax.Array:
    g = jnp.einsum("bld,df->blf", x, p["w_gate"])
    u = jnp.einsum("bld,df->blf", x, p["w_up"])
    h = jax.nn.silu(g) * u
    h = shard(h, "batch", "seq", "mlp")
    return jnp.einsum("blf,fd->bld", h, p["w_down"])
