"""Layout-driven transformer assembly.

A model is ``embed -> scan over G groups of layout positions -> norm -> head``
where the layout is a repeating tuple of (mixer, ffn) specs — dense GQA
(``internlm2``), MoE (``mixtral``), hybrid Mamba+attention (``jamba``),
attention-free SSM (``falcon-mamba``), MLA (``minicpm3``) and enc-dec
(``seamless``) are all the same assembly with different layouts.

Parameters for each layout position are stacked over the G groups and the
forward pass is a single ``lax.scan`` (per-group remat policy applies to the
scan body), so the compiled HLO is O(1) in depth.

The LM head / loss is computed in sequence chunks with the vocab dimension
shardable over the model axis — full (B, L, V) logits never materialize.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from . import attention as attn
from . import mamba as mb
from . import moe as moe_mod
from .common import (
    ArchConfig,
    LayerSpec,
    ParamBuilder,
    shard,
    split_tree,
    stack_groups,
)


# ---------------------------------------------------------------------------
# Layer init / apply
# ---------------------------------------------------------------------------


def init_layer(pb: ParamBuilder, cfg: ArchConfig, spec: LayerSpec, cross: bool) -> dict:
    p: dict = {"ln1": pb.ones((cfg.d_model,), ("embed",))}
    if spec.mixer == "attention":
        p["mixer"] = (
            attn.init_mla(pb, cfg) if cfg.attention == "mla" else attn.init_attention(pb, cfg)
        )
    elif spec.mixer == "mamba":
        p["mixer"] = mb.init_mamba(pb, cfg)
    else:
        raise ValueError(f"unknown mixer {spec.mixer}")
    if cross:
        p["ln_cross"] = pb.ones((cfg.d_model,), ("embed",))
        p["cross"] = attn.init_attention(pb, cfg)
    if spec.ffn == "dense":
        p["ln2"] = pb.ones((cfg.d_model,), ("embed",))
        p["ffn"] = moe_mod.init_dense_ffn(pb, cfg)
    elif spec.ffn == "moe":
        p["ln2"] = pb.ones((cfg.d_model,), ("embed",))
        p["ffn"] = moe_mod.init_moe(pb, cfg)
    elif spec.ffn != "none":
        raise ValueError(f"unknown ffn {spec.ffn}")
    return p


def _rms(x, w, eps):
    from .common import grad_cast, rms_norm

    # grad_cast keeps the backward cotangent in the activation dtype so the
    # tensor-parallel dx all-reduces move bf16 payloads (see common.grad_cast)
    return grad_cast(rms_norm(x, w, eps))


def apply_layer(
    p: dict,
    cfg: ArchConfig,
    spec: LayerSpec,
    x: jax.Array,
    positions: jax.Array,
    cache: Optional[dict],
    memory: Optional[jax.Array],  # encoder output for cross-attention
    kernels: Optional[dict] = None,
):
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    kernels = kernels or {}
    h = _rms(x, p["ln1"], cfg.norm_eps)
    if spec.mixer == "attention":
        if cfg.attention == "mla":
            y, new_cache = attn.mla_block(p["mixer"], cfg, h, positions, cache)
        else:
            y, new_cache = attn.attention_block(p["mixer"], cfg, h, positions, cache)
    else:
        y, new_cache = mb.mamba_block(
            p["mixer"], cfg, h, positions, cache, scan_impl=kernels.get("mamba_scan")
        )
    x = x + y

    if "cross" in p and memory is not None:
        h = _rms(x, p["ln_cross"], cfg.norm_eps)
        mk = jnp.einsum("btd,dhk->bthk", memory, p["cross"]["wk"])
        mv = jnp.einsum("btd,dhk->bthk", memory, p["cross"]["wv"])
        y, _ = attn.attention_block(
            p["cross"], cfg, h, positions, cache=None, cross_kv=(mk, mv)
        )
        x = x + y

    if "ffn" in p:
        h = _rms(x, p["ln2"], cfg.norm_eps)
        if spec.ffn == "moe":
            y, mo = moe_mod.moe_ffn(p["ffn"], cfg, h, gmm=kernels.get("moe_gmm"))
            aux = aux + mo["aux_loss"]
        else:
            y = moe_mod.dense_ffn(p["ffn"], h)
        x = x + y
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Model:
    """Functional model container: init + forward paths for one ArchConfig."""

    cfg: ArchConfig

    # -- init ---------------------------------------------------------------
    def init(self, key: jax.Array):
        """Returns (params, logical_axes) pytrees (same treedef)."""
        cfg = self.cfg
        pb = ParamBuilder(key, cfg.compute_dtype())
        tree: dict = {
            "embed": pb.dense((cfg.vocab, cfg.d_model), ("vocab", "embed"), scale=1.0),
            "final_norm": pb.ones((cfg.d_model,), ("embed",)),
        }
        if not cfg.tie_embeddings:
            tree["lm_head"] = pb.dense((cfg.d_model, cfg.vocab), ("embed", "vocab"))
        cross = cfg.cross_attention
        tree["blocks"] = [
            stack_groups(
                [init_layer(pb, cfg, spec, cross) for _ in range(cfg.n_groups)]
            )
            for spec in cfg.layout
        ]
        if cfg.encoder_layers:
            enc_spec = LayerSpec(mixer="attention", ffn="dense")
            enc_cfg = dataclasses.replace(cfg, attention="full", cross_attention=False)
            tree["encoder"] = {
                "blocks": stack_groups(
                    [
                        init_layer(pb, enc_cfg, enc_spec, cross=False)
                        for _ in range(cfg.encoder_layers)
                    ]
                ),
                "norm": pb.ones((cfg.d_model,), ("embed",)),
            }
        return split_tree(tree)

    # -- encoder --------------------------------------------------------------
    def encode(self, params: dict, frames: jax.Array) -> jax.Array:
        """frames: (B, T, D) stub frontend embeddings -> (B, T, D) memory."""
        cfg = self.cfg
        enc_cfg = dataclasses.replace(cfg, attention="full", cross_attention=False)
        spec = LayerSpec(mixer="attention", ffn="dense")
        x = frames.astype(cfg.compute_dtype())
        positions = jnp.broadcast_to(jnp.arange(x.shape[1])[None], x.shape[:2])

        def body(carry, p_g):
            h = _rms(carry, p_g["ln1"], cfg.norm_eps)
            q = jnp.einsum("bld,dhk->blhk", h, p_g["mixer"]["wq"])
            k = jnp.einsum("bld,dhk->blhk", h, p_g["mixer"]["wk"])
            v = jnp.einsum("bld,dhk->blhk", h, p_g["mixer"]["wv"])
            from .common import apply_rope

            q = apply_rope(q.swapaxes(1, 2), positions[:, None], cfg.rope_theta).swapaxes(1, 2)
            k = apply_rope(k.swapaxes(1, 2), positions[:, None], cfg.rope_theta).swapaxes(1, 2)
            o = attn.blocked_attention(
                q, k, v, causal=False, block_q=cfg.block_q, block_kv=cfg.block_kv
            )
            carry = carry + jnp.einsum("blhk,hkd->bld", o, p_g["mixer"]["wo"])
            h = _rms(carry, p_g["ln2"], cfg.norm_eps)
            carry = carry + moe_mod.dense_ffn(p_g["ffn"], h)
            return carry, None

        body = _maybe_remat(body, cfg)
        x, _ = jax.lax.scan(body, x, params["encoder"]["blocks"])
        return _rms(x, params["encoder"]["norm"], cfg.norm_eps)

    # -- decoder trunk ----------------------------------------------------------
    def trunk(
        self,
        params: dict,
        x: jax.Array,  # (B, L, D) embedded inputs
        positions: jax.Array,  # (B, L)
        caches: Optional[list] = None,  # per layout position, stacked (G,...)
        memory: Optional[jax.Array] = None,
        kernels: Optional[dict] = None,
    ):
        cfg = self.cfg

        def body(carry, xs):
            x, aux = carry
            p_gs, c_gs = xs
            new_cs = []
            for spec, p_g, c_g in zip(cfg.layout, p_gs, c_gs):
                x, nc, a = apply_layer(
                    p_g, cfg, spec, x, positions, c_g, memory, kernels
                )
                aux = aux + a
                new_cs.append(nc)
            return (x, aux), new_cs

        # remat only matters under autodiff; serve paths (caches present)
        # skip it — no backward, and checkpoint would rewrite op metadata.
        if caches is None:
            body = _maybe_remat(body, cfg)
        caches_in = caches if caches is not None else [None] * len(cfg.layout)
        (x, aux), new_caches = jax.lax.scan(
            body,
            (x, jnp.zeros((), jnp.float32)),
            (list(params["blocks"]), caches_in),
        )
        return x, aux, (new_caches if caches is not None else None)

    # -- heads --------------------------------------------------------------
    def embed(self, params: dict, tokens: jax.Array) -> jax.Array:
        x = params["embed"][tokens]  # (B, L, D)
        return shard(x, "batch", "seq", None)

    def logits(self, params: dict, x: jax.Array) -> jax.Array:
        cfg = self.cfg
        x = _rms(x, params["final_norm"], cfg.norm_eps)
        w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        out = jnp.einsum("bld,dv->blv", x, w)
        return shard(out, "batch", "seq", "vocab")

    def chunked_loss(
        self,
        params: dict,
        x: jax.Array,  # (B, L, D) trunk output
        labels: jax.Array,  # (B, L) next-token ids, -1 = ignore
        chunk: int = 512,
    ) -> jax.Array:
        """Token-mean CE without materializing (B, L, V): scan over L-chunks;
        the V dim of each chunk's logits is shardable over 'model'."""
        cfg = self.cfg
        x = _rms(x, params["final_norm"], cfg.norm_eps)
        w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        B, L, D = x.shape
        chunk = min(chunk, L)
        pad = (-L) % chunk
        if pad:
            x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
            labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
        nc = (L + pad) // chunk
        xb = x.reshape(B, nc, chunk, D).swapaxes(0, 1)
        lb = labels.reshape(B, nc, chunk).swapaxes(0, 1)

        def body(carry, inp):
            tot, cnt = carry
            xc, lc = inp
            logits = jnp.einsum("bld,dv->blv", xc, w).astype(jnp.float32)
            logits = shard(logits, "batch", "seq", "vocab")
            lse = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, lc[..., None].clip(0), axis=-1)[..., 0]
            mask = (lc != -1).astype(jnp.float32)
            return (tot + ((lse - gold) * mask).sum(), cnt + mask.sum()), None

        (tot, cnt), _ = jax.lax.scan(
            body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)), (xb, lb)
        )
        return tot / jnp.maximum(cnt, 1.0)

    # -- cache --------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int) -> list:
        """Per layout position: stacked (G, ...) cache trees."""
        cfg = self.cfg
        dt = cfg.compute_dtype()

        def one(spec: LayerSpec):
            if spec.mixer == "mamba":
                c = mb.init_mamba_cache(cfg, batch, dt)
            elif cfg.attention == "mla":
                c = attn.init_mla_cache(cfg, batch, max_len, dt)
            else:
                c = attn.init_attention_cache(cfg, batch, max_len, dt)
            return jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (cfg.n_groups,) + a.shape), c
            )

        return [one(spec) for spec in cfg.layout]


def _maybe_remat(fn, cfg: ArchConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "full":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
    # "block": save only big matmul outputs entering the block boundary
    return jax.checkpoint(fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
