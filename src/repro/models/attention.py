"""Attention mixers: full / sliding-window / MLA, GQA-aware, blocked.

Two execution paths share one math definition:

  - ``blocked_attention`` — pure-jnp online-softmax attention, scanned over
    KV blocks (and Q blocks). This is what the multi-pod dry-run lowers: the
    compiled HLO never materializes an (Lq, Lkv) score matrix, so the memory
    analysis is honest about what a fused kernel would use.
  - ``repro.kernels.flash_attention`` — the Pallas TPU kernel with the same
    blocking scheme (HBM->VMEM streaming). Selected by ``cfg.use_pallas``.

GQA is computed grouped — KV heads are never repeated in memory: scores are
einsummed as (B, KVH, Gq, Lq, Lkv) against unexpanded KV.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .common import ArchConfig, ParamBuilder, apply_rope, rms_norm, shard

NEG_INF = -2.0e38


# ---------------------------------------------------------------------------
# Blocked online-softmax attention (reference shared by train & prefill)
# ---------------------------------------------------------------------------


def _gqa_scores(q: jax.Array, k: jax.Array) -> jax.Array:
    """q: (B, Lq, KVH, Gq, Dh), k: (B, Lk, KVH, Dh) -> (B, KVH, Gq, Lq, Lk)."""
    return jnp.einsum("bqhgd,bkhd->bhgqk", q, k, preferred_element_type=jnp.float32)


def _mask_bias(q_pos, k_pos, causal: bool, window: int):
    """Additive bias (Lq, Lk): 0 where attendable, NEG_INF elsewhere."""
    ok = jnp.ones((q_pos.shape[0], k_pos.shape[0]), dtype=bool)
    if causal:
        ok &= k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        ok &= k_pos[None, :] > (q_pos[:, None] - window)
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def blocked_attention(
    q: jax.Array,  # (B, Lq, H, Dh)
    k: jax.Array,  # (B, Lk, KVH, Dh)
    v: jax.Array,  # (B, Lk, KVH, Dh)
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = 512,
    block_kv: int = 512,
    causal_skip: bool = False,  # hillclimb lever: unrolled growing-window
) -> jax.Array:
    """Online-softmax attention, O(block) memory. Returns (B, Lq, H, Dv).

    v's head dim may differ from q/k's (MLA: Dk=96, Dv=64)."""
    with jax.named_scope("pallas_flash_attention"):
        return _blocked_attention(
            q, k, v, causal=causal, window=window,
            block_q=block_q, block_kv=block_kv, causal_skip=causal_skip,
        )


def _blocked_attention(q, k, v, *, causal, window, block_q, block_kv, causal_skip):
    B, Lq, H, Dh = q.shape
    Lk, KVH = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    Gq = H // KVH
    qg = q.reshape(B, Lq, KVH, Gq, Dh)
    scale = Dh**-0.5

    if causal_skip and causal and Lq == Lk and Lq % block_q == 0:
        out = _causal_skip_attention(qg, k, v, scale, block_q, block_kv, window)
        return out.reshape(B, Lq, H, Dv).astype(q.dtype)

    block_kv = min(block_kv, Lk)
    nkv = -(-Lk // block_kv)
    pad_k = nkv * block_kv - Lk
    kv_ok = jnp.arange(nkv * block_kv) < Lk  # (nkv*bkv,) padding validity
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    kb = k.reshape(B, nkv, block_kv, KVH, Dh).swapaxes(0, 1)
    vb = v.reshape(B, nkv, block_kv, KVH, Dv).swapaxes(0, 1)
    kidx = jnp.arange(nkv * block_kv).reshape(nkv, block_kv)
    okb = kv_ok.reshape(nkv, block_kv)

    def one_q_block(qblk: jax.Array, q_pos: jax.Array) -> jax.Array:
        # qblk: (B, bq, KVH, Gq, Dh); scan over kv blocks w/ running stats
        bq = qblk.shape[1]
        acc0 = jnp.zeros((B, KVH, Gq, bq, Dv), jnp.float32)
        m0 = jnp.full((B, KVH, Gq, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KVH, Gq, bq), jnp.float32)

        def body(carry, inp):
            acc, m, l = carry
            kblk, vblk, ki, okk = inp
            s = _gqa_scores(qblk, kblk) * scale  # (B,KVH,Gq,bq,bkv) f32
            bias = _mask_bias(q_pos, ki, causal, window)
            bias = bias + jnp.where(okk, 0.0, NEG_INF)[None, :]
            s = s + bias
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p, vblk, preferred_element_type=jnp.float32
            )
            return (acc_new, m_new, l_new), None

        (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0), (kb, vb, kidx, okb))
        out = acc / jnp.maximum(l, 1e-37)[..., None]  # (B,KVH,Gq,bq,Dh)
        return out.transpose(0, 3, 1, 2, 4)  # (B,bq,KVH,Gq,Dh)

    if Lq <= block_q:
        out = one_q_block(qg, jnp.arange(Lq))
    else:
        bq = block_q
        nq = -(-Lq // bq)
        pad_q = nq * bq - Lq
        qp = jnp.pad(qg, ((0, 0), (0, pad_q), (0, 0), (0, 0), (0, 0))) if pad_q else qg
        qblocks = qp.reshape(B, nq, bq, KVH, Gq, Dh).swapaxes(0, 1)
        qpos = jnp.arange(nq * bq).reshape(nq, bq)

        def qbody(_, inp):
            qblk, qpo = inp
            return None, one_q_block(qblk, qpo)

        _, outs = jax.lax.scan(qbody, None, (qblocks, qpos))
        out = outs.swapaxes(0, 1).reshape(B, nq * bq, KVH, Gq, Dv)
        if pad_q:
            out = out[:, :Lq]
    return out.reshape(B, Lq, H, Dv).astype(q.dtype)


def _causal_skip_attention(qg, k, v, scale, block_q, block_kv, window):
    """Beyond-baseline lever: unrolled Python loop over Q blocks, each slicing
    only the causally-visible KV prefix — compiled FLOPs ~ N^2/2 instead of
    N^2 (the masked-full baseline). SWA additionally drops the out-of-window
    prefix so compiled FLOPs ~ N*W."""
    B, Lq, KVH, Gq, Dh = qg.shape
    nq = Lq // block_q
    outs = []
    for i in range(nq):
        qblk = jax.lax.slice_in_dim(qg, i * block_q, (i + 1) * block_q, axis=1)
        lo = 0
        if window > 0:
            # earliest K any q-row in this block can see: q_lo - window + 1
            lo = max(0, i * block_q - window + 1)
            lo = (lo // block_kv) * block_kv  # block-align downwards
        hi = (i + 1) * block_q
        kblk = jax.lax.slice_in_dim(k, lo, hi, axis=1)
        vblk = jax.lax.slice_in_dim(v, lo, hi, axis=1)
        s = _gqa_scores(qblk, kblk) * scale
        qpos = i * block_q + jnp.arange(block_q)
        kpos = lo + jnp.arange(hi - lo)
        s = s + _mask_bias(qpos, kpos, True, window)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhgqk,bkhd->bhgqd", p, vblk, preferred_element_type=jnp.float32)
        outs.append(o.transpose(0, 3, 1, 2, 4))
    return jnp.concatenate(outs, axis=1)


# ---------------------------------------------------------------------------
# Standard (GQA / SWA / QKV-bias) attention block
# ---------------------------------------------------------------------------


def _zero_pad_rows(pair, n_real: int):
    w, axes = pair
    return w.at[n_real:].set(0), axes


def init_attention(pb: ParamBuilder, cfg: ArchConfig) -> dict:
    d, H, KVH, Dh = cfg.d_model, cfg.n_heads_eff, cfg.n_kv_heads, cfg.head_dim
    assert H % KVH == 0, f"padded heads {H} must stay a multiple of kv={KVH}"
    p = {
        "wq": pb.dense((d, H, Dh), ("embed", "heads", "head_dim")),
        "wk": pb.dense((d, KVH, Dh), ("embed", "kv_heads", "head_dim")),
        "wv": pb.dense((d, KVH, Dh), ("embed", "kv_heads", "head_dim")),
        "wo": pb.dense((H, Dh, d), ("heads", "head_dim", "embed")),
    }
    if cfg.pad_heads:
        p["wo"] = _zero_pad_rows(p["wo"], cfg.n_heads)
    if cfg.qkv_bias:
        p["bq"] = pb.zeros((H, Dh), ("heads", "head_dim"))
        p["bk"] = pb.zeros((KVH, Dh), ("kv_heads", "head_dim"))
        p["bv"] = pb.zeros((KVH, Dh), ("kv_heads", "head_dim"))
    return p


def _project_qkv(p: dict, cfg: ArchConfig, x: jax.Array, positions: jax.Array):
    q = jnp.einsum("bld,dhk->blhk", x, p["wq"])
    k = jnp.einsum("bld,dhk->blhk", x, p["wk"])
    v = jnp.einsum("bld,dhk->blhk", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    # RoPE on q/k (positions broadcast over heads)
    q = apply_rope(q.swapaxes(1, 2), positions[:, None], cfg.rope_theta).swapaxes(1, 2)
    k = apply_rope(k.swapaxes(1, 2), positions[:, None], cfg.rope_theta).swapaxes(1, 2)
    return q, k, v


def attention_block(
    p: dict,
    cfg: ArchConfig,
    x: jax.Array,  # (B, L, D)
    positions: jax.Array,  # (B, L) absolute positions
    cache: Optional[dict] = None,  # see init_attention_cache
    cross_kv: Optional[tuple] = None,  # (k, v) encoder memory for cross-attn
):
    """Self-attention with optional KV cache (decode) — returns (y, new_cache)."""
    B, L, _ = x.shape
    if cross_kv is not None:
        q = jnp.einsum("bld,dhk->blhk", x, p["wq"])
        k, v = cross_kv
        out = blocked_attention(
            q, k, v, causal=False, block_q=cfg.block_q, block_kv=cfg.block_kv
        )
        y = jnp.einsum("blhk,hkd->bld", out, p["wo"])
        return y, cache

    q, k, v = _project_qkv(p, cfg, x, positions)
    q = shard(q, "batch", "seq", "heads", None)

    if cache is None:
        out = blocked_attention(
            q,
            k,
            v,
            causal=True,
            window=cfg.window if cfg.attention == "swa" else 0,
            block_q=cfg.block_q,
            block_kv=cfg.block_kv,
            causal_skip=cfg.causal_skip,
        )
        new_cache = None
    else:
        idx = cache["index"]  # scalar int32: #tokens already in cache
        S = cache["k"].shape[1]
        if "pos" in cache:  # SWA ring buffer of size W
            wpos = jnp.mod(idx + jnp.arange(L), S)  # (L,)
            ck = cache["k"].at[:, wpos].set(k)
            cv = cache["v"].at[:, wpos].set(v)
            kpos = cache["pos"].at[:, wpos].set(positions)
            total = idx + L
            valid = jnp.arange(S)[None, :] < total  # ring: slot written yet?
            out = _cached_attention(q, ck, cv, kpos, positions, valid, cfg)
            new_cache = {"k": ck, "v": cv, "pos": kpos, "index": total}
        else:
            ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, idx, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, idx, axis=1)
            total = idx + L
            if L > 1:
                # prefill: blocked attention over the cache (slots >= L are
                # causally dead for a fresh cache; prefill starts at idx=0)
                out = blocked_attention(
                    q, ck, cv,
                    causal=True,
                    window=cfg.window if cfg.attention == "swa" else 0,
                    block_q=cfg.block_q, block_kv=cfg.block_kv,
                    causal_skip=cfg.causal_skip,
                )
            else:
                valid = jnp.arange(S)[None, :] < total
                kpos = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
                out = _cached_attention(q, ck, cv, kpos, positions, valid, cfg)
            new_cache = {"k": ck, "v": cv, "index": total}

    out = shard(out, "batch", "seq", "heads", None)
    y = jnp.einsum("blhk,hkd->bld", out, p["wo"])
    return y, new_cache


def _cached_attention(q, k, v, k_pos, q_pos, valid, cfg: ArchConfig):
    """Decode-path attention over a (possibly ring) cache with explicit
    per-slot positions. q: (B, L, H, Dh); k/v: (B, S, KVH, Dh). The cache's
    seq axis may be sharded (flash-decoding layout) — the reductions below
    then lower to per-shard partial softmax + cross-shard combine."""
    with jax.named_scope("pallas_flash_attention"):
        return _cached_attention_impl(q, k, v, k_pos, q_pos, valid, cfg)


def _cached_attention_impl(q, k, v, k_pos, q_pos, valid, cfg: ArchConfig):
    B, L, H, Dh = q.shape
    S, KVH = k.shape[1], k.shape[2]
    Gq = H // KVH
    qg = q.reshape(B, L, KVH, Gq, Dh)
    s = _gqa_scores(qg, k) * (Dh**-0.5)  # (B,KVH,Gq,L,S)
    ok = k_pos[:, None, :] <= q_pos[:, :, None]  # (B, L, S) causal
    if cfg.attention == "swa" and cfg.window > 0:
        ok &= k_pos[:, None, :] > (q_pos[:, :, None] - cfg.window)
    ok &= valid[:, None, :]
    s = s + jnp.where(ok, 0.0, NEG_INF)[:, None, None]  # (B,1,1,L,S)
    pw = jax.nn.softmax(s.astype(jnp.float32), axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bhgqd", pw, v, preferred_element_type=jnp.float32)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, L, H, Dh).astype(q.dtype)


def init_attention_cache(cfg: ArchConfig, batch: int, max_len: int, dtype) -> dict:
    S = min(max_len, cfg.window) if (cfg.attention == "swa" and cfg.window) else max_len
    KVH, Dh = cfg.n_kv_heads, cfg.head_dim
    cache = {
        "k": jnp.zeros((batch, S, KVH, Dh), dtype),
        "v": jnp.zeros((batch, S, KVH, Dh), dtype),
        "index": jnp.zeros((), jnp.int32),
    }
    if cfg.attention == "swa" and cfg.window and S == cfg.window:
        cache["pos"] = jnp.full((batch, S), -1, jnp.int32)
    return cache


# ---------------------------------------------------------------------------
# MLA — Multi-head Latent Attention (MiniCPM3 / DeepSeek-V2 style)
# ---------------------------------------------------------------------------
#
# The KV cache stores only the compressed latent c_kv (kv_lora_rank) plus the
# shared rotary key k_rope (qk_rope_dim) — the Koalja transport insight
# applied to attention state: cache the *reference* (latent), not the payload
# (full per-head KV). Scores are computed "absorbed": q is projected into
# latent space so per-head K is never reconstituted for the cache.


def init_mla(pb: ParamBuilder, cfg: ArchConfig) -> dict:
    d, H = cfg.d_model, cfg.n_heads_eff
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    p = {
        "wq_a": pb.dense((d, qr), ("embed", "q_lora")),
        "q_norm": pb.ones((qr,), ("q_lora",)),
        "wq_b": pb.dense((qr, H, dn + dr), ("q_lora", "heads", "head_dim")),
        "wkv_a": pb.dense((d, kvr + dr), ("embed", "kv_lora")),
        "kv_norm": pb.ones((kvr,), ("kv_lora",)),
        "wk_b": pb.dense((kvr, H, dn), ("kv_lora", "heads", "head_dim")),
        "wv_b": pb.dense((kvr, H, dv), ("kv_lora", "heads", "head_dim")),
        "wo": pb.dense((H, dv, d), ("heads", "head_dim", "embed")),
    }
    if cfg.pad_heads:
        p["wo"] = _zero_pad_rows(p["wo"], cfg.n_heads)
    return p


def mla_block(
    p: dict,
    cfg: ArchConfig,
    x: jax.Array,
    positions: jax.Array,
    cache: Optional[dict] = None,
):
    B, L, _ = x.shape
    dn, dr = cfg.qk_nope_dim, cfg.qk_rope_dim
    kvr = cfg.kv_lora_rank

    cq = rms_norm(jnp.einsum("bld,dr->blr", x, p["wq_a"]), p["q_norm"], cfg.norm_eps)
    q = jnp.einsum("blr,rhk->blhk", cq, p["wq_b"])  # (B,L,H,dn+dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope.swapaxes(1, 2), positions[:, None], cfg.rope_theta).swapaxes(1, 2)

    ckv_full = jnp.einsum("bld,dr->blr", x, p["wkv_a"])  # (B,L,kvr+dr)
    c_kv = rms_norm(ckv_full[..., :kvr], p["kv_norm"], cfg.norm_eps)
    k_rope = ckv_full[..., kvr:]  # (B,L,dr) shared across heads
    k_rope = apply_rope(k_rope[:, None], positions[:, None], cfg.rope_theta)[:, 0]

    if cache is None:
        # train / prefill: reconstitute per-head K,V once and run blocked
        # attention (scores never materialized at (L, L)).
        H = cfg.n_heads_eff
        k_nope = jnp.einsum("blr,rhk->blhk", c_kv, p["wk_b"])  # (B,L,H,dn)
        k_full = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None], (B, L, H, dr))], axis=-1
        )
        v_full = jnp.einsum("blr,rhk->blhk", c_kv, p["wv_b"])  # (B,L,H,dv)
        q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
        o = blocked_attention(
            q_full, k_full, v_full, causal=True,
            block_q=cfg.block_q, block_kv=cfg.block_kv,
            causal_skip=cfg.causal_skip,
        )
        y = jnp.einsum("blhk,hkd->bld", o, p["wo"])
        return y, None

    idx = cache["index"]
    c_kv = jax.lax.dynamic_update_slice_in_dim(cache["c_kv"], c_kv, idx, axis=1)
    k_rope = jax.lax.dynamic_update_slice_in_dim(cache["k_rope"], k_rope, idx, axis=1)
    total = idx + L
    new_cache = {**cache, "c_kv": c_kv, "k_rope": k_rope, "index": total}
    S = c_kv.shape[1]

    if L > 1:
        # prefill into the latent cache: reconstitute per-head K/V from the
        # cached latents and run blocked attention (absorbed scores would
        # materialize (L, S) — fine for decode, catastrophic for prefill).
        H = cfg.n_heads_eff
        k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, p["wk_b"])
        k_full = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None], (B, S, H, dr))], axis=-1
        )
        v_full = jnp.einsum("bsr,rhk->bshk", c_kv, p["wv_b"])
        q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
        o = blocked_attention(
            q_full, k_full, v_full, causal=True,
            block_q=cfg.block_q, block_kv=cfg.block_kv,
            causal_skip=cfg.causal_skip,
        )
        y = jnp.einsum("blhk,hkd->bld", o, p["wo"])
        return y, new_cache

    # decode: absorbed attention over the latent cache —
    # q_nope^T (W_kb c) = (q_nope W_kb)^T c, so the cache holds only latents.
    valid = jnp.arange(S)[None, :] < total
    kpos = jnp.arange(S)[None, :]
    ok = (kpos[:, None, :] <= positions[:, :, None]) & valid[:, None, :]

    q_lat = jnp.einsum("blhk,rhk->blhr", q_nope, p["wk_b"])  # (B,L,H,kvr)
    scale = (dn + dr) ** -0.5
    s = (
        jnp.einsum("blhr,bsr->bhls", q_lat, c_kv, preferred_element_type=jnp.float32)
        + jnp.einsum("blhk,bsk->bhls", q_rope, k_rope, preferred_element_type=jnp.float32)
    ) * scale
    s = s + jnp.where(ok, 0.0, NEG_INF)[:, None]
    pw = jax.nn.softmax(s.astype(jnp.float32), axis=-1)  # (B,H,L,S)
    o_lat = jnp.einsum("bhls,bsr->blhr", pw, c_kv, preferred_element_type=jnp.float32)
    o = jnp.einsum("blhr,rhk->blhk", o_lat.astype(x.dtype), p["wv_b"])  # (B,L,H,dv)
    y = jnp.einsum("blhk,hkd->bld", o, p["wo"])
    return y, new_cache


def init_mla_cache(cfg: ArchConfig, batch: int, max_len: int, dtype) -> dict:
    return {
        "c_kv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope_dim), dtype),
        "index": jnp.zeros((), jnp.int32),
    }
