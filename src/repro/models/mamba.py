"""Mamba-1 selective-state-space mixer (falcon-mamba / Jamba layers).

Train/prefill path: *chunked* selective scan — time is split into chunks of
``chunk_len``; within a chunk the recurrence h_t = a_t h_{t-1} + b_t is an
``associative_scan`` over affine maps (all |a_t| <= 1, numerically tame), and
the (B, Di, N) state is carried across chunks with ``lax.scan``. The
(B, L, Di, N) discretized tensors therefore only ever exist one chunk at a
time — the same blocking the ``repro.kernels.mamba_scan`` Pallas kernel uses
to keep the working set in VMEM.

Decode path: O(1) per token — one affine state update plus a depthwise-conv
ring window.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .common import ArchConfig, ParamBuilder, shard


def init_mamba(pb: ParamBuilder, cfg: ArchConfig) -> dict:
    import numpy as np

    d, di, n = cfg.d_model, cfg.d_inner, cfg.ssm_state
    r, k = cfg.dt_rank, cfg.ssm_conv
    # S4D-real init for A; dt bias init so softplus(dt) spans [1e-3, 1e-1]
    a_init = np.tile(np.arange(1, n + 1, dtype=np.float32)[None, :], (di, 1))
    dt = np.exp(
        np.random.RandomState(0).uniform(np.log(1e-3), np.log(1e-1), size=(di,))
    ).astype(np.float32)
    dt_bias = dt + np.log1p(-np.exp(-dt))  # inverse softplus
    return {
        "in_proj": pb.dense((d, 2 * di), ("embed", "inner")),
        "conv_w": pb.dense((k, di), (None, "inner"), scale=k**-0.5),
        "conv_b": pb.zeros((di,), ("inner",)),
        "x_proj": pb.dense((di, r + 2 * n), ("inner", None)),
        "dt_proj": pb.dense((r, di), (None, "inner"), scale=r**-0.5),
        "dt_bias": pb.const(dt_bias, ("inner",), jnp.float32),
        "a_log": pb.const(np.log(a_init), ("inner", None), jnp.float32),
        "d_skip": pb.ones((di,), ("inner",)),
        "out_proj": pb.dense((di, d), ("inner", "embed")),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv. x: (B, L, Di), w: (K, Di) -> (B, L, Di)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    # sum_k w[k] * x[t - (K-1) + k] — K static shifts (K is 4): cheap & fusable
    out = jnp.zeros_like(x)
    L = x.shape[1]
    for k in range(K):
        out = out + w[k] * jax.lax.slice_in_dim(xp, k, k + L, axis=1)
    return out + b


def _ssm_params(p: dict, cfg: ArchConfig, xc: jax.Array):
    """xc: (B, L, Di) post-conv activations -> dt (f32), Bmat, Cmat."""
    r, n = cfg.dt_rank, cfg.ssm_state
    proj = jnp.einsum("bld,dr->blr", xc, p["x_proj"])  # (B,L,r+2n)
    dt_in, Bm, Cm = proj[..., :r], proj[..., r : r + n], proj[..., r + n :]
    dt = jnp.einsum("blr,rd->bld", dt_in, p["dt_proj"]).astype(jnp.float32)
    dt = jax.nn.softplus(dt + p["dt_bias"])  # (B,L,Di) f32
    return dt, Bm.astype(jnp.float32), Cm.astype(jnp.float32)


def selective_scan(
    xc: jax.Array,  # (B, L, Di) f32/bf16 post-conv
    dt: jax.Array,  # (B, L, Di) f32
    Bm: jax.Array,  # (B, L, N) f32
    Cm: jax.Array,  # (B, L, N) f32
    a: jax.Array,  # (Di, N) f32, negative (= -exp(a_log))
    h0: Optional[jax.Array] = None,  # (B, Di, N) carry-in state
    chunk_len: int = 256,
):
    """Chunked selective scan. Returns (y: (B,L,Di) f32, h_final: (B,Di,N))."""
    with jax.named_scope("pallas_mamba_scan"):
        return _selective_scan_impl(xc, dt, Bm, Cm, a, h0, chunk_len)


def _selective_scan_impl(xc, dt, Bm, Cm, a, h0=None, chunk_len=256):
    B, L, Di = xc.shape
    N = a.shape[1]
    Lc = min(chunk_len, L)
    h0 = jnp.zeros((B, Di, N), jnp.float32) if h0 is None else h0

    pad = (-L) % Lc  # padded steps have dt=0 => a=1, b=0: state untouched
    if pad:
        xc = jnp.pad(xc, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    nc = (L + pad) // Lc
    xcf = xc.astype(jnp.float32)

    def chunk_body(h, inp):
        xck, dtk, Bk, Ck = inp  # (B, Lc, ...)
        dta = dtk[..., None] * a  # (B,Lc,Di,N)  log of decay per step
        ak = jnp.exp(dta)
        bk = (dtk * xck)[..., None] * Bk[:, :, None, :]  # (B,Lc,Di,N)

        def combine(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a1 * a2, a2 * b1 + b2

        a_cum, b_cum = jax.lax.associative_scan(combine, (ak, bk), axis=1)
        hk = a_cum * h[:, None] + b_cum  # (B,Lc,Di,N)
        yk = jnp.einsum("blin,bln->bli", hk, Ck)  # (B,Lc,Di)
        return hk[:, -1], yk

    xs = tuple(
        t.reshape(B, nc, Lc, *t.shape[2:]).swapaxes(0, 1)
        for t in (xcf, dt, Bm, Cm)
    )
    h_final, ys = jax.lax.scan(chunk_body, h0, xs)
    y = ys.swapaxes(0, 1).reshape(B, L + pad, Di)
    if pad:
        y = y[:, :L]
    return y, h_final


def mamba_block(
    p: dict,
    cfg: ArchConfig,
    x: jax.Array,  # (B, L, D)
    positions: jax.Array,  # unused (kept for mixer-uniform signature)
    cache: Optional[dict] = None,  # {"h": (B,Di,N), "conv": (B,K-1,Di)}
    scan_impl: Optional[object] = None,  # Pallas selective scan on TPU
):
    B, L, D = x.shape
    di, n, K = cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    xz = jnp.einsum("bld,de->ble", x, p["in_proj"])
    xr, z = jnp.split(xz, 2, axis=-1)
    xr = shard(xr, "batch", "seq", "inner")
    a = -jnp.exp(p["a_log"])  # (Di, N)

    if cache is None:
        xc = jax.nn.silu(_causal_conv(xr, p["conv_w"], p["conv_b"]))
        dt, Bm, Cm = _ssm_params(p, cfg, xc)
        scan = scan_impl or selective_scan
        y, _ = scan(xc, dt, Bm, Cm, a, chunk_len=min(256, L))
        new_cache = None
    elif L == 1:
        # decode: single-token affine update
        conv_win = jnp.concatenate([cache["conv"], xr], axis=1)  # (B, K, Di)
        xc = jax.nn.silu(
            jnp.einsum("bkd,kd->bd", conv_win, p["conv_w"]) + p["conv_b"]
        )[:, None]
        dt, Bm, Cm = _ssm_params(p, cfg, xc)
        dta = dt[:, 0, :, None] * a  # (B,Di,N)
        h = jnp.exp(dta) * cache["h"] + (dt[:, 0] * xc[:, 0].astype(jnp.float32))[
            ..., None
        ] * Bm[:, 0, None, :]
        y = jnp.einsum("bin,bn->bi", h, Cm[:, 0])[:, None]  # (B,1,Di)
        new_cache = {"h": h, "conv": conv_win[:, 1:]}
    else:
        # prefill into an existing state: conv seeded from the cached window,
        # scan seeded from the cached h
        conv_in = jnp.concatenate([cache["conv"], xr], axis=1)  # (B, K-1+L, Di)
        acc = jnp.zeros_like(xr)
        for k in range(K):
            acc = acc + p["conv_w"][k] * jax.lax.slice_in_dim(conv_in, k, k + L, axis=1)
        xc = jax.nn.silu(acc + p["conv_b"])
        dt, Bm, Cm = _ssm_params(p, cfg, xc)
        scan = scan_impl or selective_scan
        y, h_final = scan(xc, dt, Bm, Cm, a, h0=cache["h"], chunk_len=min(256, L))
        new_cache = {"h": h_final, "conv": conv_in[:, -(K - 1) :]}

    y = y + xcf_skip(xc, p["d_skip"])
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = jnp.einsum("bli,id->bld", y, p["out_proj"])
    return out, new_cache


def xcf_skip(xc: jax.Array, d_skip: jax.Array) -> jax.Array:
    return xc.astype(jnp.float32) * d_skip


def init_mamba_cache(cfg: ArchConfig, batch: int, dtype) -> dict:
    return {
        "h": jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, cfg.d_inner), dtype),
    }
