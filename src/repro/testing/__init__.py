"""Test support utilities (vendored fallbacks for optional dev deps)."""
