"""Minimal drop-in for the ``hypothesis`` API surface this repo uses.

The real hypothesis is preferred (install it and this module is never
imported); in hermetic containers without it, property tests still run as
seeded random sampling: ``@given`` draws ``max_examples`` pseudo-random
examples per strategy from a deterministic per-example seed. No shrinking,
no database, no edge-case heuristics — strictly weaker than hypothesis, but
it keeps the invariants exercised and the test module collectable.

Supported: given (positional + keyword strategies), settings(max_examples,
deadline), strategies.{integers, floats, lists, tuples, text, dictionaries,
data}.
"""

from __future__ import annotations

import functools
import random as _random
import types
from typing import Any, Callable, Optional

__all__ = ["given", "settings", "strategies"]


class Strategy:
    def __init__(self, draw: Callable, label: str = "strategy") -> None:
        self._draw = draw
        self.label = label

    def example(self, rng: _random.Random) -> Any:
        return self._draw(rng)

    def __repr__(self) -> str:
        return f"<fallback {self.label}>"


def _integers(min_value: int, max_value: int) -> Strategy:
    return Strategy(
        lambda rng: rng.randint(min_value, max_value),
        f"integers({min_value}, {max_value})",
    )


def _floats(
    min_value: Optional[float] = None,
    max_value: Optional[float] = None,
    allow_nan: bool = True,
    allow_infinity: bool = True,
    width: int = 64,
) -> Strategy:
    lo = -1e9 if min_value is None else float(min_value)
    hi = 1e9 if max_value is None else float(max_value)

    def draw(rng: _random.Random) -> float:
        # mix uniform with a few magnitude-spanning draws
        if rng.random() < 0.2:
            sign = rng.choice((-1.0, 1.0))
            x = sign * (10.0 ** rng.uniform(-6, 6))
            x = min(max(x, lo), hi)
        else:
            x = rng.uniform(lo, hi)
        if width == 32:
            import numpy as np

            x = float(np.float32(x))
            x = min(max(x, lo), hi)
        return x

    return Strategy(draw, f"floats({lo}, {hi})")


def _lists(elements: Strategy, min_size: int = 0, max_size: int = 10) -> Strategy:
    return Strategy(
        lambda rng: [
            elements.example(rng) for _ in range(rng.randint(min_size, max_size))
        ],
        f"lists({elements.label})",
    )


def _tuples(*elems: Strategy) -> Strategy:
    return Strategy(
        lambda rng: tuple(e.example(rng) for e in elems),
        f"tuples[{len(elems)}]",
    )


def _text(alphabet: str = "abcdefghijklmnopqrstuvwxyz", min_size: int = 0, max_size: int = 10) -> Strategy:
    chars = list(alphabet)
    return Strategy(
        lambda rng: "".join(
            rng.choice(chars) for _ in range(rng.randint(min_size, max_size))
        ),
        "text",
    )


def _dictionaries(
    keys: Strategy, values: Strategy, min_size: int = 0, max_size: int = 10
) -> Strategy:
    def draw(rng: _random.Random) -> dict:
        n = rng.randint(min_size, max_size)
        out: dict = {}
        for _ in range(4 * max(n, 1)):
            if len(out) >= n:
                break
            out[keys.example(rng)] = values.example(rng)
        return out

    return Strategy(draw, "dictionaries")


class _DataObject:
    """Interactive draw handle (``st.data()``)."""

    def __init__(self, rng: _random.Random) -> None:
        self._rng = rng

    def draw(self, strategy: Strategy, label: Optional[str] = None) -> Any:
        return strategy.example(self._rng)


def _data() -> Strategy:
    return Strategy(lambda rng: _DataObject(rng), "data()")


strategies = types.SimpleNamespace(
    integers=_integers,
    floats=_floats,
    lists=_lists,
    tuples=_tuples,
    text=_text,
    dictionaries=_dictionaries,
    data=_data,
)


def given(*arg_strategies: Strategy, **kw_strategies: Strategy):
    def deco(fn: Callable) -> Callable:
        @functools.wraps(fn)
        def wrapper(*args: Any, **kwargs: Any):
            n = getattr(wrapper, "_fallback_max_examples", 100)
            for i in range(n):
                rng = _random.Random(0x5EED + 7919 * i)
                vals = [s.example(rng) for s in arg_strategies]
                kvals = {k: s.example(rng) for k, s in kw_strategies.items()}
                try:
                    fn(*args, *vals, **kwargs, **kvals)
                except Exception as e:
                    head = e.args[0] if e.args else repr(e)
                    e.args = (
                        f"{head}\n[hypothesis-fallback example #{i}: "
                        f"args={vals!r} kwargs={kvals!r}]",
                    ) + tuple(e.args[1:])
                    raise

        wrapper.is_hypothesis_test = True
        # pytest must not mistake strategy-bound params for fixtures: hide
        # the original signature (hypothesis does the same re-signing)
        import inspect

        del wrapper.__wrapped__
        wrapper.__signature__ = inspect.Signature([])
        return wrapper

    return deco


def settings(max_examples: int = 100, deadline: Any = None, **_ignored: Any):
    def deco(fn: Callable) -> Callable:
        fn._fallback_max_examples = max_examples
        return fn

    return deco
