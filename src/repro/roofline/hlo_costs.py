"""Trip-count-aware cost extraction from optimized HLO text.

``compiled.cost_analysis()`` visits each while-loop body ONCE, so any model
whose layers run under ``lax.scan`` (all of ours: the compiled HLO is O(1) in
depth by design) under-counts FLOPs/bytes/collectives by the trip count.
XLA's optimized HLO carries ``backend_config={"known_trip_count":{"n":N}}``
on every while op, so we walk the module call graph with multipliers:

  cost(entry) = sum(op costs) with
  cost(while) = trips x cost(body) + trips x cost(cond)
  cost(fusion/call) = cost(called computation)

Counted:
  - dot FLOPs: 2 x elems(result) x contraction extent (from operand shapes)
  - elementwise arithmetic FLOPs: elems(result) (transcendentals weighted 4x)
  - reduce / reduce-window FLOPs: elems(operand)
  - HBM traffic: for materializing ops (dot, fusion, copy, dynamic-slice/
    update, reduce(-window), gather/scatter, sort, collectives): result bytes
    + operand bytes. Ops that fuse on TPU (inside fusion computations) are
    not double counted — only fusion boundaries count.
  - collective payloads by kind, ring-algorithm weighted with the replica
    group size parsed per op.

This is a structural model (CPU-backend HLO stands in for TPU HLO); the
numbers are for roofline *terms*, not wall-clock predictions.
"""

from __future__ import annotations

import dataclasses
import json
import re
from collections import defaultdict
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1,
    "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OPLINE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)\("
)
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_SET_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")

_TRANSCENDENTAL = {
    "exponential", "log", "tanh", "logistic", "rsqrt", "sqrt", "power",
    "sine", "cosine", "expm1", "log1p", "erf", "atan2", "cbrt",
}
_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum",
    "compare", "select", "and", "or", "xor", "not", "negate", "abs",
    "floor", "ceil", "round-nearest-afz", "round-nearest-even", "sign",
    "clamp", "remainder", "shift-left", "shift-right-logical",
    "shift-right-arithmetic", "clz", "popcnt",
} | _TRANSCENDENTAL
# Ops that materialize HBM buffers in a well-fused TPU program. Pure layout /
# elementwise ops (reshape, convert, broadcast, transpose, copy ...) are
# assumed to fuse into consumers / alias on TPU even where the CPU backend
# materializes them, so they are deliberately NOT counted — the memory term
# models the fused program (see EXPERIMENTS.md §Roofline method).
_TRAFFIC_OPS = {
    "dot", "convolution", "fusion", "dynamic-slice",
    "dynamic-update-slice", "reduce", "reduce-window", "sort", "gather",
    "scatter", "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "all-reduce-start", "all-gather-start",
    "select-and-scatter", "rng", "cholesky", "triangular-solve",
}
_COLLECTIVES = {
    "all-reduce": "all-reduce",
    "all-reduce-start": "all-reduce",
    "all-gather": "all-gather",
    "all-gather-start": "all-gather",
    "reduce-scatter": "reduce-scatter",
    "all-to-all": "all-to-all",
    "collective-permute": "collective-permute",
    "collective-permute-start": "collective-permute",
}


@dataclasses.dataclass
class _Op:
    name: str
    type_text: str
    opcode: str
    operands: list
    raw: str


_SCOPE_RE = re.compile(r'op_name="[^"]*?(pallas_[\w]+)')


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    traffic_bytes: float = 0.0
    collective: dict = dataclasses.field(
        default_factory=lambda: defaultdict(lambda: {"bytes": 0.0, "weighted": 0.0, "count": 0.0})
    )
    # per named-scope attribution (jax.named_scope("pallas_*") markers around
    # regions that run as fused Pallas kernels on TPU)
    buckets: dict = dataclasses.field(
        default_factory=lambda: defaultdict(lambda: {"flops": 0.0, "traffic_bytes": 0.0})
    )

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.traffic_bytes += other.traffic_bytes * mult
        for k, v in other.collective.items():
            e = self.collective[k]
            e["bytes"] += v["bytes"] * mult
            e["weighted"] += v["weighted"] * mult
            e["count"] += v["count"] * mult
        for k, v in other.buckets.items():
            b = self.buckets[k]
            b["flops"] += v["flops"] * mult
            b["traffic_bytes"] += v["traffic_bytes"] * mult


def _shape_elems_list(type_text: str):
    out = []
    for m in _SHAPE_RE.finditer(type_text):
        dt, dims = m.groups()
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        out.append((n, _DTYPE_BYTES[dt], dims))
    return out

def _shape_bytes(type_text: str) -> int:
    return sum(n * b for n, b, _ in _shape_elems_list(type_text))


def _shape_elems(type_text: str) -> int:
    return sum(n for n, _, _ in _shape_elems_list(type_text))


def _split_args_attrs(rest: str):
    """rest = text after the opening '(' of the op. Returns (args, attrs)."""
    depth = 1
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return rest[:i], rest[i + 1 :]
    return rest, ""


def parse_module(text: str):
    """-> (computations: {name: [Op]}, entry_name)."""
    comps: dict = {}
    entry = None
    cur_name = None
    cur_ops: list = []
    for line in text.splitlines():
        if line.startswith("%") or line.startswith("ENTRY"):
            # computation header: `%name (params) -> type {` / `ENTRY %name ...`
            m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(", line)
            if m and line.rstrip().endswith("{"):
                cur_name = m.group(1)
                cur_ops = []
                comps[cur_name] = cur_ops
                if line.startswith("ENTRY"):
                    entry = cur_name
                continue
        if cur_name is None:
            continue
        if line.strip() == "}":
            cur_name = None
            continue
        m = _OPLINE_RE.match(line)
        if not m:
            continue
        name, type_text, opcode = m.groups()
        rest = line[m.end():]
        args, attrs = _split_args_attrs(rest)
        operands = re.findall(r"%([\w.\-]+)", args)
        cur_ops.append(_Op(name, type_text, opcode, operands, line))
    return comps, entry


def _group_size(raw: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(raw)
    if m:
        return int(m.group(2))
    m = _GROUPS_SET_RE.search(raw)
    if m:
        ids = [x for x in m.group(1).split(",") if x.strip()]
        return max(len(ids), 1)
    return default


def _called_comps(raw: str):
    """Names referenced by calls=/body=/condition=/branch computations."""
    out = {}
    for key in ("calls", "body", "condition", "to_apply"):
        m = re.search(rf"{key}=%?([\w.\-]+)", raw)
        if m:
            out[key] = m.group(1)
    m = re.search(r"branch_computations=\{([^}]*)\}", raw)
    if m:
        out["branches"] = [s.strip().lstrip("%") for s in m.group(1).split(",")]
    return out


class HloCostModel:
    def __init__(self, text: str, n_devices: int, debug: bool = False):
        self.comps, self.entry = parse_module(text)
        self.n_devices = n_devices
        self._memo: dict = {}
        self.unknown_trip_whiles = 0
        self.debug = debug
        self.traffic_notes: list = []  # (bytes_one_visit, op raw) if debug

    def _note_traffic(self, op: _Op, t: float):
        if self.debug:
            self.traffic_notes.append((t, op.opcode, op.raw[:200]))

    def cost(self) -> Cost:
        if self.entry is None:
            return Cost()
        return self._comp_cost(self.entry, top=True)

    # -- per computation -------------------------------------------------------
    def _comp_cost(self, comp_name: str, top: bool) -> Cost:
        key = (comp_name, top)
        if key in self._memo:
            return self._memo[key]
        total = Cost()
        symtab = {op.name: op.type_text for op in self.comps.get(comp_name, [])}
        for op in self.comps.get(comp_name, []):
            total.add(self._op_cost(op, symtab, top))
        self._memo[key] = total
        return total

    def _operand_bytes(self, op: _Op, symtab: dict) -> int:
        n = 0
        for o in op.operands:
            t = symtab.get(o)
            if t:
                n += _shape_bytes(t)
        return n

    def _op_cost(self, op: _Op, symtab: dict, top: bool) -> Cost:
        c = Cost()
        calls = _called_comps(op.raw)
        if op.opcode == "while":
            m = _TRIP_RE.search(op.raw)
            trips = int(m.group(1)) if m else 1
            if not m:
                self.unknown_trip_whiles += 1
            body = calls.get("body")
            cond = calls.get("condition")
            if body in self.comps:
                c.add(self._comp_cost(body, top=top), mult=trips)
            if cond in self.comps:
                c.add(self._comp_cost(cond, top=False), mult=trips)
            return c
        if op.opcode in ("call", "async-start"):
            tgt = calls.get("calls") or calls.get("to_apply")
            if tgt in self.comps:
                c.add(self._comp_cost(tgt, top=top))
            return c
        if op.opcode == "conditional":
            for b in calls.get("branches", []):
                if b in self.comps:
                    c.add(self._comp_cost(b, top=top))
            return c

        # collectives
        if op.opcode in _COLLECTIVES:
            kind = _COLLECTIVES[op.opcode]
            n = _group_size(op.raw, self.n_devices)
            payload = _shape_bytes(op.type_text)
            if kind == "all-reduce":
                w = 2.0 * (n - 1) / max(n, 1)
            elif kind == "collective-permute":
                w = 1.0
            else:
                w = (n - 1) / max(n, 1)
            c.collective[kind]["bytes"] += payload
            c.collective[kind]["weighted"] += payload * w
            c.collective[kind]["count"] += 1
            # per-group-size attribution: group size 2 on the 2-pod mesh is
            # cross-pod traffic (the slow links)
            gk = f"{kind}@n{n}"
            c.collective[gk]["bytes"] += payload
            c.collective[gk]["weighted"] += payload * w
            c.collective[gk]["count"] += 1
            c.traffic_bytes += payload + self._operand_bytes(op, symtab)
            return c

        # FLOPs (leaf costs below are attributed to this op's named scope;
        # sub-computation costs were attributed by their own op lines)
        leaf0_flops, leaf0_traffic = c.flops, c.traffic_bytes
        if op.opcode == "dot":
            out_elems = _shape_elems(op.type_text)
            lhs = symtab.get(op.operands[0]) if op.operands else None
            contraction = 1
            mdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.raw)
            if lhs and mdims:
                shapes = _shape_elems_list(lhs)
                if shapes:
                    dims_text = shapes[0][2]
                    dims = [int(d) for d in dims_text.split(",")] if dims_text else []
                    for i in mdims.group(1).split(","):
                        if i.strip() != "" and int(i) < len(dims):
                            contraction *= dims[int(i)]
            c.flops += 2.0 * out_elems * contraction
        elif op.opcode == "convolution":
            # rare here; approximate 2 * out_elems * (operand0 elems / batch)
            c.flops += 2.0 * _shape_elems(op.type_text)
        elif op.opcode == "fusion":
            tgt = calls.get("calls")
            if tgt in self.comps:
                c.add(self._comp_cost(tgt, top=False))
        elif op.opcode in ("reduce", "reduce-window"):
            c.flops += self._operand_elems0(op, symtab)
        elif op.opcode in _ELEMENTWISE:
            w = 4.0 if op.opcode in _TRANSCENDENTAL else 1.0
            c.flops += w * _shape_elems(op.type_text)

        # HBM traffic at fusion/materialization boundaries only
        if top and op.opcode in _TRAFFIC_OPS:
            if op.opcode == "dynamic-slice":
                # reads only the slice; the big operand is NOT streamed
                t = 2 * _shape_bytes(op.type_text)
            elif op.opcode == "dynamic-update-slice":
                # in-place: writes only the update region
                upd = symtab.get(op.operands[1]) if len(op.operands) > 1 else None
                t = 2 * _shape_bytes(upd) if upd else _shape_bytes(op.type_text)
            elif op.opcode in ("gather", "scatter"):
                # reads/writes only the gathered/scattered rows
                t = 2 * _shape_bytes(op.type_text)
            elif op.opcode == "fusion":
                t = self._fusion_traffic(op, symtab, calls.get("calls"))
            else:
                t = _shape_bytes(op.type_text) + self._operand_bytes(op, symtab)
            c.traffic_bytes += t
            self._note_traffic(op, t)

        # named-scope attribution of this op's leaf costs. For fusion ops the
        # interior flops were attributed by their own lines; attribute only
        # the boundary traffic here — but interior lines can't see traffic,
        # so a fusion whose metadata carries the scope attributes its traffic.
        leaf_flops = c.flops - leaf0_flops
        leaf_traffic = c.traffic_bytes - leaf0_traffic
        if op.opcode == "fusion":
            leaf_flops = 0.0  # interior lines attributed their own flops
        if leaf_flops or leaf_traffic:
            m = _SCOPE_RE.search(op.raw)
            if m:
                b = c.buckets[m.group(1)]
                b["flops"] += leaf_flops
                b["traffic_bytes"] += leaf_traffic
        return c

    def _operand_elems0(self, op: _Op, symtab: dict) -> int:
        if not op.operands:
            return 0
        t = symtab.get(op.operands[0])
        return _shape_elems(t) if t else 0

    # -- fusion operand narrowing ------------------------------------------------
    _NARROW_OPS = ("dynamic-slice", "gather", "slice")

    def _fusion_traffic(self, op: _Op, symtab: dict, tgt) -> float:
        """Operands that are only dynamic-sliced / gathered inside the fused
        computation stream only the slice, not the whole buffer (a scan
        reading its per-iteration slab of stacked params reads the slab); a
        root dynamic-update-slice writes only the update region."""
        called = self.comps.get(tgt, [])
        if not called:
            return _shape_bytes(op.type_text) + self._operand_bytes(op, symtab)
        param_name = {}
        consumers = defaultdict(list)
        root = None
        for cop in called:
            if cop.opcode == "parameter":
                m = re.search(r"parameter\((\d+)\)", cop.raw)
                if m:
                    param_name[int(m.group(1))] = cop.name
            for o in cop.operands:
                consumers[o].append(cop)
            if "ROOT" in cop.raw:
                root = cop
        by_name = {cop.name: cop for cop in called}
        dus_ops = [cop for cop in called if cop.opcode == "dynamic-update-slice"]

        # follow passthrough chains (convert/bitcast/... inserted around remat
        # saves) so `param -> convert -> DUS-target` still reads as aliasing
        PASSTHROUGH = {"convert", "bitcast", "reshape", "copy", "reduce-precision"}

        def terminal_consumers(name, depth=0):
            outs = []
            for x in consumers.get(name, []):
                if x.opcode in PASSTHROUGH and depth < 8:
                    outs.extend(terminal_consumers(x.name, depth + 1))
                else:
                    outs.append((x, name))
            return outs

        t = 0.0
        for i, oname in enumerate(op.operands):
            full = _shape_bytes(symtab.get(oname, ""))
            pname = param_name.get(i)
            cons = terminal_consumers(pname) if pname else []
            if cons and all(
                (x.opcode == "dynamic-update-slice" and x.operands and x.operands[0] == via)
                or x.opcode in self._NARROW_OPS
                for x, via in cons
            ) and any(x.opcode == "dynamic-update-slice" for x, _ in cons):
                # in-place update target (possibly also sliced): slices only
                t += sum(
                    _shape_bytes(x.type_text)
                    for x, _ in cons
                    if x.opcode in self._NARROW_OPS
                )
            elif cons and all(x.opcode in self._NARROW_OPS for x, _ in cons):
                narrow = sum(_shape_bytes(x.type_text) for x, _ in cons)
                t += min(narrow, full)
            else:
                t += full

        # result: if the fusion is an in-place update of a big buffer (a DUS
        # with the same element count as the fusion result), only the update
        # region is written.
        result_elems = _shape_elems(op.type_text)
        result_bytes = _shape_bytes(op.type_text)
        matching_dus = [
            cop for cop in dus_ops if _shape_elems(cop.type_text) == result_elems
        ]
        if matching_dus and result_elems:
            bpe = max(result_bytes // result_elems, 1)
            upd_bytes = 0
            for cop in matching_dus:
                upd = by_name.get(cop.operands[1]) if len(cop.operands) > 1 else None
                if upd is not None:
                    upd_bytes += _shape_elems(upd.type_text) * bpe
            t += upd_bytes if upd_bytes else result_bytes
        else:
            t += result_bytes
        return t


def hlo_costs(text: str, n_devices: int) -> dict:
    model = HloCostModel(text, n_devices)
    c = model.cost()
    coll = {
        k: {kk: float(vv) for kk, vv in v.items()} for k, v in c.collective.items()
    }
    return {
        "flops": c.flops,
        "traffic_bytes": c.traffic_bytes,
        "collectives": coll,
        "collective_bytes": sum(v["bytes"] for k, v in c.collective.items() if "@" not in k),
        "collective_weighted_bytes": sum(
            v["weighted"] for k, v in c.collective.items() if "@" not in k
        ),
        "unknown_trip_whiles": model.unknown_trip_whiles,
        "buckets": {k: dict(v) for k, v in c.buckets.items()},
    }
