"""Pallas kernel-credit substitution for the memory roofline term.

The dry-run lowers the pure-jnp reference path (Mosaic/Pallas cannot lower on
the CPU container), whose blocked-attention / selective-scan / grouped-matmul
regions materialize their working tiles in HBM — on TPU those regions run as
the ``repro.kernels`` Pallas kernels whose tiles live in VMEM. The walker
(``hlo_costs``) attributes every region's traffic to a ``pallas_*`` bucket;
this module computes what the *kernel* would actually move (inputs + outputs
+ K/V re-streams), so the roofline can report both:

  memory_raw   — the program as literally lowered (no kernels)
  memory_pallas — kernel regions' traffic replaced by their analytic IO

Assumptions (documented, deliberately simple):
  - train passes move ~3x the forward IO (fwd read/write + bwd re-read of
    inputs under remat + gradient streams);
  - flash attention re-streams K/V once per Q block row (grid order);
  - per-device sizes divide by the shard counts actually achieved by the
    rules (divisibility-checked — replicated dims divide by 1).
"""

from __future__ import annotations

import math

from repro.models.common import ArchConfig


def _shards(rules: dict, mesh_shape: dict, logical: str, dim: int) -> int:
    axis = rules.get(logical)
    if axis is None:
        return 1
    axes = axis if isinstance(axis, (tuple, list)) else (axis,)
    size = math.prod(mesh_shape.get(a, 1) for a in axes)
    return size if size > 0 and dim % size == 0 else 1


def kernel_io_bytes(
    cfg: ArchConfig,
    kind: str,  # train | prefill | decode
    seq_len: int,
    global_batch: int,
    mesh_shape: dict,
    rules: dict,
) -> dict:
    """Per-device analytic IO bytes per step for each pallas bucket."""
    B, L = global_batch, seq_len
    G = cfg.n_groups
    bpe = 2  # bf16 activations
    mult = 3.0 if kind == "train" else 1.0
    out: dict = {}

    b_sh = _shards(rules, mesh_shape, "batch", B)

    # ---- flash attention ----------------------------------------------------
    n_attn = sum(1 for s in cfg.layout if s.mixer == "attention") * G
    if cfg.encoder_layers:
        n_attn += cfg.encoder_layers + cfg.n_layers  # encoder self + cross
    if n_attn:
        if cfg.attention == "mla":
            # reconstituted per-head KV shards with the (padded) q heads
            H = KVH = cfg.n_heads_eff
            dk, dv = cfg.qk_nope_dim + cfg.qk_rope_dim, cfg.v_head_dim
            h_sh = kvh_sh = _shards(rules, mesh_shape, "heads", H)
        else:
            H, KVH = cfg.n_heads_eff, cfg.n_kv_heads
            dk = dv = cfg.head_dim
            h_sh = _shards(rules, mesh_shape, "heads", H)
            kvh_sh = _shards(rules, mesh_shape, "kv_heads", KVH)

        if kind in ("train", "prefill"):
            q = B * L * H * dk * bpe / (b_sh * h_sh)
            o = B * L * H * dv * bpe / (b_sh * h_sh)
            kv = B * L * KVH * (dk + dv) * bpe / (b_sh * kvh_sh)
            nq_rows = max(1, L // max(cfg.block_q, 1))
            restream = (nq_rows - 1) * kv
            if cfg.window and cfg.attention == "swa":
                # SWA only re-streams the in-window KV stripe
                restream = (nq_rows - 1) * kv * min(1.0, cfg.window / L)
            elif cfg.causal_skip:
                restream *= 0.5  # q-row i reads only the causal prefix
            out["pallas_flash_attention"] = mult * n_attn * (q + o + kv + restream)
        else:  # decode: dominated by one full KV-cache read per layer
            S = min(L, cfg.window) if (cfg.attention == "swa" and cfg.window) else L
            seq_sh = _shards(rules, mesh_shape, "kv_seq", S)
            kv = B * S * KVH * (dk + dv) * bpe / (b_sh * kvh_sh * seq_sh)
            out["pallas_flash_attention"] = n_attn * kv

    # ---- mamba selective scan -------------------------------------------------
    n_mamba = sum(1 for s in cfg.layout if s.mixer == "mamba") * G
    if n_mamba and kind != "decode":
        Di, N = cfg.d_inner, cfg.ssm_state
        i_sh = _shards(rules, mesh_shape, "inner", Di)
        io = (B * L * Di * (bpe + 4 + 4) + 2 * B * L * N * 4) / (b_sh * i_sh)
        out["pallas_mamba_scan"] = mult * n_mamba * io
    elif n_mamba:  # decode: state read+write per layer
        Di, N = cfg.d_inner, cfg.ssm_state
        i_sh = _shards(rules, mesh_shape, "inner", Di)
        out["pallas_mamba_scan"] = n_mamba * 2 * B * Di * N * 4 / (b_sh * i_sh)

    # ---- moe grouped matmul ---------------------------------------------------
    n_moe = sum(1 for s in cfg.layout if s.ffn == "moe") * G
    if n_moe:
        from repro.models.moe import expert_capacity

        E, D, F = cfg.n_experts, cfg.d_model, cfg.d_ff
        T = B * (L if kind != "decode" else 1)
        Gd = max(1, cfg.moe_groups)  # group-local dispatch groups
        Tg = T // Gd
        C = expert_capacity(Tg, cfg)  # matches the runtime dispatch bins
        e_sh = _shards(rules, mesh_shape, "experts", E)
        f_sh = _shards(rules, mesh_shape, "mlp", F) if e_sh == 1 else 1
        g_sh = _shards(rules, mesh_shape, "moe_group", Gd)
        groups_per_dev = max(1, Gd // g_sh)
        acts = 2 * groups_per_dev * E * C * D * bpe / e_sh
        weights = 3 * E * D * F * bpe / (e_sh * f_sh)
        out["pallas_moe_gmm"] = mult * n_moe * (acts + weights)

    return out


def apply_kernel_credit(
    raw_traffic: float,
    buckets: dict,
    io: dict,
) -> dict:
    """memory term substitution. Returns details + corrected bytes."""
    credited = raw_traffic
    detail = {}
    for name, kio in io.items():
        braw = buckets.get(name, {}).get("traffic_bytes", 0.0)
        credited = credited - braw + kio
        detail[name] = {"raw_bytes": braw, "kernel_io_bytes": kio}
    return {"corrected_traffic": max(credited, 0.0), "detail": detail}
