from .model import (
    V5E,
    HardwareSpec,
    RooflineReport,
    analyze_compiled,
    collective_bytes,
    model_flops,
)

__all__ = [
    "V5E",
    "HardwareSpec",
    "RooflineReport",
    "analyze_compiled",
    "collective_bytes",
    "model_flops",
]
