"""Roofline model for TPU v5e from compiled-HLO structure (no hardware).

Three terms per (arch x shape x mesh) cell, in seconds:

  compute    = HLO_FLOPs / (chips x peak bf16 FLOP/s)
  memory     = HLO_bytes / (chips x HBM bandwidth)
  collective = sum over collectives of (algorithm-weighted payload bytes)
               / (per-chip ICI bandwidth)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()`` (already
per-partition under SPMD: XLA reports the per-device module). Collective
bytes are parsed from the optimized HLO text (``compiled.as_text()``) —
cost_analysis does not attribute collectives, so we sum operand payloads of
every all-reduce / all-gather / reduce-scatter / all-to-all /
collective-permute, weighted by the ring-algorithm factor:

  all-reduce      2 x (n-1)/n      (reduce-scatter + all-gather)
  all-gather      (n-1)/n          (each chip receives (n-1)/n of output)
  reduce-scatter  (n-1)/n
  all-to-all      (n-1)/n
  collective-permute 1

where n = replica-group size of that op. Payload is the per-device shard
bytes (the optimized HLO shapes are already per-partition).
"""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1,
    "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    name: str
    peak_flops_bf16: float  # per chip
    hbm_bw: float  # bytes/s per chip
    ici_bw: float  # bytes/s per link direction
    hbm_bytes: float  # capacity per chip


V5E = HardwareSpec(
    name="tpu-v5e",
    peak_flops_bf16=197e12,
    hbm_bw=819e9,
    ici_bw=50e9,
    hbm_bytes=16e9,
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVE_KINDS = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\(?[^)=]*\)?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
)
_GROUPS_RE = re.compile(r"replica_groups=\{([^}]*)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_text: str) -> int:
    """Bytes of 'f32[16,128]' or tuple '(f32[2,4], u32[])'."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_text):
        dt, dims = m.groups()
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_V2_RE.search(line)
    if m:  # iota format [num_groups, group_size]
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        first = m.group(1).split("}")[0].strip("{} ")
        if first:
            return len([x for x in first.split(",") if x.strip() != ""])
    return default


def collective_bytes(hlo_text: str, n_devices: int) -> dict:
    """Parse optimized HLO: per-kind payload bytes, algorithm-weighted."""
    out = {k: {"bytes": 0, "weighted_bytes": 0.0, "count": 0} for k in _COLLECTIVE_KINDS}
    seen_starts = set()
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if not m:
            continue
        shape_text, kind = m.groups()
        # avoid double counting async pairs: `-done` ops repeat the shape
        if "-done(" in line:
            continue
        n = _group_size(line, n_devices)
        payload = _shape_bytes(shape_text)
        if kind == "all-reduce":
            w = 2.0 * (n - 1) / max(n, 1)
        elif kind == "collective-permute":
            w = 1.0
        else:
            w = (n - 1) / max(n, 1)
        out[kind]["bytes"] += payload
        out[kind]["weighted_bytes"] += payload * w
        out[kind]["count"] += 1
    out["total_bytes"] = sum(v["bytes"] for v in out.values() if isinstance(v, dict))
    out["total_weighted"] = sum(
        v["weighted_bytes"] for v in out.values() if isinstance(v, dict)
    )
    return out


def model_flops(cfg, seq_len: int, global_batch: int, kind: str) -> float:
    """MODEL_FLOPS = 6·N_active·D (train) / 2·N_active·D (inference) plus the
    attention term 2·n_attn·B·H·(dk+dv)·Σ_context (causal ⇒ L²/2; SWA caps
    the context at the window; decode ⇒ one row of length S)."""
    n_active = cfg.n_active_params()
    B, L = global_batch, seq_len
    tokens = B * (1 if kind == "decode" else L)
    mult = 6.0 if kind == "train" else 2.0
    total = mult * n_active * tokens

    # attention context flops (not part of 6ND)
    n_attn = sum(1 for s in cfg.layout if s.mixer == "attention") * cfg.n_groups
    if cfg.encoder_layers:
        n_attn += cfg.encoder_layers + cfg.n_layers  # enc self + dec cross
    if n_attn:
        if cfg.attention == "mla":
            H = cfg.n_heads
            dsum = cfg.qk_nope_dim + cfg.qk_rope_dim + cfg.v_head_dim
        else:
            H = cfg.n_heads
            dsum = 2 * cfg.head_dim
        if kind == "decode":
            ctx = min(L, cfg.window) if (cfg.attention == "swa" and cfg.window) else L
            pair_sum = B * ctx  # one new token vs S cached
        else:
            if cfg.attention == "swa" and cfg.window and cfg.window < L:
                pair_sum = B * L * cfg.window
            else:
                pair_sum = B * L * L / 2.0  # causal
        total += (mult / 2.0) * n_attn * 2.0 * H * dsum * pair_sum
    return total


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    n_devices: int
    kind: str
    hlo_gflops: float  # per device
    hlo_gbytes: float  # per device
    collectives: dict
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    model_gflops_total: float
    useful_flops_frac: float  # MODEL_FLOPS / (HLO_FLOPs * devices)
    per_device_peak_memory: Optional[float] = None
    xla_cost_analysis: Optional[dict] = None  # raw cross-check numbers
    t_memory_raw: Optional[float] = None  # memory term before kernel credit
    kernel_credit: Optional[dict] = None
    buckets: Optional[dict] = None
    note: str = ""

    def to_record(self) -> dict:
        d = dataclasses.asdict(self)
        return d

    @property
    def roofline_frac(self) -> float:
        """useful-FLOPs utilization at the roofline bound: MODEL_FLOPS /
        (chips * peak * max(terms)) — an MFU-at-bound estimate."""
        t = max(self.t_compute, self.t_memory, self.t_collective)
        if t <= 0:
            return 0.0
        return (self.model_gflops_total * 1e9) / (
            self.n_devices * V5E.peak_flops_bf16 * t
        )


def analyze_compiled(
    compiled,
    *,
    arch: str,
    shape: str,
    mesh_name: str,
    n_devices: int,
    kind: str,
    cfg,
    seq_len: int,
    global_batch: int,
    hw: HardwareSpec = V5E,
    mesh_shape: Optional[dict] = None,
    rules: Optional[dict] = None,
) -> RooflineReport:
    from .hlo_costs import hlo_costs

    hlo = compiled.as_text()
    # trip-count-aware walk of the optimized HLO (xla's cost_analysis visits
    # while bodies once — useless for scanned layers; see hlo_costs.py)
    costs = hlo_costs(hlo, n_devices)
    flops = costs["flops"]
    bytes_accessed = costs["traffic_bytes"]
    coll = {
        **costs["collectives"],
        "total_bytes": costs["collective_bytes"],
        "total_weighted": costs["collective_weighted_bytes"],
    }

    # raw XLA numbers kept as a cross-check (per-partition, loop bodies x1)
    try:
        xla_cost = compiled.cost_analysis()
        if isinstance(xla_cost, list):
            xla_cost = xla_cost[0]
        xla_raw = {
            "flops": float(xla_cost.get("flops", 0.0)),
            "bytes_accessed": float(xla_cost.get("bytes accessed", 0.0)),
        }
    except Exception:
        xla_raw = None

    # kernel credit: substitute Pallas-kernel IO for jnp-region traffic
    credit = None
    if mesh_shape is not None and rules is not None:
        from .kernel_credit import apply_kernel_credit, kernel_io_bytes

        io = kernel_io_bytes(cfg, kind, seq_len, global_batch, mesh_shape, rules)
        credit = apply_kernel_credit(bytes_accessed, costs["buckets"], io)

    t_compute = flops / hw.peak_flops_bf16
    t_memory_raw = bytes_accessed / hw.hbm_bw
    t_memory = (
        credit["corrected_traffic"] / hw.hbm_bw if credit else t_memory_raw
    )
    t_coll = coll["total_weighted"] / hw.ici_bw

    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    bottleneck = max(terms, key=terms.get)

    mfl = model_flops(cfg, seq_len, global_batch, kind)
    useful = mfl / max(flops * n_devices, 1.0)

    mem = None
    try:
        ma = compiled.memory_analysis()
        # the CPU host-platform backend reports whole-module totals across
        # all partitions; per-device = / n_devices (validated: the argument
        # size equals the full global state byte count exactly)
        mem = float(
            getattr(ma, "temp_size_in_bytes", 0)
            + getattr(ma, "argument_size_in_bytes", 0)
            + getattr(ma, "output_size_in_bytes", 0)
            - getattr(ma, "alias_size_in_bytes", 0)
        ) / max(n_devices, 1)
    except Exception:
        pass

    rep = RooflineReport(
        arch=arch,
        shape=shape,
        mesh=mesh_name,
        n_devices=n_devices,
        kind=kind,
        hlo_gflops=flops / 1e9,
        hlo_gbytes=bytes_accessed / 1e9,
        collectives=coll,
        t_compute=t_compute,
        t_memory=t_memory,
        t_collective=t_coll,
        bottleneck=bottleneck,
        model_gflops_total=mfl / 1e9,
        useful_flops_frac=useful,
        per_device_peak_memory=mem,
        xla_cost_analysis=xla_raw,
    )
    rep.t_memory_raw = t_memory_raw
    rep.kernel_credit = credit
    rep.buckets = costs["buckets"]
    return rep
