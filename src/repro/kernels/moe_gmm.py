"""Pallas TPU grouped matmul for MoE expert FFNs (SwiGLU).

One grid cell = (expert, token-block, ffn-block); the ffn axis is innermost
(sequential) so the (c_blk, D) output accumulator lives in VMEM scratch and
each w_down tile is applied as soon as its h tile is formed — gate, up, silu,
elementwise product and down-projection are fused in one VMEM residency
(MegaBlocks adapted to the MXU: dense tiles over static capacity bins instead
of CUDA block-sparse indices; the token->bin gather happens outside in the
dispatch einsum where XLA can overlap it with the previous layer).

Tile sizes default to MXU-aligned (128 rows, 256 ffn cols); the contraction
dim D stays whole per tile (weights stream (D, f_blk) slabs HBM->VMEM).

Validated on CPU via ``interpret=True`` against ``ref.reference_gmm``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gmm_kernel(
    x_ref,  # (1, c_blk, D)
    wg_ref,  # (1, D, f_blk)
    wu_ref,  # (1, D, f_blk)
    wd_ref,  # (1, f_blk, D)
    o_ref,  # (1, c_blk, D)
    acc_scr,  # (c_blk, D) f32
):
    fi = pl.program_id(2)
    nf = pl.num_programs(2)

    @pl.when(fi == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    x = x_ref[0]
    g = jax.lax.dot_general(
        x, wg_ref[0], (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    u = jax.lax.dot_general(
        x, wu_ref[0], (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    h = (jax.nn.silu(g) * u).astype(x.dtype)
    acc_scr[...] += jax.lax.dot_general(
        h, wd_ref[0], (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(fi == nf - 1)
    def _finish():
        o_ref[0] = acc_scr[...].astype(o_ref.dtype)


def moe_gmm(
    x: jax.Array,  # (E, C, D)
    w_gate: jax.Array,  # (E, D, F)
    w_up: jax.Array,  # (E, D, F)
    w_down: jax.Array,  # (E, F, D)
    *,
    block_c: int = 128,
    block_f: int = 256,
    interpret: bool = True,
) -> jax.Array:
    E, C, D = x.shape
    F = w_gate.shape[-1]
    bc = min(block_c, C)
    bf = min(block_f, F)
    nc = -(-C // bc)
    nf = -(-F // bf)
    pad_c = nc * bc - C
    pad_f = nf * bf - F
    if pad_c:
        x = jnp.pad(x, ((0, 0), (0, pad_c), (0, 0)))
    if pad_f:
        w_gate = jnp.pad(w_gate, ((0, 0), (0, 0), (0, pad_f)))
        w_up = jnp.pad(w_up, ((0, 0), (0, 0), (0, pad_f)))
        w_down = jnp.pad(w_down, ((0, 0), (0, pad_f), (0, 0)))

    out = pl.pallas_call(
        _gmm_kernel,
        grid=(E, nc, nf),
        in_specs=[
            pl.BlockSpec((1, bc, D), lambda e, ci, fi: (e, ci, 0)),
            pl.BlockSpec((1, D, bf), lambda e, ci, fi: (e, 0, fi)),
            pl.BlockSpec((1, D, bf), lambda e, ci, fi: (e, 0, fi)),
            pl.BlockSpec((1, bf, D), lambda e, ci, fi: (e, fi, 0)),
        ],
        out_specs=pl.BlockSpec((1, bc, D), lambda e, ci, fi: (e, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((E, nc * bc, D), x.dtype),
        scratch_shapes=[pltpu.VMEM((bc, D), jnp.float32)],
        interpret=interpret,
    )(x, w_gate, w_up, w_down)
    return out[:, :C] if pad_c else out
