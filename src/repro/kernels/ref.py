"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

Deliberately naive: full score matrices, dense per-expert matmuls, direct
sequential scans. Used by tests to validate the kernels across shape/dtype
sweeps, and by the models on CPU where Mosaic lowering is unavailable.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.0e38


def reference_attention(
    q: jax.Array,  # (B, Lq, H, Dh)
    k: jax.Array,  # (B, Lk, KVH, Dh)
    v: jax.Array,  # (B, Lk, KVH, Dh)
    *,
    causal: bool = True,
    window: int = 0,
) -> jax.Array:
    B, Lq, H, Dh = q.shape
    Lk, KVH = k.shape[1], k.shape[2]
    gq = H // KVH
    qg = q.reshape(B, Lq, KVH, gq, Dh)
    s = jnp.einsum(
        "bqhgd,bkhd->bhgqk", qg, k, preferred_element_type=jnp.float32
    ) * (Dh**-0.5)
    q_pos = jnp.arange(Lq)[:, None]
    k_pos = jnp.arange(Lk)[None, :]
    ok = jnp.ones((Lq, Lk), bool)
    if causal:
        ok &= k_pos <= q_pos
    if window > 0:
        ok &= k_pos > q_pos - window
    s = jnp.where(ok, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v, preferred_element_type=jnp.float32)
    return o.reshape(B, Lq, H, Dh).astype(q.dtype)


def reference_selective_scan(
    xc: jax.Array,  # (B, L, Di)
    dt: jax.Array,  # (B, L, Di) f32 (post-softplus)
    Bm: jax.Array,  # (B, L, N) f32
    Cm: jax.Array,  # (B, L, N) f32
    a: jax.Array,  # (Di, N) f32 negative
    h0: jax.Array | None = None,
):
    """Direct sequential scan over time. Returns (y (B,L,Di) f32, h_final)."""
    B, L, Di = xc.shape
    N = a.shape[1]
    h = jnp.zeros((B, Di, N), jnp.float32) if h0 is None else h0
    xcf = xc.astype(jnp.float32)

    def step(h, t):
        ab = jnp.exp(dt[:, t, :, None] * a)  # (B,Di,N)
        h = ab * h + (dt[:, t] * xcf[:, t])[..., None] * Bm[:, t, None, :]
        y = jnp.einsum("bin,bn->bi", h, Cm[:, t])
        return h, y

    h, ys = jax.lax.scan(step, h, jnp.arange(L))
    return ys.transpose(1, 0, 2), h


def reference_decode(
    q: jax.Array,  # (B, 1, H, Dh)
    k: jax.Array,  # (B, S, KVH, Dh)
    v: jax.Array,  # (B, S, KVH, Dh)
    k_pos: jax.Array,  # (B, S)
    q_pos: jax.Array,  # (B,)
    n_valid: jax.Array,  # (B,)
    *,
    window: int = 0,
) -> jax.Array:
    B, _, H, Dh = q.shape
    S, KVH = k.shape[1], k.shape[2]
    gq = H // KVH
    qg = q.reshape(B, 1, KVH, gq, Dh)
    s = jnp.einsum(
        "bqhgd,bkhd->bhgqk", qg, k, preferred_element_type=jnp.float32
    ) * (Dh**-0.5)
    ok = (k_pos <= q_pos[:, None]) & (jnp.arange(S)[None, :] < n_valid[:, None])
    if window > 0:
        ok &= k_pos > (q_pos[:, None] - window)
    s = jnp.where(ok[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v, preferred_element_type=jnp.float32)
    return o.reshape(B, 1, H, Dh).astype(q.dtype)


def reference_hash_tree(words: jax.Array, *, block_words: int = 128) -> jax.Array:
    """Pure-jnp oracle for ``hash_tree.hash_tree_state``: blockwise uint32
    tree state (sum-of-mixed, xor-of-mixed, sum-of-blocksums) with wraparound
    arithmetic — bit-exact vs the Pallas kernel and the numpy definition in
    ``repro.core.hashing.tree_state_np``. ``len(words)`` must be a multiple
    of ``block_words``."""
    w = jnp.asarray(words, dtype=jnp.uint32).reshape(-1, block_words)
    s = jnp.sum(w, axis=1, dtype=jnp.uint32)
    j = jnp.arange(s.shape[0], dtype=jnp.uint32)
    c = (j * jnp.uint32(0x9E3779B1) + jnp.uint32(0x85EBCA77)) | jnp.uint32(1)
    m = (s ^ c) * c
    h1 = jnp.sum(m, dtype=jnp.uint32)
    h2 = jax.lax.reduce(m, jnp.uint32(0), jax.lax.bitwise_xor, (0,))
    h3 = jnp.sum(s, dtype=jnp.uint32)
    return jnp.stack([h1, h2, h3])


def reference_gmm(
    x: jax.Array,  # (E, C, D) per-expert token bins
    w_gate: jax.Array,  # (E, D, F)
    w_up: jax.Array,  # (E, D, F)
    w_down: jax.Array,  # (E, F, D)
) -> jax.Array:
    g = jnp.einsum("ecd,edf->ecf", x, w_gate)
    u = jnp.einsum("ecd,edf->ecf", x, w_up)
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, w_down)
