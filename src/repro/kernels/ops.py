"""Jit'd kernel wrappers + the kernel registry handed to the models.

``kernel_set(use_pallas, interpret)`` returns the dict that
``repro.models`` threads through the layers: on TPU the Pallas kernels run
compiled; on CPU they run in interpret mode (tests) or the models fall back
to the pure-jnp references (fast path for CI).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax

from . import ref
from .flash_attention import flash_attention
from .flash_decode import flash_decode
from .hash_tree import hash_tree_state
from .mamba_scan import mamba_scan
from .moe_gmm import moe_gmm


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_kv", "interpret"))
def flash_attention_op(q, k, v, *, causal=True, window=0, block_q=128, block_kv=128, interpret=True):
    return flash_attention(
        q, k, v, causal=causal, window=window,
        block_q=block_q, block_kv=block_kv, interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=("chunk_len", "d_block", "interpret"))
def mamba_scan_op(xc, dt, Bm, Cm, a, h0=None, *, chunk_len=256, d_block=512, interpret=True):
    return mamba_scan(
        xc, dt, Bm, Cm, a, h0,
        chunk_len=chunk_len, d_block=d_block, interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=("block_c", "block_f", "interpret"))
def moe_gmm_op(x, w_gate, w_up, w_down, *, block_c=128, block_f=256, interpret=True):
    return moe_gmm(
        x, w_gate, w_up, w_down,
        block_c=block_c, block_f=block_f, interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=("blocks_per_chunk", "interpret"))
def hash_tree_op(words, *, blocks_per_chunk=64, interpret=True):
    return hash_tree_state(
        words, blocks_per_chunk=blocks_per_chunk, interpret=interpret
    )


def kernel_set(use_pallas: bool, interpret: bool = True) -> Optional[dict]:
    """The dict the model trunk consumes (keys: moe_gmm, mamba_scan)."""
    if not use_pallas:
        return None

    def _gmm(x, wg, wu, wd):
        return moe_gmm(x, wg, wu, wd, interpret=interpret)

    def _scan(xc, dt, Bm, Cm, a, h0=None, chunk_len=256):
        return mamba_scan(xc, dt, Bm, Cm, a, h0, chunk_len=chunk_len, interpret=interpret)

    def _decode(q, k, v, k_pos, q_pos, n_valid, window=0):
        return flash_decode(
            q, k, v, k_pos, q_pos, n_valid, window=window, interpret=interpret
        )

    def _hash_tree(words):
        return hash_tree_state(words, interpret=interpret)

    return {
        "moe_gmm": _gmm,
        "mamba_scan": _scan,
        "flash_decode": _decode,
        "hash_tree": _hash_tree,
    }
