"""Pallas TPU flash-decoding: single-token attention against a KV cache.

Decode attention is a memory-bound GEMV over the cache: the kernel's job is
to stream K/V exactly once HBM->VMEM and keep the softmax running stats in
scratch. Grid: (batch x kv_head, kv_blocks) with the kv axis innermost
(sequential); the q tile (gq rows — the GQA group of this KV head) stays
resident across all kv steps.

Masking is position-based (matches ``models.attention._cached_attention``):
a per-slot position array handles both linear caches (pos = slot index) and
SWA ring buffers (pos = stored absolute position); slots beyond the write
index are invalid.

Validated on CPU via ``interpret=True`` against ``ref.reference_decode``.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0e38


def _decode_kernel(
    q_ref,  # (1, gq, d)
    k_ref,  # (1, bkv, d)
    v_ref,  # (1, bkv, d)
    pos_ref,  # (1, bkv) s32 per-slot absolute positions
    qpos_ref,  # (1, 1) s32 current query position
    valid_ref,  # (1, bkv) s32 1 = slot written
    o_ref,  # (1, gq, d)
    m_scr,  # (gq, 128)
    l_scr,  # (gq, 128)
    acc_scr,  # (gq, d)
    *,
    window: int,
    scale: float,
):
    ki = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]  # (gq, d)
    k = k_ref[0]  # (bkv, d)
    v = v_ref[0]
    s = (
        jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        * scale
    )  # (gq, bkv)
    kpos = pos_ref[0]  # (bkv,)
    qpos = qpos_ref[0, 0]
    ok = (kpos <= qpos) & (valid_ref[0] > 0)
    if window > 0:
        ok &= kpos > qpos - window
    s = jnp.where(ok[None, :], s, NEG_INF)

    m_prev = m_scr[:, 0]
    l_prev = l_scr[:, 0]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=1)
    acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_scr[...] = jnp.broadcast_to(m_new[:, None], m_scr.shape)
    l_scr[...] = jnp.broadcast_to(l_new[:, None], l_scr.shape)

    @pl.when(ki == nk - 1)
    def _finish():
        l = l_scr[:, 0]
        o_ref[0] = (acc_scr[...] / jnp.maximum(l, 1e-37)[:, None]).astype(o_ref.dtype)


def flash_decode(
    q: jax.Array,  # (B, 1, H, Dh) the new token's queries
    k: jax.Array,  # (B, S, KVH, Dh) cache keys
    v: jax.Array,  # (B, S, KVH, Dh) cache values
    k_pos: jax.Array,  # (B, S) s32 absolute position per slot
    q_pos: jax.Array,  # (B,) s32 current position
    n_valid: jax.Array,  # (B,) s32 number of written slots
    *,
    window: int = 0,
    block_kv: int = 512,
    interpret: bool = True,
) -> jax.Array:
    B, Lq, H, Dh = q.shape
    assert Lq == 1, "flash_decode is single-token"
    S, KVH = k.shape[1], k.shape[2]
    gq = H // KVH
    scale = Dh**-0.5

    block_kv = min(block_kv, S)
    nk = math.ceil(S / block_kv)
    pad = nk * block_kv - S
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)))
    Sp = S + pad

    # fold: (B, 1, KVH, gq, d) -> (B*KVH, gq, d); KV -> (B*KVH, Sp, d)
    qf = q.reshape(B, KVH, gq, Dh).reshape(B * KVH, gq, Dh)
    kf = k.transpose(0, 2, 1, 3).reshape(B * KVH, Sp, Dh)
    vf = v.transpose(0, 2, 1, 3).reshape(B * KVH, Sp, Dh)
    slot = jnp.arange(Sp)[None, :]
    valid = (slot < (n_valid[:, None] + 0)) & (slot < S)
    posf = jnp.repeat(k_pos, KVH, axis=0)  # (B*KVH, Sp)
    validf = jnp.repeat(valid.astype(jnp.int32), KVH, axis=0)
    qposf = jnp.repeat(q_pos[:, None].astype(jnp.int32), KVH, axis=0)  # (B*KVH,1)

    kernel = functools.partial(_decode_kernel, window=window, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=(B * KVH, nk),
        in_specs=[
            pl.BlockSpec((1, gq, Dh), lambda b, ki: (b, 0, 0)),
            pl.BlockSpec((1, block_kv, Dh), lambda b, ki: (b, ki, 0)),
            pl.BlockSpec((1, block_kv, Dh), lambda b, ki: (b, ki, 0)),
            pl.BlockSpec((1, block_kv), lambda b, ki: (b, ki)),
            pl.BlockSpec((1, 1), lambda b, ki: (b, 0)),
            pl.BlockSpec((1, block_kv), lambda b, ki: (b, ki)),
        ],
        out_specs=pl.BlockSpec((1, gq, Dh), lambda b, ki: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * KVH, gq, Dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((gq, 128), jnp.float32),
            pltpu.VMEM((gq, 128), jnp.float32),
            pltpu.VMEM((gq, Dh), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf, posf, qposf, validf)
    return out.reshape(B, KVH, gq, Dh).reshape(B, 1, H, Dh)
