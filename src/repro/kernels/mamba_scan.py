"""Pallas TPU selective-scan kernel (Mamba-1).

Grid: (batch, d_inner blocks, time chunks) with the *chunk* axis innermost
(sequential on TPU). The (d_blk, N) recurrent state lives in VMEM scratch and
is carried across chunk grid steps — the (B, L, Di, N) discretized tensors
never exist anywhere: each timestep's (d_blk, N) slab is formed in VREGs,
folded into the state, contracted against C_t, and dropped.

This is the TPU adaptation of the CUDA selective-scan: instead of one thread
block per (batch, d-slice) staging into SRAM and syncing warps, one grid cell
owns a (d_blk) stripe, streams its x/dt/B/C chunk HBM->VMEM via BlockSpecs,
and runs the recurrence on the VPU (there is no MXU work in Mamba-1's scan —
the matmuls live in the surrounding projections).

Validated on CPU via ``interpret=True`` against ``ref.reference_selective_scan``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(
    x_ref,  # (1, Lc, d_blk)
    dt_ref,  # (1, Lc, d_blk) f32
    b_ref,  # (1, Lc, N) f32
    c_ref,  # (1, Lc, N) f32
    a_ref,  # (d_blk, N) f32
    h0_ref,  # (1, d_blk, N) f32
    y_ref,  # (1, Lc, d_blk)
    hout_ref,  # (1, d_blk, N) f32 final state (revisited; last write wins)
    h_scr,  # (d_blk, N) f32 carry across chunks
    *,
    chunk_len: int,
    seq_len: int,
):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = h0_ref[0]

    xb = x_ref[0].astype(jnp.float32)  # (Lc, d_blk)
    dtb = dt_ref[0]
    bb = b_ref[0]
    cb = c_ref[0]
    ab = a_ref[...]  # (d_blk, N)

    def step(t, h):
        live = ci * chunk_len + t < seq_len
        dt_t = dtb[t]  # (d_blk,)
        decay = jnp.exp(dt_t[:, None] * ab)  # (d_blk, N)
        h_new = decay * h + (dt_t * xb[t])[:, None] * bb[t][None, :]
        h_new = jnp.where(live, h_new, h)
        y_t = jnp.sum(h_new * cb[t][None, :], axis=1)  # (d_blk,)
        y_ref[0, pl.dslice(t, 1), :] = y_t[None].astype(y_ref.dtype)
        return h_new

    h = jax.lax.fori_loop(0, chunk_len, step, h_scr[...])
    h_scr[...] = h
    hout_ref[0] = h


def mamba_scan(
    xc: jax.Array,  # (B, L, Di)
    dt: jax.Array,  # (B, L, Di) f32
    Bm: jax.Array,  # (B, L, N) f32
    Cm: jax.Array,  # (B, L, N) f32
    a: jax.Array,  # (Di, N) f32
    h0: jax.Array | None = None,  # (B, Di, N)
    chunk_len: int = 256,
    d_block: int = 512,
    interpret: bool = True,
):
    """Pallas selective scan. Returns (y (B, L, Di) f32, h_final (B, Di, N)).

    h_final is reconstructed from a second tiny kernel-free pass? No — the
    state is also emitted: we allocate y plus an (B, nd, d_blk, N) state
    output written on the last chunk.
    """
    B, L, Di = xc.shape
    N = a.shape[1]
    Lc = min(chunk_len, L)
    db = min(d_block, Di)
    nc = -(-L // Lc)
    nd = -(-Di // db)
    pad_l = nc * Lc - L
    pad_d = nd * db - Di
    if pad_l or pad_d:
        xc = jnp.pad(xc, ((0, 0), (0, pad_l), (0, pad_d)))
        dt = jnp.pad(dt, ((0, 0), (0, pad_l), (0, pad_d)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad_l), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad_l), (0, 0)))
        a = jnp.pad(a, ((0, pad_d), (0, 0)))
    h0 = jnp.zeros((B, Di + pad_d, N), jnp.float32) if h0 is None else (
        jnp.pad(h0, ((0, 0), (0, pad_d), (0, 0))) if pad_d else h0
    )

    kernel = functools.partial(_scan_kernel, chunk_len=Lc, seq_len=L)
    y, h_out = pl.pallas_call(
        kernel,
        grid=(B, nd, nc),
        in_specs=[
            pl.BlockSpec((1, Lc, db), lambda b, di, ci: (b, ci, di)),
            pl.BlockSpec((1, Lc, db), lambda b, di, ci: (b, ci, di)),
            pl.BlockSpec((1, Lc, N), lambda b, di, ci: (b, ci, 0)),
            pl.BlockSpec((1, Lc, N), lambda b, di, ci: (b, ci, 0)),
            pl.BlockSpec((db, N), lambda b, di, ci: (di, 0)),
            pl.BlockSpec((1, db, N), lambda b, di, ci: (b, di, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Lc, db), lambda b, di, ci: (b, ci, di)),
            pl.BlockSpec((1, db, N), lambda b, di, ci: (b, di, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, nc * Lc, Di + pad_d), jnp.float32),
            jax.ShapeDtypeStruct((B, Di + pad_d, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((db, N), jnp.float32)],
        interpret=interpret,
    )(xc, dt, Bm, Cm, a, h0)
    # h_out is written every chunk step (last write wins = final state)
    if pad_l or pad_d:
        y = y[:, :L, :Di]
        h_out = h_out[:, :Di]
    return y, h_out
