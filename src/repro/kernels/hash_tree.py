"""Pallas blockwise tree-hash for large array payloads (the data plane's
content-hash kernel — see ``repro.core.hashing`` for the digest contract).

One grid cell = one chunk of ``CHUNK_BLOCKS`` level-0 blocks (128 uint32
words each). Per block ``j`` the kernel folds the words to a wraparound
uint32 blocksum ``s_j``, mixes it with a per-block odd constant
(``c_j = (j*0x9E3779B1 + 0x85EBCA77) | 1``; golden-ratio / murmur fmix
constants) into ``m_j = (s_j ^ c_j) * c_j``, and tree-combines the chunk
into a 3-word running state ``(sum m, xor m, sum s)`` held in VMEM scratch
across the sequential grid — the same init/accumulate/finish shape as
``moe_gmm``. All arithmetic wraps mod 2**32, so the result is bit-identical
to ``ref.reference_hash_tree`` (pure jnp) and to the numpy definition in
``repro.core.hashing.tree_state_np``.

Roofline audit (analytic, like the other kernels): the kernel reads
``4 * n_words`` bytes once and writes a 12-byte state — arithmetic
intensity ~= 3 ops / 4 bytes, i.e. firmly **memory-bound**; the ceiling is
DRAM bandwidth, not compute. ``B14_hotpath_throughput`` reports achieved
bytes/s against the host's memcpy roofline (the numpy path reaches
~10x sha256 on the bench host; sha256 is compute-bound at ~1 GiB/s).

Contract: input is a 1-D uint32 word array whose length is a multiple of
``TREE_BLOCK_WORDS * CHUNK_BLOCKS`` (callers slice the chunk-aligned bulk
through the kernel and finish the ragged remainder on the host — see
``repro.core.hashing._tree_state``). Validated on CPU via
``interpret=True`` against the reference.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.hashing import _TREE_GOLD, _TREE_SALT, TREE_BLOCK_WORDS

CHUNK_BLOCKS = 64  # level-0 blocks per grid cell (64 * 512 B = 32 KiB/chunk)


def hash_tree_io_bytes(n_words: int) -> dict:
    """Analytic IO for the roofline audit: one streaming read of the
    payload, one 12-byte state write."""
    return {"bytes_in": 4 * n_words, "bytes_out": 12}


def _hash_tree_kernel(w_ref, o_ref, acc_scr, *, blocks_per_chunk: int):
    ci = pl.program_id(0)
    nc = pl.num_programs(0)

    @pl.when(ci == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    w = w_ref[...]  # (blocks_per_chunk, TREE_BLOCK_WORDS) uint32
    s = jnp.sum(w, axis=1, dtype=jnp.uint32)
    base = (ci * blocks_per_chunk).astype(jnp.uint32)
    j = base + jax.lax.broadcasted_iota(jnp.uint32, (blocks_per_chunk,), 0)
    c = (j * jnp.uint32(_TREE_GOLD) + jnp.uint32(_TREE_SALT)) | jnp.uint32(1)
    m = (s ^ c) * c
    h1 = jnp.sum(m, dtype=jnp.uint32)
    h2 = jax.lax.reduce(m, jnp.uint32(0), jax.lax.bitwise_xor, (0,))
    h3 = jnp.sum(s, dtype=jnp.uint32)
    cur = acc_scr[...]
    acc_scr[...] = jnp.stack([cur[0] + h1, cur[1] ^ h2, cur[2] + h3])

    @pl.when(ci == nc - 1)
    def _finish():
        o_ref[...] = acc_scr[...]


def hash_tree_state(
    words: jax.Array,  # (n,) uint32, n % (TREE_BLOCK_WORDS * CHUNK_BLOCKS) == 0
    *,
    blocks_per_chunk: int = CHUNK_BLOCKS,
    interpret: bool = True,
) -> jax.Array:
    """Tree state ``(h1, h2, h3)`` as a (3,) uint32 array."""
    n = words.shape[0]
    chunk_words = TREE_BLOCK_WORDS * blocks_per_chunk
    if n == 0 or n % chunk_words:
        raise ValueError(
            f"hash_tree_state needs len(words) a non-zero multiple of "
            f"{chunk_words}, got {n}"
        )
    w2 = jnp.asarray(words, dtype=jnp.uint32).reshape(-1, TREE_BLOCK_WORDS)
    nchunks = n // chunk_words
    return pl.pallas_call(
        functools.partial(_hash_tree_kernel, blocks_per_chunk=blocks_per_chunk),
        grid=(nchunks,),
        in_specs=[
            pl.BlockSpec((blocks_per_chunk, TREE_BLOCK_WORDS), lambda i: (i, 0))
        ],
        out_specs=pl.BlockSpec((3,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((3,), jnp.uint32),
        scratch_shapes=[pltpu.VMEM((3,), jnp.uint32)],
        interpret=interpret,
    )(w2)


@functools.partial(jax.jit, static_argnames=("blocks_per_chunk", "interpret"))
def hash_tree_state_op(words, *, blocks_per_chunk: int = CHUNK_BLOCKS, interpret: bool = True):
    return hash_tree_state(
        words, blocks_per_chunk=blocks_per_chunk, interpret=interpret
    )
