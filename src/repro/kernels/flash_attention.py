"""Pallas TPU flash attention (causal / sliding-window / GQA).

TPU-native blocking: the grid is (batch x kv_head, q_blocks, kv_blocks) with
the KV axis innermost (sequential on TPU), so the online-softmax running
stats (m, l, acc) live in VMEM scratch and are carried across KV grid steps.
Q/K/V blocks are streamed HBM->VMEM by the BlockSpec index maps; the
(block_q, block_kv) score tile exists only in VMEM/VREGs — never in HBM.

GQA: the q-heads of one KV head are folded into the q-block rows (the kernel
sees q of shape (gq*block_q, d)) so KV tiles are fetched once per KV head —
no KV replication in VMEM.

Sliding-window / causal predication happens at two levels:
  1. whole-block skip via ``pl.when`` (no MXU work issued for dead tiles),
  2. elementwise masking on the boundary tiles.

Validated on CPU via ``interpret=True`` against ``ref.reference_attention``.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0e38


def _attn_kernel(
    q_ref,  # (1, gq*bq, d)
    k_ref,  # (1, bkv, d)
    v_ref,  # (1, bkv, d)
    o_ref,  # (1, gq*bq, d)
    m_scr,  # (gq*bq, 128) f32 running max
    l_scr,  # (gq*bq, 128) f32 running denom
    acc_scr,  # (gq*bq, d) f32 running numerator
    *,
    block_q: int,
    block_kv: int,
    seq_q: int,
    seq_kv: int,
    causal: bool,
    window: int,
    scale: float,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # block-level predication: any (q, k) pair live in this tile?
    q_lo = qi * block_q
    q_hi = q_lo + block_q - 1
    k_lo = ki * block_kv
    k_hi = k_lo + block_kv - 1
    live = k_lo < seq_kv
    if causal:
        live = jnp.logical_and(live, k_lo <= q_hi)
    if window > 0:
        live = jnp.logical_and(live, k_hi > q_lo - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0]  # (gq*bq, d)
        k = k_ref[0]  # (bkv, d)
        v = v_ref[0]
        s = (
            jax.lax.dot_general(
                q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
            )
            * scale
        )  # (gq*bq, bkv)
        # row r = (g, q): q position = q_lo + r % block_q; column c: k_lo + c
        r = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        c = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        q_pos = q_lo + jnp.remainder(r, block_q)
        k_pos = k_lo + c
        ok = (q_pos < seq_q) & (k_pos < seq_kv)
        if causal:
            ok &= k_pos <= q_pos
        if window > 0:
            ok &= k_pos > q_pos - window
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_scr[:, 0]
        l_prev = l_scr[:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=1)
        acc = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p.astype(v.dtype),
            v,
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_scr[...] = jnp.broadcast_to(m_new[:, None], m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new[:, None], l_scr.shape)
        acc_scr[...] = acc

    @pl.when(ki == nk - 1)
    def _finish():
        l = l_scr[:, 0]
        o_ref[0] = (acc_scr[...] / jnp.maximum(l, 1e-37)[:, None]).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,  # (B, Lq, H, Dh)
    k: jax.Array,  # (B, Lk, KVH, Dh)
    v: jax.Array,  # (B, Lk, KVH, Dh)
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool = True,  # CPU container: interpret; on TPU pass False
) -> jax.Array:
    B, Lq, H, Dh = q.shape
    Lk, KVH = k.shape[1], k.shape[2]
    gq = H // KVH
    scale = Dh**-0.5

    block_q = min(block_q, Lq)
    block_kv = min(block_kv, Lk)
    nq = math.ceil(Lq / block_q)
    nk = math.ceil(Lk / block_kv)
    pad_q = nq * block_q - Lq
    pad_k = nk * block_kv - Lk

    # fold GQA: (B, L, H, D) -> (B*KVH, nq*gq*block_q, D) with row layout
    # (q_block, group, q_in_block) so one q-tile = (gq, block_q) rows and one
    # grid row owns exactly one KV head.
    qf = q.reshape(B, Lq, KVH, gq, Dh)
    if pad_q:
        qf = jnp.pad(qf, ((0, 0), (0, pad_q), (0, 0), (0, 0), (0, 0)))
    qf = (
        qf.reshape(B, nq, block_q, KVH, gq, Dh)
        .transpose(0, 3, 1, 4, 2, 5)  # (B, KVH, nq, gq, bq, D)
        .reshape(B * KVH, nq * gq * block_q, Dh)
    )
    kf, vf = k, v
    if pad_k:
        kf = jnp.pad(kf, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    kf = kf.transpose(0, 2, 1, 3).reshape(B * KVH, nk * block_kv, Dh)
    vf = vf.transpose(0, 2, 1, 3).reshape(B * KVH, nk * block_kv, Dh)

    kernel = functools.partial(
        _attn_kernel,
        block_q=block_q,
        block_kv=block_kv,
        seq_q=Lq,
        seq_kv=Lk,
        causal=causal,
        window=window,
        scale=scale,
    )
    qspec = pl.BlockSpec((1, gq * block_q, Dh), lambda b, qi, ki: (b, qi, 0))
    kvspec = pl.BlockSpec((1, block_kv, Dh), lambda b, qi, ki: (b, ki, 0))
    out = pl.pallas_call(
        kernel,
        grid=(B * KVH, nq, nk),
        in_specs=[qspec, kvspec, kvspec],
        out_specs=qspec,
        out_shape=jax.ShapeDtypeStruct((B * KVH, nq * gq * block_q, Dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((gq * block_q, 128), jnp.float32),
            pltpu.VMEM((gq * block_q, 128), jnp.float32),
            pltpu.VMEM((gq * block_q, Dh), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)

    # unfold: (B*KVH, nq*gq*block_q, D) -> (B, Lq, H, D)
    out = out.reshape(B, KVH, nq, gq, block_q, Dh).transpose(0, 2, 4, 1, 3, 5)
    out = out.reshape(B, nq * block_q, H, Dh)
    if pad_q:
        out = out[:, :Lq]
    return out
