from .pipeline import TokenSource, build_data_pipeline, synthetic_batch

__all__ = ["TokenSource", "build_data_pipeline", "synthetic_batch"]
