"""Training data pipeline as a Koalja Workspace.

The stages — sample -> tokenize/pack -> batch — are declared on the typed
:class:`repro.workspace.Workspace` breadboard and wired with ports, so every
training batch is an AnnotatedValue whose travel document names the source
shard, the packing code version, and the batch content hash. A checkpoint
restored at step N can therefore name exactly which data batches went into
it (forensic reconstruction, paper §III.C).

The generator is synthetic (deterministic per (seed, step): a Zipf-ish token
sampler) — the "sensor at the edge". Real deployments drop a loader into the
`sample` task; the wiring does not change.
"""

from __future__ import annotations

from typing import Optional

import numpy as np

from repro.models.common import ArchConfig
from repro.workspace import Workspace


def synthetic_batch(
    cfg: ArchConfig, global_batch: int, seq_len: int, step: int, seed: int = 0
) -> dict:
    """Deterministic synthetic LM batch (Zipf-distributed token ids)."""
    rng = np.random.RandomState((seed * 1_000_003 + step) % (2**31 - 1))
    zipf = rng.zipf(1.3, size=(global_batch, seq_len + 1))
    tokens_full = (zipf % cfg.vocab).astype(np.int32)
    batch = {
        "tokens": tokens_full[:, :-1],
        "labels": tokens_full[:, 1:].copy(),
    }
    if cfg.encoder_layers:
        batch["frames"] = rng.randn(global_batch, cfg.frontend_len, cfg.d_model).astype(
            np.float32
        )
    if cfg.frontend == "vision":
        batch["prefix"] = rng.randn(global_batch, cfg.frontend_len, cfg.d_model).astype(
            np.float32
        )
    return batch


class TokenSource:
    """The edge sensor: emits raw document chunks at its own rate."""

    def __init__(self, cfg: ArchConfig, seq_len: int, seed: int = 0):
        self.cfg = cfg
        self.seq_len = seq_len
        self.seed = seed
        self.cursor = 0

    def sample(self) -> np.ndarray:
        rng = np.random.RandomState((self.seed * 7_368_787 + self.cursor) % (2**31 - 1))
        self.cursor += 1
        doc_len = int(rng.randint(self.seq_len // 2, self.seq_len * 2))
        return (rng.zipf(1.3, size=(doc_len,)) % self.cfg.vocab).astype(np.int32)


def build_data_pipeline(
    cfg: ArchConfig,
    global_batch: int,
    seq_len: int,
    seed: int = 0,
    rows_per_pack: Optional[int] = None,
    store=None,
    cache=None,
) -> Workspace:
    """sample -> pack -> batch declared as a Workspace circuit.

    Drive it with ``next_batch(ws, cfg)`` (samples the source until a fresh
    batch AV lands) or ``ws.sample("sample")`` for single reactive ticks.
    A lone ``ws.pull("batch")`` cannot fill the ``doc[4]``/``panel[N]``
    buffers — one pull fires the sensor once — so pull only resolves after
    the circuit has produced a batch (it then returns the cached artifact).

    ``store``/``cache`` pass through to the Workspace: a bounded
    :class:`~repro.core.store.ArtifactStore` gives the batch stream an LRU
    local tier, and the shared :class:`~repro.cache.MemoCache` means a
    replayed shard (identical docs) re-packs and re-batches for free.
    """
    src = TokenSource(cfg, seq_len, seed)
    rows = rows_per_pack or max(1, global_batch // 8)

    def sample() -> dict:
        return {"doc": src.sample()}

    def pack(doc) -> dict:
        # documents are packed/truncated into fixed (rows, seq_len+1) panels
        docs = doc if isinstance(doc, list) else [doc]
        flat = np.concatenate(docs)
        need = rows * (seq_len + 1)
        reps = int(np.ceil(need / max(flat.size, 1)))
        flat = np.tile(flat, reps)[:need]
        return {"panel": flat.reshape(rows, seq_len + 1)}

    def batch(panel) -> dict:
        panels = panel if isinstance(panel, list) else [panel]
        full = np.concatenate(panels, axis=0)[:global_batch]
        while full.shape[0] < global_batch:
            full = np.concatenate([full, full], axis=0)[:global_batch]
        return {"batch": {"tokens": full[:, :-1], "labels": full[:, 1:].copy()}}

    ws = Workspace("data", store=store, cache=cache)
    sample_t = ws.source(sample, name="sample", outputs=["doc"])
    # pack buffers 4 docs per panel; batch consumes n_panels fresh panels
    n_panels = max(1, global_batch // rows)
    pack_t = ws.task(pack, name="pack", inputs=["doc"], outputs=["panel"]).buffer(4)
    batch_t = ws.task(batch, name="batch", inputs=["panel"], outputs=["batch"]).buffer(
        n_panels
    )
    sample_t["doc"] >> pack_t["doc"]
    pack_t["panel"] >> batch_t["panel"]
    return ws


def next_batch(ws: Workspace, cfg: ArchConfig) -> dict:
    """Drive the circuit until a fresh batch AV is produced; return payload."""
    task = ws.pipeline.tasks["batch"]
    before = task.last_outputs.get("batch")
    for _ in range(64):
        ws.sample("sample")
        out = task.last_outputs.get("batch")
        if out is not None and out is not before:
            return ws.value_of(out)
    raise RuntimeError("data pipeline did not produce a batch")
