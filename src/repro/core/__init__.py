"""Koalja core: smart tasks + smart links + annotated values + provenance.

The paper's contribution (Burgess & Prangsma, 2019) as a composable layer:
data circuitry where payloads live in a tiered store, references (Annotated
Values) flow on links, every artifact carries its travel document, and both
'make' (pull) and 'reactive' (push) trigger modes share one engine.
"""

from repro.cache import ContentCache, MemoCache, snapshot_key

from .av import AnnotatedValue, Stamp, content_hash, is_ghost
from .evalloop import EvalLoop, build_eval_circuit
from .link import LinkBackpressureError, RegionFenceError, SmartLink
from .pipeline import Pipeline, PipelineManager
from .policy import InputSpec, SnapshotPolicy
from .provenance import ProvenanceRegistry
from .scheduler import Scheduler, SerialWaveRunner
from .store import ArtifactStore
from .task import ServiceCall, SmartTask, software_version_of
from .wireframe import GhostValue, ghost_run
from .wiring import build_wiring, parse_wiring

__all__ = [
    "AnnotatedValue", "Stamp", "content_hash", "is_ghost",
    "ContentCache", "MemoCache", "snapshot_key",
    "EvalLoop", "build_eval_circuit",
    "LinkBackpressureError", "RegionFenceError", "SmartLink",
    "Pipeline", "PipelineManager",
    "InputSpec", "SnapshotPolicy",
    "ProvenanceRegistry", "ArtifactStore",
    "Scheduler", "SerialWaveRunner",
    "ServiceCall", "SmartTask", "software_version_of",
    "GhostValue", "ghost_run", "build_wiring", "parse_wiring",
]
