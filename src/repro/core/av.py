"""Annotated Values — the unit of data handover in Koalja (paper §III.I).

An AnnotatedValue (AV) is *not* data. It is a message that points to a storage
location for the data, plus the metadata needed for forensic tracing:

  - a unique identifier,
  - the source task that produced it,
  - pointers (URIs) to the links and storage locations of the actual data,
  - a local timestamp referring to the clock of the source agent,
  - the accumulated travel document (stamped at every checkpoint it passes).

Payloads live in an :class:`repro.core.store.ArtifactStore`; links and tasks
move AVs only. This is the paper's central transport optimization: moving a
reference is free, moving the payload is the thing to avoid.
"""

from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Any, Optional

# Content hashing moved to repro.core.hashing in PR 8 (batched + kernelized
# data plane); the names are re-exported here because av is the historical
# import site for them throughout the engine.
from .hashing import _stable_hash_bytes, content_hash, is_ghost  # noqa: F401

_AV_COUNTER = itertools.count()


def reserve_uid_numbers(n: int) -> list:
    """Claim ``n`` consecutive-draw uid numbers from the process-global AV
    counter without minting AVs yet.

    The multi-process runtime (:mod:`repro.runtime`) mints output AVs in a
    *runner* process but their identity must live in the parent's uid space:
    the parent reserves the numbers up front, ships them with the work
    order, and the runner builds uids via ``produce(..., uid_no=...)`` — so
    a merged registry can never collide with AVs minted locally in between.
    """
    return [next(_AV_COUNTER) for _ in range(max(0, int(n)))]


@dataclasses.dataclass
class Stamp:
    """One entry in an AV's travel document (paper fig. 8/9)."""

    task: str
    event: str  # "produced" | "consumed" | "cached" | "transit" | "region" | "dropped"
    software_version: str  # code hash of the task that touched it
    timestamp: float
    region: str = "local"
    note: str = ""

    def to_record(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class AnnotatedValue:
    """Metadata wrapper around a stored payload reference."""

    uid: str
    source_task: str
    uri: str  # storage location in the ArtifactStore
    chash: str  # content hash of the payload
    created_at: float  # clock of the source agent
    region: str = "local"
    meta: dict = dataclasses.field(default_factory=dict)
    travel_document: list = dataclasses.field(default_factory=list)

    @classmethod
    def produce(
        cls,
        payload_hash: str,
        uri: str,
        source_task: str,
        software_version: str,
        region: str = "local",
        meta: Optional[dict] = None,
        uid_no: Optional[int] = None,
    ) -> "AnnotatedValue":
        if uid_no is None:
            uid_no = next(_AV_COUNTER)
        uid = f"av-{uid_no:08d}-{payload_hash[:8]}"
        av = cls(
            uid=uid,
            source_task=source_task,
            uri=uri,
            chash=payload_hash,
            created_at=time.time(),
            region=region,
            meta=dict(meta or {}),
        )
        av.stamp(source_task, "produced", software_version, region=region)
        return av

    def stamp(
        self,
        task: str,
        event: str,
        software_version: str,
        region: str = "local",
        note: str = "",
    ) -> None:
        self.travel_document.append(
            Stamp(
                task=task,
                event=event,
                software_version=software_version,
                timestamp=time.time(),
                region=region,
                note=note,
            )
        )

    @property
    def zone(self) -> Optional[str]:
        """Extended-cloud zone this AV's payload was born in (repro.topology);
        None outside a topology-bound circuit."""
        return self.meta.get("zone")

    @property
    def payload_nbytes(self) -> Optional[int]:
        """Declared payload size riding the AV (set at produce time under a
        topology) — lets placement and ledgers price transfers from metadata
        alone, never touching the payload."""
        return self.meta.get("nbytes")

    @property
    def journey(self) -> list:
        """The traveller log: ordered (task, event) pairs."""
        return [(s.task, s.event) for s in self.travel_document]

    def crossed_regions(self) -> list:
        """Region transitions — audits 'data may not leave region X' policy."""
        regions, out = [], []
        for s in self.travel_document:
            if not regions or regions[-1] != s.region:
                regions.append(s.region)
        for a, b in zip(regions, regions[1:]):
            out.append((a, b))
        return out

    def to_record(self) -> dict:
        d = dataclasses.asdict(self)
        return d
