"""Breadboard wiring language (paper fig. 5, §III.H).

Parses descriptions like::

    [tfmodel]
    (in) learn-tf (model)
    (model) server (lookup implicit)
    (in[10/2]) convert (json)
    (json, lookup implicit) predict (result)

Each line is ``(inputs) taskname (outputs)``. Input tokens may carry buffer
``[N]`` / sliding-window ``[N/k]`` annotations; the token suffix ``implicit``
marks a client-server side channel (§III.D) rather than a pipeline wire.
A leading ``[name]`` line names the circuit. Matching output->input names are
wired automatically ('each matching promise of an output (+) is matched by the
promise to consume it (-)').
"""

from __future__ import annotations

import re
from typing import Callable, Optional

from .pipeline import Pipeline
from .policy import InputSpec
from .task import SmartTask

_LINE = re.compile(r"^\(([^)]*)\)\s*([\w.\-]+)\s*\(([^)]*)\)$")


def _split_ports(text: str) -> list:
    return [p.strip() for p in text.split(",") if p.strip()]


def parse_wiring(
    text: str,
    impls: dict,
    default_mode: str = "all_new",
    modes: Optional[dict] = None,
) -> Pipeline:
    """Deprecated entry point — use ``Workspace.from_wiring(text, impls)``
    (repro.workspace), which wraps the same parser behind the typed facade."""
    from .pipeline import _deprecated

    _deprecated("parse_wiring", "Workspace.from_wiring(text, impls)")
    return build_wiring(text, impls, default_mode=default_mode, modes=modes)


def build_wiring(
    text: str,
    impls: dict,
    default_mode: str = "all_new",
    modes: Optional[dict] = None,
) -> Pipeline:
    """Build a Pipeline from a wiring description (the parsing engine).

    impls: task name -> python callable (the plugin user code).
    modes: optional per-task snapshot mode overrides.
    """
    modes = modes or {}
    name = "circuit"
    rows = []
    for raw in text.strip().splitlines():
        line = raw.strip()
        if not line or line.startswith("#"):
            continue
        m = re.match(r"^\[(\w+)\]$", line)
        if m:
            name = m.group(1)
            continue
        m = _LINE.match(line)
        if not m:
            raise ValueError(f"unparseable wiring line: {raw!r}")
        ins, task, outs = m.groups()
        rows.append((_split_ports(ins), task, _split_ports(outs)))

    pipe = Pipeline(name)
    implicit_inputs: dict = {}
    for ins, tname, outs in rows:
        if tname not in impls:
            raise KeyError(f"no implementation supplied for task {tname!r}")
        wires, implicits = [], []
        for tok in ins:
            if tok.endswith(" implicit"):
                implicits.append(tok[: -len(" implicit")].strip())
            else:
                wires.append(tok)
        # outputs may also declare 'implicit' service exposure; keep the name.
        out_names = [o.replace(" implicit", "").strip() for o in outs]
        task = SmartTask(
            name=tname,
            fn=impls[tname],
            inputs=wires,
            outputs=out_names,
            mode=modes.get(tname, default_mode),
            source=(len(wires) == 0),
        )
        pipe._add_task(task)
        implicit_inputs[tname] = implicits

    # wire matching output names to input names across tasks
    producers: dict = {}
    for ins, tname, outs in rows:
        for o in outs:
            producers.setdefault(o.replace(" implicit", "").strip(), []).append(tname)
    for ins, tname, outs in rows:
        for tok in ins:
            if tok.endswith(" implicit"):
                continue
            port = InputSpec.parse(tok).name
            for src in producers.get(port, []):
                if src != tname:
                    pipe._connect(src, port, tname, port)
    # implicit client-server edges recorded in the design map via link-less note
    pipe.implicit_edges = [
        (svc, tname) for tname, svcs in implicit_inputs.items() for svc in svcs
    ]
    return pipe
