"""Wireframing: ghost batches through the circuit (paper §III.K / §III.L).

"The most basic execution of a data pipeline is to send no real data at all.
By sending ghost batches through a pipeline, we can expose where data actually
end up being routed, in test runs prior to exposing to real data."

Ghost payloads are ``jax.ShapeDtypeStruct``s. Each task's user code is run
under ``jax.eval_shape`` — zero FLOPs, zero bytes moved — while the AV
machinery (links, stamps, visitor logs, region transits) runs for real. The
result is the routing trace plus the shape contract of every wire.

Ghost values never touch the :class:`~repro.core.store.ArtifactStore`: the
shape spec rides on the AV itself (``meta["ghost_spec"]``, ``ghost://``
URIs), so a wireframe run leaves the store's put/get counters at exactly
zero — the strongest form of the paper's transport-avoidance claim.

On the distributed side this concept *is* the multi-pod dry-run
(``repro.launch.dryrun``): lower + compile against ghost inputs proves the
sharded wiring without allocating a byte.
"""

from __future__ import annotations

from typing import Any, Optional

import jax

from .pipeline import Pipeline, PipelineManager


class GhostValue:
    """Opaque ghost for tasks whose code is not jax-traceable."""

    def __init__(self, label: str = "ghost") -> None:
        self.label = label
        self.shape = ()
        self.dtype = "ghost"
        self.nbytes = None

    def __repr__(self) -> str:
        return f"GhostValue({self.label})"


def _ghostify_fn(task_name: str, fn, outputs: list):
    def ghost_fn(**kwargs: Any):
        # Service handles pass through untouched; array ghosts stay abstract.
        try:
            specs = {
                k: v
                for k, v in kwargs.items()
                if isinstance(v, jax.ShapeDtypeStruct)
                or (isinstance(v, list) and all(isinstance(x, jax.ShapeDtypeStruct) for x in v))
            }
            if specs and len(specs) == len(kwargs):
                out = jax.eval_shape(lambda **kw: fn(**kw), **kwargs)
                if not isinstance(out, dict):
                    out = {outputs[0]: out}
                return out
        except Exception:
            pass  # non-traceable user code: fall through to opaque ghosts
        return {o: GhostValue(f"{task_name}.{o}") for o in outputs}

    return ghost_fn


def ghost_run(
    manager: PipelineManager,
    injections: dict,
    pulls: Optional[list] = None,
) -> dict:
    """Run the pipeline with ghosts.

    injections: {(task, input_name): ShapeDtypeStruct or list thereof}
    pulls: optional make-mode targets to resolve after injection.

    Returns a routing report: per-link traffic, per-task visits, and the shape
    contract discovered on every wire.
    """
    pipe = manager.pipeline
    originals = {}
    for t in pipe.tasks.values():
        originals[t.name] = t.fn
        t.fn = _ghostify_fn(t.name, t.fn, t.outputs)
    try:
        for (task, iname), spec in injections.items():
            specs = spec if isinstance(spec, list) else [spec]
            for s in specs:
                manager._inject(task, iname, s)
        manager.propagate()
        for target in pulls or []:
            manager._pull(target)
    finally:
        for t in pipe.tasks.values():
            t.fn = originals[t.name]

    contract = {}
    for link in pipe.links:
        av = None
        # last AV seen on this wire, if any, via registry lineage
        for uid in reversed(manager.registry.all_avs()):
            a = manager.registry.get_av(uid)
            if a.source_task == link.src_task:
                av = a
                break
        contract[link.name] = {
            "carried": link.avs_carried,
            "last_hash": av.chash if av else None,
        }
    return {
        "routes": contract,
        "tasks": {
            n: {"executions": t.executions}
            for n, t in pipe.tasks.items()
        },
        "design_map": manager.registry.design_map(),
    }
