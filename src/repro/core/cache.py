"""Compatibility shim — the memoization subsystem lives in :mod:`repro.cache`.

The seed grew this file into a full subsystem (memo records with forensic
back-pointers, sustainability counters, TTL purge classes); it moved out of
``repro.core`` so the engine and the policy layer can evolve separately.
All seed-era imports keep working.
"""

from repro.cache.memo import (  # noqa: F401
    ContentCache,
    MemoCache,
    make_record,
    snapshot_key,
)

__all__ = ["ContentCache", "MemoCache", "make_record", "snapshot_key"]
