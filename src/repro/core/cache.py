"""Make-style content-addressed memoization (paper §III.F / §III.J).

Cache key = (task software version, snapshot content hashes, policy config).
Unchanged inputs + unchanged code ⇒ cache hit ⇒ no recompute ("it's
unnecessary to recompile binaries that are unchanged"). A software-version
change invalidates downstream results exactly as the paper prescribes for
"software updates trigger recomputation".

Purge policy: per-entry TTL classes so caches can "purge at different rates
depending on the risk of recomputation" (§III.F Principle 2 discussion).
"""

from __future__ import annotations

import hashlib
import time
from typing import Any, Optional


def snapshot_key(software_version: str, input_hashes: dict, extra: str = "") -> str:
    parts = [software_version, extra]
    for name in sorted(input_hashes):
        v = input_hashes[name]
        if isinstance(v, (list, tuple)):
            parts.append(f"{name}=[{','.join(v)}]")
        else:
            parts.append(f"{name}={v}")
    return hashlib.sha256("|".join(parts).encode()).hexdigest()[:24]


class ContentCache:
    def __init__(self, default_ttl_s: Optional[float] = None) -> None:
        self._entries: dict = {}  # key -> (uris/hashes record, expiry)
        self.default_ttl_s = default_ttl_s
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def lookup(self, key: str) -> Optional[Any]:
        rec = self._entries.get(key)
        if rec is None:
            self.misses += 1
            return None
        value, expiry = rec
        if expiry is not None and time.time() > expiry:
            del self._entries[key]
            self.evictions += 1
            self.misses += 1
            return None
        self.hits += 1
        return value

    def insert(self, key: str, value: Any, ttl_s: Optional[float] = None) -> None:
        ttl = ttl_s if ttl_s is not None else self.default_ttl_s
        expiry = (time.time() + ttl) if ttl is not None else None
        self._entries[key] = (value, expiry)

    def invalidate_version(self, software_version_prefix: str) -> int:
        """Purge entries produced by a given software version (forensic
        recall: 'a change may be due to software errors, indicating that
        recomputation is needed')."""
        doomed = [
            k
            for k, (v, _) in self._entries.items()
            if isinstance(v, dict) and v.get("software_version", "").startswith(software_version_prefix)
        ]
        for k in doomed:
            del self._entries[k]
            self.evictions += 1
        return len(doomed)

    def purge_expired(self) -> int:
        now = time.time()
        doomed = [k for k, (_, e) in self._entries.items() if e is not None and now > e]
        for k in doomed:
            del self._entries[k]
            self.evictions += 1
        return len(doomed)

    def stats(self) -> dict:
        return {
            "entries": len(self._entries),
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
        }
