"""Batched content hashing — the vectorized half of the data plane.

Every cache key, travel document, and store ingest in Koalja starts from a
content hash. Until PR 8 that was a per-payload Python loop (`content_hash`
in ``repro.core.av``) with a collision-prone 4096-element *sampled* stripe
for large arrays. This module replaces it with a batch-first API:

- :func:`content_hash_batch` hashes a whole wave's payloads in one fused
  call: small arrays are copied into **one** shared buffer and hashed as
  slices of a single memoryview (one allocation, one sequential pass);
  large (> 4 MiB) arrays get a **full-coverage** blockwise tree digest that
  runs at memory bandwidth (~10x sha256 on this host) with bit-identical
  numpy / jnp / pallas implementations (see ``repro.kernels.hash_tree``).
- :func:`content_hash` is now a thin single-payload wrapper.

Digest compatibility contract (existing journals / memo records stay
valid):

=====================  ==========================================
tier                   digest
=====================  ==========================================
ghost (aval only)      ``sha256("ghost:{shape}:{dtype}")``        (unchanged)
array  <= 4 MiB        ``sha256(bytes + shape + dtype)``          (unchanged)
array  >  4 MiB        blockwise tree digest, full coverage       (NEW — was sampled)
pure-JSON container    ``sha256(json.dumps(sort_keys=True))``     (unchanged)
scalar (str/int/...)   ``sha256(repr(payload))``                  (unchanged)
arbitrary object       ``sha256("pickle:" + pickle.dumps)``       (NEW — was repr)
=====================  ==========================================

The last row is the cross-process fix: ``repr`` of an arbitrary object
embeds its memory address (``<... at 0x7f...>``), so identical payloads
hashed differently in every ``ProcessExecutor`` worker, silently defeating
memo dedup and ``bytes_not_moved`` parity. Pickle output is
address-free and fork-stable. When even pickle fails the repr fallback
remains, but the event is surfaced through the ``on_unstable`` callback so
the store can journal an ``unstable_hash`` anomaly instead of silently
producing a process-local digest.

Tree digest definition (the > 4 MiB tier)
-----------------------------------------
The payload bytes are viewed as little-endian uint32 words (a 0..3-byte
tail is packed LE into one extra word). Words are grouped into blocks of
``TREE_BLOCK_WORDS`` = 128; per block ``j``::

    s_j = sum(words in block j)            (uint32, wraparound)
    c_j = (j * 0x9E3779B1 + 0x85EBCA77) | 1
    m_j = (s_j ^ c_j) * c_j                (uint32, wraparound)

and the state is ``(h1, h2, h3) = (sum m_j, xor m_j, sum s_j)``; the final
digest is ``sha256(state || nbytes || shape || dtype || "tree")[:16]``.
All arithmetic wraps mod 2**32, which numpy, XLA, and Pallas implement
identically — the three backends are bit-exact (``KOALJA_HASH_BACKEND``
selects ``numpy`` (default) / ``jnp`` / ``pallas``; the jax paths exist
for accelerator offload and are validated against numpy in the tests).
"""

from __future__ import annotations

import hashlib
import json
import os
import pickle
import struct
from typing import Any, Callable, Iterable, List, Optional, Sequence

__all__ = [
    "content_hash",
    "content_hash_batch",
    "tree_state_np",
    "tree_digest",
    "hashing_stats",
    "bind_fallback_anomalies",
    "is_ghost",
    "LARGE_ARRAY_BYTES",
    "TREE_BLOCK_WORDS",
]

# Arrays at or below this many bytes keep the seed-era sha256(bytes) digest
# so existing journals and memo records stay valid; above it the sampled
# stripe is replaced by the full-coverage tree digest.
LARGE_ARRAY_BYTES = 1 << 22  # 4 MiB

TREE_BLOCK_WORDS = 128  # words per level-0 block (512 bytes)
_TREE_GOLD = 0x9E3779B1  # golden-ratio odd constant (Fibonacci hashing)
_TREE_SALT = 0x85EBCA77  # murmur3 fmix constant

# Scalar types whose repr is canonical and address-free: these keep the
# seed-era repr digest. Everything else non-JSON goes through pickle.
_STABLE_REPR_TYPES = (str, bytes, bytearray, int, float, complex, bool, type(None))

_STATS = {
    "calls": 0,  # content_hash_batch invocations
    "payloads": 0,  # payloads hashed
    "fused_bytes": 0,  # bytes that went through the shared small-array buffer
    "tree_hashes": 0,  # large arrays hashed via the tree digest
    "pickle_hashes": 0,  # payloads hashed via the pickle tier
    "unstable_hashes": 0,  # repr fallbacks (pickle failed) — process-local!
    "backend_fallbacks": 0,  # jnp/pallas kernel failures rescued by numpy
}

_HASH_BACKENDS = ("numpy", "jnp", "pallas")

# Optional anomaly sink for kernel fallbacks (bound by PipelineManager to
# registry.record_anomaly): a silently degraded backend is an operational
# event worth a forensic record, not just a counter.
_FALLBACK_SINK: Optional[Callable[[str], None]] = None


def bind_fallback_anomalies(sink: Optional[Callable[[str], None]]) -> None:
    """Route hash-backend fallback notices into an anomaly sink (typically
    ``lambda note: registry.record_anomaly("hashing", note)``). Pass None to
    unbind. The digests themselves are unaffected — the numpy path is
    bit-identical — so this is observability, not determinism."""
    global _FALLBACK_SINK
    _FALLBACK_SINK = sink


def _hash_backend() -> str:
    """The validated ``KOALJA_HASH_BACKEND`` selection. Unknown values fail
    loudly (like KOALJA_EXECUTOR / KOALJA_PLACEMENT) instead of silently
    hashing on numpy while the operator believes a kernel is running."""
    backend = os.environ.get("KOALJA_HASH_BACKEND", "numpy")
    if backend not in _HASH_BACKENDS:
        raise ValueError(
            f"KOALJA_HASH_BACKEND={backend!r} is not a hash backend "
            f"(choose from: {', '.join(_HASH_BACKENDS)})"
        )
    return backend


def hashing_stats() -> dict:
    """Counters for the hashing hot path (observability, not determinism)."""
    return dict(_STATS)


def _stable_hash_bytes(data: bytes) -> str:
    return hashlib.sha256(data).hexdigest()[:16]


def is_ghost(payload: Any) -> bool:
    """True for abstract payloads (shape+dtype but no materialized bytes):
    ``jax.ShapeDtypeStruct``, :class:`~repro.core.wireframe.GhostValue`, and
    anything else that *declares* ``nbytes = None``. Ghosts are pure
    metadata — the circuit routes them without ever touching the store.

    The check is deliberately narrow: a payload must opt in, either by being
    a ShapeDtypeStruct or by carrying an explicit ``nbytes`` of None. Real
    array-likes that merely lack an ``nbytes`` attribute (e.g. sparse
    matrices) are data, not ghosts, and go through the store."""
    if type(payload).__name__ == "ShapeDtypeStruct":
        return True
    return (
        hasattr(payload, "shape")
        and hasattr(payload, "dtype")
        and hasattr(payload, "nbytes")
        and payload.nbytes is None
    )


# ---------------------------------------------------------------------------
# tree digest (> 4 MiB arrays)
# ---------------------------------------------------------------------------


def _mix_blocks_np(s, j0: int):
    """Mix + combine uint32 blocksums ``s`` whose global block indices start
    at ``j0``. Returns the partial state ``(h1, h2, h3)`` as Python ints."""
    import numpy as np

    j = (np.arange(s.size, dtype=np.uint64) + np.uint64(j0)).astype(np.uint32)
    c = (j * np.uint32(_TREE_GOLD) + np.uint32(_TREE_SALT)) | np.uint32(1)
    m = (s ^ c) * c
    h1 = int(m.sum(dtype=np.uint32))
    h2 = int(np.bitwise_xor.reduce(m)) if m.size else 0
    h3 = int(s.sum(dtype=np.uint32))
    return h1, h2, h3


def _state_from_words(w, tail_bytes: bytes, j0: int):
    """Tree state over uint32 word array ``w`` plus an optional 0..3-byte
    tail, with block numbering starting at global index ``j0``."""
    import numpy as np

    B = TREE_BLOCK_WORDS
    nb = w.size // B
    # reduceat outruns reshape().sum(axis=1) by ~1.5x at memory-bandwidth
    # sizes; u32 addition wraps identically in any order, so the digests
    # are unchanged
    if nb:
        s = np.add.reduceat(w[: nb * B], np.arange(0, nb * B, B), dtype=np.uint32)
    else:
        s = np.empty(0, dtype=np.uint32)
    rem = w[nb * B :]
    if rem.size or tail_bytes:
        s_tail = np.uint32(rem.sum(dtype=np.uint32))
        if tail_bytes:
            s_tail = np.uint32(
                (int(s_tail) + int.from_bytes(tail_bytes, "little")) & 0xFFFFFFFF
            )
        s = np.concatenate([s, np.asarray([s_tail], dtype=np.uint32)])
    return _mix_blocks_np(s, j0)


def _combine_states(a, b):
    return (
        (a[0] + b[0]) & 0xFFFFFFFF,
        a[1] ^ b[1],
        (a[2] + b[2]) & 0xFFFFFFFF,
    )


def tree_state_np(u8) -> tuple:
    """Reference tree state over a 1-D uint8 array (pure numpy, zero-copy:
    the bulk is viewed as uint32 in place, only the <4-byte tail is packed
    separately). This is the canonical definition the jnp / pallas kernels
    must match bit-for-bit."""
    import numpy as np

    u8 = np.ascontiguousarray(u8, dtype=np.uint8).reshape(-1)
    n4 = (u8.size // 4) * 4
    w = u8[:n4].view(np.uint32)
    return _state_from_words(w, u8[n4:].tobytes(), 0)


def _tree_state(u8):
    """Dispatch the tree state to the selected backend. The jax backends
    (``KOALJA_HASH_BACKEND=jnp|pallas``) cover the chunk-aligned bulk with
    the kernel and finish the ragged remainder with numpy — bit-identical
    to the pure-numpy path by construction."""
    backend = _hash_backend()
    if backend in ("jnp", "pallas"):
        try:
            import numpy as np

            from repro.kernels.hash_tree import CHUNK_BLOCKS, hash_tree_state
            from repro.kernels.ref import reference_hash_tree

            u8 = np.ascontiguousarray(u8, dtype=np.uint8).reshape(-1)
            n4 = (u8.size // 4) * 4
            w = u8[:n4].view(np.uint32)
            cw = TREE_BLOCK_WORDS * CHUNK_BLOCKS
            nk = (w.size // cw) * cw
            if nk:
                if backend == "pallas":
                    st = hash_tree_state(w[:nk], interpret=True)
                else:
                    st = reference_hash_tree(w[:nk])
                head = (int(st[0]), int(st[1]), int(st[2]))
            else:
                head = (0, 0, 0)
            rest = _state_from_words(w[nk:], u8[n4:].tobytes(), nk // TREE_BLOCK_WORDS)
            return _combine_states(head, rest)
        except Exception as exc:
            # no jax / kernel import failure: the numpy path computes the
            # same bits, but count the degradation and leave a forensic
            # trail instead of silently eating it forever
            _STATS["backend_fallbacks"] += 1
            if _FALLBACK_SINK is not None:
                try:
                    _FALLBACK_SINK(
                        f"hash_backend_fallback: backend={backend!r} failed "
                        f"({type(exc).__name__}: {exc}); digest computed on "
                        f"numpy (bit-identical)"
                    )
                except Exception:
                    pass
    return tree_state_np(u8)


def tree_digest(arr) -> str:
    """Full-coverage digest of a large array: tree state + (nbytes, shape,
    dtype) finalized through sha256. Replaces the seed-era sampled stripe."""
    import numpy as np

    a = np.asarray(arr)
    if not a.flags["C_CONTIGUOUS"]:
        a = np.ascontiguousarray(a)
    u8 = a.reshape(-1).view(np.uint8) if a.size else np.empty(0, np.uint8)
    h1, h2, h3 = _tree_state(u8)
    trailer = f":{u8.size}:{a.shape}:{a.dtype}:tree".encode()
    return _stable_hash_bytes(struct.pack("<3I", h1, h2, h3) + trailer)


# ---------------------------------------------------------------------------
# tiered per-payload hashing
# ---------------------------------------------------------------------------


def _json_canonical(payload) -> Optional[bytes]:
    """Strict canonical JSON bytes for pure-JSON containers (no ``default``
    hook — anything non-JSON falls through to the pickle tier rather than
    being repr-embedded with a memory address)."""
    try:
        return json.dumps(payload, sort_keys=True).encode()
    except (TypeError, ValueError):
        return None


def _pickle_digest(payload, on_unstable: Optional[Callable[[str], None]]) -> str:
    try:
        if isinstance(payload, (set, frozenset)):
            # Set iteration order is hash-salted per process; canonicalize
            # by sorting when the elements allow it.
            try:
                blob = pickle.dumps(("sorted-set", sorted(payload)), protocol=4)
            except TypeError:
                blob = pickle.dumps(payload, protocol=4)
        else:
            blob = pickle.dumps(payload, protocol=4)
        _STATS["pickle_hashes"] += 1
        return _stable_hash_bytes(b"pickle:" + blob)
    except Exception:
        _STATS["unstable_hashes"] += 1
        if on_unstable is not None:
            try:
                on_unstable(
                    f"unstable_hash: payload of type "
                    f"{type(payload).__name__} is not picklable; repr digest "
                    f"is process-local"
                )
            except Exception:
                pass
        return _stable_hash_bytes(repr(payload).encode())


class _SmallArray:
    """Deferred small-array hash: bytes land in the batch's shared buffer
    and are hashed as one memoryview slice per payload (one allocation and
    one sequential pass for the whole wave)."""

    __slots__ = ("arr", "u8", "index")

    def __init__(self, arr, u8, index):
        self.arr = arr
        self.u8 = u8
        self.index = index


def _classify(payload: Any, out: list, small: list, on_unstable) -> None:
    """Hash one payload, or defer it into ``small`` for the fused pass.
    Appends the digest (or a placeholder) to ``out``."""
    try:  # numpy-like arrays
        import numpy as np

        if hasattr(payload, "shape") and hasattr(payload, "dtype"):
            if not hasattr(payload, "nbytes") or payload.nbytes is None:
                # ShapeDtypeStruct / abstract value: hash the aval.
                out.append(
                    _stable_hash_bytes(
                        f"ghost:{payload.shape}:{payload.dtype}".encode()
                    )
                )
                return
            arr = np.asarray(payload)
            if arr.dtype.hasobject:
                # Object arrays serialize as pointers under tobytes();
                # that digest was always address-garbage — pickle instead.
                out.append(_pickle_digest(payload, on_unstable))
                return
            if payload.nbytes <= LARGE_ARRAY_BYTES:  # <= 4 MiB: real bytes
                if not arr.flags["C_CONTIGUOUS"]:
                    arr = np.ascontiguousarray(arr)
                u8 = (
                    arr.reshape(-1).view(np.uint8)
                    if arr.size
                    else np.empty(0, np.uint8)
                )
                out.append(None)
                small.append(_SmallArray(arr, u8, len(out) - 1))
                return
            # Large arrays: full-coverage tree digest at memory bandwidth
            # (was: a 4096-element sampled stripe, collision-prone).
            _STATS["tree_hashes"] += 1
            out.append(tree_digest(arr))
            return
    except Exception:
        pass
    if isinstance(payload, (dict, list, tuple)):
        blob = _json_canonical(payload)
        if blob is not None:
            out.append(_stable_hash_bytes(blob))
            return
        out.append(_pickle_digest(payload, on_unstable))
        return
    if isinstance(payload, _STABLE_REPR_TYPES):
        out.append(_stable_hash_bytes(repr(payload).encode()))
        return
    out.append(_pickle_digest(payload, on_unstable))


def _fuse_small(small: List[_SmallArray], out: list) -> None:
    """One shared buffer pass for all small arrays in the batch. Digests are
    byte-identical to the seed-era ``sha256(tobytes + shape + dtype)``: the
    shared buffer just replaces N ``tobytes()`` allocations with one."""
    import numpy as np

    total = sum(s.u8.size for s in small)
    buf = np.empty(total, dtype=np.uint8)
    off = 0
    for s in small:
        n = s.u8.size
        buf[off : off + n] = s.u8
        off += n
    mv = memoryview(buf)
    _STATS["fused_bytes"] += total
    off = 0
    for s in small:
        n = s.u8.size
        h = hashlib.sha256(mv[off : off + n])
        h.update(str(s.arr.shape).encode())
        h.update(str(s.arr.dtype).encode())
        out[s.index] = h.hexdigest()[:16]
        off += n


def content_hash_batch(
    payloads: Sequence[Any],
    *,
    on_unstable: Optional[Callable[[str], None]] = None,
) -> List[str]:
    """Content hashes for a whole wave of payloads in one fused call.

    Semantics are identical to mapping :func:`content_hash` over the
    payloads (the property tests assert this); the batch form exists so
    the per-payload Python dispatch and buffer allocations are paid once
    per wave instead of once per AV. ``on_unstable`` is invoked with a
    note for every payload that fell back to a process-local repr digest
    (see :meth:`repro.core.store.ArtifactStore.bind_provenance`).
    """
    payloads = list(payloads)
    _hash_backend()  # fail loudly on a typo'd KOALJA_HASH_BACKEND up front
    _STATS["calls"] += 1
    _STATS["payloads"] += len(payloads)
    out: list = []
    small: List[_SmallArray] = []
    for payload in payloads:
        _classify(payload, out, small, on_unstable)
    if small:
        _fuse_small(small, out)
    return out


def content_hash(payload: Any, *, on_unstable=None) -> str:
    """Content hash of a payload for cache keys and travel documents.

    Thin single-payload wrapper over :func:`content_hash_batch` — see the
    module docstring for the tier table and compatibility contract.
    """
    return content_hash_batch((payload,), on_unstable=on_unstable)[0]
