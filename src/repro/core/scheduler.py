"""Event-driven ready-queue scheduler (paper §III.J, Principle 1).

The seed engine was a polling loop: ``propagate()`` rescanned *every* task in
the circuit every round until quiescence — O(rounds × tasks) work even when a
single event touched two tasks. Smart Links already carry a notification side
channel precisely so consumers stop polling; this module makes that channel
drive computation:

  - ``SmartLink.offer()`` notifies the scheduler, which enqueues exactly the
    consumer whose :class:`~repro.core.policy.SnapshotPolicy` may have become
    ready (a *dirty* mark, deduplicated).
  - ``drain()`` turns the dirty queue into **waves**: the set of tasks that
    are simultaneously ready. Each wave is handed to the executor through the
    ``run_wave(manager, tasks)`` seam — serially for
    :class:`~repro.workspace.executors.InlineExecutor`, concurrently for
    :class:`~repro.workspace.executors.ConcurrentExecutor`.
  - User code runs with emission *deferred* (``execute(emit=False)``); the
    scheduler then emits serially in wave order, so downstream arrival seqs —
    and therefore merge-FCFS snapshots — are bit-identical no matter which
    worker thread finished first.
  - Cycle control moves from global ``max_rounds`` to a **per-task fire
    budget** per drain: a cyclic circuit rate-limits only the tasks actually
    spinning, without capping unrelated work.

Suppressed notifications (``notify_threshold_s`` — arrivals faster than the
threshold coalesce, §III.J's poll-mode fast path) are caught by a *sweep*: at
quiescence the scheduler batch-polls only the links that still hold AVs, so
correctness never depends on per-event interrupts.

Make-mode ``pull()`` runs on the same machinery: an iterative postorder walk
of the target's dependency cone (back-edges skipped — the old recursion's
cycle guard) where each node executes through the same wave seam.

The scheduler's stats are the §III.F sustainability counters for *trigger*
work: ``tasks_enqueued`` (what the event engine touched) vs
``polling_scan_equivalent`` (what the seed's full-graph scan would have
touched) quantifies the polling work avoided.
"""

from __future__ import annotations

import threading
from collections import deque
from typing import TYPE_CHECKING, Optional

from .task import FiringBatch

if TYPE_CHECKING:  # pragma: no cover
    from .pipeline import PipelineManager
    from .task import SmartTask


class LoadSignals:
    """Feedback snapshot for the adaptive runtime, recomputed at wave
    boundaries by the scheduler that owns it.

    Three signals, each chosen because a knob can act on it between waves
    without touching merge order or provenance:

      - **windowed wave-width percentiles** — how much simultaneous work the
        circuit is actually presenting (p95 is what an adaptive pool sizes
        itself to; pure function of the push schedule, so identical across
        executor backends);
      - **queue-depth high-water per drain** — burst pressure the current
        drain built up before waves caught up;
      - **per-task service-time EWMAs** — wall seconds per execution
        (observability only: wall clocks vary run to run, so no
        deterministic decision may depend on them).

    Surfaced under ``stats()["scheduler"]["load"]`` and read by
    :class:`~repro.workspace.executors.AdaptiveExecutor`.
    """

    #: EWMA smoothing for per-task service seconds
    ALPHA = 0.3

    def __init__(self, window: int = 64) -> None:
        self.window = window
        self._widths: deque = deque(maxlen=window)
        self.waves_observed = 0
        self.current_wave_width = 0
        self.wave_width_p50 = 0
        self.wave_width_p95 = 0
        self.queue_depth_high_water = 0  # per-drain (current/last drain)
        self.service_ewma_s: dict = {}  # task name -> EWMA wall seconds

    @staticmethod
    def _percentile(ordered: list, q: float) -> int:
        # nearest-rank on the sorted window: deterministic, no interpolation
        idx = max(0, min(len(ordered) - 1, int(q * len(ordered) + 0.999999) - 1))
        return ordered[idx]

    def observe_wave(self, width: int) -> None:
        """Record a formed wave's width and refresh the width percentiles
        (called on the scheduler thread *before* run_wave, so an adaptive
        executor sees signals that include the wave it is about to run)."""
        self.waves_observed += 1
        self.current_wave_width = width
        self._widths.append(width)
        ordered = sorted(self._widths)
        self.wave_width_p50 = self._percentile(ordered, 0.50)
        self.wave_width_p95 = self._percentile(ordered, 0.95)

    def observe_services(self, tasks: list) -> None:
        """Fold the wave's tasks' per-execution EWMAs into the snapshot
        (tasks update their own EWMA as executions finish)."""
        for t in tasks:
            ewma = getattr(t, "service_ewma_s", None)
            if ewma is not None:
                self.service_ewma_s[t.name] = ewma

    @property
    def recommended_workers(self) -> int:
        """Pool size the signals suggest: the p95 wave width (at least 1).
        Deterministic for a given push schedule — the adaptive executor
        clamps it to its own [min, max] band."""
        return max(1, int(self.wave_width_p95))

    def snapshot(self) -> dict:
        ewmas = dict(sorted(self.service_ewma_s.items()))
        return {
            "waves_observed": self.waves_observed,
            "wave_width_window": len(self._widths),
            "current_wave_width": self.current_wave_width,
            "wave_width_p50": self.wave_width_p50,
            "wave_width_p95": self.wave_width_p95,
            "queue_depth_high_water_last_drain": self.queue_depth_high_water,
            "recommended_workers": self.recommended_workers,
            "service_ewma_s": ewmas,
            "service_ewma_max_s": max(ewmas.values()) if ewmas else None,
        }


class SerialWaveRunner:
    """Default wave backend: run a wave's tasks one after another on the
    calling thread (the engine-level analogue of ``InlineExecutor``; used
    when a ``PipelineManager`` is driven without a Workspace executor)."""

    def run_wave(self, manager: "PipelineManager", tasks: list) -> list:
        return [
            (t.name, t.execute(manager.store, manager.registry, manager.cache, emit=False))
            for t in tasks
        ]

    def __repr__(self) -> str:
        return "SerialWaveRunner()"


class Scheduler:
    """Notification-driven ready queue over one pipeline.

    Owned by :class:`~repro.core.pipeline.PipelineManager`; subscribes to
    every link's notification channel and is marked dirty directly by
    ``_inject`` (edge arrivals have no link to notify on).
    """

    def __init__(self, manager: "PipelineManager", fire_budget: int = 100) -> None:
        self.manager = manager
        self.fire_budget = fire_budget
        # dict-as-ordered-set: insertion order is wave order (determinism)
        self._dirty: dict = {}
        self._lock = threading.Lock()
        self._subscribed: set = set()
        # tasks dropped by the fire budget resume on the next drain (the
        # seed's "call propagate() again to keep a cycle going" semantics)
        self._throttled: set = set()
        # -- stats (trigger-work sustainability counters) ------------------
        self.waves = 0
        # widest wave formed: the parallelism a pooled backend (thread or
        # process, repro.runtime) could extract from this circuit — width 1
        # everywhere means a pool buys nothing
        self.max_wave_width = 0
        self.tasks_enqueued = 0
        self.tasks_executed = 0
        self.notifications_received = 0
        self.queue_depth_high_water = 0
        self.polling_scan_equivalent = 0
        self.budget_exhausted = 0
        self.sweeps = 0
        self.pulls = 0
        # adaptive-runtime feedback snapshot (wave widths, queue pressure,
        # service EWMAs), recomputed at wave boundaries in drain()
        self.load = LoadSignals()
        self._drain_depth_high = 0  # queue high-water within current drain
        self._subscribe_links()

    # ------------------------------------------------------------------
    # notification intake
    # ------------------------------------------------------------------

    def _subscribe_links(self) -> None:
        """Idempotently subscribe to every link (links wired after manager
        construction — legacy direct-engine use — are picked up on the next
        drain)."""
        for link in self.manager.pipeline.links:
            if id(link) not in self._subscribed:
                self._subscribed.add(id(link))
                link.subscribe(self._on_notify)
                # overflow drops on this link log a 'dropped' visit (and
                # journal record) instead of silently vanishing
                link.bind_provenance(self.manager.registry)

    def _on_notify(self, link, av) -> None:
        with self._lock:
            self.notifications_received += 1
        self.mark_dirty(link.dst_task)

    def mark_dirty(self, task_name: str, external: bool = True) -> None:
        """Enqueue a task whose policy may have become ready (deduplicated).

        ``external=False`` marks a *self-requeue*: the task is still ready
        from data already in its policy buffers (no new arrival). Requeues
        drain pre-buffered work and are exempt from the fire budget — only
        arrival-driven fires (the ones a cycle feeds on) are budgeted,
        matching the seed's unbounded ``while ready()`` inner loop on
        acyclic circuits.
        """
        with self._lock:
            entry = self._dirty.get(task_name)
            if entry is None:
                self._dirty[task_name] = external
                self.tasks_enqueued += 1
                depth = len(self._dirty)
                if depth > self.queue_depth_high_water:
                    self.queue_depth_high_water = depth
                if depth > self._drain_depth_high:
                    self._drain_depth_high = depth
            elif external and not entry:
                self._dirty[task_name] = True

    # ------------------------------------------------------------------
    # reactive mode: waves until quiescence
    # ------------------------------------------------------------------

    def _runner(self):
        return self.manager.executor

    def drain(self) -> dict:
        """Process the ready queue to quiescence. Returns the fired map
        (task -> [out_avs per firing], in firing order) — the contract of
        the old polling ``propagate()``."""
        self._subscribe_links()
        mgr = self.manager
        tasks = mgr.pipeline.tasks
        n_tasks = len(tasks)
        fired: dict = {}
        budgets: dict = {}
        with self._lock:
            self._drain_depth_high = len(self._dirty)
        throttled, self._throttled = self._throttled, set()
        for name in throttled:  # fresh budget, pick up where the cap hit
            self.mark_dirty(name)
        while True:
            wave = self._form_wave(tasks, budgets)
            if not wave:
                # poll-mode fast path: arrivals whose notifications were
                # suppressed (notify_threshold_s) still sit on links; one
                # batch sweep coalesces them. ingest() empties the link
                # queues, so this converges.
                if self._sweep():
                    continue
                break
            self.waves += 1
            if len(wave) > self.max_wave_width:
                self.max_wave_width = len(wave)
            # wave boundary: refresh the load signals an AdaptiveExecutor
            # will read inside the run_wave call below
            self.load.observe_wave(len(wave))
            with self._lock:
                self.load.queue_depth_high_water = self._drain_depth_high
            # A polling engine would have scanned every task this round.
            self.polling_scan_equivalent += n_tasks
            # Extended-cloud placement happens here, on the scheduler thread,
            # with the wave's snapshots already ingested: a data-gravity
            # policy sees the exact pending input bytes per zone, and the
            # assignment is deterministic across executor backends.
            if mgr.placement is not None:
                mgr.placement.place_wave(mgr, wave)
            results = self._runner().run_wave(mgr, wave)
            self.tasks_executed += len(results)
            self.load.observe_services(wave)
            # Emission is serialized in wave order: downstream arrival seqs
            # (merge FCFS) are identical across Inline/Concurrent backends.
            # A coalescing task returns a FiringBatch; each firing emits in
            # its original order, so seqs match the uncoalesced schedule.
            for task, (name, out) in zip(wave, results):
                firings = out if isinstance(out, FiringBatch) else [out]
                for out_avs in firings:
                    self._relieve_backpressure(task, tasks)
                    task._emit(out_avs)
                    fired.setdefault(name, []).append(out_avs)
            # A task may still be ready from already-buffered data (no new
            # notification will come for it) — requeue it. external=False:
            # draining one's own buffers is not arrival-driven work, so it
            # is exempt from the cycle fire budget (seed semantics).
            for task in wave:
                if task.policy.ready():
                    self.mark_dirty(task.name, external=False)
        # the polling engine needed one extra full scan to detect quiescence
        self.polling_scan_equivalent += n_tasks
        return fired

    def _form_wave(self, tasks: dict, budgets: dict) -> list:
        with self._lock:
            dirty = list(self._dirty.items())
            self._dirty.clear()
        candidates, charged = [], {}
        for name, external in dirty:
            t = tasks.get(name)
            if t is None:
                continue
            t.ingest()  # drain links into the policy (always, for sweep convergence)
            if external and budgets.get(name, 0) >= self.fire_budget:
                # arrival-driven refire over budget: a cycle spinning. Drop
                # it for this drain; it resumes (fresh budget) next drain.
                self.budget_exhausted += 1
                self._throttled.add(name)
                continue
            if t.ready():
                candidates.append(t)
                charged[name] = external
        # Glitch avoidance: a task whose direct producer is also ready in
        # this wave would fire on a stale/partial snapshot (e.g. the short
        # leg of a diamond under swap_new_for_old). Defer it one wave so it
        # sees the producer's fresh output — unless deferral would empty the
        # wave entirely (a cycle of mutually-ready tasks), where everyone
        # runs and the fire budget bounds the spin.
        names = {t.name for t in candidates}
        wave, deferred = [], []
        for t in candidates:
            upstream_firing = any(
                l.src_task in names and l.src_task != t.name
                for l in t.in_links.values()
            )
            (deferred if upstream_firing else wave).append(t)
        if not wave:
            wave, deferred = candidates, []
        for t in deferred:
            # revisit right after this wave emits, keeping the arrival flag
            self.mark_dirty(t.name, external=charged[t.name])
        for t in wave:
            if charged[t.name]:  # only arrival-driven fires count (cycles)
                budgets[t.name] = budgets.get(t.name, 0) + 1
        return wave

    def _relieve_backpressure(self, task: "SmartTask", tasks: dict) -> None:
        """In-engine relief valve for ``overflow='block'`` links: the drain
        thread is both producer and (via ingest) consumer, so blocking on a
        full link would only stall the engine until the timeout and then
        fail. Before emitting, drain any full block-policy out-link into its
        consumer's policy buffer and queue the consumer — no loss, no
        stall. True blocking applies to producers on *other* threads (e.g.
        a sensor thread offering into the circuit)."""
        for links in task.out_links.values():
            for link in links:
                if (
                    link.capacity is not None
                    and link.overflow == "block"
                    and link.peek_count() >= link.capacity
                ):
                    dst = tasks.get(link.dst_task)
                    if dst is not None:
                        dst.ingest()
                        self.mark_dirty(dst.name, external=False)

    def _sweep(self) -> bool:
        """Batch-poll links that still hold AVs (suppressed notifications);
        returns True if any consumer was enqueued."""
        found = False
        for link in self.manager.pipeline.links:
            if link.peek_count() > 0:
                self.mark_dirty(link.dst_task)
                found = True
        if found:
            self.sweeps += 1
        return found

    # ------------------------------------------------------------------
    # make mode: dependency-cone pull
    # ------------------------------------------------------------------

    def pull(self, target: str) -> dict:
        """Resolve one task's outputs, rebuilding dependencies backwards.

        Iterative postorder over the dependency cone (the old recursion,
        without re-entry); back-edges are skipped, which is exactly the
        recursive cycle guard's "reuse last outputs" behaviour. Each node
        executes through the wave seam, so pull-mode work runs under the
        same executor as reactive waves.
        """
        self.pulls += 1
        tasks = self.manager.pipeline.tasks
        if target not in tasks:
            raise KeyError(f"no task {target!r} in pipeline")
        order = self._dependency_postorder(tasks, target)
        results: dict = {}
        for name in order:
            t = tasks[name]
            t.ingest()
            if t.ready() or (t.source and not t.input_specs):
                results[name] = self._execute_one(t)
            elif t.last_outputs:
                results[name] = dict(t.last_outputs)
            else:
                raise RuntimeError(
                    f"pull({name}): dependencies produced no data and no prior "
                    f"outputs exist (pending={t.policy.stats()['pending']})"
                )
        return results[target]

    @staticmethod
    def _dependency_postorder(tasks: dict, target: str) -> list:
        order: list = []
        state: dict = {target: "visiting"}
        deps = lambda n: [l.src_task for l in tasks[n].in_links.values()]  # noqa: E731
        stack = [(target, iter(deps(target)))]
        while stack:
            name, it = stack[-1]
            child = next((d for d in it if state.get(d) is None), None)
            if child is not None:
                state[child] = "visiting"
                stack.append((child, iter(deps(child))))
            else:
                state[name] = "done"
                order.append(name)
                stack.pop()
        return order

    def _execute_one(self, task: "SmartTask") -> dict:
        if self.manager.placement is not None:
            self.manager.placement.place_wave(self.manager, [task])
        self.load.observe_wave(1)
        [(_, out)] = self._runner().run_wave(self.manager, [task])
        self.load.observe_services([task])
        firings = out if isinstance(out, FiringBatch) else [out]
        for out_avs in firings:
            self._relieve_backpressure(task, self.manager.pipeline.tasks)
            task._emit(out_avs)
        self.tasks_executed += 1
        return firings[-1] if firings else {}

    # ------------------------------------------------------------------

    def stats(self) -> dict:
        with self._lock:
            depth = len(self._dirty)
        enq = self.tasks_enqueued
        scan = self.polling_scan_equivalent
        return {
            "backend": type(self._runner()).__name__,
            "waves": self.waves,
            "max_wave_width": self.max_wave_width,
            "tasks_enqueued": enq,
            "tasks_executed": self.tasks_executed,
            "notifications_received": self.notifications_received,
            "queue_depth": depth,
            "queue_depth_high_water": self.queue_depth_high_water,
            # the §III.F-style avoided-work counter: what the seed's
            # full-graph polling loop would have scanned for the same runs
            "polling_scan_equivalent": scan,
            "scan_reduction_x": scan / enq if enq else None,
            "budget_exhausted": self.budget_exhausted,
            "sweeps": self.sweeps,
            "pulls": self.pulls,
            "fire_budget": self.fire_budget,
            "load": self.load.snapshot(),
        }

    def __repr__(self) -> str:
        return (
            f"Scheduler(waves={self.waves}, enqueued={self.tasks_enqueued}, "
            f"backend={type(self._runner()).__name__})"
        )
