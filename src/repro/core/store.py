"""Tiered artifact store with the paper's rho placement policy (§III.F eq. 1).

Two tiers model the paper's "near and far" storage (§III.G):

  - ``local``  — in-process dict (device/host memory analogue): fast, bounded.
  - ``object`` — a directory on disk standing in for S3/MinIO object storage:
                 slower, durable, unbounded.

The critical ratio  rho = avg latency(local) / avg latency(object)  is measured
online from actual get() calls; placement policy consults it. The paper "bets on
network attached storage" — we encode that as: artifacts above
``local_bytes_limit`` go to the object tier, small/hot artifacts stay local, and
Principle 2 (cache close to dependents) lets a consumer *pin* a remote artifact
into its local tier.
"""

from __future__ import annotations

import io
import os
import pickle
import threading
import time
from typing import Any, Optional

import numpy as np

from .av import content_hash


class _Timer:
    def __init__(self) -> None:
        self.total = 0.0
        self.count = 0

    def add(self, dt: float) -> None:
        self.total += dt
        self.count += 1

    @property
    def avg(self) -> float:
        return self.total / self.count if self.count else 0.0


class ArtifactStore:
    """Content-addressed, tiered payload store. URIs: ``local://h``, ``object://h``."""

    def __init__(
        self,
        object_dir: Optional[str] = None,
        local_bytes_limit: int = 1 << 28,  # 256 MiB of "device/host" tier
        region: str = "local",
    ) -> None:
        self._local: dict = {}
        self._local_bytes = 0
        self.local_bytes_limit = local_bytes_limit
        self.object_dir = object_dir
        self.region = region
        self._lock = threading.RLock()
        self._lat = {"local": _Timer(), "object": _Timer()}
        self.puts = 0
        self.gets = 0
        self.bytes_moved_to_object = 0
        if object_dir:
            os.makedirs(object_dir, exist_ok=True)

    # -- rho policy ---------------------------------------------------------
    @property
    def rho(self) -> float:
        """avg latency(internal storage) / avg latency(network storage).

        rho < 1 means local is faster (the usual case); the placement policy
        only spills to the object tier on capacity, mirroring the paper's
        conclusion to bet on network storage for bulk, local for hot sets.
        """
        lo, ob = self._lat["local"].avg, self._lat["object"].avg
        if ob == 0.0:
            return 0.0
        return lo / ob

    @staticmethod
    def _nbytes(payload: Any) -> int:
        if hasattr(payload, "nbytes") and payload.nbytes is not None:
            return int(payload.nbytes)
        try:
            return len(pickle.dumps(payload, protocol=4))
        except Exception:
            return 1 << 12

    # -- API ----------------------------------------------------------------
    def put(self, payload: Any, prefer: Optional[str] = None) -> tuple:
        """Store payload; return (uri, content_hash). Reference-dedup by hash."""
        h = content_hash(payload)
        nbytes = self._nbytes(payload)
        with self._lock:
            self.puts += 1
            if f"local://{h}" in self._uris():
                return f"local://{h}", h
            tier = prefer
            if tier is None:
                tier = (
                    "local"
                    if self._local_bytes + nbytes <= self.local_bytes_limit
                    else "object"
                )
            if tier == "object" and self.object_dir is None:
                tier = "local"  # no object tier configured
            if tier == "local":
                self._local[h] = payload
                self._local_bytes += nbytes
                return f"local://{h}", h
            path = os.path.join(self.object_dir, h + ".pkl")
            if not os.path.exists(path):
                t0 = time.perf_counter()
                with open(path, "wb") as f:
                    self._dump(payload, f)
                self._lat["object"].add(time.perf_counter() - t0)
                self.bytes_moved_to_object += nbytes
            return f"object://{h}", h

    def get(self, uri: str) -> Any:
        tier, h = uri.split("://", 1)
        self.gets += 1
        t0 = time.perf_counter()
        if tier == "local":
            payload = self._local[h]
            self._lat["local"].add(time.perf_counter() - t0)
            return payload
        path = os.path.join(self.object_dir, h + ".pkl")
        with open(path, "rb") as f:
            payload = self._load(f)
        self._lat["object"].add(time.perf_counter() - t0)
        return payload

    def pin_local(self, uri: str) -> str:
        """Principle 2: cache a (possibly remote) artifact close to a dependent."""
        tier, h = uri.split("://", 1)
        if tier == "local":
            return uri
        payload = self.get(uri)
        with self._lock:
            self._local[h] = payload
            self._local_bytes += self._nbytes(payload)
        return f"local://{h}"

    def evict_local(self, uri: str) -> None:
        _, h = uri.split("://", 1)
        with self._lock:
            payload = self._local.pop(h, None)
            if payload is not None:
                self._local_bytes -= self._nbytes(payload)

    def has(self, uri: str) -> bool:
        tier, h = uri.split("://", 1)
        if tier == "local":
            return h in self._local
        return self.object_dir is not None and os.path.exists(
            os.path.join(self.object_dir, h + ".pkl")
        )

    def _uris(self):
        return {f"local://{k}" for k in self._local}

    # Arrays via np.save for fidelity; everything else via pickle.
    @staticmethod
    def _dump(payload: Any, f: io.IOBase) -> None:
        if isinstance(payload, np.ndarray):
            f.write(b"NPY0")
            np.save(f, payload, allow_pickle=False)
        else:
            f.write(b"PKL0")
            pickle.dump(payload, f, protocol=4)

    @staticmethod
    def _load(f: io.IOBase) -> Any:
        tag = f.read(4)
        if tag == b"NPY0":
            return np.load(f, allow_pickle=False)
        return pickle.load(f)

    def stats(self) -> dict:
        return {
            "puts": self.puts,
            "gets": self.gets,
            "local_bytes": self._local_bytes,
            "bytes_moved_to_object": self.bytes_moved_to_object,
            "rho": self.rho,
        }
