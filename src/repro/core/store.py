"""Tiered artifact store with the paper's rho placement policy (§III.F eq. 1).

Two tiers model the paper's "near and far" storage (§III.G):

  - ``local``  — in-process dict (device/host memory analogue): fast, bounded,
                 LRU-managed.
  - ``object`` — a directory on disk standing in for S3/MinIO object storage:
                 slower, durable, unbounded.

The critical ratio  rho = avg latency(local) / avg latency(object)  is measured
online from actual get() calls; placement policy consults it. The paper "bets on
network attached storage" — we encode that as: artifacts above
``local_bytes_limit`` go to the object tier, small/hot artifacts stay local
(evicting least-recently-used entries to the object tier on pressure), and
Principle 2 (cache close to dependents) lets a consumer *pin* a remote artifact
into its local tier — ``prefetch`` does so for a whole snapshot's inputs ahead
of execution, counting cross-region traffic for the region audit.

Transport avoidance is counted, not just claimed: a ``put`` whose content hash
is already resident moves zero bytes and credits ``bytes_not_moved`` — the
reference-handover half of the paper's sustainability argument (the memo layer
in :mod:`repro.cache` counts the recompute-avoidance half).
"""

from __future__ import annotations

import io
import os
import pickle
import threading
import time
from collections import OrderedDict
from typing import Any, Iterable, Optional, Union

import numpy as np

from .hashing import content_hash_batch


class _Timer:
    def __init__(self) -> None:
        self.total = 0.0
        self.count = 0

    def add(self, dt: float) -> None:
        self.total += dt
        self.count += 1

    @property
    def avg(self) -> float:
        return self.total / self.count if self.count else 0.0


class ArtifactStore:
    """Content-addressed, tiered payload store. URIs: ``local://h``, ``object://h``.

    The local tier is an LRU: ``get``/``put``/``pin_local`` refresh recency,
    and inserts over ``local_bytes_limit`` spill the least-recently-used
    entries to the object tier. Without an object tier there is nowhere safe
    to spill, so the local tier is allowed to grow past the limit rather than
    drop the only copy of a payload.
    """

    def __init__(
        self,
        object_dir: Optional[str] = None,
        local_bytes_limit: int = 1 << 28,  # 256 MiB of "device/host" tier
        region: str = "local",
    ) -> None:
        self._local: OrderedDict = OrderedDict()  # hash -> payload, LRU order
        self._local_bytes = 0
        self._sizes: dict = {}  # hash -> nbytes (every hash ever seen)
        self.local_bytes_limit = local_bytes_limit
        self.object_dir = object_dir
        self.region = region
        self._lock = threading.RLock()
        self._lat = {"local": _Timer(), "object": _Timer()}
        self.puts = 0
        self.gets = 0
        self.pins = 0
        self.prefetches = 0
        self.bytes_moved_to_object = 0
        self.bytes_not_moved = 0
        self.bytes_spilled = 0
        self.evictions_local = 0
        self.cross_region_pins = 0
        self.cross_region_bytes = 0
        # cross-process sharing counters (repro.runtime): payloads staged
        # into / registered from the shared object tier — the bytes that
        # moved via storage so they would NOT have to move over a pipe
        self.publishes = 0
        self.bytes_published = 0
        self.adopts = 0
        # payloads whose content hash fell back to a process-local repr
        # digest (not even picklable) — each one is journaled as an
        # ``unstable_hash`` anomaly through the bound registry
        self.unstable_hashes = 0
        # zone-local tier (repro.topology adaptive runtime): which content
        # hashes have a replica resident in which zone. Fed by task births,
        # cross-zone materializations, and edge injections; consulted on
        # memo hits so a cache hit in zone Z is served from a Z-local
        # replica (never forcing a cross-zone transfer) when one exists.
        self._zone_residents: dict = {}  # zone -> set of content hashes
        self.zone_local_serves = 0  # zone_resident() checks that said yes
        self._provenance = None
        if object_dir:
            os.makedirs(object_dir, exist_ok=True)

    # -- zone-local resident index (adaptive runtime, repro.topology) --------
    def note_zone_resident(self, chash: str, zone: Optional[str]) -> None:
        """Record that a replica of ``chash`` is resident in ``zone``."""
        if zone is None:
            return
        with self._lock:
            self._zone_residents.setdefault(zone, set()).add(chash)

    def zone_resident(self, chash: str, zone: Optional[str]) -> bool:
        """Is a replica of ``chash`` resident in ``zone``? A True answer on
        a memo hit means the hit is served zone-locally (counted)."""
        if zone is None:
            return False
        with self._lock:
            hit = chash in self._zone_residents.get(zone, ())
            if hit:
                self.zone_local_serves += 1
            return hit

    def zone_resident_counts(self) -> dict:
        with self._lock:
            return {z: len(s) for z, s in sorted(self._zone_residents.items())}

    def bind_provenance(self, registry: Any) -> None:
        """Give the store a registry to journal ``unstable_hash`` anomalies
        through: a payload that defeats even the pickle hash tier gets a
        process-local digest, which silently breaks memo dedup across
        workers — that deserves a forensic record, not a silent repr."""
        self._provenance = registry

    def _on_unstable(self, note: str) -> None:
        self.unstable_hashes += 1
        reg = self._provenance
        if reg is not None:
            try:
                reg.record_anomaly("store", note)
            except Exception:
                pass

    # -- rho policy ---------------------------------------------------------
    @property
    def rho(self) -> float:
        """avg latency(internal storage) / avg latency(network storage).

        rho < 1 means local is faster (the usual case); the placement policy
        only spills to the object tier on capacity, mirroring the paper's
        conclusion to bet on network storage for bulk, local for hot sets.
        """
        lo, ob = self._lat["local"].avg, self._lat["object"].avg
        if ob == 0.0:
            return 0.0
        return lo / ob

    @staticmethod
    def _nbytes(payload: Any) -> int:
        if hasattr(payload, "nbytes") and payload.nbytes is not None:
            return int(payload.nbytes)
        try:
            return len(pickle.dumps(payload, protocol=4))
        except Exception:
            return 1 << 12

    def _object_path(self, h: str) -> Optional[str]:
        if self.object_dir is None:
            return None
        return os.path.join(self.object_dir, h + ".pkl")

    def _in_object(self, h: str) -> bool:
        path = self._object_path(h)
        return path is not None and os.path.exists(path)

    def _write_object(self, h: str, payload: Any, nbytes: int) -> None:
        path = self._object_path(h)
        if os.path.exists(path):
            return
        t0 = time.perf_counter()
        # Write-then-rename: the object tier is shared across worker
        # processes (repro.runtime), and a writer killed mid-write must
        # never leave a half-file at the content-addressed path — existence
        # of the final path is the "resident" signal everyone trusts.
        tmp = f"{path}.tmp.{os.getpid()}"
        with open(tmp, "wb") as f:
            self._dump(payload, f)
        os.replace(tmp, path)
        self._lat["object"].add(time.perf_counter() - t0)
        self.bytes_moved_to_object += nbytes

    # -- LRU management -----------------------------------------------------
    def _insert_local(self, h: str, payload: Any, nbytes: int) -> None:
        """Caller holds the lock. Insert (or refresh) a local entry, then
        shed LRU entries to the object tier if over the limit — never the
        entry just inserted (a pin must stick even when oversized)."""
        if h in self._local:
            self._local.move_to_end(h)
            return
        self._local[h] = payload
        self._local_bytes += nbytes
        self._sizes[h] = nbytes
        self._enforce_limit(keep=h)

    def _enforce_limit(self, keep: Optional[str] = None) -> None:
        if self.object_dir is None:
            return  # nowhere safe to spill
        while self._local_bytes > self.local_bytes_limit:
            victim = next((h for h in self._local if h != keep), None)
            if victim is None:
                break
            payload = self._local.pop(victim)
            nbytes = self._sizes.get(victim, self._nbytes(payload))
            self._local_bytes -= nbytes
            if not self._in_object(victim):
                self._write_object(victim, payload, nbytes)
                self.bytes_spilled += nbytes
            self.evictions_local += 1

    # -- API ----------------------------------------------------------------
    def put(self, payload: Any, prefer: Optional[str] = None) -> tuple:
        """Store payload; return (uri, content_hash). Reference-dedup by hash:
        re-putting resident content moves zero bytes (counted). Thin wrapper
        over :meth:`put_batch` — the engine's ingest seam."""
        uri, h, _ = self.put_batch((payload,), prefer=prefer)[0]
        return uri, h

    def put_batch(
        self,
        payloads: Iterable[Any],
        prefer: Optional[str] = None,
        hashes: Optional[list] = None,
    ) -> list:
        """Store a wave's payloads in one fused call: all content hashes are
        computed through :func:`content_hash_batch` (one buffer pass for the
        small-array tier), then every placement decision happens under ONE
        lock acquisition. Per-payload semantics and counters are identical
        to N calls to :meth:`put`. ``hashes`` lets a caller that already
        batch-hashed the payloads (e.g. ``finish_execution``) skip the
        rehash. Returns ``[(uri, chash, nbytes), ...]``."""
        payloads = list(payloads)
        if hashes is None:
            hashes = content_hash_batch(payloads, on_unstable=self._on_unstable)
        sizes = [self._nbytes(p) for p in payloads]
        out = []
        with self._lock:
            for payload, h, nbytes in zip(payloads, hashes, sizes):
                out.append((self._put_locked(payload, h, nbytes, prefer), h, nbytes))
        return out

    def _put_locked(self, payload: Any, h: str, nbytes: int, prefer: Optional[str]) -> str:
        self.puts += 1
        self._sizes.setdefault(h, nbytes)
        if h in self._local:
            self._local.move_to_end(h)
            self.bytes_not_moved += nbytes
            return f"local://{h}"
        if prefer != "local" and self._in_object(h):
            self.bytes_not_moved += nbytes
            return f"object://{h}"
        tier = prefer
        if tier is None:
            tier = "local" if nbytes <= self.local_bytes_limit else "object"
        if tier == "object" and self.object_dir is None:
            tier = "local"  # no object tier configured
        if tier == "local":
            self._insert_local(h, payload, nbytes)
            return f"local://{h}"
        self._write_object(h, payload, nbytes)
        return f"object://{h}"

    def get(self, uri: str) -> Any:
        """Resolve a reference to its payload. The tier in the URI is a
        placement *hint*, not a location contract: a ``local://`` reference
        whose entry was LRU-spilled after the URI was issued falls back to
        the object tier transparently (content addressing means the hash is
        the identity; the tier may drift underneath old AVs and memo
        records)."""
        tier, h = uri.split("://", 1)
        if tier == "ghost":
            raise KeyError(
                f"ghost artifact {uri} has no payload — ghost runs never "
                f"materialize (§III.K); the spec rides on the AV metadata"
            )
        self.gets += 1
        t0 = time.perf_counter()
        if tier == "local":
            with self._lock:
                if h in self._local:
                    payload = self._local[h]
                    self._local.move_to_end(h)
                    self._lat["local"].add(time.perf_counter() - t0)
                    return payload
            if not self._in_object(h):
                raise KeyError(h)
        path = self._object_path(h)
        with open(path, "rb") as f:
            payload = self._load(f)
        self._lat["object"].add(time.perf_counter() - t0)
        return payload

    def pin_local(self, uri: str, *, region: Optional[str] = None) -> str:
        """Principle 2: cache a (possibly remote) artifact close to a
        dependent. Idempotent — re-pinning a resident hash refreshes recency
        and counts no bytes. ``region`` is the artifact's origin region;
        pins crossing into this store's region are tallied for the audit."""
        tier, h = uri.split("://", 1)
        with self._lock:
            if h in self._local:
                self._local.move_to_end(h)
                return f"local://{h}"
        payload = self.get(uri)
        nbytes = self._sizes.get(h) or self._nbytes(payload)
        with self._lock:
            if h not in self._local:
                self.pins += 1
                if region is not None and region != self.region:
                    self.cross_region_pins += 1
                    self.cross_region_bytes += nbytes
                self._insert_local(h, payload, nbytes)
        return f"local://{h}"

    def prefetch(self, refs: Iterable[Union[str, tuple]]) -> int:
        """Pin a batch of artifacts ahead of a consumer forming a snapshot.

        ``refs`` holds ``uri`` strings or ``(uri, origin_region)`` pairs;
        ghost references are skipped (nothing to move). Returns the number
        of artifacts now resident in the local tier.
        """
        n = 0
        self.prefetches += 1
        for ref in refs:
            uri, region = ref if isinstance(ref, tuple) else (ref, None)
            if uri.startswith("ghost://"):
                continue
            self.pin_local(uri, region=region)
            n += 1
        return n

    def evict_local(self, uri: str) -> None:
        """Drop a local entry. With an object tier configured, the payload is
        spilled there first if it holds no copy, so the artifact stays
        resolvable. Without an object tier the caller is explicitly
        discarding the only copy — later ``get``s of this hash will raise."""
        _, h = uri.split("://", 1)
        with self._lock:
            payload = self._local.pop(h, None)
            if payload is None:
                return
            nbytes = self._sizes.get(h, self._nbytes(payload))
            self._local_bytes -= nbytes
            if self.object_dir is not None and not self._in_object(h):
                self._write_object(h, payload, nbytes)
                self.bytes_spilled += nbytes
            self.evictions_local += 1

    # -- cross-process sharing (repro.runtime) -------------------------------
    def ensure_object_dir(self) -> str:
        """Make sure this store has an on-disk object tier and return its
        path. The object directory is the only payload channel worker
        processes share with the parent — a store born without one (the
        common in-memory default) gets a per-store temp directory the first
        time a process pool spins up."""
        import tempfile

        with self._lock:
            if self.object_dir is None:
                self.object_dir = tempfile.mkdtemp(prefix="koalja-store-")
            else:
                os.makedirs(self.object_dir, exist_ok=True)
            return self.object_dir

    def publish(self, chash: str) -> int:
        """Ensure a content hash resident in the local tier also has an
        object-tier copy, so a worker process can resolve it by hash.
        Returns the bytes written (0 when the object tier already had it —
        the reference crossed, the payload did not move again)."""
        with self._lock:
            if self.object_dir is None:
                raise RuntimeError(
                    "publish() needs an object tier — call ensure_object_dir()"
                )
            if self._in_object(chash):
                return 0
            if chash not in self._local:
                raise KeyError(chash)
            payload = self._local[chash]
            nbytes = self._sizes.get(chash) or self._nbytes(payload)
            self._write_object(chash, payload, nbytes)
            self.publishes += 1
            self.bytes_published += nbytes
            return nbytes

    def export(self, payload: Any) -> tuple:
        """Worker-side ``put``: write a produced payload straight to the
        *shared* object tier (never this process's private local tier) and
        report whether the bytes already existed there.

        Returns ``(uri, chash, nbytes, existed)``. ``existed`` reflects the
        object tier *before* this write — the parent's ``adopt`` uses it to
        keep ``bytes_not_moved`` accounting identical to an in-process
        ``put`` of the same content."""
        return self.export_batch((payload,))[0]

    def export_batch(self, payloads: Iterable[Any], hashes: Optional[list] = None) -> list:
        """Worker-side batch ingest: hash a whole firing's outputs in one
        fused call, then write them to the shared object tier under one
        lock. Returns ``[(uri, chash, nbytes, existed), ...]`` — the same
        tuples N :meth:`export` calls would have produced."""
        payloads = list(payloads)
        if hashes is None:
            hashes = content_hash_batch(payloads, on_unstable=self._on_unstable)
        sizes = [self._nbytes(p) for p in payloads]
        out = []
        with self._lock:
            if self.object_dir is None:
                raise RuntimeError(
                    "export() needs an object tier — call ensure_object_dir()"
                )
            for payload, h, nbytes in zip(payloads, hashes, sizes):
                self.puts += 1
                self._sizes.setdefault(h, nbytes)
                existed = self._in_object(h)
                if not existed:
                    self._write_object(h, payload, nbytes)
                out.append((f"object://{h}", h, nbytes, bool(existed)))
        return out

    def adopt(self, chash: str, nbytes: int, existed: bool = False) -> str:
        """Parent-side bookkeeping for a payload a worker already exported
        to the shared object tier: register the size, count the put, and
        credit ``bytes_not_moved`` exactly when an in-process ``put`` would
        have (content already in this local tier, or already in the object
        tier before the worker wrote). Returns the URI to mint the AV with."""
        nbytes = int(nbytes)
        with self._lock:
            self.puts += 1
            self.adopts += 1
            self._sizes.setdefault(chash, nbytes)
            if chash in self._local:
                self._local.move_to_end(chash)
                self.bytes_not_moved += nbytes
                return f"local://{chash}"
            if existed:
                self.bytes_not_moved += nbytes
            return f"object://{chash}"

    def nbytes_of(self, chash: str) -> Optional[int]:
        """Known size of a content hash (any hash ever put/seen), or None.
        The transfer ledger and data-gravity placement price movement by
        size without ever touching the payload itself."""
        with self._lock:
            return self._sizes.get(chash)

    def has(self, uri: str) -> bool:
        """Tier-strict residency check (is it in *that* tier right now)."""
        tier, h = uri.split("://", 1)
        if tier == "local":
            return h in self._local
        return self._in_object(h)

    def resolvable(self, uri: str) -> bool:
        """Content check: can this store produce the payload from *either*
        tier, regardless of the tier hint in the URI? (Used to reject memo
        records minted against a different store.)"""
        tier, h = uri.split("://", 1)
        if tier == "ghost":
            return False
        with self._lock:
            if h in self._local:
                return True
        return self._in_object(h)

    def _uris(self):
        return {f"local://{k}" for k in self._local}

    # Arrays via np.save for fidelity; everything else via pickle.
    @staticmethod
    def _dump(payload: Any, f: io.IOBase) -> None:
        if isinstance(payload, np.ndarray):
            f.write(b"NPY0")
            np.save(f, payload, allow_pickle=False)
        else:
            f.write(b"PKL0")
            pickle.dump(payload, f, protocol=4)

    @staticmethod
    def _load(f: io.IOBase) -> Any:
        tag = f.read(4)
        if tag == b"NPY0":
            return np.load(f, allow_pickle=False)
        return pickle.load(f)

    def stats(self) -> dict:
        return {
            "puts": self.puts,
            "gets": self.gets,
            "pins": self.pins,
            "prefetches": self.prefetches,
            "local_bytes": self._local_bytes,
            "local_items": len(self._local),
            "bytes_moved_to_object": self.bytes_moved_to_object,
            "bytes_not_moved": self.bytes_not_moved,
            "bytes_spilled": self.bytes_spilled,
            "evictions_local": self.evictions_local,
            "cross_region_pins": self.cross_region_pins,
            "cross_region_bytes": self.cross_region_bytes,
            "publishes": self.publishes,
            "bytes_published": self.bytes_published,
            "adopts": self.adopts,
            "unstable_hashes": self.unstable_hashes,
            "zone_residents": self.zone_resident_counts(),
            "zone_local_serves": self.zone_local_serves,
            "rho": self.rho,
        }
