"""Snapshot / data-arrival policies (paper §III.E, §III.I).

A task's inputs arrive on links at different rates. A *snapshot* is the tuple
of input value-sets handed to one execution of the user code. The paper names
three aggregation policies plus sliding windows and rate control:

  - **All new** — no reuse; each snapshot is formed from completely fresh data
    (the usual stream semantics).
  - **Swap new for old** — fresh values where links have them, previous values
    where they don't (the Makefile semantics: recompile when any source file
    changes, reusing the unchanged ones).
  - **Merge** — data from multiple links aggregated First-Come-First-Served
    into a single scalar stream (types must match).

Buffers: ``input[N]`` needs N values per snapshot. Sliding windows:
``input[N/k]`` keeps the last N values and advances by k fresh values per
snapshot (e.g. moving averages). Rate control bounds trigger frequency
(the paper's DoS guard).
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Any, Optional


@dataclasses.dataclass(frozen=True)
class InputSpec:
    """Parsed ``name[N/k]`` input declaration."""

    name: str
    buffer: int = 1  # N: values per snapshot
    slide: Optional[int] = None  # k: fresh values to advance per snapshot

    @property
    def is_window(self) -> bool:
        return self.slide is not None

    @property
    def fresh_needed(self) -> int:
        return self.slide if self.is_window else self.buffer

    @staticmethod
    def parse(text: str) -> "InputSpec":
        text = text.strip()
        if "[" not in text:
            return InputSpec(text)
        name, rest = text.split("[", 1)
        rest = rest.rstrip("]")
        if "/" in rest:
            n, k = rest.split("/")
            n, k = int(n), int(k)
            if not (1 <= k <= n):
                raise ValueError(f"window slide must satisfy 1<=k<=N: {text}")
            return InputSpec(name.strip(), n, k)
        return InputSpec(name.strip(), int(rest))

    def __str__(self) -> str:
        if self.is_window:
            return f"{self.name}[{self.buffer}/{self.slide}]"
        if self.buffer != 1:
            return f"{self.name}[{self.buffer}]"
        return self.name


class _LinkBuffer:
    """Per-input accumulation buffer with window/new-value accounting."""

    def __init__(self, spec: InputSpec) -> None:
        self.spec = spec
        self.window: deque = deque(maxlen=spec.buffer)
        self.fresh: deque = deque()  # values not yet consumed by a snapshot
        self.arrival_seqs: deque = deque()  # global arrival order (merge FCFS)
        self.last_value: Any = None
        self.ever: bool = False

    def push(self, value: Any, seq: int = 0) -> None:
        self.fresh.append(value)
        self.arrival_seqs.append(seq)
        self.last_value = value
        self.ever = True

    def take(self) -> Any:
        """Consume the oldest fresh value (keeps seq accounting in step)."""
        self.arrival_seqs.popleft()
        return self.fresh.popleft()

    def take_seq(self) -> tuple:
        """Consume the oldest fresh value with its global arrival seq."""
        return self.arrival_seqs.popleft(), self.fresh.popleft()

    def fresh_count(self) -> int:
        return len(self.fresh)


class SnapshotPolicy:
    """Assembles execution snapshots from per-input buffers.

    mode: "all_new" | "swap_new_for_old" | "merge"
    """

    MODES = ("all_new", "swap_new_for_old", "merge")

    def __init__(
        self,
        inputs: list,
        mode: str = "all_new",
        min_interval_s: float = 0.0,
    ) -> None:
        if mode not in self.MODES:
            raise ValueError(f"unknown snapshot mode {mode!r}")
        specs = [s if isinstance(s, InputSpec) else InputSpec.parse(s) for s in inputs]
        if mode == "merge" and any(s.is_window or s.buffer != 1 for s in specs):
            raise ValueError("merge mode uses plain FCFS inputs (no buffers/windows)")
        self.mode = mode
        self.specs = specs
        self.buffers = {s.name: _LinkBuffer(s) for s in specs}
        self.min_interval_s = min_interval_s
        self._last_fire = 0.0
        self._arrival_seq = 0  # global arrival counter (merge FCFS ordering)
        self.snapshots_formed = 0
        self.rate_suppressions = 0
        # Arrivals land from the scheduler thread while snapshot() may run
        # in an executor worker; an RLock keeps buffer/seq accounting
        # coherent (snapshot() re-enters ready()).
        self._lock = threading.RLock()

    # -- arrivals -------------------------------------------------------------
    def arrive(self, input_name: str, value: Any) -> None:
        with self._lock:
            self.buffers[input_name].push(value, seq=self._arrival_seq)
            self._arrival_seq += 1

    # -- readiness ------------------------------------------------------------
    def _rate_ok(self) -> bool:
        return (time.time() - self._last_fire) >= self.min_interval_s

    def ready(self) -> bool:
        with self._lock:
            return self._ready_locked()

    def _ready_locked(self) -> bool:
        if not self.buffers:
            # Source tasks have no inputs; they fire only when explicitly
            # sampled or pulled, never spontaneously in reactive rounds.
            return False
        if not self._rate_ok():
            if self._any_data():
                self.rate_suppressions += 1
            return False
        if self.mode == "merge":
            return self._any_data()
        if self.mode == "all_new":
            return all(
                b.fresh_count() >= b.spec.fresh_needed
                and (not b.spec.is_window or self._window_fillable(b))
                for b in self.buffers.values()
            )
        # swap_new_for_old: window inputs still advance only on >=k fresh
        # values; plain inputs reuse their last value. At least one input
        # must have fresh data ('changes to a do not lead to a new event').
        for b in self.buffers.values():
            if b.spec.is_window:
                if b.fresh_count() < b.spec.fresh_needed or not self._window_fillable(b):
                    return False
            elif not b.ever:
                return False
        return self._any_data()

    def _any_data(self) -> bool:
        return any(b.fresh_count() > 0 for b in self.buffers.values())

    def _window_fillable(self, b: _LinkBuffer) -> bool:
        # First snapshot must fill the whole window (N fresh); later ones
        # advance by k and reuse the other N-k positions.
        return len(b.window) + b.fresh_count() >= b.spec.buffer

    # -- snapshot formation -----------------------------------------------------
    def snapshot(self) -> dict:
        """Form one execution set. Caller must have checked ready()."""
        with self._lock:
            return self._snapshot_locked()

    def _snapshot_locked(self) -> dict:
        if not self.buffers:
            # Source task: explicit sample()/pull() fires it with an empty set.
            self._last_fire = time.time()
            self.snapshots_formed += 1
            return {}
        if not self._ready_locked():
            raise RuntimeError("snapshot() called when not ready")
        self._last_fire = time.time()
        self.snapshots_formed += 1
        if self.mode == "merge":
            return {"merged": self._merge_snapshot()}
        out = {}
        for name, b in self.buffers.items():
            spec = b.spec
            if spec.is_window:
                # advance window by k fresh values (or fill it on the first
                # snapshot), emit the last N
                take = max(spec.fresh_needed, spec.buffer - len(b.window))
                for _ in range(take):
                    b.window.append(b.take())
                out[name] = list(b.window)
            elif self.mode == "all_new":
                vals = [b.take() for _ in range(spec.buffer)]
                out[name] = vals if spec.buffer > 1 else vals[0]
            else:  # swap_new_for_old
                if b.fresh_count() >= spec.buffer:
                    vals = [b.take() for _ in range(spec.buffer)]
                else:
                    # reuse old values; consume whatever fresh exist
                    reuse = spec.buffer - b.fresh_count()
                    vals = [b.last_value] * reuse + [
                        b.take() for _ in range(b.fresh_count())
                    ]
                out[name] = vals if spec.buffer > 1 else vals[-1]
        return out

    def _merge_snapshot(self) -> list:
        """First-Come-First-Served merge of all links into one scalar
        stream: values are ordered by *global* arrival time across links,
        not by which link happens to drain first."""
        tagged = []
        for b in self.buffers.values():
            while b.fresh:
                tagged.append(b.take_seq())
        tagged.sort(key=lambda sv: sv[0])
        return [v for _, v in tagged]

    def stats(self) -> dict:
        with self._lock:
            return {
                "mode": self.mode,
                "snapshots_formed": self.snapshots_formed,
                "rate_suppressions": self.rate_suppressions,
                "pending": {n: b.fresh_count() for n, b in self.buffers.items()},
            }
