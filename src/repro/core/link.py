"""Smart Links (paper §III.J) — typed wires carrying AV references.

A SmartLink connects one producer task output to one consumer task input. It
holds a queue of AnnotatedValues and a *separate* notification channel
(Principle 1: separation of channels by timescale): consumers may poll the
data queue, or subscribe for arrival notifications when arrivals are slow
relative to service time. Payloads never travel on the link — only AVs.

Links carry region policy: an AV crossing into a link whose region differs
from the AV's gets a 'transit' stamp, and a ``region_fence`` link refuses AVs
from fenced regions (the paper's 'US data cannot leave the US' audit/enforce
case, §III.L / §IV).
"""

from __future__ import annotations

import threading
from collections import deque
from typing import Callable, Optional

from .av import AnnotatedValue


class RegionFenceError(RuntimeError):
    pass


class SmartLink:
    def __init__(
        self,
        name: str,
        src_task: str,
        dst_task: str,
        dst_input: str,
        region: str = "local",
        fenced_regions: tuple = (),
        notify_threshold_s: float = 0.0,
    ) -> None:
        self.name = name
        self.src_task = src_task
        self.dst_task = dst_task
        self.dst_input = dst_input
        self.region = region
        self.fenced_regions = tuple(fenced_regions)
        # data channel
        self._queue: deque = deque()
        self._lock = threading.Lock()
        # notification side channel (Principle 1)
        self._subscribers: list = []
        self.notify_threshold_s = notify_threshold_s
        self.notifications_sent = 0
        self.avs_carried = 0

    # -- data channel ---------------------------------------------------------
    def offer(self, av: AnnotatedValue, software_version: str = "?") -> None:
        """Producer side: put an AV reference on the wire."""
        if av.region in self.fenced_regions:
            raise RegionFenceError(
                f"AV {av.uid} from region {av.region!r} fenced on link {self.name}"
            )
        if av.region != self.region:
            av.stamp(
                self.name,
                "transit",
                software_version,
                region=self.region,
                note=f"{av.region}->{self.region}",
            )
        with self._lock:
            self._queue.append(av)
            self.avs_carried += 1
        self._notify(av)

    def poll(self) -> Optional[AnnotatedValue]:
        """Consumer side: non-blocking get (the paper's 'get' on the
        pseudo-stream; 'it wants to know if there is anything new')."""
        with self._lock:
            if self._queue:
                return self._queue.popleft()
        return None

    def peek_count(self) -> int:
        with self._lock:
            return len(self._queue)

    # -- notification channel ---------------------------------------------------
    def subscribe(self, callback: Callable) -> None:
        self._subscribers.append(callback)

    def _notify(self, av: AnnotatedValue) -> None:
        for cb in self._subscribers:
            cb(self, av)
            self.notifications_sent += 1

    def __repr__(self) -> str:
        return (
            f"SmartLink({self.src_task}->{self.dst_task}.{self.dst_input},"
            f" depth={self.peek_count()})"
        )
