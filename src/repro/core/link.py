"""Smart Links (paper §III.J) — typed wires carrying AV references.

A SmartLink connects one producer task output to one consumer task input. It
holds a queue of AnnotatedValues and a *separate* notification channel
(Principle 1: separation of channels by timescale): consumers may poll the
data queue, or subscribe for arrival notifications when arrivals are slow
relative to service time. Payloads never travel on the link — only AVs.

The notification channel drives the event scheduler
(:mod:`repro.core.scheduler`): every ``offer()`` wakes exactly the consumer
whose policy may have become ready. When arrivals are *faster* than
``notify_threshold_s`` the link suppresses per-event notifications (the
paper's poll-mode fast path — "when data arrive quickly, it's cheaper to
poll than to be interrupted per event"); the scheduler then coalesces those
arrivals into a single batch poll at quiescence. Suppressions are counted in
link stats so the timescale separation is observable, not just claimed.

Flow control: a link may be bounded (``capacity``) with an ``overflow``
policy — ``"block"`` (wait for the consumer, raising on timeout),
``"drop_oldest"`` (ring-buffer semantics for sensor streams), or
``"error"`` (fail fast). The default is unbounded, preserving the seed
semantics. ``block`` is cross-thread backpressure: it waits for a consumer
on *another* thread to ``poll()``. Inside a single-threaded drain the
scheduler is both producer and consumer, so it relieves a full block-link
itself (draining it into the consumer's policy buffer) rather than
stalling — see ``Scheduler._relieve_backpressure``.

Links carry region policy: an AV crossing into a link whose region differs
from the AV's gets a 'transit' stamp, and a ``region_fence`` link refuses AVs
from fenced regions (the paper's 'US data cannot leave the US' audit/enforce
case, §III.L / §IV).
"""

from __future__ import annotations

import threading
import time
from collections import deque
from typing import Callable, Optional

from .av import AnnotatedValue


class RegionFenceError(RuntimeError):
    pass


class LinkBackpressureError(RuntimeError):
    """A bounded link could not accept an AV (full + policy refused it)."""


OVERFLOW_POLICIES = ("block", "drop_oldest", "error")


class SmartLink:
    def __init__(
        self,
        name: str,
        src_task: str,
        dst_task: str,
        dst_input: str,
        region: str = "local",
        fenced_regions: tuple = (),
        notify_threshold_s: float = 0.0,
        capacity: Optional[int] = None,
        overflow: str = "block",
        block_timeout_s: float = 5.0,
    ) -> None:
        if overflow not in OVERFLOW_POLICIES:
            raise ValueError(
                f"unknown overflow policy {overflow!r} (choose from {OVERFLOW_POLICIES})"
            )
        if capacity is not None and capacity < 1:
            raise ValueError(f"link capacity must be >= 1, got {capacity}")
        self.name = name
        self.src_task = src_task
        self.dst_task = dst_task
        self.dst_input = dst_input
        self.region = region
        self.fenced_regions = tuple(fenced_regions)
        # data channel (bounded iff capacity is set)
        self._queue: deque = deque()
        self._lock = threading.Lock()
        self._not_full = threading.Condition(self._lock)
        self.capacity = capacity
        self.overflow = overflow
        self.block_timeout_s = block_timeout_s
        # notification side channel (Principle 1)
        self._subscribers: list = []
        self.notify_threshold_s = notify_threshold_s
        self._last_offer_t: Optional[float] = None
        # notifications_sent counts *events* that notified (one per offer),
        # not callback invocations — fan-out to N subscribers is one event.
        self.notifications_sent = 0
        self.notifications_suppressed = 0
        self.avs_carried = 0
        self.avs_dropped = 0
        self.blocked_waits = 0
        # Extended-cloud transport (repro.topology): AV references that
        # crossed a zone boundary on this link. Counting refs — not bytes —
        # is the point: cross-zone edges are hash-only ghost transfers, and
        # payload bytes are charged separately (TransferLedger) only when a
        # consumer materializes them.
        self.crosszone_refs = 0
        # Forensic sink for overflow drops (bound by the scheduler): a
        # drop_oldest eviction logs a 'dropped' visit so the traveller's
        # disappearance stays reconstructable, not just counted.
        self._provenance = None

    def bind_provenance(self, registry) -> None:
        self._provenance = registry

    # -- data channel ---------------------------------------------------------
    def offer(self, av: AnnotatedValue, software_version: str = "?") -> None:
        """Producer side: put an AV reference on the wire."""
        if av.region in self.fenced_regions:
            raise RegionFenceError(
                f"AV {av.uid} from region {av.region!r} fenced on link {self.name}"
            )
        if av.region != self.region:
            av.stamp(
                self.name,
                "transit",
                software_version,
                region=self.region,
                note=f"{av.region}->{self.region}",
            )
        dropped: Optional[AnnotatedValue] = None
        with self._not_full:
            if self.capacity is not None and len(self._queue) >= self.capacity:
                if self.overflow == "error":
                    raise LinkBackpressureError(
                        f"link {self.name} full (capacity={self.capacity}, "
                        f"overflow='error')"
                    )
                if self.overflow == "drop_oldest":
                    dropped = self._queue.popleft()
                    self.avs_dropped += 1
                else:  # block
                    self.blocked_waits += 1
                    deadline = time.monotonic() + self.block_timeout_s
                    while len(self._queue) >= self.capacity:
                        remaining = deadline - time.monotonic()
                        if remaining <= 0 or not self._not_full.wait(remaining):
                            if len(self._queue) < self.capacity:
                                break
                            raise LinkBackpressureError(
                                f"link {self.name} full (capacity="
                                f"{self.capacity}): consumer did not drain "
                                f"within {self.block_timeout_s}s"
                            )
            self._queue.append(av)
            self.avs_carried += 1
            # poll-mode fast path (§III.J): arrivals faster than the
            # threshold coalesce — no per-event interrupt.
            now = time.monotonic()
            suppress = (
                self.notify_threshold_s > 0.0
                and self._last_offer_t is not None
                and (now - self._last_offer_t) < self.notify_threshold_s
            )
            self._last_offer_t = now
            if suppress:
                self.notifications_suppressed += 1
                subscribers = ()
            else:
                self.notifications_sent += 1
                subscribers = tuple(self._subscribers)
        # Outside the link lock (registry has its own): the evicted AV gets
        # a 'dropped' stamp and a visitor-log entry at the consumer it never
        # reached — before this, a drop_oldest eviction was a bare counter
        # bump and the traveller silently vanished from every story.
        if dropped is not None:
            dropped.stamp(
                self.name,
                "dropped",
                software_version,
                region=self.region,
                note=f"overflow=drop_oldest capacity={self.capacity}",
            )
            if self._provenance is not None:
                self._provenance.log_visit(
                    self.dst_task,
                    dropped.uid,
                    "dropped",
                    software_version,
                    note=f"link={self.name} overflow=drop_oldest",
                )
        # callbacks run outside the lock: a subscriber may poll() or inspect
        # the link without deadlocking.
        for cb in subscribers:
            cb(self, av)

    def poll(self) -> Optional[AnnotatedValue]:
        """Consumer side: non-blocking get (the paper's 'get' on the
        pseudo-stream; 'it wants to know if there is anything new')."""
        with self._not_full:
            if self._queue:
                av = self._queue.popleft()
                self._not_full.notify()
                return av
        return None

    def peek_count(self) -> int:
        with self._lock:
            return len(self._queue)

    # -- notification channel ---------------------------------------------------
    def subscribe(self, callback: Callable) -> None:
        with self._lock:
            self._subscribers.append(callback)

    def _notify(self, av: AnnotatedValue) -> None:
        """Force one notification event to all subscribers (bypasses the
        threshold; used by tests/tools — ``offer`` notifies inline)."""
        with self._lock:
            self.notifications_sent += 1
            subscribers = tuple(self._subscribers)
        for cb in subscribers:
            cb(self, av)

    def stats(self) -> dict:
        return {
            "carried": self.avs_carried,
            "depth": self.peek_count(),
            "notified": self.notifications_sent,
            "suppressed": self.notifications_suppressed,
            "dropped": self.avs_dropped,
            "blocked_waits": self.blocked_waits,
            "capacity": self.capacity,
            "crosszone_refs": self.crosszone_refs,
        }

    def __repr__(self) -> str:
        return (
            f"SmartLink({self.src_task}->{self.dst_task}.{self.dst_input},"
            f" depth={self.peek_count()})"
        )
