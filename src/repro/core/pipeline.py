"""Pipeline (DCG) + PipelineManager (paper §III.B).

The manager owns the registry of processes, the scheduling of work, and the
assembly of metadata. Both trigger modes share one engine (the paper's point
that they are not orthogonal):

  - **reactive** (push): events arriving at the input end drive computation
    downstream — ``push()`` / ``sample()`` then ``propagate()``.
  - **make** (pull): a request for a target output triggers a hierarchical
    rebuild of dependencies backwards — ``pull()`` — with content-addressed
    cache hits standing in for up-to-date build artifacts.

Both modes are thin wrappers over the event-driven
:class:`~repro.core.scheduler.Scheduler`: link notifications enqueue exactly
the tasks whose policies may have become ready, and waves of simultaneously
ready tasks execute through the pluggable ``run_wave`` seam (serial inline,
or concurrent via :class:`~repro.workspace.executors.ConcurrentExecutor`).
There is no full-graph polling anywhere on the hot path.

Cycles are allowed (DCG, not DAG): each task gets a per-drain *fire budget*
(the ``max_rounds`` knob) rather than a topology restriction.
"""

from __future__ import annotations

import warnings
from typing import Any, Optional

from repro.cache import MemoCache

from .av import AnnotatedValue, content_hash, is_ghost
from .link import SmartLink
from .provenance import ProvenanceRegistry
from .scheduler import Scheduler, SerialWaveRunner
from .store import ArtifactStore
from .task import SmartTask


def _deprecated(old: str, new: str) -> None:
    warnings.warn(
        f"{old} is deprecated; use {new} — see repro.workspace.Workspace",
        DeprecationWarning,
        stacklevel=3,
    )


class Pipeline:
    """The wiring diagram: tasks and the links between them.

    This class is the *engine* behind :class:`repro.workspace.Workspace`;
    the direct ``add_task``/``connect`` surface is deprecated in favour of
    the typed facade (``ws.task(...)``, ``src["out"] >> dst["in"]``)."""

    def __init__(self, name: str = "pipeline") -> None:
        self.name = name
        self.tasks: dict = {}
        self.links: list = []
        self.implicit_edges: list = []

    def add_task(self, task: SmartTask) -> SmartTask:
        _deprecated("Pipeline.add_task", "Workspace.task(...)")
        return self._add_task(task)

    def _add_task(self, task: SmartTask) -> SmartTask:
        if task.name in self.tasks:
            raise ValueError(f"duplicate task {task.name}")
        self.tasks[task.name] = task
        return task

    def connect(
        self,
        src: str,
        output: str,
        dst: str,
        dst_input: str,
        **link_kwargs: Any,
    ) -> SmartLink:
        _deprecated("Pipeline.connect", 'src["out"] >> dst["in"]')
        return self._connect(src, output, dst, dst_input, **link_kwargs)

    def _connect(
        self,
        src: str,
        output: str,
        dst: str,
        dst_input: str,
        **link_kwargs: Any,
    ) -> SmartLink:
        src_t, dst_t = self.tasks[src], self.tasks[dst]
        if output not in src_t.outputs:
            raise KeyError(f"{src} has no output {output!r}")
        if dst_input not in {s.name for s in dst_t.input_specs}:
            raise KeyError(f"{dst} has no input {dst_input!r}")
        if dst_input in dst_t.in_links:
            # One link per input: a second wire would silently shadow the
            # first (its AVs would never be ingested, and the scheduler's
            # quiescence sweep would spin on the undrainable queue forever).
            # Fan-in is modelled as distinct inputs on a merge-mode task.
            prior = dst_t.in_links[dst_input]
            raise ValueError(
                f"input {dst}.{dst_input} is already wired from "
                f"{prior.src_task}.{prior.name.split('->')[0].split('.')[-1]}; "
                f"declare one input per producer (merge mode FCFS-merges them)"
            )
        link = SmartLink(
            name=f"{src}.{output}->{dst}.{dst_input}",
            src_task=src,
            dst_task=dst,
            dst_input=dst_input,
            **link_kwargs,
        )
        src_t.out_links.setdefault(output, []).append(link)
        dst_t.in_links[dst_input] = link
        self.links.append(link)
        return link

    def producers_of(self, task_name: str) -> list:
        t = self.tasks[task_name]
        return [l.src_task for l in t.in_links.values()]

    def validate(self) -> list:
        """Every non-source input must be wired. Returns list of problems."""
        problems = []
        for t in self.tasks.values():
            for spec in t.input_specs:
                if spec.name not in t.in_links and not t.source:
                    problems.append(f"{t.name}.{spec.name} unwired")
        return problems


class PipelineManager:
    def __init__(
        self,
        pipeline: Pipeline,
        store: Optional[ArtifactStore] = None,
        registry: Optional[ProvenanceRegistry] = None,
        cache: Optional[MemoCache] = None,
        max_rounds: int = 100,
        executor: Any = None,
        topology: Any = None,
        placement: Any = None,
        journal: Any = None,
    ) -> None:
        self.pipeline = pipeline
        self.store = store or ArtifactStore()
        self.registry = registry or ProvenanceRegistry()
        # cache=None -> default MemoCache; cache=False -> caching disabled
        self.cache = MemoCache() if cache is None else (cache or None)
        # Durable provenance (repro.provenance.Journal): registry, memo
        # cache, and transfer ledger write through one append-only event
        # log, so the forensic stories survive restarts (Workspace.
        # from_journal replays them). Bound before _register_design so the
        # design-map records land in the journal too.
        self.journal = journal
        if journal is not None:
            self.registry.bind_journal(journal)
            if self.cache is not None:
                self.cache.bind_journal(journal)
        # unstable-hash anomalies (unpicklable payloads whose digests are
        # process-local) surface in the visitor trail rather than vanishing
        self.store.bind_provenance(self.registry)
        # hash-kernel fallbacks (jnp/pallas failing over to numpy) surface
        # the same way — the digest is unchanged, the degradation is not
        from repro.core.hashing import bind_fallback_anomalies

        bind_fallback_anomalies(
            lambda note: self.registry.record_anomaly("hashing", note)
        )
        # max_rounds survives as the per-task fire budget per drain (cycle
        # rate control); it no longer multiplies full-graph scans.
        self.max_rounds = max_rounds
        # anything exposing run_wave(manager, tasks) -> [(name, out_avs)];
        # Workspace passes its executor backend here.
        self.executor = executor if executor is not None else SerialWaveRunner()
        # Extended-cloud placement (repro.topology): a Topology binds every
        # task to a zone, installs the transfer ledger, and gives the
        # scheduler a placement policy to run at wave formation.
        self.topology = topology
        if topology is not None:
            from repro.topology import TransferLedger, make_placement

            self.ledger = TransferLedger(topology)
            self.placement = make_placement(placement, topology)
            for t in pipeline.tasks.values():
                t.bind_topology(topology, self.ledger)
            if journal is not None:
                # the zone/tier/link-cost spec rides the journal so a replay
                # can rebuild the ledger — energy prices and all
                journal.append("topology", topology.describe())
                self.ledger.bind_journal(journal)
        else:
            self.ledger = None
            self.placement = None
        self.scheduler = Scheduler(self, fire_budget=max_rounds)
        self._register_design()

    def _register_design(self) -> None:
        for t in self.pipeline.tasks.values():
            self.registry.register_task(
                t.name,
                [str(s) for s in t.input_specs],
                t.outputs,
                t.version,
            )
        for link in self.pipeline.links:
            self.registry.add_design_edge(link.src_task, "precedes", link.dst_task)

    # -- external data entry (edge sampling) -----------------------------------
    def inject(self, task: str, input_name: str, payload: Any, region: str = "local"):
        _deprecated("PipelineManager.inject", "Workspace.inject(...)")
        return self._inject(task, input_name, payload, region=region)

    def _inject(self, task: str, input_name: str, payload: Any, region: str = "local"):
        """Edge-node sampling: wrap an external payload as an AV and deliver it
        to a task input ('data are intentionally sampled by the edge nodes').
        Ghost payloads (shape specs) ride the AV itself and never hit the
        store — a wireframe run moves zero bytes end to end (§III.K).
        Under a topology the sample is born in the receiving task's zone —
        edge sampling happens where the edge node lives."""
        t = self.pipeline.tasks[task]
        zone = t.zone if self.topology is not None else None
        if is_ghost(payload):
            chash = content_hash(payload)
            meta = {"ghost": True, "ghost_spec": payload}
            if zone is not None:
                meta["zone"] = zone
            av = AnnotatedValue.produce(
                chash, f"ghost://{chash}", f"edge:{input_name}", "edge",
                region=region, meta=meta,
            )
        else:
            uri, chash = self.store.put(payload)
            meta = None
            if zone is not None:
                meta = {"zone": zone, "nbytes": self.store.nbytes_of(chash)}
                self.ledger.register_resident(chash, zone)
                self.store.note_zone_resident(chash, zone)
            av = AnnotatedValue.produce(
                chash, uri, f"edge:{input_name}", "edge", region=region, meta=meta
            )
        self.registry.register_av(av)
        av.stamp(t.name, "consumed", t.version, region=t.region)
        t.policy.arrive(input_name, av)
        # Edge arrivals bypass links, so there is no notification to ride:
        # tell the scheduler directly that this task may have become ready.
        self.scheduler.mark_dirty(t.name)
        return av

    def _emit_external(self, task: str, output: str, payload: Any, region: str = "local"):
        """Emit a payload *as* a source task's output ('the camera saw this
        image'). Restricted to sensors: letting arbitrary tasks emit
        externally-supplied payloads would let forged artifacts carry
        authentic-looking travel documents. The AV is marked external."""
        t = self.pipeline.tasks[task]
        if not t.source:
            raise ValueError(
                f"cannot emit {output!r} on non-source task {task!r}: "
                f"output-emission push is sensor semantics; wire data into "
                f"an input instead"
            )
        uri, chash = self.store.put(payload)
        meta = {"external": True}
        if self.topology is not None and t.zone is not None:
            # the sensor saw it where the sensor lives: the payload is
            # resident in the source task's zone at zero transport cost
            meta["zone"] = t.zone
            meta["nbytes"] = self.store.nbytes_of(chash)
            self.ledger.register_resident(chash, t.zone)
            self.store.note_zone_resident(chash, t.zone)
        av = AnnotatedValue.produce(
            chash, uri, t.name, t.version, region=region, meta=meta
        )
        self.registry.register_av(av)
        self.registry.log_visit(t.name, av.uid, "emitted", t.version, note="external")
        t._emit({output: av})
        return av

    # -- reactive (push) mode ----------------------------------------------------
    def push(self, task: str, region: str = "local", **payloads: Any) -> dict:
        _deprecated("PipelineManager.push", "Workspace.push(...)")
        return self._push(task, region=region, **payloads)

    def _push(self, task: str, region: str = "local", **payloads: Any) -> dict:
        """Deliver payloads and propagate downstream. A payload named after a
        task *input* is injected there; one named after an *output* is
        emitted as that output (sensor semantics for source tasks)."""
        t = self.pipeline.tasks[task]
        input_names = {s.name for s in t.input_specs}
        emitted: list = []
        for iname, payload in payloads.items():
            if iname in input_names:
                self._inject(task, iname, payload, region=region)
            elif iname in t.outputs:
                emitted.append({iname: self._emit_external(task, iname, payload, region)})
            else:
                raise KeyError(
                    f"task {task!r} has no input or output named {iname!r} "
                    f"(inputs={sorted(input_names)}, outputs={t.outputs})"
                )
        fired = self.propagate()
        if emitted:
            fired[task] = emitted + fired.get(task, [])
        return fired

    def sample(self, source_task: str) -> dict:
        _deprecated("PipelineManager.sample", "Workspace.sample(...)")
        return self._sample(source_task)

    def _sample(self, source_task: str) -> dict:
        """Fire a source task once (sample its sensor) and propagate."""
        t = self.pipeline.tasks[source_task]
        if not t.source:
            raise ValueError(f"{source_task} is not a source task")
        out = t.execute(self.store, self.registry, self.cache)
        fired = self.propagate()
        fired.setdefault(source_task, []).append(out)
        return fired

    def propagate(self) -> dict:
        """Drain the ready queue until quiescent (event-driven; no
        full-graph polling — see :class:`~repro.core.scheduler.Scheduler`).
        Cycles are bounded by the per-task fire budget (``max_rounds``)."""
        return self.scheduler.drain()

    # -- make (pull) mode -----------------------------------------------------------
    def pull(self, target: str, _visiting: Optional[set] = None) -> dict:
        _deprecated("PipelineManager.pull", "Workspace.pull(...)")
        return self._pull(target, _visiting)

    def _pull(self, target: str, _visiting: Optional[set] = None) -> dict:
        """Request the target task's outputs, rebuilding dependencies
        backwards (iterative dependency-cone walk on the scheduler; the old
        recursion's cycle guard becomes a skipped back-edge). Unchanged
        subtrees resolve as cache hits or prior outputs.

        ``_visiting`` is accepted for signature compatibility with the seed
        recursion; the scheduler tracks the cone itself.
        """
        if _visiting and target in _visiting:  # legacy re-entry: old guard
            return self.pipeline.tasks[target].last_outputs
        return self.scheduler.pull(target)

    # -- convenience -------------------------------------------------------------
    def value_of(self, av: AnnotatedValue) -> Any:
        return self.store.get(av.uri)

    def stats(self) -> dict:
        store_stats = self.store.stats()
        cache_stats = self.cache.stats() if self.cache else None
        tasks = self.pipeline.tasks.values()
        executions = sum(t.executions for t in tasks)
        cache_hits = sum(t.cache_hits for t in tasks)
        return {
            "store": store_stats,
            "cache": cache_stats,
            "sustainability": {
                # §III.F: work and transport avoided, not just work done.
                # Derived from per-task counters so the scorecard stays
                # per-pipeline even when the MemoCache/store are shared
                # across workspaces (the "cache" block above is cache-global).
                "executions": executions,
                "cache_hits": cache_hits,
                "executions_avoided": cache_hits,
                "bytes_not_moved": store_stats["bytes_not_moved"]
                + sum(t.bytes_saved for t in tasks),
            },
            "tasks": {
                n: {"executions": t.executions, "cache_hits": t.cache_hits}
                for n, t in self.pipeline.tasks.items()
            },
            "links": {l.name: l.stats() for l in self.pipeline.links},
            # trigger-work scorecard: enqueued (event-driven) vs the
            # polling-scan equivalent the seed engine would have burned
            "scheduler": self.scheduler.stats(),
            # extended-cloud scorecard (repro.topology): where work ran and
            # what transport the zone boundaries cost — None on flat circuits
            "topology": self._topology_stats(),
        }

    def _topology_stats(self) -> Optional[dict]:
        if self.topology is None:
            return None
        tasks = self.pipeline.tasks.values()
        zones = {}
        for zname in self.topology.zone_names():
            residents = sorted(
                t.name for t in tasks if (t.zone or self.topology.default_zone) == zname
            )
            zones[zname] = {
                "tier": self.topology.tier_of(zname),
                "tasks": residents,
                "executions": sum(
                    t.zone_executions.get(zname, 0) for t in tasks
                ),
            }
        return {
            "name": self.topology.name,
            "default_zone": self.topology.default_zone,
            "placement": self.placement.stats(),
            "ledger": self.ledger.stats(),
            "zones": zones,
            "crosszone_refs": sum(l.crosszone_refs for l in self.pipeline.links),
        }
