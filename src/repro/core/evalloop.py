"""Make-mode evaluation: 'pull("eval")' recomputes only when something in
its dependency cone changed (paper §III.B/F applied to ML evaluation).

The eval circuit is::

    (checkpoint) eval (report)     # + a frozen eval-set dependency

Pulling ``eval`` after a new checkpoint AV arrives recomputes perplexity;
pulling it again — or after a checkpoint that hashes identically — resolves
from the content cache with zero forward passes. A code change to the eval
fn (new software version) also invalidates, exactly as the paper prescribes
for 'software updates trigger recomputation'.
"""

from __future__ import annotations

from typing import Callable, Optional

import numpy as np

from .pipeline import Pipeline, PipelineManager
from .task import SmartTask


def build_eval_circuit(
    eval_fn: Callable,  # (params, eval_batch) -> dict of metrics
    eval_batch,  # frozen eval set (hashed once; a 'cached service response')
    name: str = "eval",
) -> PipelineManager:
    pipe = Pipeline(f"{name}-circuit")

    def run_eval(checkpoint):
        metrics = eval_fn(checkpoint["params"], eval_batch)
        return {"report": {"step": checkpoint.get("step", -1), **metrics}}

    pipe._add_task(
        SmartTask(name, run_eval, inputs=["checkpoint"], outputs=["report"],
                  mode="swap_new_for_old")
    )
    return PipelineManager(pipe)


class EvalLoop:
    """Publish checkpoints; pull reports. Unchanged checkpoints cache-hit."""

    def __init__(self, manager: PipelineManager, name: str = "eval"):
        self.manager = manager
        self.name = name

    def publish(self, params, step: int):
        self.manager._inject(self.name, "checkpoint", {"params": params, "step": step})

    def report(self) -> Optional[dict]:
        task = self.manager.pipeline.tasks[self.name]
        task.ingest()
        if task.ready() or task.last_outputs:
            out = self.manager._pull(self.name)
            return self.manager.value_of(out["report"])
        return None

    @property
    def evals_run(self) -> int:
        return self.manager.pipeline.tasks[self.name].executions

    @property
    def cache_hits(self) -> int:
        return self.manager.pipeline.tasks[self.name].cache_hits
