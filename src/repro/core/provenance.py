"""The three provenance stories of paper §III.C / §III.L.

1. **Traveller log** — what each data packet experienced along its journey
   (which software version processed it, in what order). Stored as travel
   documents on the AVs themselves plus a registry index here.
2. **Checkpoint visitor log** — per-task log of which AVs passed through and
   when, with interleaving timelines (paper fig. 9).
3. **Design map** — the long-term map of checkpoints (tasks), their promises,
   the kinds of data passed between them, and significant anomalies
   (paper fig. 10: ``(a) --b(precedes)--> "b"`` records).

Strict record format; queries are structured (no regex scraping, per §III.L).

Durability: the registry can write through to an append-only
:class:`~repro.provenance.Journal` (``bind_journal``) — one typed JSONL
record per registration/visit/edge/anomaly — so the stories survive process
restarts and replay via ``Workspace.from_journal``. Every visitor entry also
carries a registry-assigned monotonic ``seq`` (assigned under the lock, so
it is a total order over this registry's events), which is the cross-task
ordering key: wall clocks tie on coarse granularities, sequence numbers
never do.
"""

from __future__ import annotations

import dataclasses
import json
import threading
import time
from collections import defaultdict
from typing import Any, Iterable, Optional

from .av import AnnotatedValue, Stamp


@dataclasses.dataclass
class VisitorEntry:
    """One line of a checkpoint (task) visitor log."""

    task: str
    av_uid: str
    event: str  # "arrived" | "executed" | "emitted" | "cache_hit" | "anomaly" | "dropped"
    timestamp: float
    software_version: str
    note: str = ""
    # Monotonic registry event number — the deterministic ordering key for
    # cross-task queries (and the replay order after a restart).
    seq: int = 0

    def to_record(self) -> dict:
        return dataclasses.asdict(self)


class ProvenanceRegistry:
    """Pipeline-manager-held registry: the 'secure location' for travel docs.

    All reads and writes hold ``_lock`` (an RLock — ``lineage`` recurses):
    concurrent wave workers register AVs and log visits while forensic
    queries iterate the same dicts, and an unlocked iteration would throw
    ``dictionary changed size during iteration`` or return a lineage with
    parents missing mid-recursion.
    """

    def __init__(self) -> None:
        self._avs: dict = {}  # uid -> AnnotatedValue
        self._visitor_logs: dict = defaultdict(list)  # task -> [VisitorEntry]
        self._design_edges: set = set()  # (src, relation, dst)
        self._task_promises: dict = {}  # task -> {inputs, outputs, version}
        self._lineage: dict = {}  # av uid -> list of parent av uids
        self.anomalies: list = []
        # ConcurrentExecutor workers register AVs and log visits from
        # multiple threads; an RLock keeps the stories coherent.
        self._lock = threading.RLock()
        # monotonic event counter (visitor-log seq); survives rehydration
        self._next_seq = 0
        # optional durable write-through (repro.provenance.Journal)
        self._journal = None

    # -- durability ----------------------------------------------------------
    def bind_journal(self, journal) -> None:
        """Attach an append-only journal; every subsequent registration,
        visit, edge, and anomaly writes through. Replayed registries stay
        unbound — rehydration never re-journals history.

        Binding a *resumed* journal (one with records already on disk)
        advances the event counter past the highest journaled visit seq, so
        post-restart entries keep the total order ``visits_of`` sorts by."""
        with self._lock:
            self._journal = journal
            if journal is not None:
                self._next_seq = max(
                    self._next_seq,
                    getattr(journal, "resumed_visit_seq", -1) + 1,
                )

    @property
    def journal(self):
        return self._journal

    def reserve_seqs(self, n: int) -> int:
        """Claim ``n`` consecutive visitor-log seq numbers and return the
        first. The multi-process runtime reserves a window per remote
        firing, ships the start with the work order, and the runner stamps
        its visit records inside the window — so entries streamed back via
        ``restore_visit`` interleave deterministically with entries logged
        here, and ``visits_of``'s total order never collides."""
        with self._lock:
            start = self._next_seq
            self._next_seq += max(0, int(n))
            return start

    # -- registration --------------------------------------------------------
    def register_av(self, av: AnnotatedValue, parents: Iterable[str] = ()) -> None:
        parents = list(parents)
        with self._lock:
            self._avs[av.uid] = av
            self._lineage[av.uid] = parents
            if self._journal is not None:
                self._journal.append(
                    "av", {"av": av.to_record(), "parents": parents}
                )

    def log_visit(
        self,
        task: str,
        av_uid: str,
        event: str,
        software_version: str,
        note: str = "",
    ) -> None:
        with self._lock:
            entry = VisitorEntry(
                task=task,
                av_uid=av_uid,
                event=event,
                timestamp=time.time(),
                software_version=software_version,
                note=note,
                seq=self._next_seq,
            )
            self._next_seq += 1
            self._visitor_logs[task].append(entry)
            if self._journal is not None:
                self._journal.append("visit", entry.to_record())

    def register_task(
        self, task: str, inputs: list, outputs: list, version: str
    ) -> None:
        with self._lock:
            self._task_promises[task] = {
                "inputs": list(inputs),
                "outputs": list(outputs),
                "version": version,
            }
            if self._journal is not None:
                self._journal.append(
                    "task",
                    {
                        "task": task,
                        "inputs": list(inputs),
                        "outputs": list(outputs),
                        "version": version,
                    },
                )

    def add_design_edge(self, src: str, relation: str, dst: str) -> None:
        with self._lock:
            self._design_edges.add((src, relation, dst))
            if self._journal is not None:
                self._journal.append(
                    "edge", {"src": src, "relation": relation, "dst": dst}
                )

    def record_anomaly(self, task: str, note: str) -> None:
        with self._lock:
            rec = {"task": task, "note": note, "timestamp": time.time()}
            self.anomalies.append(rec)
            if self._journal is not None:
                self._journal.append("anomaly", rec)
            self.log_visit(task, "-", "anomaly", self.task_version(task), note)

    def task_version(self, task: str) -> str:
        with self._lock:
            return self._task_promises.get(task, {}).get("version", "?")

    # -- replay (journal rehydration; see repro.provenance.journal) ----------
    def restore_av(self, data: dict) -> None:
        """Rebuild one AV (and its lineage) from a journaled ``av`` record.
        The travel document is restored as of registration time — stamps
        added later in the original process were link/task-side mutations
        the journal does not track."""
        rec = dict(data["av"])
        stamps = [Stamp(**s) for s in rec.get("travel_document", [])]
        av = AnnotatedValue(
            uid=rec["uid"],
            source_task=rec["source_task"],
            uri=rec["uri"],
            chash=rec["chash"],
            created_at=rec["created_at"],
            region=rec.get("region", "local"),
            meta=dict(rec.get("meta") or {}),
            travel_document=stamps,
        )
        with self._lock:
            self._avs[av.uid] = av
            self._lineage[av.uid] = list(data.get("parents", []))

    def restore_visit(self, data: dict) -> None:
        """Rebuild one visitor-log entry from a journaled ``visit`` record,
        preserving its original seq (and advancing the counter past it so
        post-rehydration events keep the total order)."""
        entry = VisitorEntry(**data)
        with self._lock:
            self._visitor_logs[entry.task].append(entry)
            self._next_seq = max(self._next_seq, entry.seq + 1)

    def restore_anomaly(self, data: dict) -> None:
        """Rebuild one anomaly record (its visitor-log line replays
        separately — ``record_anomaly`` journaled both)."""
        with self._lock:
            self.anomalies.append(dict(data))

    # -- forensic horizon (journal compaction support) -----------------------
    def _apply_retirement(self, gone: set, horizon: int) -> None:
        """The shared removal rule for live retirement and journal replay:
        drop the retired AVs, their lineage rows, every visitor entry that
        references them, and the AV-less ``executed`` markers (one per task
        firing, ``av_uid == '-'``) at or below the horizon seq — a firing
        whose artifacts are all retired has nothing left to anchor its
        marker to. Anomaly lines are never trimmed (they are design-map
        content, deliberately permanent)."""
        for uid in gone:
            self._avs.pop(uid, None)
            self._lineage.pop(uid, None)
        for task in list(self._visitor_logs):
            kept = [
                e
                for e in self._visitor_logs[task]
                if e.av_uid not in gone
                and not (
                    e.av_uid == "-" and e.event == "executed" and e.seq <= horizon
                )
            ]
            if kept:
                self._visitor_logs[task][:] = kept
            else:
                del self._visitor_logs[task]

    def retire_avs(self, uids: Iterable[str], note: str = "") -> list:
        """Drop AVs — and the visitor-log entries that reference them — from
        the registry's forensic horizon, journaling one ``retired`` record so
        every view of history agrees: the live registry, a full-history
        replay (which applies the marker), and a compacted replay (whose
        checkpoint simply no longer contains them).

        This is the deliberate forgetting that makes
        :meth:`~repro.provenance.Journal.compact` *bound* state rather than
        merely re-encode it: dropped travellers, store-evicted payloads, and
        aged-out ``[N/k]`` window members stop costing memory and checkpoint
        bytes. Lineage pointers from surviving AVs to retired parents go
        dangling, which ``lineage()`` already tolerates (it skips unknown
        uids). Returns the uids actually retired."""
        with self._lock:
            doomed = [u for u in uids if u in self._avs]
            if not doomed:
                return []
            gone = set(doomed)
            # horizon for AV-less `executed` markers: the newest visit being
            # retired — markers older than that belong to folded firings
            horizon = max(
                (
                    e.seq
                    for es in self._visitor_logs.values()
                    for e in es
                    if e.av_uid in gone
                ),
                default=-1,
            )
            self._apply_retirement(gone, horizon)
            if self._journal is not None:
                self._journal.append(
                    "retired",
                    {"uids": sorted(doomed), "horizon_seq": horizon, "note": note},
                )
            return sorted(doomed)

    def restore_retired(self, data: dict) -> None:
        """Apply a journaled ``retired`` marker during replay: the same
        removals the live registry performed, without re-journaling."""
        with self._lock:
            self._apply_retirement(
                set(data.get("uids", [])), int(data.get("horizon_seq", -1))
            )

    # -- checkpoint snapshot (journal compaction support) --------------------
    def snapshot_state(self) -> dict:
        """Serialize the whole registry as one JSON-safe state blob — the
        ``registry`` payload of a journal checkpoint record. Everything a
        replay of the folded records would have produced is here: AVs with
        lineage (insertion order preserved), visitor entries (sorted by
        their total-order seq), promises, edges, anomalies, and the event
        counter."""
        with self._lock:
            visits = sorted(
                (e for es in self._visitor_logs.values() for e in es),
                key=lambda e: e.seq,
            )
            return {
                "avs": [
                    {"av": av.to_record(), "parents": list(self._lineage.get(uid, []))}
                    for uid, av in self._avs.items()
                ],
                "visits": [e.to_record() for e in visits],
                "tasks": {t: dict(p) for t, p in self._task_promises.items()},
                "edges": sorted(list(e) for e in self._design_edges),
                "anomalies": [dict(a) for a in self.anomalies],
                "next_seq": self._next_seq,
            }

    def restore_state(self, state: dict) -> None:
        """Rehydrate from a checkpoint snapshot (inverse of
        :meth:`snapshot_state`), replacing current contents. Tail records
        replayed afterwards append on top, exactly as the folded records
        would have."""
        with self._lock:
            self._avs.clear()
            self._lineage.clear()
            self._visitor_logs.clear()
            self._task_promises.clear()
            self._design_edges.clear()
            self.anomalies.clear()
            for item in state.get("avs", []):
                self.restore_av(item)
            for v in state.get("visits", []):
                self.restore_visit(v)
            for t, p in (state.get("tasks") or {}).items():
                self._task_promises[t] = dict(p)
            for e in state.get("edges", []):
                self._design_edges.add(tuple(e))
            for a in state.get("anomalies", []):
                self.anomalies.append(dict(a))
            self._next_seq = max(self._next_seq, int(state.get("next_seq", 0)))

    # -- story 1: traveller log ----------------------------------------------
    def traveller_log(self, av_uid: str) -> list:
        """Full journey of one artifact: every stamp, in order."""
        with self._lock:
            av = self._avs[av_uid]
            return [s.to_record() for s in av.travel_document]

    def lineage(self, av_uid: str, depth: int = -1) -> dict:
        """Recursive forensic reconstruction: which AVs (and software
        versions) led to this outcome — the paper's 'which changes triggered
        the recomputation / which versions were involved'.

        A memoized AV (one minted by a cache hit) carries a ``memo_of``
        pointer to the AV the *original* run produced; the node includes that
        run's lineage too, so a short-circuited result reconstructs exactly
        like a computed one."""
        with self._lock:
            av = self._avs[av_uid]
            node = {
                "uid": av_uid,
                "source_task": av.source_task,
                "software_version": next(
                    (s.software_version for s in av.travel_document if s.event == "produced"),
                    "?",
                ),
                "chash": av.chash,
                "parents": [],
            }
            if av.meta.get("cache_hit"):
                node["cache_hit"] = True
            if depth != 0:
                for p in self._lineage.get(av_uid, []):
                    if p in self._avs:
                        node["parents"].append(self.lineage(p, depth - 1))
                memo_of = av.meta.get("memo_of")
                if memo_of and memo_of in self._avs:
                    node["memo_of"] = self.lineage(memo_of, depth - 1)
            return node

    # -- story 2: checkpoint visitor log --------------------------------------
    def visitor_log(self, task: str) -> list:
        with self._lock:
            return [e.to_record() for e in self._visitor_logs[task]]

    def visits_of(self, av_uid: str) -> list:
        """All checkpoints an AV passed through (cross-task query), in event
        order. Ordered by the monotonic ``seq`` — two visits in one clock
        tick used to tie-break arbitrarily on the timestamp float."""
        out = []
        with self._lock:
            for task, entries in self._visitor_logs.items():
                for e in entries:
                    if e.av_uid == av_uid:
                        out.append(e.to_record())
        return sorted(out, key=lambda r: r["seq"])

    # -- story 3: design map ---------------------------------------------------
    def design_map(self) -> dict:
        """Topology + promises + anomalies (the invariant concept map)."""
        with self._lock:
            return {
                "tasks": {t: dict(p) for t, p in self._task_promises.items()},
                "edges": sorted(self._design_edges),
                "anomalies": [dict(a) for a in self.anomalies],
            }

    def design_map_text(self) -> str:
        """Paper fig. 10 rendering: '(a) --b(precedes)--> \"b\"'."""
        with self._lock:
            edges = sorted(self._design_edges)
        lines = ["<begin NON-LOCAL CAUSE>"]
        for src, rel, dst in edges:
            lines.append(f'({src}) --b({rel})--> "{dst}"')
        lines.append("<end NON-LOCAL CAUSE>")
        return "\n".join(lines)

    # -- misc ------------------------------------------------------------------
    def overhead_bytes(self) -> int:
        """Metadata footprint — supports the paper's 'cheap to keep' claim."""
        n = 0
        with self._lock:
            for av in self._avs.values():
                n += len(json.dumps(av.to_record(), default=repr))
            for entries in self._visitor_logs.values():
                for e in entries:
                    n += len(json.dumps(e.to_record()))
        return n

    def all_avs(self) -> list:
        with self._lock:
            return list(self._avs)

    def get_av(self, uid: str) -> AnnotatedValue:
        with self._lock:
            return self._avs[uid]
