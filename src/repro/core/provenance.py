"""The three provenance stories of paper §III.C / §III.L.

1. **Traveller log** — what each data packet experienced along its journey
   (which software version processed it, in what order). Stored as travel
   documents on the AVs themselves plus a registry index here.
2. **Checkpoint visitor log** — per-task log of which AVs passed through and
   when, with interleaving timelines (paper fig. 9).
3. **Design map** — the long-term map of checkpoints (tasks), their promises,
   the kinds of data passed between them, and significant anomalies
   (paper fig. 10: ``(a) --b(precedes)--> "b"`` records).

Strict record format; queries are structured (no regex scraping, per §III.L).
"""

from __future__ import annotations

import dataclasses
import json
import threading
import time
from collections import defaultdict
from typing import Any, Iterable, Optional

from .av import AnnotatedValue


@dataclasses.dataclass
class VisitorEntry:
    """One line of a checkpoint (task) visitor log."""

    task: str
    av_uid: str
    event: str  # "arrived" | "executed" | "emitted" | "cache_hit" | "anomaly"
    timestamp: float
    software_version: str
    note: str = ""

    def to_record(self) -> dict:
        return dataclasses.asdict(self)


class ProvenanceRegistry:
    """Pipeline-manager-held registry: the 'secure location' for travel docs."""

    def __init__(self) -> None:
        self._avs: dict = {}  # uid -> AnnotatedValue
        self._visitor_logs: dict = defaultdict(list)  # task -> [VisitorEntry]
        self._design_edges: set = set()  # (src, relation, dst)
        self._task_promises: dict = {}  # task -> {inputs, outputs, version}
        self._lineage: dict = {}  # av uid -> list of parent av uids
        self.anomalies: list = []
        # ConcurrentExecutor workers register AVs and log visits from
        # multiple threads; an RLock keeps the stories coherent.
        self._lock = threading.RLock()

    # -- registration --------------------------------------------------------
    def register_av(self, av: AnnotatedValue, parents: Iterable[str] = ()) -> None:
        with self._lock:
            self._avs[av.uid] = av
            self._lineage[av.uid] = list(parents)

    def log_visit(
        self,
        task: str,
        av_uid: str,
        event: str,
        software_version: str,
        note: str = "",
    ) -> None:
        entry = VisitorEntry(
            task=task,
            av_uid=av_uid,
            event=event,
            timestamp=time.time(),
            software_version=software_version,
            note=note,
        )
        with self._lock:
            self._visitor_logs[task].append(entry)

    def register_task(
        self, task: str, inputs: list, outputs: list, version: str
    ) -> None:
        with self._lock:
            self._task_promises[task] = {
                "inputs": list(inputs),
                "outputs": list(outputs),
                "version": version,
            }

    def add_design_edge(self, src: str, relation: str, dst: str) -> None:
        with self._lock:
            self._design_edges.add((src, relation, dst))

    def record_anomaly(self, task: str, note: str) -> None:
        with self._lock:
            self.anomalies.append(
                {"task": task, "note": note, "timestamp": time.time()}
            )
            self.log_visit(task, "-", "anomaly", self.task_version(task), note)

    def task_version(self, task: str) -> str:
        return self._task_promises.get(task, {}).get("version", "?")

    # -- story 1: traveller log ----------------------------------------------
    def traveller_log(self, av_uid: str) -> list:
        """Full journey of one artifact: every stamp, in order."""
        av = self._avs[av_uid]
        return [s.to_record() for s in av.travel_document]

    def lineage(self, av_uid: str, depth: int = -1) -> dict:
        """Recursive forensic reconstruction: which AVs (and software
        versions) led to this outcome — the paper's 'which changes triggered
        the recomputation / which versions were involved'.

        A memoized AV (one minted by a cache hit) carries a ``memo_of``
        pointer to the AV the *original* run produced; the node includes that
        run's lineage too, so a short-circuited result reconstructs exactly
        like a computed one."""
        av = self._avs[av_uid]
        node = {
            "uid": av_uid,
            "source_task": av.source_task,
            "software_version": next(
                (s.software_version for s in av.travel_document if s.event == "produced"),
                "?",
            ),
            "chash": av.chash,
            "parents": [],
        }
        if av.meta.get("cache_hit"):
            node["cache_hit"] = True
        if depth != 0:
            for p in self._lineage.get(av_uid, []):
                if p in self._avs:
                    node["parents"].append(self.lineage(p, depth - 1))
            memo_of = av.meta.get("memo_of")
            if memo_of and memo_of in self._avs:
                node["memo_of"] = self.lineage(memo_of, depth - 1)
        return node

    # -- story 2: checkpoint visitor log --------------------------------------
    def visitor_log(self, task: str) -> list:
        with self._lock:
            return [e.to_record() for e in self._visitor_logs[task]]

    def visits_of(self, av_uid: str) -> list:
        """All checkpoints an AV passed through (cross-task query)."""
        out = []
        with self._lock:
            for task, entries in self._visitor_logs.items():
                for e in entries:
                    if e.av_uid == av_uid:
                        out.append(e.to_record())
        return sorted(out, key=lambda r: r["timestamp"])

    # -- story 3: design map ---------------------------------------------------
    def design_map(self) -> dict:
        """Topology + promises + anomalies (the invariant concept map)."""
        return {
            "tasks": dict(self._task_promises),
            "edges": sorted(self._design_edges),
            "anomalies": list(self.anomalies),
        }

    def design_map_text(self) -> str:
        """Paper fig. 10 rendering: '(a) --b(precedes)--> \"b\"'."""
        lines = ["<begin NON-LOCAL CAUSE>"]
        for src, rel, dst in sorted(self._design_edges):
            lines.append(f'({src}) --b({rel})--> "{dst}"')
        lines.append("<end NON-LOCAL CAUSE>")
        return "\n".join(lines)

    # -- misc ------------------------------------------------------------------
    def overhead_bytes(self) -> int:
        """Metadata footprint — supports the paper's 'cheap to keep' claim."""
        n = 0
        for av in self._avs.values():
            n += len(json.dumps(av.to_record(), default=repr))
        for entries in self._visitor_logs.values():
            for e in entries:
                n += len(json.dumps(e.to_record()))
        return n

    def all_avs(self) -> list:
        return list(self._avs)

    def get_av(self, uid: str) -> AnnotatedValue:
        return self._avs[uid]
