"""Smart Task agents (paper §III.I).

A SmartTask wraps plugin user code in policy-guided services so the platform,
not the user, handles: snapshot assembly from incoming links, content-addressed
caching (make semantics), provenance stamping, out-of-band service-call
freezing (§III.D), and anomaly notes.

The user function receives the assembled snapshot as keyword arguments — the
platform analogue of ``<USER CODE> <ARGV list>`` — and returns a dict of
outputs (or a single value for single-output tasks).
"""

from __future__ import annotations

import contextlib
import dataclasses
import hashlib
import inspect
import time
from typing import Any, Callable, Optional

from repro.cache import MemoCache, make_record, snapshot_key

from .av import AnnotatedValue, content_hash, is_ghost
from .hashing import content_hash_batch
from .policy import InputSpec, SnapshotPolicy
from .provenance import ProvenanceRegistry
from .store import ArtifactStore


class FiringBatch(list):
    """Outputs of a *coalesced* ``execute()``: one ``{output: AV}`` dict per
    firing, in firing order. Tasks opted in via ``TaskHandle.coalesce`` drain
    several ready snapshots in one dispatch; the scheduler emits each firing
    separately and in order, so downstream arrival order (merge FCFS) is
    bit-identical to the non-coalesced run — only the per-dispatch overhead
    is amortized."""

    @property
    def last(self) -> dict:
        return self[-1] if self else {}


@dataclasses.dataclass
class ExecutionPlan:
    """A cache-missed firing, frozen between snapshot and user code.

    ``begin_execution`` produces one when the memo layer cannot answer; the
    caller then runs the user function wherever it likes — on this thread
    (``execute``), or in a worker process (:mod:`repro.runtime`) that only
    ever sees the plan's *references* — and completes the firing with
    ``finish_execution`` / ``finish_remote``.
    """

    snap: dict  # input name -> AV | [AVs] (the formed snapshot)
    in_hashes: dict  # input name -> chash | [chashes]
    parent_uids: list  # lineage parents for every output AV
    key: str  # memo key (already looked up — it missed)
    use_cache: bool  # memoize the result (False for sources / cache off)
    # Optional content-dedup closure (multi-tenant hubs): a cache that
    # implements ``plan_dedup(key)`` may hand back a callable that replays
    # the outputs another scope already computed for this key. The firing
    # then skips the user function but keeps every tenant-visible side
    # effect of a real execution (see ``run_user_fn``). Never pickled —
    # plans crossing a process pipe go as ``snapshot_refs()``.
    dedup: Any = None

    def snapshot_refs(self) -> dict:
        """Picklable reference view of the snapshot — ``(uri, chash)`` plus
        AV metadata, never payloads — for shipping to a worker process."""

        def ref(av: AnnotatedValue) -> dict:
            return {
                "uid": av.uid,
                "uri": av.uri,
                "chash": av.chash,
                "region": av.region,
                "meta": dict(av.meta),
            }

        return {
            name: [ref(a) for a in val] if isinstance(val, list) else ref(val)
            for name, val in self.snap.items()
        }


def software_version_of(fn: Callable) -> str:
    """Code hash standing in for the container image digest: the 'software
    version' recorded in every travel document."""
    try:
        src = inspect.getsource(fn)
    except (OSError, TypeError):
        code = getattr(fn, "__code__", None)
        src = repr(code.co_code) + repr(code.co_consts) if code else repr(fn)
    return "v-" + hashlib.sha256(src.encode()).hexdigest()[:12]


class ServiceCall:
    """An out-of-band client-server lookup made forensically traceable
    (paper §III.D: 'if data were read from a mutable external source, say
    DNS, cache the response for forensic traceability')."""

    def __init__(self, name: str, fn: Callable) -> None:
        self.name = name
        self.fn = fn
        self.version = software_version_of(fn)
        self.frozen_responses: list = []

    def __call__(self, *args: Any) -> Any:
        resp = self.fn(*args)
        args_hash, response_hash = content_hash_batch((args, resp))
        self.frozen_responses.append(
            {
                "service": self.name,
                "args_hash": args_hash,
                "response_hash": response_hash,
                "timestamp": time.time(),
            }
        )
        return resp


class SmartTask:
    def __init__(
        self,
        name: str,
        fn: Callable,
        inputs: list,
        outputs: list,
        mode: str = "all_new",
        min_interval_s: float = 0.0,
        region: str = "local",
        cache_ttl_s: Optional[float] = None,
        services: Optional[dict] = None,
        source: bool = False,
        zone: Optional[str] = None,
        coalesce_max: Optional[int] = None,
    ) -> None:
        self.name = name
        self.fn = fn
        self.version = software_version_of(fn)
        self.input_specs = [
            s if isinstance(s, InputSpec) else InputSpec.parse(s) for s in inputs
        ]
        self.outputs = list(outputs)
        self.policy = SnapshotPolicy(
            self.input_specs, mode=mode, min_interval_s=min_interval_s
        )
        self.region = region
        self.cache_ttl_s = cache_ttl_s
        self.services = {
            n: (s if isinstance(s, ServiceCall) else ServiceCall(n, s))
            for n, s in (services or {}).items()
        }
        self.source = source
        # Arrival coalescing (TaskHandle.coalesce): drain up to this many
        # ready snapshots in one execute() dispatch. 1 = classic behavior.
        self.coalesce_max = max(1, int(coalesce_max or 1))
        # Extended-cloud placement (repro.topology): `pinned_zone` is the
        # user's constraint (TaskHandle.place), `zone` the current
        # assignment — rewritten per wave by the manager's PlacementPolicy.
        self.pinned_zone = zone
        self.zone: Optional[str] = None
        self.topology = None
        self.ledger = None
        self.zone_executions: dict = {}
        # (link, src_zone) of ingested AVs, judged against the *final* zone
        # assignment at execute time (a task is in at most one wave at a
        # time, so only its own execution thread touches this list)
        self._pending_zone_refs: list = []
        self.executions = 0
        self.cache_hits = 0
        self.bytes_saved = 0  # output bytes this task's memo hits never remade
        # EWMA of wall seconds per execution (adaptive-runtime feedback;
        # folded into the scheduler's LoadSignals at wave boundaries). Only
        # this task's execution thread writes it — a task is in at most one
        # wave at a time.
        self.service_ewma_s: Optional[float] = None
        # wired by Pipeline
        self.in_links: dict = {}  # input name -> SmartLink
        self.out_links: dict = {}  # output name -> [SmartLink]
        self.last_outputs: dict = {}  # output name -> AnnotatedValue

    # -- extended-cloud placement (repro.topology) ----------------------------
    def bind_topology(self, topology, ledger) -> None:
        """Attach this task to a Topology + TransferLedger (done once by the
        PipelineManager). The initial zone is the pin or the topology
        default; a data-gravity policy may re-place it every wave."""
        if self.pinned_zone is not None and not topology.has_zone(self.pinned_zone):
            raise ValueError(
                f"task {self.name!r} pinned to unknown zone {self.pinned_zone!r} "
                f"(topology {topology.name!r} has {topology.zone_names()})"
            )
        self.topology = topology
        self.ledger = ledger
        if self.zone is None:
            self.zone = self.pinned_zone or topology.default_zone

    # -- arrival handling (called by the pipeline manager) ---------------------
    def ingest(self) -> int:
        """Drain incoming links into the snapshot policy. Returns #AVs taken."""
        n = 0
        for spec in self.input_specs:
            link = self.in_links.get(spec.name)
            if link is None:
                continue
            while True:
                av = link.poll()
                if av is None:
                    break
                av.stamp(self.name, "consumed", self.version, region=self.region)
                if self.ledger is not None:
                    src_zone = av.meta.get("zone")
                    if src_zone is not None:
                        # Defer the crossed-a-zone-edge judgement: at ingest
                        # this task's zone is the *previous* assignment, and
                        # data_gravity may be about to move it to exactly
                        # the zone these AVs came from. The pending list is
                        # settled at execute time, after placement.
                        self._pending_zone_refs.append((link, src_zone))
                self.policy.arrive(spec.name, av)
                n += 1
        return n

    def ready(self) -> bool:
        return self.policy.ready()

    # -- execution ---------------------------------------------------------------
    def _note_service(self, dt: float) -> None:
        """Fold one execution's wall seconds into the service-time EWMA."""
        alpha = 0.3
        prev = self.service_ewma_s
        self.service_ewma_s = dt if prev is None else alpha * dt + (1 - alpha) * prev

    def _charge_compute(self, store: ArtifactStore, plan: "ExecutionPlan") -> None:
        """Charge the ledger's compute account for this firing: the zone
        where the task ran processed the snapshot's input bytes. Per-zone
        sums, so the account (and its derived joules) is independent of
        which backend ran the wave or in what order threads finished."""
        if self.ledger is None:
            return
        total = 0
        for _name, val in plan.snap.items():
            for av in val if isinstance(val, list) else [val]:
                if av.uri.startswith("ghost://"):
                    continue
                total += int(av.meta.get("nbytes") or store.nbytes_of(av.chash) or 0)
        self.ledger.on_execute(self.zone, total)

    def _journal_staging(self, registry: ProvenanceRegistry):
        """Batching window for this firing's journal writes: every record the
        firing produces (visits, AVs, ledger charges, memo inserts) lands in
        one fused ``append_batch`` at window exit — one lock acquisition, one
        encode buffer, one write/fsync decision per firing instead of per
        record."""
        journal = getattr(registry, "journal", None)
        if journal is None or getattr(journal, "closed", False):
            return contextlib.nullcontext()
        return journal.staging()

    def execute(
        self,
        store: ArtifactStore,
        registry: ProvenanceRegistry,
        cache: Optional[MemoCache] = None,
        *,
        emit: bool = True,
    ) -> dict:
        """Form a snapshot, consult the memo cache, run user code if needed,
        and emit output AVs onto outgoing links. Returns {output_name: AV} —
        or a :class:`FiringBatch` of such dicts when this task coalesces and
        more than one snapshot was ready.

        Payloads are fetched lazily: links carried only ``(uri, chash)``
        references, and bytes move just before user code runs — a memo hit
        (or a ghost run) therefore moves nothing at all.

        ``emit=False`` defers the ``_emit`` step to the caller: the event
        scheduler runs a wave's user code concurrently but emits serially in
        wave order, so downstream arrival seqs (merge FCFS) stay
        deterministic regardless of which worker finished first. With a
        FiringBatch the caller must emit each firing in order.
        """
        firings: list = []
        while True:
            with self._journal_staging(registry):
                status, payload = self.begin_execution(store, registry, cache)
                if status == "hit":
                    out = payload
                else:
                    result, dt = self.run_user_fn(payload, store)
                    out = self.finish_execution(
                        payload, result, dt, store, registry, cache, emit=False
                    )
            if emit:
                self._emit(out)
            firings.append(out)
            # Coalescing: drain further ready snapshots in the same dispatch
            # (opt-in; a task is in at most one wave at a time, so draining
            # here races nothing). Firing order matches what the scheduler's
            # requeue loop would have produced wave by wave.
            if len(firings) >= self.coalesce_max or not self.policy.ready():
                break
        if len(firings) == 1:
            return firings[0]
        return FiringBatch(firings)

    def begin_execution(
        self,
        store: ArtifactStore,
        registry: ProvenanceRegistry,
        cache: Optional[MemoCache] = None,
    ) -> tuple:
        """Phase 1 of a firing: settle zone refs, form the snapshot, log
        arrivals, and consult the memo cache. Returns ``("hit", out_avs)``
        when the memo layer answered (AVs minted, nothing left to run), or
        ``("run", ExecutionPlan)`` when user code must execute — locally via
        ``run_user_fn`` + ``finish_execution``, or in a worker process via
        the plan's reference view (:mod:`repro.runtime`). Neither path
        emits; that stays with the caller (the scheduler's serial step)."""
        with self._journal_staging(registry):
            return self._begin_execution(store, registry, cache)

    def _begin_execution(
        self,
        store: ArtifactStore,
        registry: ProvenanceRegistry,
        cache: Optional[MemoCache] = None,
    ) -> tuple:
        # Settle deferred zone-crossing counts now that placement has fixed
        # this firing's zone: a ref "crossed" only if its birth zone differs
        # from where consumption actually happens (hash-only ghost
        # transfer; payload bytes are charged separately at _materialize).
        if self.ledger is not None and self._pending_zone_refs:
            pending, self._pending_zone_refs = self._pending_zone_refs, []
            for link, src_zone in pending:
                if self.zone is not None and src_zone != self.zone:
                    link.crosszone_refs += 1

        snap = self.policy.snapshot()
        in_hashes, parent_uids = {}, []
        for name, val in snap.items():
            avs = val if isinstance(val, list) else [val]
            hs = []
            for av in avs:
                hs.append(av.chash)
                parent_uids.append(av.uid)
                registry.log_visit(self.name, av.uid, "arrived", self.version)
            in_hashes[name] = hs if isinstance(val, list) else hs[0]

        # The output-name promise is part of the key: two tasks sharing one
        # fn but promising different outputs are different computations (a
        # replayed record would emit the wrong names and silently drop the
        # emission). Same fn + same promise still dedups across tasks —
        # that's content identity, the point of make semantics.
        svc = ";".join(
            f"{n}:{s.version}:{len(s.frozen_responses)}" for n, s in self.services.items()
        )
        extra = f"out={','.join(self.outputs)};{svc}"
        key = snapshot_key(
            self.version, in_hashes, extra=extra, policy_mode=self.policy.mode
        )

        # Source tasks are sensors: each firing is a fresh observation of the
        # world, never a cacheable pure function of (no) inputs.
        if self.source:
            cache = None

        if cache is not None:
            rec = cache.lookup(key)
            if rec is not None and not all(
                store.resolvable(uri) for uri, _ in rec["outputs"].values()
            ):
                # Record minted against a different store (a shared MemoCache
                # outlives any one workspace): its URIs don't resolve here,
                # so treat it as a miss and recompute rather than replay
                # dangling references.
                rec = None
            if rec is not None:
                self.cache_hits += 1
                saved = (
                    sum(int(n) for n in rec.get("out_nbytes", {}).values())
                    if isinstance(rec, dict)
                    else 0
                )
                self.bytes_saved += saved
                credit = getattr(cache, "credit_hit", None)
                if credit is not None:
                    credit(rec)
                out_uids = rec.get("out_uids", {}) if isinstance(rec, dict) else {}
                hit_nbytes = rec.get("out_nbytes", {}) if isinstance(rec, dict) else {}
                hit_zone = rec.get("birth_zone") if isinstance(rec, dict) else None
                out_avs = {}
                for oname, (uri, chash) in rec["outputs"].items():
                    orig_uid = out_uids.get(oname)
                    meta = {"cache_hit": True}
                    if orig_uid:
                        meta["memo_of"] = orig_uid
                    if self.zone is not None:
                        # memo AVs carry the *birth* zone of the original
                        # producing run: a hit replays references to bytes
                        # still resident there, so downstream gravity and
                        # the ledger must weigh/bill against that zone, not
                        # wherever this replay happens to run. (Records
                        # minted on flat circuits fall back to the replay
                        # zone — there is no better information.)
                        #
                        # Zone-local tier: when a replica of the content is
                        # *already resident here* (store's per-zone index),
                        # the hit is served from it — the AV carries this
                        # zone, downstream materializations bill nothing
                        # cross-zone, and the ledger credits the bytes the
                        # birth-zone billing would have moved.
                        birth = hit_zone or self.zone
                        n_out = int(hit_nbytes.get(oname, 0))
                        if (
                            birth != self.zone
                            and self.ledger is not None
                            and store.zone_resident(chash, self.zone)
                        ):
                            meta["zone"] = self.zone
                            zone_local = getattr(cache, "note_zone_local_hit", None)
                            if zone_local is not None:
                                zone_local()
                            self.ledger.credit_zone_local(chash, n_out, self.zone)
                        else:
                            meta["zone"] = birth
                        if oname in hit_nbytes:
                            meta["nbytes"] = int(hit_nbytes[oname])
                    av = AnnotatedValue.produce(
                        chash, uri, self.name, self.version, region=self.region,
                        meta=meta,
                    )
                    av.stamp(self.name, "cached", self.version, region=self.region)
                    registry.register_av(av, parents=parent_uids)
                    registry.log_visit(
                        self.name, av.uid, "cache_hit", self.version,
                        note=f"memo_of={orig_uid}" if orig_uid else "",
                    )
                    out_avs[oname] = av
                return ("hit", out_avs)

        # Content-dedup peek (shared hubs): after a *local* miss, a cache
        # implementing ``plan_dedup`` may know another scope already computed
        # this key. Tasks with services stay ineligible — a real run grows
        # their frozen-response log (which feeds later memo keys), and a
        # replay must never diverge from what a solo run would have done.
        dedup = None
        if cache is not None and not self.services:
            peek = getattr(cache, "plan_dedup", None)
            if peek is not None:
                dedup = peek(key)

        plan = ExecutionPlan(
            snap=snap,
            in_hashes=in_hashes,
            parent_uids=parent_uids,
            key=key,
            use_cache=cache is not None,
            dedup=dedup,
        )
        return ("run", plan)

    def run_user_fn(self, plan: ExecutionPlan, store: ArtifactStore) -> tuple:
        """Phase 2 (local): materialize the plan's snapshot and run the user
        function on the calling thread. Returns ``(result, wall_seconds)``."""
        if plan.dedup is not None:
            # Dedup replay: load the outputs some other scope already
            # computed for this content key instead of re-running the user
            # function. The input-side ledger charges a real run would have
            # made at _materialize are replicated in the same snapshot
            # order, so the caller's ``finish_execution`` produces provenance
            # byte-identical to an actual execution. A None replay (the
            # shared payloads were evicted meanwhile) falls through to the
            # real run below.
            replayed = plan.dedup(store)
            if replayed is not None:
                self.account_remote_inputs(store, plan)
                return replayed, 0.0
        # materialize payloads (Principle 2: pin near the dependent) — this
        # is the only point where input bytes actually move
        kwargs = {}
        for name, val in plan.snap.items():
            if isinstance(val, list):
                kwargs[name] = self._materialize_batch(store, val)
            else:
                kwargs[name] = self._materialize(store, val)
        for sname, svc in self.services.items():
            kwargs[sname] = svc

        t0 = time.perf_counter()
        result = self.fn(**kwargs)
        dt = time.perf_counter() - t0
        return result, dt

    def finish_execution(
        self,
        plan: ExecutionPlan,
        result: Any,
        dt: float,
        store: ArtifactStore,
        registry: ProvenanceRegistry,
        cache: Optional[MemoCache] = None,
        *,
        emit: bool = True,
    ) -> dict:
        """Phase 3: count the execution, store outputs, mint + register the
        output AVs, memoize, and (optionally) emit — exactly the tail of the
        classic single-call ``execute``."""
        with self._journal_staging(registry):
            return self._finish_execution(
                plan, result, dt, store, registry, cache, emit=emit
            )

    def _finish_execution(
        self,
        plan: ExecutionPlan,
        result: Any,
        dt: float,
        store: ArtifactStore,
        registry: ProvenanceRegistry,
        cache: Optional[MemoCache] = None,
        *,
        emit: bool = True,
    ) -> dict:
        parent_uids, key = plan.parent_uids, plan.key
        if not plan.use_cache:
            cache = None
        self.executions += 1
        self._note_service(dt)
        if self.zone is not None:
            self.zone_executions[self.zone] = self.zone_executions.get(self.zone, 0) + 1
        self._charge_compute(store, plan)
        registry.log_visit(
            self.name, "-", "executed", self.version, note=f"wall={dt:.6f}s"
        )

        if not isinstance(result, dict):
            if len(self.outputs) != 1:
                raise TypeError(
                    f"task {self.name} returned a single value but declares "
                    f"outputs {self.outputs}"
                )
            result = {self.outputs[0]: result}
        missing = set(self.outputs) - set(result)
        if missing:
            raise KeyError(f"task {self.name} missing outputs {sorted(missing)}")

        out_avs, outputs_rec, out_uids, out_nbytes = {}, {}, {}, {}
        any_ghost = False
        # Batched ingest: one fused content_hash_batch over every output,
        # then one put_batch (single store-lock acquisition) for the
        # non-ghost payloads — digests and counters identical to the old
        # per-output put loop.
        payloads = [result[oname] for oname in self.outputs]
        hashes = content_hash_batch(
            payloads, on_unstable=getattr(store, "_on_unstable", None)
        )
        ghost_flags = [is_ghost(p) for p in payloads]
        stored = store.put_batch(
            [p for p, g in zip(payloads, ghost_flags) if not g],
            hashes=[h for h, g in zip(hashes, ghost_flags) if not g],
        )
        stored_iter = iter(stored)
        for oname, payload, chash, ghost in zip(
            self.outputs, payloads, hashes, ghost_flags
        ):
            if ghost:
                # Ghost outputs never touch the store: the shape spec *is*
                # the metadata, and it rides on the AV itself (§III.K).
                any_ghost = True
                meta = {"ghost": True, "ghost_spec": payload}
                if self.zone is not None:
                    meta["zone"] = self.zone
                av = AnnotatedValue.produce(
                    chash, f"ghost://{chash}", self.name, self.version,
                    region=self.region, meta=meta,
                )
            else:
                uri, chash, nbytes = next(stored_iter)
                meta = None
                if self.zone is not None:
                    # birth certificate for the transfer ledger: outputs are
                    # resident where the task ran, and their size rides the
                    # AV so data-gravity placement can weigh them later.
                    meta = {"zone": self.zone, "nbytes": nbytes}
                    if self.ledger is not None:
                        self.ledger.register_resident(chash, self.zone)
                    store.note_zone_resident(chash, self.zone)
                av = AnnotatedValue.produce(
                    chash, uri, self.name, self.version, region=self.region,
                    meta=meta,
                )
                outputs_rec[oname] = (uri, chash)
                out_uids[oname] = av.uid
                out_nbytes[oname] = nbytes
            registry.register_av(av, parents=parent_uids)
            registry.log_visit(self.name, av.uid, "emitted", self.version)
            out_avs[oname] = av
        if cache is not None and not any_ghost:
            cache.insert(
                key,
                make_record(
                    self.version, outputs_rec, out_uids, out_nbytes,
                    birth_zone=self.zone,
                ),
                ttl_s=self.cache_ttl_s,
            )
        if emit:
            self._emit(out_avs)
        return out_avs

    # -- remote completion (repro.runtime) ----------------------------------
    def account_remote_inputs(self, store: ArtifactStore, plan: ExecutionPlan) -> None:
        """Replicate ``_materialize``'s transfer-ledger charges for a firing
        whose payload fetches happened in a worker process. The worker's
        forked ledger is invisible here, so the parent charges the same
        bytes, in the same snapshot order, against its own ledger — keeping
        cross-zone byte/energy totals identical to an in-process run."""
        if self.ledger is None:
            return
        for _name, val in plan.snap.items():
            for av in val if isinstance(val, list) else [val]:
                if av.uri.startswith("ghost://"):
                    continue
                nbytes = av.meta.get("nbytes") or store.nbytes_of(av.chash) or 0
                self.ledger.on_materialize(
                    av.chash, int(nbytes), av.meta.get("zone"), self.zone
                )
                if self.zone is not None:
                    store.note_zone_resident(av.chash, self.zone)

    def finish_remote(
        self,
        plan: ExecutionPlan,
        outcome: dict,
        store: ArtifactStore,
        registry: ProvenanceRegistry,
        cache: Optional[MemoCache] = None,
        *,
        emit: bool = False,
    ) -> dict:
        """Complete a firing whose user code ran in a worker process.

        ``outcome`` is the worker's reference-only reply (see
        :mod:`repro.runtime.worker`): per-output ``(uri, chash, nbytes)``
        specs, the wall time, and any frozen service responses. All
        provenance side effects — ledger charges, execution counters, AV
        minting, visitor-log entries, memo insert — happen *here*, in the
        parent, in exactly the order ``finish_execution`` produces them; the
        worker only computed bytes and parked them in the shared object
        tier. A retried wave therefore cannot double-register anything: a
        worker that died mid-task left no parent-side state at all."""
        with self._journal_staging(registry):
            return self._finish_remote(
                plan, outcome, store, registry, cache, emit=emit
            )

    def _finish_remote(
        self,
        plan: ExecutionPlan,
        outcome: dict,
        store: ArtifactStore,
        registry: ProvenanceRegistry,
        cache: Optional[MemoCache] = None,
        *,
        emit: bool = False,
    ) -> dict:
        self.account_remote_inputs(store, plan)
        for sname, calls in (outcome.get("services") or {}).items():
            svc = self.services.get(sname)
            if svc is not None:
                svc.frozen_responses.extend(calls)
        dt = float(outcome["wall_s"])
        self.executions += 1
        self._note_service(dt)
        if self.zone is not None:
            self.zone_executions[self.zone] = self.zone_executions.get(self.zone, 0) + 1
        self._charge_compute(store, plan)
        registry.log_visit(
            self.name, "-", "executed", self.version, note=f"wall={dt:.6f}s"
        )
        out_avs, outputs_rec, out_uids, out_nbytes = {}, {}, {}, {}
        any_ghost = False
        for oname in self.outputs:
            spec = outcome["outputs"][oname]
            chash = spec["chash"]
            if spec.get("ghost"):
                any_ghost = True
                meta = {"ghost": True, "ghost_spec": spec.get("ghost_spec")}
                if self.zone is not None:
                    meta["zone"] = self.zone
                av = AnnotatedValue.produce(
                    chash, f"ghost://{chash}", self.name, self.version,
                    region=self.region, meta=meta,
                )
            else:
                nbytes = int(spec["nbytes"])
                uri = store.adopt(chash, nbytes, existed=spec.get("existed", False))
                meta = None
                if self.zone is not None:
                    meta = {"zone": self.zone, "nbytes": nbytes}
                    if self.ledger is not None:
                        self.ledger.register_resident(chash, self.zone)
                    store.note_zone_resident(chash, self.zone)
                av = AnnotatedValue.produce(
                    chash, uri, self.name, self.version, region=self.region,
                    meta=meta,
                )
                outputs_rec[oname] = (uri, chash)
                out_uids[oname] = av.uid
                out_nbytes[oname] = nbytes
            registry.register_av(av, parents=plan.parent_uids)
            registry.log_visit(self.name, av.uid, "emitted", self.version)
            out_avs[oname] = av
        if plan.use_cache and cache is not None and not any_ghost:
            cache.insert(
                plan.key,
                make_record(
                    self.version, outputs_rec, out_uids, out_nbytes,
                    birth_zone=self.zone,
                ),
                ttl_s=self.cache_ttl_s,
            )
        if emit:
            self._emit(out_avs)
        return out_avs

    def _materialize(self, store: ArtifactStore, av: AnnotatedValue) -> Any:
        """Lazy payload fetch: ghosts resolve from AV metadata (zero bytes);
        real artifacts are pinned near this consumer and read locally.

        Under a topology this is the *only* point where zone transport is
        charged: the AV reference crossed for free, and the TransferLedger
        bills the bytes (once per content hash per destination zone) when —
        and only when — a consumer in another zone needs the payload."""
        if av.uri.startswith("ghost://"):
            return av.meta.get("ghost_spec")
        if self.ledger is not None:
            src_zone = av.meta.get("zone")
            nbytes = av.meta.get("nbytes") or store.nbytes_of(av.chash) or 0
            self.ledger.on_materialize(av.chash, int(nbytes), src_zone, self.zone)
            if self.zone is not None:
                # the payload is now replicated here: future memo hits in
                # this zone serve from the local replica (zone-local tier)
                store.note_zone_resident(av.chash, self.zone)
        return store.get(store.pin_local(av.uri, region=av.region))

    def _materialize_batch(self, store: ArtifactStore, avs: list) -> list:
        """Materialize a buffered/window input slice. Ledger charges land in
        exact AV order (the determinism contract); the loop is the data
        plane's per-input seam — batched fetch strategies plug in here
        without touching ``run_user_fn``."""
        return [self._materialize(store, av) for av in avs]

    def _emit(self, out_avs: dict) -> None:
        self.last_outputs.update(out_avs)
        for oname, av in out_avs.items():
            for link in self.out_links.get(oname, []):
                link.offer(av, software_version=self.version)

    def __repr__(self) -> str:
        ins = ", ".join(str(s) for s in self.input_specs)
        return f"SmartTask({ins}) {self.name} ({', '.join(self.outputs)})"
