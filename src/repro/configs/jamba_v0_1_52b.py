"""Jamba v0.1 52B — hybrid Mamba+attention 1:7 interleave with MoE.

[arXiv:2403.19887] 32 layers, d_model 4096, 32 heads (GQA kv=8), d_ff 14336,
vocab 65536, MoE 16 experts top-2 applied every other layer; one attention
layer per 8-layer block (attn:mamba = 1:7), attention at in-block index 4.
Sub-quadratic (SSM-dominated) => runs long_500k.
"""

from repro.models.common import ArchConfig, LayerSpec

_LAYOUT = tuple(
    LayerSpec(
        mixer="attention" if i == 4 else "mamba",
        ffn="moe" if i % 2 == 1 else "dense",
    )
    for i in range(8)
)

CONFIG = ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=65536,
    layout=_LAYOUT,
    attention="full",
    n_experts=16,
    top_k=2,
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
)
