"""Assigned-architecture registry: 10 archs x 4 input shapes = 40 cells.

Each architecture has its own module with the exact published config; this
registry maps ``--arch <id>`` names to configs and defines the input-shape
grid plus per-cell applicability (the assignment's skip rules):

  - ``long_500k`` requires sub-quadratic attention: runs for SSM / hybrid /
    windowed archs (falcon-mamba, jamba, mixtral); full-attention archs
    record an explicit SKIP.
  - no assigned arch is encoder-only, so decode shapes run everywhere.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Optional

from repro.models.common import ArchConfig

_MODULES = {
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "mixtral-8x7b": "mixtral_8x7b",
    "phi3.5-moe-42b": "phi3_5_moe_42b",
    "internlm2-20b": "internlm2_20b",
    "qwen2.5-32b": "qwen2_5_32b",
    "stablelm-1.6b": "stablelm_1_6b",
    "minicpm3-4b": "minicpm3_4b",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "internvl2-1b": "internvl2_1b",
    "seamless-m4t-medium": "seamless_m4t_medium",
}

ARCH_IDS = tuple(_MODULES)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

SHAPE_IDS = tuple(SHAPES)


def get_config(arch: str) -> ArchConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG


def cell_skip_reason(cfg: ArchConfig, shape: str) -> Optional[str]:
    """None if the (arch, shape) cell runs; else the reason it's skipped."""
    spec = SHAPES[shape]
    if spec.name == "long_500k" and not cfg.sub_quadratic:
        return "full quadratic attention: long_500k requires sub-quadratic (per assignment)"
    return None


def all_cells():
    """Yield every runnable (arch_id, shape_id) cell + skip rows."""
    for a in ARCH_IDS:
        cfg = get_config(a)
        for s in SHAPE_IDS:
            yield a, s, cell_skip_reason(cfg, s)
