"""Qwen2.5 32B — dense GQA transformer with QKV bias.

[hf:Qwen/Qwen2.5-32B] 64 layers, d_model 5120, 40 heads (GQA kv=8),
d_ff 27648, vocab 152064, QKV bias. Full attention => long_500k SKIPPED.
40 heads % 16-way tensor parallel != 0: the sharding rules fall back to
replicated attention heads + FSDP on the embed dim for this arch.
"""

from repro.models.common import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="qwen2.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=27648,
    vocab=152064,
    layout=(LayerSpec(mixer="attention", ffn="dense"),),
    attention="full",
    qkv_bias=True,
    rope_theta=1e6,
)
