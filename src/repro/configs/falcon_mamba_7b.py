"""Falcon-Mamba 7B — attention-free Mamba-1 SSM.

[arXiv:2410.05355] 64 layers, d_model 4096, d_inner 8192 (expand 2),
ssm_state 16, conv 4, vocab 65024. No attention, no FFN (the Mamba block is
the whole layer). O(1) decode state => runs decode_32k and long_500k
trivially (no KV cache at all).
"""

from repro.models.common import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=1,
    n_kv_heads=1,
    d_ff=0,
    vocab=65024,
    layout=(LayerSpec(mixer="mamba", ffn="none"),),
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
)
