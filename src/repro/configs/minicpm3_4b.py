"""MiniCPM3 4B — dense transformer with Multi-head Latent Attention (MLA).

[hf:openbmb/MiniCPM3-4B] 62 layers, d_model 2560, 40 heads, d_ff 6400,
vocab 73448. MLA: q_lora_rank 768, kv_lora_rank 256, qk_nope 64, qk_rope 32,
v_head_dim 64 — the KV cache stores the 288-dim latent per token instead of
40x128 per-head KV (a 17x cache-payload compression; the Koalja
"move references, not payloads" insight inside attention).
Full attention => long_500k SKIPPED.
"""

from repro.models.common import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="minicpm3-4b",
    family="dense",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    d_ff=6400,
    vocab=73448,
    layout=(LayerSpec(mixer="attention", ffn="dense"),),
    attention="mla",
    q_lora_rank=768,
    kv_lora_rank=256,
    qk_nope_dim=64,
    qk_rope_dim=32,
    v_head_dim=64,
)
