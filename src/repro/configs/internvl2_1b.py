"""InternVL2 1B — VLM: InternViT frontend (STUB) + Qwen2-0.5B-style LM.

[arXiv:2404.16821] LM backbone: 24 layers, d_model 896, 14 heads (GQA kv=2),
d_ff 4864, vocab 151655. Per the assignment the vision frontend is a stub:
``input_specs()`` provides 1024 precomputed patch embeddings at model dim,
prepended to the token stream. Full attention => long_500k SKIPPED.
14 heads % 16 != 0: sharding falls back to replicated heads (the LM is 1B —
FSDP over embed covers memory).
"""

from repro.models.common import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="internvl2-1b",
    family="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab=151655,
    layout=(LayerSpec(mixer="attention", ffn="dense"),),
    attention="full",
    qkv_bias=True,
    rope_theta=1e6,
    frontend="vision",
    frontend_len=1024,
)
