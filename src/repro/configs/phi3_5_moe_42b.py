"""Phi-3.5-MoE 42B (6.6B active) — 16-expert top-2 MoE transformer.

[hf:microsoft/Phi-3.5-MoE-instruct] 32 layers, d_model 4096, 32 heads
(GQA kv=8), d_ff 6400 per expert, vocab 32064, 16 experts top-2.
Full attention => long_500k SKIPPED per assignment.
"""

from repro.models.common import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="phi3.5-moe-42b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=6400,
    vocab=32064,
    layout=(LayerSpec(mixer="attention", ffn="moe"),),
    attention="full",
    n_experts=16,
    top_k=2,
)
