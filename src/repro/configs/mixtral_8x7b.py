"""Mixtral 8x7B — sparse MoE with sliding-window attention.

[arXiv:2401.04088] 32 layers, d_model 4096, 32 heads (GQA kv=8), d_ff 14336,
vocab 32000, 8 experts top-2 on every layer, SWA window 4096.
Windowed attention (bounded KV) => runs long_500k with a ring cache.
"""

from repro.models.common import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=32000,
    layout=(LayerSpec(mixer="attention", ffn="moe"),),
    attention="swa",
    window=4096,
    rope_theta=1e6,
    n_experts=8,
    top_k=2,
)
