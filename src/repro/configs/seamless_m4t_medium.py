"""SeamlessM4T medium — encoder-decoder speech/text model (frontend STUB).

[arXiv:2308.11596] 12 encoder + 12 decoder layers, d_model 1024, 16 heads
(kv=16), d_ff 4096, vocab 256206. The speech frontend is a stub per the
assignment: the encoder consumes 4096 precomputed frame embeddings from
``input_specs()``. Decoder layers carry cross-attention over the encoder
memory. Full self+cross attention => long_500k SKIPPED. Decode shapes decode
the *decoder* against a 4096-frame encoder memory.
"""

from repro.models.common import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="seamless-m4t-medium",
    family="audio",
    n_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=256206,
    layout=(LayerSpec(mixer="attention", ffn="dense"),),
    attention="full",
    encoder_layers=12,
    cross_attention=True,
    frontend="audio",
    frontend_len=4096,
)
