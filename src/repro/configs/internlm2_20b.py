"""InternLM2 20B — dense GQA transformer.

[arXiv:2403.17297] 48 layers, d_model 6144, 48 heads (GQA kv=8),
d_ff 16384, vocab 92544. Full attention => long_500k SKIPPED.
"""

from repro.models.common import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="internlm2-20b",
    family="dense",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=92544,
    layout=(LayerSpec(mixer="attention", ffn="dense"),),
    attention="full",
    rope_theta=1e6,
)
