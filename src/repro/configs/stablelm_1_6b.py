"""StableLM 2 1.6B — dense MHA transformer (kv heads == heads).

[hf:stabilityai/stablelm-2-1_6b] 24 layers, d_model 2048, 32 heads (kv=32),
d_ff 5632, vocab 100352. Full attention => long_500k SKIPPED.
"""

from repro.models.common import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="stablelm-1.6b",
    family="dense",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=5632,
    vocab=100352,
    layout=(LayerSpec(mixer="attention", ffn="dense"),),
    attention="full",
)
