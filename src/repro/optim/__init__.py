from .adamw import adamw_init, adamw_update, global_norm
from .compress import (
    compress_state_init,
    dequantize_int8,
    ef_compress,
    quantize_int8,
)
from .schedules import constant_lr, cosine_warmup, linear_warmup

__all__ = [
    "adamw_init", "adamw_update", "global_norm",
    "quantize_int8", "dequantize_int8", "ef_compress", "compress_state_init",
    "cosine_warmup", "linear_warmup", "constant_lr",
]
