"""Int8 error-feedback gradient compression for cross-pod reduction.

The Koalja posture for the WAN/pod boundary: *move summaries, not payloads*.
Gradients crossing the slow ``pod`` axis are quantized to int8 with a
per-tensor scale; the quantization error is fed back into the next step's
gradient (error feedback a la 1-bit Adam/SGD), so the compression is unbiased
over time and training converges to the uncompressed fixed point.

Mechanics under pjit: the train step reduces gradients over the fast in-pod
axes in full precision (XLA's native psum), then does the *pod* reduction on
the int8 payload inside ``shard_map`` — 4x fewer bytes on the slowest links
(which the roofline shows are the binding constraint for multi-pod data
parallelism).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array):
    """Per-tensor symmetric int8. Returns (q, scale)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_state_init(grads) -> dict:
    """Error-feedback residual tree (f32, zero-init)."""
    return {"residual": jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)}


def ef_compress(grads, state: dict, axis_name: str, n_pods: int):
    """Inside shard_map over ``axis_name``: quantize (grad + residual), psum
    the int8 payload (as int32 accumulate), dequantize the mean, and keep the
    new residual. Returns (reduced_grads, new_state, stats)."""

    def one(g, r):
        gf = g.astype(jnp.float32) + r
        q, scale = quantize_int8(gf)
        # int8 values accumulate on an int16 wire (safe for <=256 pods):
        # 2 bytes/param crosses the pod links instead of 4 (f32). An int8
        # wire (4x) is possible by pre-scaling q to +-127/n_pods at a cost
        # of log2(n_pods) bits — error feedback absorbs either choice.
        qsum = jax.lax.psum(q.astype(jnp.int16), axis_name)
        ssum = jax.lax.psum(scale, axis_name)  # scalar — negligible bytes
        # each pod contributed q*scale_pod; approximate with mean scale
        # (exact per-pod scales would need an all-gather of scalars: still
        # negligible — we use mean scale for simplicity and fold the error
        # into the residual, which error feedback corrects next step).
        mean_scale = ssum / n_pods
        g_hat = qsum.astype(jnp.float32) * mean_scale / n_pods
        new_r = gf - dequantize_int8(q, scale)
        return g_hat.astype(g.dtype), new_r

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(state["residual"])
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    new_grads = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_state = {"residual": jax.tree.unflatten(treedef, [o[1] for o in outs])}
    bytes_fp32 = sum(g.size * 4 for g in flat_g)
    bytes_int8 = sum(g.size for g in flat_g)
    return new_grads, new_state, {
        "compress_ratio": bytes_fp32 / max(bytes_int8, 1),
    }
