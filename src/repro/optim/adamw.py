"""AdamW with decoupled weight decay + global-norm clipping.

Pure pytree functions: the optimizer state (m, v in f32) mirrors the param
tree, so it inherits the params' shardings under pjit with zero extra rules
(FSDP shards optimizer state exactly like the weights — the ZeRO posture).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def adamw_init(params) -> dict:
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(f32, params),
        "v": jax.tree.map(f32, params),
        "count": jnp.zeros((), jnp.int32),
    }


def adamw_update(
    params,
    grads,
    state: dict,
    lr: jax.Array,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    clip_norm: float = 1.0,
):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9))
    count = state["count"] + 1
    c1 = 1.0 - b1**count.astype(jnp.float32)
    c2 = 1.0 - b2**count.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        step = (m / c1) / (jnp.sqrt(v / c2) + eps)
        decay = weight_decay * p.astype(jnp.float32) if p.ndim >= 2 else 0.0
        new_p = p.astype(jnp.float32) - lr * (step + decay)
        return new_p.astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_state = {
        "m": jax.tree.unflatten(treedef, [o[1] for o in out]),
        "v": jax.tree.unflatten(treedef, [o[2] for o in out]),
        "count": count,
    }
    return new_params, new_state, {"grad_norm": gnorm, "clip_scale": scale}
