"""Learning-rate schedules as pure step -> lr functions (jit-safe)."""

from __future__ import annotations

import jax.numpy as jnp


def constant_lr(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def linear_warmup(peak_lr: float, warmup_steps: int, total_steps: int, floor: float = 0.0):
    def fn(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup_steps, 1)
        frac = (step - warmup_steps) / max(total_steps - warmup_steps, 1)
        decay = peak_lr + (floor - peak_lr) * jnp.clip(frac, 0.0, 1.0)
        return jnp.where(step < warmup_steps, warm, decay)

    return fn


def cosine_warmup(peak_lr: float, warmup_steps: int, total_steps: int, floor_frac: float = 0.1):
    def fn(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup_steps, 1)
        frac = jnp.clip((step - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = floor_frac + (1 - floor_frac) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup_steps, warm, peak_lr * cos)

    return fn
