"""repro.topology — the extended-cloud placement layer.

    from repro.topology import Topology

    topo = Topology("iot")
    topo.zone("cloud", tier="cloud")
    topo.zone("edge-a", tier="edge")
    topo.link("cloud", "edge-a", bandwidth_mbps=50, energy_j_per_mb=0.05)

    ws = Workspace("demo", topology=topo, placement="data_gravity")
    sensor = ws.source(read_fn, name="sensor", outputs=["reading"]).place("edge-a")
    ...
    ws.stats()["topology"]["ledger"]["bytes_moved_crosszone"]

Three pieces: :class:`Topology` (named cloud/edge/device zones + per-link
bandwidth/latency/energy costs), :class:`PlacementPolicy` (``pin`` /
``data_gravity`` — where each wave's tasks execute, decided on the
scheduler thread), and :class:`TransferLedger` (bytes and energy charged
only when a payload is *materialized* across a zone edge; references cross
for free). See docs/extended-cloud.md for the runnable walkthrough.
"""

from .ledger import TransferLedger
from .partition import ZonePartition, extract_partitions
from .placement import (
    DataGravityPlacement,
    EnergyAwarePlacement,
    PinPlacement,
    PlacementPolicy,
    make_placement,
)
from .topology import (
    TIERS,
    Topology,
    TopologyError,
    Zone,
    ZoneLink,
    default_topology,
)

__all__ = [
    "TIERS", "Topology", "TopologyError", "Zone", "ZoneLink",
    "default_topology",
    "TransferLedger",
    "PlacementPolicy", "PinPlacement", "DataGravityPlacement",
    "EnergyAwarePlacement", "make_placement",
    "ZonePartition", "extract_partitions",
]
