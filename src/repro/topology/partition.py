"""Zone partition extraction — what ships to a remote zone runner.

The multi-process runtime (:mod:`repro.runtime`) promotes each extended-cloud
zone to its own runner *process*: the zone's slice of the pipeline — resident
tasks, their placement pins, and the links that stay inside vs. cross the
zone boundary — is the unit of deployment. This module computes that slice
from a :class:`~repro.topology.Topology` plus a built pipeline, in topology
declaration order (the same deterministic order the zoned executors already
use for wave partitions).

The partition is also the journal story of the deployment: the runtime
journals one typed ``partition`` record per zone, so a replay can answer
"which tasks were shipped where" without the runner processes.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

from .topology import Topology


@dataclasses.dataclass
class ZonePartition:
    """One zone's slice of a pipeline: the work order for its runner."""

    zone: str
    tier: str
    tasks: list  # task names resident in this zone (pipeline declaration order)
    pinned: list  # subset of `tasks` the user pinned here (TaskHandle.place)
    internal_links: list  # link names with both endpoints in this zone
    boundary_links: list  # link names crossing into or out of this zone

    def describe(self) -> dict:
        """JSON-safe spec — journaled as a ``partition`` record."""
        return {
            "zone": self.zone,
            "tier": self.tier,
            "tasks": list(self.tasks),
            "pinned": list(self.pinned),
            "internal_links": list(self.internal_links),
            "boundary_links": list(self.boundary_links),
        }

    def __repr__(self) -> str:
        return (
            f"ZonePartition({self.zone!r}, tasks={self.tasks}, "
            f"boundary={len(self.boundary_links)})"
        )


def extract_partitions(topology: Topology, pipeline) -> Dict[str, ZonePartition]:
    """Partition a built pipeline by zone assignment.

    Returns ``{zone_name: ZonePartition}`` with one entry per topology zone
    (declaration order — empty zones included, so a runner fleet is sized by
    the topology, not by which zones happen to hold work right now). A task
    belongs to its current ``zone`` assignment, falling back to the pin and
    then the topology default — the same resolution the zoned executors use
    when they group a wave.
    """
    zone_tasks: dict = {z: [] for z in topology.zone_names()}
    zone_of: dict = {}
    for t in pipeline.tasks.values():
        zone = t.zone or t.pinned_zone or topology.default_zone
        if zone not in zone_tasks:
            raise ValueError(
                f"task {t.name!r} assigned to unknown zone {zone!r} "
                f"(topology {topology.name!r} has {topology.zone_names()})"
            )
        zone_tasks[zone].append(t.name)
        zone_of[t.name] = zone
    out: Dict[str, ZonePartition] = {}
    for zone in topology.zone_names():
        internal, boundary = [], []
        for link in pipeline.links:
            src_in = zone_of.get(link.src_task) == zone
            dst_in = zone_of.get(link.dst_task) == zone
            if src_in and dst_in:
                internal.append(link.name)
            elif src_in or dst_in:
                boundary.append(link.name)
        tasks = zone_tasks[zone]
        out[zone] = ZonePartition(
            zone=zone,
            tier=topology.tier_of(zone),
            tasks=tasks,
            pinned=[
                n for n in tasks if pipeline.tasks[n].pinned_zone == zone
            ],
            internal_links=internal,
            boundary_links=boundary,
        )
    return out
