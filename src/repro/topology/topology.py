"""Extended-cloud topology model (paper title, §IV).

The paper's pipelines "span the extended cloud": cloud datacenters, edge
sites, and devices form one continuum, and the platform — not the user —
decides where code runs and what bytes cross which boundary. This module
gives that continuum a name: a :class:`Topology` of named :class:`Zone`\\ s
(each in a *tier*: ``cloud`` / ``edge`` / ``device``) connected by
:class:`ZoneLink`\\ s carrying bandwidth / latency / energy costs per
direction.

Zones are *placement domains* — where a task executes and where its output
payloads are born. They are orthogonal to the existing region policy
(regions are jurisdiction labels for fences and audits; zones are physical
locality for transport cost). A link between two zones that was never
declared falls back to tier-pair defaults, so a topology is usable the
moment its zones are named.

The costs matter because the circuit charges them: moving an AV reference
across a zone edge is free (hash-only ghost transfer), but *materializing*
a payload in a zone where it is not resident moves real bytes, and the
:class:`~repro.topology.ledger.TransferLedger` prices that movement with
this topology's per-link ``energy_j_per_mb`` — the paper's "minimizing
energy expenditure and waste … especially with regard to edge computing"
made a number.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Optional

TIERS = ("cloud", "edge", "device")

# Default per-link costs by (tier, tier) pair, used when a zone pair has no
# declared link: (bandwidth_mbps, latency_ms, energy_j_per_mb). Values are
# order-of-magnitude stand-ins for DC backbone / metro edge / last-hop radio.
_TIER_DEFAULTS = {
    ("cloud", "cloud"): (10_000.0, 1.0, 0.01),
    ("cloud", "edge"): (100.0, 20.0, 0.05),
    ("cloud", "device"): (10.0, 50.0, 0.15),
    ("edge", "edge"): (1_000.0, 5.0, 0.02),
    ("edge", "device"): (50.0, 5.0, 0.08),
    ("device", "device"): (10.0, 10.0, 0.10),
}

# Default compute-energy coefficient by tier: joules burned per MB of input
# processed in a zone of that tier. Datacenter silicon is the most efficient
# per byte; battery-powered device hardware the least. Overridable per zone
# (``Topology.zone(..., compute_j_per_mb=...)``) so a topology can model an
# efficient edge accelerator or a power-hungry legacy site.
_TIER_COMPUTE_DEFAULTS = {
    "cloud": 0.02,
    "edge": 0.05,
    "device": 0.12,
}


class TopologyError(ValueError):
    """Bad topology declaration (unknown tier, duplicate/unknown zone)."""


@dataclasses.dataclass(frozen=True)
class Zone:
    """One placement domain in the extended cloud.

    ``compute_j_per_mb`` is the zone's compute-energy coefficient: joules
    per MB of input bytes processed by a task executing here (resolved from
    the tier default at declaration when not set explicitly). It is what
    :class:`~repro.topology.placement.EnergyAwarePlacement` trades against
    link transfer energy, and what the ledger prices executions with."""

    name: str
    tier: str = "cloud"
    compute_j_per_mb: float = _TIER_COMPUTE_DEFAULTS["cloud"]


@dataclasses.dataclass(frozen=True)
class ZoneLink:
    """Directed transport edge between two zones, with its cost model."""

    src: str
    dst: str
    bandwidth_mbps: float
    latency_ms: float
    energy_j_per_mb: float

    def transfer_time_s(self, nbytes: int) -> float:
        return self.latency_ms / 1e3 + (nbytes * 8 / 1e6) / max(
            self.bandwidth_mbps, 1e-9
        )

    def transfer_energy_j(self, nbytes: int) -> float:
        return (nbytes / 1e6) * self.energy_j_per_mb


# Zero-cost self-edge: materializing in the zone where the payload is
# resident is a reference handover, not a transfer.
_SELF_LINK_COSTS = (float("inf"), 0.0, 0.0)


class Topology:
    """Named zones + inter-zone link costs. Insertion order of zones is the
    deterministic tie-break order everywhere (placement, executor partition
    order), so two runs over the same topology always agree."""

    def __init__(self, name: str = "topology", default_zone: Optional[str] = None) -> None:
        self.name = name
        self._zones: dict = {}  # name -> Zone (insertion ordered)
        self._links: dict = {}  # (src, dst) -> ZoneLink
        self._default_zone = default_zone

    # -- declaration --------------------------------------------------------
    def zone(
        self,
        name: str,
        tier: str = "cloud",
        compute_j_per_mb: Optional[float] = None,
    ) -> Zone:
        if tier not in TIERS:
            raise TopologyError(f"unknown tier {tier!r} (choose from {TIERS})")
        if name in self._zones:
            raise TopologyError(f"duplicate zone {name!r}")
        coeff = (
            float(compute_j_per_mb)
            if compute_j_per_mb is not None
            else _TIER_COMPUTE_DEFAULTS[tier]
        )
        if coeff < 0:
            raise TopologyError(
                f"zone {name!r}: compute_j_per_mb must be >= 0, got {coeff}"
            )
        z = Zone(name, tier, coeff)
        self._zones[name] = z
        return z

    def link(
        self,
        a: str,
        b: str,
        *,
        bandwidth_mbps: Optional[float] = None,
        latency_ms: Optional[float] = None,
        energy_j_per_mb: Optional[float] = None,
        symmetric: bool = True,
    ) -> ZoneLink:
        """Declare transport costs between two zones (both directions by
        default). Unset costs fall back to the tier-pair defaults."""
        for z in (a, b):
            if z not in self._zones:
                raise TopologyError(f"unknown zone {z!r} (declare it first)")
        bw, lat, en = self._tier_defaults(a, b)
        link = ZoneLink(
            a,
            b,
            bandwidth_mbps if bandwidth_mbps is not None else bw,
            latency_ms if latency_ms is not None else lat,
            energy_j_per_mb if energy_j_per_mb is not None else en,
        )
        self._links[(a, b)] = link
        if symmetric:
            self._links[(b, a)] = dataclasses.replace(link, src=b, dst=a)
        return link

    # -- lookup -------------------------------------------------------------
    @property
    def default_zone(self) -> str:
        """Explicit default, else the first zone declared."""
        if self._default_zone is not None:
            return self._default_zone
        if not self._zones:
            raise TopologyError(f"topology {self.name!r} has no zones")
        return next(iter(self._zones))

    def zone_names(self) -> list:
        return list(self._zones)

    def has_zone(self, name: str) -> bool:
        return name in self._zones

    def tier_of(self, name: str) -> str:
        return self._zones[name].tier

    def _tier_defaults(self, a: str, b: str) -> tuple:
        ta, tb = self._zones[a].tier, self._zones[b].tier
        key = (ta, tb) if (ta, tb) in _TIER_DEFAULTS else (tb, ta)
        return _TIER_DEFAULTS[key]

    def cost(self, src: str, dst: str) -> ZoneLink:
        """The link that a transfer src→dst rides: declared, or tier-pair
        defaults, or the zero-cost self edge."""
        if src == dst:
            return ZoneLink(src, dst, *_SELF_LINK_COSTS)
        declared = self._links.get((src, dst))
        if declared is not None:
            return declared
        for z in (src, dst):
            if z not in self._zones:
                raise TopologyError(f"unknown zone {z!r} in topology {self.name!r}")
        return ZoneLink(src, dst, *self._tier_defaults(src, dst))

    def compute_j_per_mb(self, zone: str) -> float:
        """The zone's compute-energy coefficient (joules per MB processed)."""
        if zone not in self._zones:
            raise TopologyError(f"unknown zone {zone!r} in topology {self.name!r}")
        return self._zones[zone].compute_j_per_mb

    def compute_energy_j(self, zone: str, nbytes: int) -> float:
        """Joules to process ``nbytes`` of input in ``zone``."""
        return (nbytes / 1e6) * self.compute_j_per_mb(zone)

    def transfer_energy_j(self, src: str, dst: str, nbytes: int) -> float:
        return self.cost(src, dst).transfer_energy_j(nbytes)

    def transfer_time_s(self, src: str, dst: str, nbytes: int) -> float:
        return self.cost(src, dst).transfer_time_s(nbytes)

    def describe(self) -> dict:
        return {
            "name": self.name,
            "default_zone": self.default_zone,
            "zones": {z.name: z.tier for z in self._zones.values()},
            "compute": {
                z.name: z.compute_j_per_mb for z in self._zones.values()
            },
            "links": {
                f"{s}->{d}": {
                    "bandwidth_mbps": l.bandwidth_mbps,
                    "latency_ms": l.latency_ms,
                    "energy_j_per_mb": l.energy_j_per_mb,
                }
                for (s, d), l in self._links.items()
            },
        }

    @classmethod
    def from_spec(cls, spec: dict) -> "Topology":
        """Reconstruct a Topology from a :meth:`describe` dict — the inverse
        used by journal replay, so a rehydrated transfer ledger prices
        energy with the same zone tiers and link costs as the original
        process."""
        topo = cls(spec.get("name", "topology"), default_zone=spec.get("default_zone"))
        compute = spec.get("compute") or {}
        for zname, tier in (spec.get("zones") or {}).items():
            # pre-"compute" journals carry no coefficients; the tier default
            # applies, matching what the live process priced with
            topo.zone(zname, tier=tier, compute_j_per_mb=compute.get(zname))
        for pair, costs in (spec.get("links") or {}).items():
            src, _, dst = pair.partition("->")
            topo.link(
                src,
                dst,
                bandwidth_mbps=costs.get("bandwidth_mbps"),
                latency_ms=costs.get("latency_ms"),
                energy_j_per_mb=costs.get("energy_j_per_mb"),
                symmetric=False,  # describe() lists both directions
            )
        return topo

    # -- canned shapes ------------------------------------------------------
    @classmethod
    def three_zone(cls, name: str = "three-zone") -> "Topology":
        """The canonical extended-cloud chain: cloud ↔ edge ↔ device.
        ``cloud`` is the default zone (unplaced tasks run there)."""
        topo = cls(name)
        topo.zone("cloud", tier="cloud")
        topo.zone("edge", tier="edge")
        topo.zone("device", tier="device")
        topo.link("cloud", "edge")
        topo.link("edge", "device")
        topo.link("cloud", "device")
        return topo

    def __repr__(self) -> str:
        return f"Topology({self.name!r}, zones={self.zone_names()})"


def default_topology() -> Optional[Topology]:
    """Topology selected by the ``KOALJA_TOPOLOGY`` env var: ``flat`` (or
    unset) means no topology — the seed's single-site semantics — while
    ``3zone`` gives every Workspace the canonical cloud/edge/device chain.
    Lets CI matrix the whole suite over topologies without code changes."""
    name = os.environ.get("KOALJA_TOPOLOGY", "flat").strip().lower()
    if name in ("", "flat", "none"):
        return None
    if name in ("3zone", "three_zone", "three-zone"):
        return Topology.three_zone()
    raise ValueError(
        f"KOALJA_TOPOLOGY={name!r} is not a known topology (flat | 3zone)"
    )
