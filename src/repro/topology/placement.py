"""Placement policies — move code to data, not data to code.

Both DataX (arXiv 2111.04959) and Bauplan's zero-copy FaaS (2410.17465)
identify *where the function runs relative to its input bytes* as the
decisive cost lever for streaming pipelines; Koalja's edge story says the
same ("minimizing energy expenditure … especially with regard to edge
computing"). A :class:`PlacementPolicy` decides, at **wave-formation
time**, which zone each about-to-fire task executes in:

  - :class:`PinPlacement` (``"pin"``) — a task runs where it was pinned
    (``TaskHandle.place(zone)``), or in the topology's default zone. This is
    the naive all-to-default baseline: every unpinned consumer drags its
    input bytes to the default (cloud) zone.
  - :class:`DataGravityPlacement` (``"data_gravity"``) — an *unpinned* task
    is co-located with the zone holding the largest share of its pending
    input bytes, recomputed from AV sizes each wave. Pinned tasks stay
    pinned (pins are constraints, gravity is an optimization). With the
    snapshot already ingested into the policy buffers, the shares are exact
    for the bytes about to be consumed; ``swap_new_for_old`` reuse of stale
    values is not counted (only data that just arrived exerts gravity).
  - :class:`EnergyAwarePlacement` (``"energy"``) — an unpinned task runs in
    the zone minimizing *total joules*: the transfer energy of pulling its
    pending input bytes from their resident zones **plus** the compute
    energy of processing them there (the zone's ``compute_j_per_mb``
    coefficient). Gravity minimizes bytes moved; energy placement also
    weighs how expensive each zone's silicon is per byte, so it ships data
    off a power-hungry device to a nearby efficient edge site whenever the
    radio joules cost less than the compute joules saved — the paper's §IV
    sustainability objective as a placement rule.

Placement runs on the scheduler thread before ``run_wave`` hands the wave
to the executor, so zone assignment is deterministic: same pipeline, same
pushes → same placements, ledgers, and provenance under every backend.
"""

from __future__ import annotations

from typing import TYPE_CHECKING, Optional, Union

from .topology import Topology, TopologyError

if TYPE_CHECKING:  # pragma: no cover
    from repro.core.pipeline import PipelineManager
    from repro.core.task import SmartTask


class PlacementPolicy:
    """Assigns a zone to each task of a wave (subclass hook: ``zone_for``)."""

    name = "abstract"

    def __init__(self, topology: Topology) -> None:
        self.topology = topology
        self.placements = 0
        self.moves = 0  # assignments that changed a task's zone
        self.by_zone: dict = {}

    def place_wave(self, manager: "PipelineManager", tasks: list) -> None:
        for t in tasks:
            zone = self.zone_for(t, manager)
            self.placements += 1
            self.by_zone[zone] = self.by_zone.get(zone, 0) + 1
            if t.zone != zone:
                if t.zone is not None:
                    self.moves += 1
                t.zone = zone

    def zone_for(self, task: "SmartTask", manager: "PipelineManager") -> str:
        raise NotImplementedError

    def stats(self) -> dict:
        return {
            "policy": self.name,
            "placements": self.placements,
            "moves": self.moves,
            "by_zone": dict(sorted(self.by_zone.items())),
        }

    def __repr__(self) -> str:
        return f"{type(self).__name__}({self.topology.name!r})"


class PinPlacement(PlacementPolicy):
    """Pinned zone, else the topology default — the all-to-default baseline."""

    name = "pin"

    def zone_for(self, task: "SmartTask", manager: "PipelineManager") -> str:
        return task.pinned_zone or self.topology.default_zone


class DataGravityPlacement(PinPlacement):
    """Co-locate an unpinned task with the largest share of its input bytes."""

    name = "data_gravity"

    def zone_for(self, task: "SmartTask", manager: "PipelineManager") -> str:
        if task.pinned_zone is not None:
            return task.pinned_zone
        shares = self._byte_shares(task)
        if not shares:
            return task.zone or self.topology.default_zone
        order = {z: i for i, z in enumerate(self.topology.zone_names())}
        # most bytes wins; ties break to the earliest-declared zone, so the
        # assignment is a pure function of (topology, pending AVs)
        return max(shares, key=lambda z: (shares[z], -order.get(z, len(order))))

    @staticmethod
    def _byte_shares(task: "SmartTask") -> dict:
        shares: dict = {}
        seen: set = set()
        for buf in task.policy.buffers.values():
            # a sliding-window consumer (``input[N/k]``) holds a fresh value
            # in both buf.fresh and buf.window until a snapshot consumes it
            # — dedupe by AV uid so each pending value exerts gravity once
            for av in list(buf.fresh) + list(buf.window):
                uid = getattr(av, "uid", None)
                if uid is not None:
                    if uid in seen:
                        continue
                    seen.add(uid)
                meta = getattr(av, "meta", None)
                if not isinstance(meta, dict):
                    continue
                zone, nbytes = meta.get("zone"), meta.get("nbytes")
                if zone is None or not nbytes:
                    continue
                shares[zone] = shares.get(zone, 0) + int(nbytes)
        return shares


class EnergyAwarePlacement(DataGravityPlacement):
    """Place an unpinned task in the zone minimizing transfer + compute
    joules for its pending input bytes.

    The assignment is a *pure function* of (topology, pending AV byte
    shares, per-zone compute coefficients): candidate cost is

        cost(z) = Σ_src transfer_energy_j(src → z, bytes_src)
                + compute_energy_j(z, Σ bytes)

    evaluated over the topology's zones in declaration order with ties
    breaking to the earliest-declared zone — so placements, ledgers, and
    provenance fingerprints stay identical across every executor backend.
    """

    name = "energy"

    def zone_for(self, task: "SmartTask", manager: "PipelineManager") -> str:
        if task.pinned_zone is not None:
            return task.pinned_zone
        shares = self._byte_shares(task)
        if not shares:
            return task.zone or self.topology.default_zone
        total = sum(shares.values())
        topo = self.topology
        best_zone, best_cost = None, None
        for z in topo.zone_names():
            cost = topo.compute_energy_j(z, total)
            for src in sorted(shares):
                if src == z:
                    continue
                cost += topo.transfer_energy_j(src, z, shares[src])
            # strict < keeps the earliest-declared zone on exact ties
            if best_cost is None or cost < best_cost:
                best_zone, best_cost = z, cost
        return best_zone or self.topology.default_zone


_POLICIES = {
    PinPlacement.name: PinPlacement,
    DataGravityPlacement.name: DataGravityPlacement,
    EnergyAwarePlacement.name: EnergyAwarePlacement,
}


def make_placement(
    spec: Union[str, PlacementPolicy, None], topology: Topology
) -> PlacementPolicy:
    """Resolve ``"pin"`` / ``"data_gravity"`` / ``"energy"`` / a policy
    instance / None (→ data_gravity, the smart default) into a bound
    policy."""
    if isinstance(spec, PlacementPolicy):
        if spec.topology is not topology:
            # A policy bound elsewhere would place tasks into zones this
            # topology never declared — the failure would only surface as a
            # TopologyError deep inside a later stats()/cost() read.
            raise TopologyError(
                f"placement policy {spec!r} is bound to topology "
                f"{spec.topology.name!r}, not {topology.name!r} — construct "
                f"it against the workspace's topology"
            )
        return spec
    name = (spec or DataGravityPlacement.name).strip().lower()
    cls = _POLICIES.get(name)
    if cls is None:
        raise TopologyError(
            f"unknown placement policy {spec!r} (choose from {sorted(_POLICIES)})"
        )
    return cls(topology)
