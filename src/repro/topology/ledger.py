"""Transfer ledger — the extended-cloud sustainability scorecard (§III.F).

The circuit's transport rule under a topology is *data gravity in reverse*:
AV references cross zone edges freely (hash-only ghost transfer — a few
hundred bytes of metadata), and payload bytes move only when a consumer in
another zone actually **materializes** them. This ledger is where that rule
becomes auditable:

  - ``register_resident(chash, zone)`` — a payload was *born* in a zone
    (task output, edge injection): content is resident there at zero cost.
  - ``on_materialize(chash, nbytes, src, dst)`` — a consumer in ``dst``
    needed the bytes. Same zone, or already resident in ``dst``: nothing
    moves (counted as a local handover / a cross-zone dedup credit). First
    materialization in a new zone: the bytes cross, the (src, dst) pair is
    charged, and the content becomes resident in ``dst`` too.

Energy is *derived*, never accumulated: ``transfer_energy_j`` prices the
per-pair byte totals with the topology's link costs at read time, so the
number is identical no matter which executor ran the waves or in what order
threads finished — the ledger is part of the determinism contract.
"""

from __future__ import annotations

import threading
from typing import TYPE_CHECKING, Optional

if TYPE_CHECKING:  # pragma: no cover
    from .topology import Topology


class TransferLedger:
    """Byte/energy accounting for payload movement across a Topology."""

    def __init__(self, topology: "Topology") -> None:
        self.topology = topology
        self._lock = threading.Lock()
        self._resident: set = set()  # (chash, zone): content materialized there
        self._pair_bytes: dict = {}  # (src_zone, dst_zone) -> bytes moved
        self.bytes_moved_crosszone = 0
        self.bytes_not_moved_crosszone = 0  # dedup: already resident in dst
        self.crosszone_transfers = 0
        self.local_handovers = 0  # same-zone materializations (free)
        # optional durable write-through (repro.provenance.Journal)
        self._journal = None

    def bind_journal(self, journal) -> None:
        """Attach a provenance journal: every residency registration and
        materialization charge appends a typed ``ledger`` record (emitted
        under the ledger lock, so journal order *is* charge order), letting
        a replay rebuild byte/energy totals bit-identically."""
        with self._lock:
            self._journal = journal

    def register_resident(self, chash: str, zone: Optional[str]) -> None:
        if zone is None:
            return
        with self._lock:
            self._resident.add((chash, zone))
            if self._journal is not None:
                self._journal.append(
                    "ledger", {"op": "resident", "chash": chash, "zone": zone}
                )

    def on_materialize(
        self,
        chash: str,
        nbytes: int,
        src_zone: Optional[str],
        dst_zone: Optional[str],
    ) -> bool:
        """Record one consumer materializing a payload. Returns True iff
        bytes actually crossed a zone boundary (first arrival in dst)."""
        if src_zone is None or dst_zone is None:
            return False
        with self._lock:
            if self._journal is not None:
                self._journal.append(
                    "ledger",
                    {
                        "op": "materialize",
                        "chash": chash,
                        "nbytes": int(nbytes),
                        "src": src_zone,
                        "dst": dst_zone,
                    },
                )
            if src_zone == dst_zone:
                self.local_handovers += 1
                self._resident.add((chash, dst_zone))
                return False
            if (chash, dst_zone) in self._resident:
                self.bytes_not_moved_crosszone += nbytes
                return False
            self._resident.add((chash, dst_zone))
            pair = (src_zone, dst_zone)
            self._pair_bytes[pair] = self._pair_bytes.get(pair, 0) + nbytes
            self.bytes_moved_crosszone += nbytes
            self.crosszone_transfers += 1
            return True

    # -- checkpoint snapshot (journal compaction support) --------------------
    def snapshot_state(self) -> dict:
        """Serialize the ledger as the ``ledger`` payload of a journal
        checkpoint: residency set, per-pair byte totals, counters. This is
        the big fold win — thousands of per-materialization ``ledger``
        records collapse to one bounded blob, and energy stays *derived*
        (priced from the restored pair totals at read time)."""
        with self._lock:
            return {
                "resident": sorted(list(p) for p in self._resident),
                "pair_bytes": [
                    [s, d, n] for (s, d), n in sorted(self._pair_bytes.items())
                ],
                "bytes_moved_crosszone": self.bytes_moved_crosszone,
                "bytes_not_moved_crosszone": self.bytes_not_moved_crosszone,
                "crosszone_transfers": self.crosszone_transfers,
                "local_handovers": self.local_handovers,
            }

    def restore_state(self, state: dict) -> None:
        """Rehydrate from a checkpoint snapshot (inverse of
        :meth:`snapshot_state`); tail ``ledger`` records replayed afterwards
        charge on top of the restored totals."""
        with self._lock:
            self._resident = {tuple(p) for p in state.get("resident", [])}
            self._pair_bytes = {
                (s, d): int(n) for s, d, n in state.get("pair_bytes", [])
            }
            self.bytes_moved_crosszone = int(state.get("bytes_moved_crosszone", 0))
            self.bytes_not_moved_crosszone = int(
                state.get("bytes_not_moved_crosszone", 0)
            )
            self.crosszone_transfers = int(state.get("crosszone_transfers", 0))
            self.local_handovers = int(state.get("local_handovers", 0))

    @property
    def transfer_energy_j(self) -> float:
        """Energy priced from per-pair byte totals — order-independent, so
        ledgers agree bit-for-bit across Inline/Concurrent/Zoned backends."""
        with self._lock:
            pairs = dict(self._pair_bytes)
        return sum(
            self.topology.transfer_energy_j(s, d, n) for (s, d), n in sorted(pairs.items())
        )

    def by_pair(self) -> dict:
        with self._lock:
            return {f"{s}->{d}": n for (s, d), n in sorted(self._pair_bytes.items())}

    def stats(self) -> dict:
        with self._lock:
            pairs = {f"{s}->{d}": n for (s, d), n in sorted(self._pair_bytes.items())}
            out = {
                "bytes_moved_crosszone": self.bytes_moved_crosszone,
                "bytes_not_moved_crosszone": self.bytes_not_moved_crosszone,
                "crosszone_transfers": self.crosszone_transfers,
                "local_handovers": self.local_handovers,
                "by_pair": pairs,
            }
        out["transfer_energy_j"] = self.transfer_energy_j
        return out

    def __repr__(self) -> str:
        return (
            f"TransferLedger(crosszone={self.bytes_moved_crosszone}B, "
            f"energy={self.transfer_energy_j:.4f}J)"
        )
