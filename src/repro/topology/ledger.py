"""Transfer ledger — the extended-cloud sustainability scorecard (§III.F).

The circuit's transport rule under a topology is *data gravity in reverse*:
AV references cross zone edges freely (hash-only ghost transfer — a few
hundred bytes of metadata), and payload bytes move only when a consumer in
another zone actually **materializes** them. This ledger is where that rule
becomes auditable:

  - ``register_resident(chash, zone)`` — a payload was *born* in a zone
    (task output, edge injection): content is resident there at zero cost.
  - ``on_materialize(chash, nbytes, src, dst)`` — a consumer in ``dst``
    needed the bytes. Same zone, or already resident in ``dst``: nothing
    moves (counted as a local handover / a cross-zone dedup credit). First
    materialization in a new zone: the bytes cross, the (src, dst) pair is
    charged, and the content becomes resident in ``dst`` too.

Energy is *derived*, never accumulated: ``transfer_energy_j`` prices the
per-pair byte totals with the topology's link costs at read time, so the
number is identical no matter which executor ran the waves or in what order
threads finished — the ledger is part of the determinism contract.

Two further accounts ride the same contract (paper §IV sustainability):

  - ``on_execute(zone, nbytes)`` — a task processed ``nbytes`` of input in
    ``zone``. Per-zone processed-byte totals are accumulated and priced at
    read time with the zone's ``compute_j_per_mb`` coefficient
    (``compute_energy_j``); ``total_energy_j`` is transfer + compute.
  - ``credit_zone_local(chash, nbytes, zone)`` — a memo hit was served from
    a replica already resident in the consumer's zone, so a cross-zone
    materialization that the birth zone would otherwise have billed never
    happened. The avoided bytes are credited.
"""

from __future__ import annotations

import threading
from typing import TYPE_CHECKING, Optional

if TYPE_CHECKING:  # pragma: no cover
    from .topology import Topology


class TransferLedger:
    """Byte/energy accounting for payload movement across a Topology."""

    def __init__(self, topology: "Topology") -> None:
        self.topology = topology
        self._lock = threading.Lock()
        self._resident: set = set()  # (chash, zone): content materialized there
        self._pair_bytes: dict = {}  # (src_zone, dst_zone) -> bytes moved
        self._zone_compute_bytes: dict = {}  # zone -> input bytes processed
        self.bytes_moved_crosszone = 0
        self.bytes_not_moved_crosszone = 0  # dedup: already resident in dst
        self.crosszone_transfers = 0
        self.local_handovers = 0  # same-zone materializations (free)
        self.executions_charged = 0  # on_execute calls (compute account)
        self.zone_local_hits = 0  # memo hits served from a same-zone replica
        self.bytes_served_zone_local = 0  # transfer bytes those hits avoided
        # optional durable write-through (repro.provenance.Journal)
        self._journal = None

    def bind_journal(self, journal) -> None:
        """Attach a provenance journal: every residency registration and
        materialization charge appends a typed ``ledger`` record (emitted
        under the ledger lock, so journal order *is* charge order), letting
        a replay rebuild byte/energy totals bit-identically."""
        with self._lock:
            self._journal = journal

    def register_resident(self, chash: str, zone: Optional[str]) -> None:
        if zone is None:
            return
        with self._lock:
            self._resident.add((chash, zone))
            if self._journal is not None:
                self._journal.append(
                    "ledger", {"op": "resident", "chash": chash, "zone": zone}
                )

    def on_materialize(
        self,
        chash: str,
        nbytes: int,
        src_zone: Optional[str],
        dst_zone: Optional[str],
    ) -> bool:
        """Record one consumer materializing a payload. Returns True iff
        bytes actually crossed a zone boundary (first arrival in dst)."""
        if src_zone is None or dst_zone is None:
            return False
        with self._lock:
            if self._journal is not None:
                self._journal.append(
                    "ledger",
                    {
                        "op": "materialize",
                        "chash": chash,
                        "nbytes": int(nbytes),
                        "src": src_zone,
                        "dst": dst_zone,
                    },
                )
            if src_zone == dst_zone:
                self.local_handovers += 1
                self._resident.add((chash, dst_zone))
                return False
            if (chash, dst_zone) in self._resident:
                self.bytes_not_moved_crosszone += nbytes
                return False
            self._resident.add((chash, dst_zone))
            pair = (src_zone, dst_zone)
            self._pair_bytes[pair] = self._pair_bytes.get(pair, 0) + nbytes
            self.bytes_moved_crosszone += nbytes
            self.crosszone_transfers += 1
            return True

    def is_resident(self, chash: str, zone: Optional[str]) -> bool:
        """Is this content already materialized in ``zone``?"""
        if zone is None:
            return False
        with self._lock:
            return (chash, zone) in self._resident

    def on_execute(self, zone: Optional[str], nbytes: int) -> None:
        """Charge the compute account: a task processed ``nbytes`` of input
        in ``zone``. Totals are per-zone sums, so the account is independent
        of thread finish order (same contract as the transfer account)."""
        if zone is None:
            return
        with self._lock:
            if self._journal is not None:
                self._journal.append(
                    "ledger",
                    {"op": "execute", "zone": zone, "nbytes": int(nbytes)},
                )
            self._zone_compute_bytes[zone] = (
                self._zone_compute_bytes.get(zone, 0) + int(nbytes)
            )
            self.executions_charged += 1

    def credit_zone_local(
        self, chash: str, nbytes: int, zone: Optional[str]
    ) -> None:
        """Credit a memo hit served from a replica already resident in the
        consumer's zone: the bytes that a birth-zone billing would have
        moved cross-zone never crossed."""
        if zone is None:
            return
        with self._lock:
            if self._journal is not None:
                self._journal.append(
                    "ledger",
                    {
                        "op": "zone_local",
                        "chash": chash,
                        "nbytes": int(nbytes),
                        "zone": zone,
                    },
                )
            self.zone_local_hits += 1
            self.bytes_served_zone_local += int(nbytes)

    # -- checkpoint snapshot (journal compaction support) --------------------
    def snapshot_state(self) -> dict:
        """Serialize the ledger as the ``ledger`` payload of a journal
        checkpoint: residency set, per-pair byte totals, counters. This is
        the big fold win — thousands of per-materialization ``ledger``
        records collapse to one bounded blob, and energy stays *derived*
        (priced from the restored pair totals at read time)."""
        with self._lock:
            return {
                "resident": sorted(list(p) for p in self._resident),
                "pair_bytes": [
                    [s, d, n] for (s, d), n in sorted(self._pair_bytes.items())
                ],
                "zone_compute_bytes": [
                    [z, n] for z, n in sorted(self._zone_compute_bytes.items())
                ],
                "bytes_moved_crosszone": self.bytes_moved_crosszone,
                "bytes_not_moved_crosszone": self.bytes_not_moved_crosszone,
                "crosszone_transfers": self.crosszone_transfers,
                "local_handovers": self.local_handovers,
                "executions_charged": self.executions_charged,
                "zone_local_hits": self.zone_local_hits,
                "bytes_served_zone_local": self.bytes_served_zone_local,
            }

    def restore_state(self, state: dict) -> None:
        """Rehydrate from a checkpoint snapshot (inverse of
        :meth:`snapshot_state`); tail ``ledger`` records replayed afterwards
        charge on top of the restored totals."""
        with self._lock:
            self._resident = {tuple(p) for p in state.get("resident", [])}
            self._pair_bytes = {
                (s, d): int(n) for s, d, n in state.get("pair_bytes", [])
            }
            self._zone_compute_bytes = {
                z: int(n) for z, n in state.get("zone_compute_bytes", [])
            }
            self.bytes_moved_crosszone = int(state.get("bytes_moved_crosszone", 0))
            self.bytes_not_moved_crosszone = int(
                state.get("bytes_not_moved_crosszone", 0)
            )
            self.crosszone_transfers = int(state.get("crosszone_transfers", 0))
            self.local_handovers = int(state.get("local_handovers", 0))
            self.executions_charged = int(state.get("executions_charged", 0))
            self.zone_local_hits = int(state.get("zone_local_hits", 0))
            self.bytes_served_zone_local = int(
                state.get("bytes_served_zone_local", 0)
            )

    @property
    def transfer_energy_j(self) -> float:
        """Energy priced from per-pair byte totals — order-independent, so
        ledgers agree bit-for-bit across Inline/Concurrent/Zoned backends."""
        with self._lock:
            pairs = dict(self._pair_bytes)
        return sum(
            self.topology.transfer_energy_j(s, d, n) for (s, d), n in sorted(pairs.items())
        )

    @property
    def compute_energy_j(self) -> float:
        """Compute energy priced from per-zone processed-byte totals with
        the zones' ``compute_j_per_mb`` coefficients — derived at read time,
        order-independent like :attr:`transfer_energy_j`."""
        with self._lock:
            zones = dict(self._zone_compute_bytes)
        return sum(
            self.topology.compute_energy_j(z, n) for z, n in sorted(zones.items())
        )

    @property
    def total_energy_j(self) -> float:
        """Transfer + compute joules: the one number the §IV sustainability
        story (and :class:`EnergyAwarePlacement`) minimizes."""
        return self.transfer_energy_j + self.compute_energy_j

    def by_pair(self) -> dict:
        with self._lock:
            return {f"{s}->{d}": n for (s, d), n in sorted(self._pair_bytes.items())}

    def stats(self) -> dict:
        with self._lock:
            pairs = {f"{s}->{d}": n for (s, d), n in sorted(self._pair_bytes.items())}
            zones = dict(sorted(self._zone_compute_bytes.items()))
            out = {
                "bytes_moved_crosszone": self.bytes_moved_crosszone,
                "bytes_not_moved_crosszone": self.bytes_not_moved_crosszone,
                "crosszone_transfers": self.crosszone_transfers,
                "local_handovers": self.local_handovers,
                "executions_charged": self.executions_charged,
                "zone_local_hits": self.zone_local_hits,
                "bytes_served_zone_local": self.bytes_served_zone_local,
                "by_pair": pairs,
                "zone_compute_bytes": zones,
            }
        out["transfer_energy_j"] = self.transfer_energy_j
        out["compute_energy_j"] = self.compute_energy_j
        out["total_energy_j"] = out["transfer_energy_j"] + out["compute_energy_j"]
        return out

    def __repr__(self) -> str:
        return (
            f"TransferLedger(crosszone={self.bytes_moved_crosszone}B, "
            f"energy={self.transfer_energy_j:.4f}J)"
        )
