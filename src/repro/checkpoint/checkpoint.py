"""Provenance-stamped sharded checkpoints.

Every checkpoint is a Koalja artifact: the payload (one npz per host with
that host's addressable shards — Principle 2: storage near the dependent)
plus an AnnotatedValue travel document naming the exact step, code version,
config hash and mesh that produced it. Restart is 'make'-mode: pull the
latest checkpoint AV and resume — completed work cache-hits.

Async save: the host-side serialization runs on a worker thread so the train
loop only blocks for the device->host copy of its own shards.
"""

from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Optional

import jax
import numpy as np

from repro.core import AnnotatedValue, ArtifactStore, content_hash


def _flatten_with_paths(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        flat[key] = leaf
    return flat


def save_checkpoint(
    directory: str,
    state: Any,
    step: int,
    *,
    meta: Optional[dict] = None,
    software_version: str = "?",
    store: Optional[ArtifactStore] = None,
    host_id: int = 0,
) -> AnnotatedValue:
    """Write <dir>/step_<N>/host_<id>.npz + manifest; returns the AV."""
    os.makedirs(directory, exist_ok=True)
    step_dir = os.path.join(directory, f"step_{step:08d}")
    os.makedirs(step_dir, exist_ok=True)
    flat = _flatten_with_paths(state)
    arrays = {}
    for k, v in flat.items():
        # each host saves only its addressable shards; on single-host this is
        # the full array (np.asarray gathers the local view)
        arrays[k] = np.asarray(jax.device_get(v))
    path = os.path.join(step_dir, f"host_{host_id}.npz")
    np.savez(path, **arrays)

    manifest = {
        "step": step,
        "host": host_id,
        "keys": sorted(arrays.keys()),
        "software_version": software_version,
        "meta": meta or {},
        "written_at": time.time(),
        "payload_hash": content_hash({k: (v.shape, str(v.dtype)) for k, v in arrays.items()}),
    }
    with open(os.path.join(step_dir, f"manifest_{host_id}.json"), "w") as f:
        json.dump(manifest, f, indent=2)

    av = AnnotatedValue.produce(
        manifest["payload_hash"],
        f"file://{path}",
        source_task="checkpoint.save",
        software_version=software_version,
        meta={"step": step, "dir": step_dir},
    )
    if store is not None:
        store.put(manifest)
    return av


def restore_checkpoint(directory: str, like: Any, step: Optional[int] = None, host_id: int = 0):
    """Restore into the structure of `like` (shapes validated). Returns
    (state, manifest)."""
    steps = sorted(
        int(d.split("_")[1]) for d in os.listdir(directory) if d.startswith("step_")
    )
    if not steps:
        raise FileNotFoundError(f"no checkpoints under {directory}")
    step = steps[-1] if step is None else step
    step_dir = os.path.join(directory, f"step_{step:08d}")
    data = np.load(os.path.join(step_dir, f"host_{host_id}.npz"))
    with open(os.path.join(step_dir, f"manifest_{host_id}.json")) as f:
        manifest = json.load(f)

    flat_like = _flatten_with_paths(like)
    missing = set(flat_like) - set(data.files)
    if missing:
        raise KeyError(f"checkpoint missing keys: {sorted(missing)[:5]} ...")
    leaves_by_key = {k: data[k] for k in flat_like}

    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    out_leaves = []
    for path, leaf in paths:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        arr = leaves_by_key[key]
        if hasattr(leaf, "shape") and tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: {arr.shape} vs {leaf.shape}")
        out_leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out_leaves), manifest


class CheckpointManager:
    """Async save + retention + provenance wiring."""

    def __init__(
        self,
        directory: str,
        *,
        keep: int = 3,
        software_version: str = "?",
        store: Optional[ArtifactStore] = None,
    ) -> None:
        self.directory = directory
        self.keep = keep
        self.software_version = software_version
        self.store = store
        self._thread: Optional[threading.Thread] = None
        self.saved: list = []  # AVs

    def save_async(self, state: Any, step: int, meta: Optional[dict] = None):
        # device->host copy happens here (blocking, cheap relative to IO)
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)
        self.wait()

        def _write():
            av = save_checkpoint(
                self.directory,
                host_state,
                step,
                meta=meta,
                software_version=self.software_version,
                store=self.store,
            )
            self.saved.append(av)
            self._gc()

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def latest_step(self) -> Optional[int]:
        if not os.path.isdir(self.directory):
            return None
        steps = sorted(
            int(d.split("_")[1])
            for d in os.listdir(self.directory)
            if d.startswith("step_")
        )
        return steps[-1] if steps else None

    def restore(self, like: Any, step: Optional[int] = None):
        return restore_checkpoint(self.directory, like, step)

    def _gc(self):
        steps = sorted(
            int(d.split("_")[1])
            for d in os.listdir(self.directory)
            if d.startswith("step_")
        )
        for s in steps[: -self.keep]:
            import shutil

            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"), ignore_errors=True)
