"""Paper fig. 6: twin pipelines — a training workspace publishes model-state
artifacts; a serving workspace consults the latest published model through an
implicit client-server link. The two circuits run on unrelated timescales.

Here the "model" is a real (reduced) stablelm trained for a few steps with
the full JAX substrate; the serving workspace classifies token streams with
greedy decoding against whichever model version is newest. Both circuits are
declared on the typed Workspace breadboard and wired with ports.

  PYTHONPATH=src python examples/twin_pipelines.py
"""

import itertools

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import synthetic_batch
from repro.models.registry import build_model, greedy_generate, train_loss
from repro.optim import adamw_init, adamw_update, constant_lr
from repro.workspace import Workspace, service


def main():
    cfg = get_config("stablelm-1.6b").reduced()
    model = build_model(cfg)

    # ---------------- upper workspace: train --------------------------------
    params, _ = model.init(jax.random.key(0))
    state = {"params": params, "opt": adamw_init(params)}
    published = {}  # the model registry the serving side consults

    @jax.jit
    def step(params, opt, batch):
        (l, _), g = jax.value_and_grad(
            lambda p: train_loss(model, p, batch), has_aux=True
        )(params)
        p2, o2, _ = adamw_update(params, g, opt, constant_lr(1e-3)(opt["count"]))
        return l, p2, o2

    def train_task(batch):
        l, state["params"], state["opt"] = step(state["params"], state["opt"], batch)
        version = int(state["opt"]["count"])
        published["latest"] = (version, state["params"])
        return {"model_ref": {"version": version, "loss": float(l)}}

    tick = itertools.count()
    trainer = Workspace("train")
    sample = trainer.source(
        lambda: {"batch": synthetic_batch(cfg, 4, 32, step=next(tick))},
        name="sample",
        outputs=["batch"],
    )
    train = trainer.task(train_task, name="train", inputs=["batch"], outputs=["model_ref"])
    sample["batch"] >> train["batch"]

    # ---------------- lower workspace: serve --------------------------------
    def model_lookup():  # the implicit client-server edge of fig. 6
        return published["latest"]

    def recognize(request, model_service):
        version, p = model_service()
        toks = greedy_generate(model, p, jnp.asarray(request), n_steps=4, max_len=64)
        return {"label": {"model_version": version, "tokens": toks.tolist()}}

    server = Workspace("serve")
    rec = server.task(
        recognize,
        name="recognize",
        inputs=["request"],
        outputs=["label"],
        services={"model_service": service("model_lookup", model_lookup)},
    )
    server.implicit("model_lookup", rec)

    # ---------------- interleaved timescales --------------------------------
    rng = np.random.RandomState(1)
    for round_ in range(3):
        trainer.sample(sample)  # slow pipeline ticks
        trainer.sample(sample)
        req = rng.randint(0, cfg.vocab, size=(1, 8))
        label = server.push(rec, request=req)["recognize"]["label"]
        print(
            f"round {round_}: served with model v{label['model_version']} "
            f"-> {label['tokens'][0]}"
        )

    # forensic traceability: the served artifact's lineage names the frozen
    # service response (which model version answered) — paper §III.D
    svc = server.pipeline.tasks["recognize"].services["model_service"]
    print(f"\nfrozen service responses: {len(svc.frozen_responses)}")
    print("last:", {k: v for k, v in svc.frozen_responses[-1].items() if k != "timestamp"})
    print("\nserve visitor log:")
    for v in server.visitor_log(rec)[-3:]:
        print(" ", v["event"], v["av_uid"], v["note"])


if __name__ == "__main__":
    main()
