"""End-to-end example: train a ~100M-param dense LM for a few hundred steps
on CPU with the full stack — Workspace data circuit, MeshExecutor-built
train step, provenance, checkpoints, fault-tolerant resume.

~100M params: stablelm family at d_model=512, 8 layers, vocab 100352
(vocab embedding dominates: ~51M embed + ~51M head + 25M body ≈ 128M).

  PYTHONPATH=src python examples/train_lm.py --steps 300
(defaults to 30 steps so CI stays fast; pass --steps 300 for the real run)
"""

import argparse
import dataclasses

from repro.configs import get_config
from repro.launch import train as train_driver


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    args = ap.parse_args(argv)

    # register a ~100M config under the stablelm family
    import repro.configs as configs

    base = get_config("stablelm-1.6b")
    cfg100m = dataclasses.replace(
        base,
        name="stablelm-100m",
        n_layers=8,
        d_model=512,
        n_heads=8,
        n_kv_heads=8,
        d_head=64,
        d_ff=1408,
        dtype="float32",
        remat="none",
    )
    print(f"training {cfg100m.name}: {cfg100m.n_params()/1e6:.0f}M params")
    # monkey-register so the driver can find it
    orig_get = configs.get_config
    configs.get_config = lambda a: cfg100m if a == "stablelm-100m" else orig_get(a)
    train_driver.get_config = configs.get_config

    return train_driver.main(
        [
            "--arch", "stablelm-100m",
            "--steps", str(args.steps),
            "--batch", str(args.batch),
            "--seq", str(args.seq),
            "--ckpt-every", str(max(10, args.steps // 3)),
            "--ckpt-dir", "/tmp/repro_ckpt_100m",
        ]
    )


if __name__ == "__main__":
    main()
