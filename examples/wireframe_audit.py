"""Wireframing + region audit (paper §III.K / §IV).

Sends ghost batches (ShapeDtypeStructs) through a multi-region data circuit
to expose where data WOULD be routed before any real data moves — then runs
real data and audits region crossings from the travel documents, including a
fenced link that refuses to carry EU-origin artifacts ("US data cannot leave
the US" enforced and auditable).

  PYTHONPATH=src python examples/wireframe_audit.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    Pipeline,
    PipelineManager,
    RegionFenceError,
    SmartTask,
    ghost_run,
)


def build_circuit():
    pipe = Pipeline("multi_region")
    pipe.add_task(
        SmartTask("eu_sensor", lambda x: {"eu_raw": x}, ["x"], ["eu_raw"], region="eu")
    )
    pipe.add_task(
        SmartTask(
            "eu_summarize",
            lambda eu_raw: {"summary": jnp.mean(eu_raw, axis=0)},
            ["eu_raw"],
            ["summary"],
            region="eu",
        )
    )
    pipe.add_task(
        SmartTask(
            "hq_aggregate",
            lambda summary: {"report": jnp.sum(summary)},
            ["summary"],
            ["report"],
            region="us",
        )
    )
    pipe.connect("eu_sensor", "eu_raw", "eu_summarize", "eu_raw", region="eu")
    # only summaries cross the region boundary (transport avoidance, §IV)
    pipe.connect("eu_summarize", "summary", "hq_aggregate", "summary", region="us")
    return pipe


def main():
    # 1. wireframe: ghost batches expose routing, zero FLOPs moved
    mgr = PipelineManager(build_circuit())
    report = ghost_run(
        mgr, {("eu_sensor", "x"): jax.ShapeDtypeStruct((1024, 1024), jnp.float32)}
    )
    print("ghost routing ('trust, but verify' before real data):")
    for route, info in report["routes"].items():
        print(f"  {route}: carried {info['carried']} AV(s)")

    # 2. real run + region audit from travel documents
    mgr2 = PipelineManager(build_circuit())
    fired = mgr2.push("eu_sensor", x=np.random.RandomState(0).randn(1024, 1024))
    report_av = fired["hq_aggregate"][-1]["report"]
    lineage = mgr2.registry.lineage(report_av.uid)

    def walk(node, depth=0):
        av = mgr2.registry.get_av(node["uid"])
        crossings = av.crossed_regions()
        print(
            f"  {'  '*depth}{node['source_task']:<14s} {node['uid']}"
            + (f"  crossed: {crossings}" if crossings else "")
        )
        for p in node["parents"]:
            walk(p, depth + 1)

    print("\nregion audit of the HQ report's lineage:")
    walk(lineage)

    # 3. fencing: a link that refuses EU payloads
    pipe3 = build_circuit()
    pipe3.add_task(
        SmartTask("exfil", lambda eu_raw: {"out": eu_raw}, ["eu_raw"], ["out"], region="offshore")
    )
    pipe3.connect(
        "eu_sensor", "eu_raw", "exfil", "eu_raw",
        region="offshore", fenced_regions=("eu",),
    )
    mgr3 = PipelineManager(pipe3)
    try:
        mgr3.push("eu_sensor", x=np.ones((4, 4)))
    except RegionFenceError as e:
        print(f"\nfenced link refused the transfer:\n  {e}")


if __name__ == "__main__":
    main()
