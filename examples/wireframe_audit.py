"""Wireframing + region audit (paper §III.K / §IV) on the Workspace API.

Sends ghost batches (ShapeDtypeStructs) through a multi-region workspace to
expose where data WOULD be routed before any real data moves — then runs
real data and audits region crossings from the travel documents, including
a fenced wire that refuses to carry EU-origin artifacts ("US data cannot
leave the US" enforced and auditable). Link policy is set fluently on the
wires: ``(a["s"] >> b["t"]).region("us").fence("eu")``.

  PYTHONPATH=src python examples/wireframe_audit.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import RegionFenceError
from repro.workspace import Workspace


def build_workspace(with_exfil: bool = False) -> Workspace:
    ws = Workspace("multi_region")
    sensor = ws.task(
        lambda x: {"eu_raw": x}, name="eu_sensor", inputs=["x"], outputs=["eu_raw"],
        region="eu",
    )
    summarize = ws.task(
        lambda eu_raw: {"summary": jnp.mean(eu_raw, axis=0)},
        name="eu_summarize", inputs=["eu_raw"], outputs=["summary"], region="eu",
    )
    aggregate = ws.task(
        lambda summary: {"report": jnp.sum(summary)},
        name="hq_aggregate", inputs=["summary"], outputs=["report"], region="us",
    )
    (sensor["eu_raw"] >> summarize["eu_raw"]).region("eu")
    # only summaries cross the region boundary (transport avoidance, §IV)
    (summarize["summary"] >> aggregate["summary"]).region("us")
    if with_exfil:
        exfil = ws.task(
            lambda eu_raw: {"out": eu_raw}, name="exfil", inputs=["eu_raw"],
            outputs=["out"], region="offshore",
        )
        (sensor["eu_raw"] >> exfil["eu_raw"]).region("offshore").fence("eu")
    return ws


def main():
    # 1. wireframe: ghost batches expose routing, zero FLOPs moved
    ws = build_workspace()
    report = ws.ghost(
        {ws["eu_sensor"]["x"]: jax.ShapeDtypeStruct((1024, 1024), jnp.float32)}
    )
    print("ghost routing ('trust, but verify' before real data):")
    for route, info in report["routes"].items():
        print(f"  {route}: carried {info['carried']} AV(s)")

    # 2. real run + region audit from travel documents
    ws2 = build_workspace()
    fired = ws2.push("eu_sensor", x=np.random.RandomState(0).randn(1024, 1024))
    report_av = fired["hq_aggregate"].av("report")
    lineage = ws2.lineage(report_av)

    def walk(node, depth=0):
        av = ws2.registry.get_av(node["uid"])
        crossings = av.crossed_regions()
        print(
            f"  {'  '*depth}{node['source_task']:<14s} {node['uid']}"
            + (f"  crossed: {crossings}" if crossings else "")
        )
        for p in node["parents"]:
            walk(p, depth + 1)

    print("\nregion audit of the HQ report's lineage:")
    walk(lineage)

    # 3. fencing: a wire that refuses EU payloads
    ws3 = build_workspace(with_exfil=True)
    try:
        ws3.push("eu_sensor", x=np.ones((4, 4)))
    except RegionFenceError as e:
        print(f"\nfenced wire refused the transfer:\n  {e}")


if __name__ == "__main__":
    main()
