"""Quickstart: the Koalja Workspace in 60 lines.

Builds the paper's fig. 5 circuit from the wiring DSL (one constructor),
pushes data through it reactively, pulls a target make-style (watch the
cache hits), and prints all three provenance stories for the final artifact
— all from one typed entry point.

  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.workspace import Workspace

# --- plugin user code (what a Koalja user writes) ---------------------------


def learn_tf(**inputs):
    data = inputs["in"]
    return {"model": {"w": np.mean(data), "version": 1}}


def server(model):
    return {"lookup": {"scale": model["w"] * 2}}


def convert(**inputs):
    windows = inputs["in"]  # sliding window [10/2]: last 10 samples
    return {"json": {"series": [float(np.sum(w)) for w in windows]}}


def predict(json, lookup):
    return {"result": float(sum(json["series"])) * lookup["scale"]}


WIRING = """
[tfmodel]
(in) learn-tf (model)
(model) server (lookup)
(in[10/2]) convert (json)
(json, lookup) predict (result)
"""


def main():
    ws = Workspace.from_wiring(
        WIRING,
        {"learn-tf": learn_tf, "server": server, "convert": convert, "predict": predict},
        modes={"predict": "swap_new_for_old"},
    )

    # reactive mode: sensor samples arrive at the edge
    rng = np.random.RandomState(0)
    for step in range(14):
        sample = rng.randn(8)
        ws.push("learn-tf", **{"in": sample})
        ws.push("convert", **{"in": sample})

    # result-oriented: name the target, get the payload (make semantics)
    result = ws.pull("predict")
    print("result:", result["result"])

    # pulling again with nothing new -> cache hits, no recompute
    execs_before = {n: t.executions for n, t in ws.pipeline.tasks.items()}
    ws.pull("predict")
    assert {n: t.executions for n, t in ws.pipeline.tasks.items()} == execs_before
    print("pull with no new data: zero re-executions (make semantics)")

    # the three stories (paper §III.C), straight off the result handle
    result_av = result.av("result")
    print("\n--- story 1: traveller log of the result artifact ---")
    for stamp in ws.traveller_log(result_av):
        print(f"  {stamp['task']:>10s} {stamp['event']:<9s} sw={stamp['software_version']}")
    print("\n--- story 2: checkpoint visitor log (predict) ---")
    for v in ws.visitor_log("predict")[-4:]:
        print(f"  {v['event']:<9s} av={v['av_uid']} {v['note']}")
    print("\n--- story 3: design map ---")
    print(ws.design_map_text())
    print("\nmetadata overhead:", ws.registry.overhead_bytes(), "bytes")


if __name__ == "__main__":
    main()
