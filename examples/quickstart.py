"""Quickstart: the Koalja layer in 60 lines.

Builds the paper's fig. 5 circuit from the wiring language, pushes data
through it reactively, pulls a target make-style (watch the cache hits), and
prints all three provenance stories for the final artifact.

  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import PipelineManager, parse_wiring

# --- plugin user code (what a Koalja user writes) ---------------------------


def learn_tf(**inputs):
    data = inputs["in"]
    return {"model": {"w": np.mean(data), "version": 1}}


def server(model):
    return {"lookup": {"scale": model["w"] * 2}}


def convert(**inputs):
    windows = inputs["in"]  # sliding window [10/2]: last 10 samples
    return {"json": {"series": [float(np.sum(w)) for w in windows]}}


def predict(json, lookup):
    return {"result": float(sum(json["series"])) * lookup["scale"]}


WIRING = """
[tfmodel]
(in) learn-tf (model)
(model) server (lookup)
(in[10/2]) convert (json)
(json, lookup) predict (result)
"""


def main():
    pipe = parse_wiring(
        WIRING,
        {"learn-tf": learn_tf, "server": server, "convert": convert, "predict": predict},
        modes={"predict": "swap_new_for_old"},
    )
    mgr = PipelineManager(pipe)

    # reactive mode: sensor samples arrive at the edge
    rng = np.random.RandomState(0)
    for step in range(14):
        sample = rng.randn(8)
        mgr.push("learn-tf", **{"in": sample})
        mgr.push("convert", **{"in": sample})

    result_av = pipe.tasks["predict"].last_outputs["result"]
    print("result:", mgr.value_of(result_av))

    # make mode: pulling again with nothing new -> cache hits, no recompute
    execs_before = {n: t.executions for n, t in pipe.tasks.items()}
    mgr.pull("predict")
    assert {n: t.executions for n, t in pipe.tasks.items()} == execs_before
    print("pull with no new data: zero re-executions (make semantics)")

    # the three stories (paper §III.C)
    print("\n--- story 1: traveller log of the result artifact ---")
    for stamp in mgr.registry.traveller_log(result_av.uid):
        print(f"  {stamp['task']:>10s} {stamp['event']:<9s} sw={stamp['software_version']}")
    print("\n--- story 2: checkpoint visitor log (predict) ---")
    for v in mgr.registry.visitor_log("predict")[-4:]:
        print(f"  {v['event']:<9s} av={v['av_uid']} {v['note']}")
    print("\n--- story 3: design map ---")
    print(mgr.registry.design_map_text())
    print("\nmetadata overhead:", mgr.registry.overhead_bytes(), "bytes")


if __name__ == "__main__":
    main()
