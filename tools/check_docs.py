#!/usr/bin/env python
"""Docs gate: link-check the markdown suite and execute the registered
walkthroughs, so the documentation cannot rot.

Two checks, both also exercised by ``tests/test_docs.py``:

1. Every relative markdown link in ``README.md`` and ``docs/*.md`` must
   resolve to an existing file.
2. Every ```python``` block in each ``WALKTHROUGHS`` document is executed,
   in order, in one shared namespace per document — the walkthroughs'
   asserts are the contract between the docs and the engine.

Usage: ``python tools/check_docs.py`` (exit code 0 = docs are healthy).
"""

from __future__ import annotations

import pathlib
import re
import sys

REPO = pathlib.Path(__file__).resolve().parents[1]

# Runnable documents: every ```python``` block in these executes in CI.
WALKTHROUGHS = (
    "docs/provenance.md",
    "docs/scheduler.md",
    "docs/extended-cloud.md",
    "docs/journal.md",
    "docs/runtime.md",
    "docs/hotpath.md",
    "docs/tenancy.md",
    "docs/adaptive.md",
)

# [text](target) — markdown links, excluding images handled identically
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
PY_BLOCK_RE = re.compile(r"```python\n(.*?)```", re.S)


def doc_files() -> list:
    docs = sorted((REPO / "docs").glob("*.md")) if (REPO / "docs").is_dir() else []
    return [REPO / "README.md", *docs]


def check_links(files=None) -> list:
    """Return a list of 'file: broken link -> target' problems."""
    problems = []
    for md in files or doc_files():
        for target in LINK_RE.findall(md.read_text()):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            if not (md.parent / path).resolve().exists():
                problems.append(
                    f"{md.relative_to(REPO)}: broken link -> {target}"
                )
    return problems


def run_walkthrough(doc: str = "docs/provenance.md") -> int:
    """Execute every python block in the walkthrough; returns block count.

    Blocks share one namespace (the document reads top to bottom as one
    session). Raises on the first failing block, naming it.
    """
    src = (REPO / doc).read_text()
    blocks = PY_BLOCK_RE.findall(src)
    if not blocks:
        raise AssertionError(f"{doc}: no python blocks found to execute")
    sys.path.insert(0, str(REPO / "src"))
    try:
        ns: dict = {}
        for i, block in enumerate(blocks):
            code = compile(block, f"{doc}#block{i + 1}", "exec")
            exec(code, ns)  # noqa: S102 - executing our own docs is the point
    finally:
        sys.path.remove(str(REPO / "src"))
    return len(blocks)


def main() -> int:
    problems = check_links()
    for p in problems:
        print(f"FAIL {p}")
    total = 0
    for doc in WALKTHROUGHS:
        n = run_walkthrough(doc)
        print(f"  {doc}: {n} blocks executed")
        total += n
    print(
        f"docs OK: {len(doc_files())} files link-checked, "
        f"{total} walkthrough blocks executed across {len(WALKTHROUGHS)} docs"
    )
    return 1 if problems else 0


if __name__ == "__main__":
    raise SystemExit(main())
