#!/usr/bin/env python
"""Perf-smoke gate: rerun the hot-path benchmarks and fail on regression.

Runs the benches named in ``GATED`` / ``GATED_LOWER`` (policy/arrival
throughput, journal throughput, the PR 8 vectorized data plane, and the
adaptive-runtime diurnal bench) and compares every gated metric against
the committed trajectory file ``BENCH_koalja.json``. ``GATED`` metrics are
higher-is-better rates: a value more than ``TOLERANCE`` below the
committed one fails. ``GATED_LOWER`` metrics are lower-is-better costs
(latency seconds, joules): a value more than ``TOLERANCE`` *above* the
committed one fails. In both cases the gate only fails on regressions —
improvements land via ``python -m benchmarks.run`` refreshing the file.

Each gated bench runs in a fresh interpreter via ``benchmarks.run --one``
— the same hermetic methodology that produces the committed baseline, so
the comparison is apples to apples (in one shared process, heap and GC
state left by one bench skews the next one's timings).

Usage: ``python tools/check_bench.py`` (exit 0 = no regression). CI runs
this as the ``perf-smoke`` job.
"""

from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys
import tempfile

REPO = pathlib.Path(__file__).resolve().parents[1]
BASELINE = REPO / "BENCH_koalja.json"

# bench name -> gated dotted metrics (throughputs only: rates regress,
# wall-clock totals vary with machine load and bench size)
GATED = {
    "B5_policy_throughput": ["merge.arrivals_per_s"],
    "B11_journal_overhead": ["records_per_s"],
    "B14_hotpath_throughput": [
        "journal.records_per_s",
        "coalesce.arrivals_per_s",
    ],
    "B15_multitenant": ["records_per_s"],
}

# bench name -> gated lower-is-better metrics (costs: seconds, joules).
# B16's joules are deterministic ledger arithmetic; its p99 carries the
# modeled WAN time plus a little wall time, so the same tolerance holds.
GATED_LOWER = {
    "B16_diurnal_load": ["p99_push_s", "total_energy_j"],
}

TOLERANCE = 0.30  # fail when a metric lands >30% on the wrong side


def _dig(result: dict, dotted: str):
    cur = result
    for part in dotted.split("."):
        if not isinstance(cur, dict) or part not in cur:
            return None
        cur = cur[part]
    return cur


def _run_hermetic(bench: str) -> dict:
    """Run one bench in a fresh interpreter; returns its result dict."""
    fd, out_path = tempfile.mkstemp(suffix=".json", prefix="koalja-gate-")
    os.close(fd)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (str(REPO / "src"), env.get("PYTHONPATH")) if p
    )
    try:
        proc = subprocess.run(
            [
                sys.executable, "-m", "benchmarks.run",
                "--one", bench, "--out", out_path,
            ],
            cwd=str(REPO),
            env=env,
        )
        if proc.returncode != 0 or not os.path.getsize(out_path):
            raise RuntimeError(f"{bench}: hermetic run exited {proc.returncode}")
        with open(out_path) as f:
            entry = json.load(f)
    finally:
        os.unlink(out_path)
    if "error" in entry:
        raise RuntimeError(f"{bench}: {entry['error']}")
    return entry["result"]


RETRIES = 2  # re-runs granted to a bench whose metrics land below floor


def _limit(want: float, lower_is_better: bool) -> float:
    """The worst acceptable value for a committed baseline."""
    if lower_is_better:
        return want * (1.0 + TOLERANCE)
    return want * (1.0 - TOLERANCE)


def _ok(got: float, limit: float, lower_is_better: bool) -> bool:
    return got <= limit if lower_is_better else got >= limit


def _gate_bench(bench: str, metrics: list, committed: dict,
                lower_is_better: bool, failures: list) -> int:
    """Run one bench (with noise retries) and gate its metrics; returns
    the number of metrics actually checked."""
    # fsync latency and scheduler jitter make single runs noisy; a bench
    # only fails after RETRIES extra fresh-interpreter runs all leave
    # some metric on the wrong side (best observed value counts)
    pick = min if lower_is_better else max
    best: dict = {}
    for attempt in range(1 + RETRIES):
        fresh = _run_hermetic(bench)
        for dotted in metrics:
            got = _dig(fresh, dotted)
            if got is not None:
                best[dotted] = pick(best.get(dotted, got), got)
        if all(
            committed.get(d) is None
            or (
                best.get(d) is not None
                and _ok(best[d], _limit(float(committed[d]), lower_is_better),
                        lower_is_better)
            )
            for d in metrics
        ):
            break
    checked = 0
    unit = "" if lower_is_better else "/s"
    for dotted in metrics:
        want = committed.get(dotted)
        got = best.get(dotted)
        if want is None:
            print(f"SKIP {bench}.{dotted}: no committed baseline")
            continue
        if got is None:
            failures.append(f"{bench}.{dotted}: metric missing from run")
            continue
        checked += 1
        limit = _limit(float(want), lower_is_better)
        good = _ok(got, limit, lower_is_better)
        word = "ceiling" if lower_is_better else "floor"
        print(
            f"{'ok' if good else 'FAIL':4s} {bench}.{dotted}: {got:,.4g}{unit} "
            f"(committed {float(want):,.4g}{unit}, {word} {limit:,.4g}{unit})"
        )
        if not good:
            op = ">" if lower_is_better else "<"
            failures.append(
                f"{bench}.{dotted}: {got:,.4g} {op} {word} {limit:,.4g}"
            )
    return checked


def main() -> int:
    baseline = json.loads(BASELINE.read_text())
    failures: list = []
    checked = 0
    for bench, metrics in GATED.items():
        checked += _gate_bench(
            bench, metrics, baseline.get(bench, {}), False, failures
        )
    for bench, metrics in GATED_LOWER.items():
        checked += _gate_bench(
            bench, metrics, baseline.get(bench, {}), True, failures
        )
    if failures:
        print(f"\nperf-smoke FAILED ({len(failures)} regression(s)):")
        for f in failures:
            print(f"  {f}")
        return 1
    print(f"\nperf-smoke OK: {checked} metrics within {TOLERANCE:.0%} of baseline")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
