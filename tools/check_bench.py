#!/usr/bin/env python
"""Perf-smoke gate: rerun the hot-path benchmarks and fail on regression.

Runs the benches named in ``GATED`` (policy/arrival throughput, journal
throughput, and the PR 8 vectorized data plane) and compares every gated
throughput metric against the committed trajectory file
``BENCH_koalja.json``. A metric that lands more than ``TOLERANCE`` below
its committed value fails the gate; higher is never a failure (the
trajectory file is refreshed by ``python -m benchmarks.run``, not here).

Each gated bench runs in a fresh interpreter via ``benchmarks.run --one``
— the same hermetic methodology that produces the committed baseline, so
the comparison is apples to apples (in one shared process, heap and GC
state left by one bench skews the next one's timings).

Usage: ``python tools/check_bench.py`` (exit 0 = no regression). CI runs
this as the ``perf-smoke`` job.
"""

from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys
import tempfile

REPO = pathlib.Path(__file__).resolve().parents[1]
BASELINE = REPO / "BENCH_koalja.json"

# bench name -> gated dotted metrics (throughputs only: rates regress,
# wall-clock totals vary with machine load and bench size)
GATED = {
    "B5_policy_throughput": ["merge.arrivals_per_s"],
    "B11_journal_overhead": ["records_per_s"],
    "B14_hotpath_throughput": [
        "journal.records_per_s",
        "coalesce.arrivals_per_s",
    ],
    "B15_multitenant": ["records_per_s"],
}

TOLERANCE = 0.30  # fail when a metric drops >30% below the committed value


def _dig(result: dict, dotted: str):
    cur = result
    for part in dotted.split("."):
        if not isinstance(cur, dict) or part not in cur:
            return None
        cur = cur[part]
    return cur


def _run_hermetic(bench: str) -> dict:
    """Run one bench in a fresh interpreter; returns its result dict."""
    fd, out_path = tempfile.mkstemp(suffix=".json", prefix="koalja-gate-")
    os.close(fd)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (str(REPO / "src"), env.get("PYTHONPATH")) if p
    )
    try:
        proc = subprocess.run(
            [
                sys.executable, "-m", "benchmarks.run",
                "--one", bench, "--out", out_path,
            ],
            cwd=str(REPO),
            env=env,
        )
        if proc.returncode != 0 or not os.path.getsize(out_path):
            raise RuntimeError(f"{bench}: hermetic run exited {proc.returncode}")
        with open(out_path) as f:
            entry = json.load(f)
    finally:
        os.unlink(out_path)
    if "error" in entry:
        raise RuntimeError(f"{bench}: {entry['error']}")
    return entry["result"]


RETRIES = 2  # re-runs granted to a bench whose metrics land below floor


def main() -> int:
    baseline = json.loads(BASELINE.read_text())
    failures, checked = [], 0
    for bench, metrics in GATED.items():
        committed = baseline.get(bench, {})
        # fsync latency and scheduler jitter make single runs noisy; a
        # bench only fails after RETRIES extra fresh-interpreter runs all
        # leave some metric below its floor (best observed value counts)
        best: dict = {}
        for attempt in range(1 + RETRIES):
            fresh = _run_hermetic(bench)
            for dotted in metrics:
                got = _dig(fresh, dotted)
                if got is not None:
                    best[dotted] = max(best.get(dotted, got), got)
            if all(
                committed.get(d) is None
                or (
                    best.get(d) is not None
                    and best[d] >= float(committed[d]) * (1.0 - TOLERANCE)
                )
                for d in metrics
            ):
                break
        for dotted in metrics:
            want = committed.get(dotted)
            got = best.get(dotted)
            if want is None:
                print(f"SKIP {bench}.{dotted}: no committed baseline")
                continue
            if got is None:
                failures.append(f"{bench}.{dotted}: metric missing from run")
                continue
            checked += 1
            floor = float(want) * (1.0 - TOLERANCE)
            status = "FAIL" if got < floor else "ok"
            print(
                f"{status:4s} {bench}.{dotted}: {got:,.0f}/s "
                f"(committed {float(want):,.0f}/s, floor {floor:,.0f}/s)"
            )
            if got < floor:
                failures.append(
                    f"{bench}.{dotted}: {got:,.0f}/s < floor {floor:,.0f}/s"
                )
    if failures:
        print(f"\nperf-smoke FAILED ({len(failures)} regression(s)):")
        for f in failures:
            print(f"  {f}")
        return 1
    print(f"\nperf-smoke OK: {checked} metrics within {TOLERANCE:.0%} of baseline")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
